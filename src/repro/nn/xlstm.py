"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory w/ recurrence).

Faithful to Beck et al. 2024 at the block level:

  * mLSTM — pre-up-projection block; per head a matrix memory
    ``C in R^{dh x dh}`` with exponential input/forget gates and the
    max-stabilizer ``m``; q/k/v from a causal conv path; parallelizable over
    the sequence in chunks (we scan chunks carrying (C, n, m)).
  * sLSTM — post-up-projection block; scalar cell per channel with
    *recurrent* gate connections (block-diagonal R per head) — inherently
    sequential, scanned step by step.

Both expose O(1) ``decode_step`` states, which is what makes the xlstm-125m
``long_500k`` cell run at constant memory.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.dist import hints
from repro.nn.layers import _trunc_normal
from repro.nn.module import logical


@dataclasses.dataclass(frozen=True)
class MLSTMBlock:
    d_model: int
    n_heads: int
    cfg: XLSTMConfig
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    chunk: int = 64

    @property
    def d_inner(self):
        return int(self.cfg.proj_factor_mlstm * self.d_model)

    @property
    def d_head(self):
        return self.d_inner // self.n_heads

    def init(self, key):
        di, h = self.d_inner, self.d_model
        dh, H = self.d_head, self.n_heads
        ks = jax.random.split(key, 8)
        std = h ** -0.5
        stdi = di ** -0.5
        return {
            "up_proj": _trunc_normal(ks[0], (h, 2 * di), std, self.param_dtype),
            "conv_w": _trunc_normal(ks[1], (self.cfg.conv1d_kernel, di),
                                    self.cfg.conv1d_kernel ** -0.5, self.param_dtype),
            "conv_b": jnp.zeros((di,), self.param_dtype),
            "wq": _trunc_normal(ks[2], (di, di), stdi, self.param_dtype),
            "wk": _trunc_normal(ks[3], (di, di), stdi, self.param_dtype),
            "wv": _trunc_normal(ks[4], (di, di), stdi, self.param_dtype),
            "w_if": _trunc_normal(ks[5], (di, 2 * H), stdi, jnp.float32),
            "b_if": jnp.concatenate([jnp.zeros((H,)),
                                     jnp.linspace(3.0, 6.0, H)]).astype(jnp.float32),
            "ln_scale": jnp.ones((di,), self.param_dtype),
            "down_proj": _trunc_normal(ks[6], (di, h), stdi, self.param_dtype),
        }

    def specs(self):
        return {"up_proj": logical("embed", "mlp"), "conv_w": logical(None, "mlp"),
                "conv_b": logical("mlp"), "wq": logical("mlp", None),
                "wk": logical("mlp", None), "wv": logical("mlp", None),
                "w_if": logical("mlp", None), "b_if": logical(None),
                "ln_scale": logical("mlp"), "down_proj": logical("mlp", "embed")}

    def _qkv_gates(self, params, x_inner):
        """x_inner: (B, L, di) -> q,k,v (B,L,H,dh), i/f preacts (B,L,H) fp32."""
        cd = self.compute_dtype
        B, L, di = x_inner.shape
        H, dh = self.n_heads, self.d_head
        K = self.cfg.conv1d_kernel
        w = params["conv_w"].astype(cd)
        xp = jnp.pad(x_inner, ((0, 0), (K - 1, 0), (0, 0)))
        x_conv = sum(xp[:, i:i + L] * w[i] for i in range(K))
        x_conv = jax.nn.silu(x_conv + params["conv_b"].astype(cd))
        q = jnp.dot(x_conv, params["wq"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        k = jnp.dot(x_conv, params["wk"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd) * (dh ** -0.5)
        v = jnp.dot(x_inner, params["wv"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        gates = jnp.dot(x_conv.astype(jnp.float32), params["w_if"]) + params["b_if"]
        i_pre, f_pre = jnp.split(gates, 2, axis=-1)          # (B, L, H)
        rs = lambda t: t.reshape(B, L, H, dh)
        return rs(q), rs(k), rs(v), i_pre, f_pre

    def _scan(self, q, k, v, i_pre, f_pre, state):
        """Sequential scan (stabilized).  state: dict(C (B,H,dh,dh), n (B,H,dh), m (B,H))."""

        def step(s, inp):
            qt, kt, vt, it, ft = inp
            logf = -jax.nn.softplus(-ft)                     # log sigmoid(f)
            m_new = jnp.maximum(logf + s["m"], it)
            i_g = jnp.exp(it - m_new)
            f_g = jnp.exp(logf + s["m"] - m_new)
            C = f_g[..., None, None] * s["C"] + \
                i_g[..., None, None] * (vt[..., :, None] *
                                        kt[..., None, :]).astype(jnp.float32)
            n = f_g[..., None] * s["n"] + i_g[..., None] * kt.astype(jnp.float32)
            qf = qt.astype(jnp.float32)
            num = jnp.einsum("bhvk,bhk->bhv", C, qf)
            den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf))
            den = jnp.maximum(den, jnp.exp(-s["m"]) * 0 + 1.0)
            y = num / den[..., None]
            return {"C": C, "n": n, "m": m_new}, y

        inputs = tuple(t.transpose(1, 0, 2, 3) for t in (q, k, v)) + \
            tuple(t.transpose(1, 0, 2) for t in (i_pre, f_pre))
        state, ys = jax.lax.scan(step, state, inputs)
        return state, ys.transpose(1, 0, 2, 3)               # (B, L, H, dh)

    def init_state(self, batch):
        H, dh = self.n_heads, self.d_head
        return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, H, dh), jnp.float32),
                "m": jnp.zeros((batch, H), jnp.float32),
                "conv": jnp.zeros((batch, self.cfg.conv1d_kernel - 1, self.d_inner),
                                  self.compute_dtype)}

    def __call__(self, params, x, positions=None, state=None, return_state=False):
        cd = self.compute_dtype
        B, T, _ = x.shape
        di = self.d_inner
        up = jnp.dot(x.astype(cd), params["up_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
        # whole sequence before the recurrent chunk scan (one gather)
        up = hints.constrain(up, ("dp", None, "tp"))
        x_inner, z = jnp.split(up, 2, axis=-1)
        q, k, v, i_pre, f_pre = self._qkv_gates(params, x_inner)
        if state is None:
            state = {k_: v_ for k_, v_ in self.init_state(B).items() if k_ != "conv"}

        chunk = min(self.chunk, T)
        n = -(-T // chunk)
        pad = n * chunk - T
        if pad:
            q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                       for t in (q, k, v))
            i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)),
                            constant_values=-1e9)  # i=0: pad steps write nothing
            f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)),
                            constant_values=1e9)   # f=1: state preserved

        H, dh = self.n_heads, self.d_head

        def outer(st, inp):
            qc, kc, vc, ic, fc = inp
            return self._scan(qc, kc, vc, ic, fc, st)

        xs = (q.reshape(B, n, chunk, H, dh).transpose(1, 0, 2, 3, 4),
              k.reshape(B, n, chunk, H, dh).transpose(1, 0, 2, 3, 4),
              v.reshape(B, n, chunk, H, dh).transpose(1, 0, 2, 3, 4),
              i_pre.reshape(B, n, chunk, H).transpose(1, 0, 2, 3),
              f_pre.reshape(B, n, chunk, H).transpose(1, 0, 2, 3))
        state, ys = jax.lax.scan(
            jax.checkpoint(outer, policy=jax.checkpoint_policies.nothing_saveable),
            state, xs)
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, n * chunk, di)[:, :T]

        # per-head group norm (multi-head layer norm in the paper)
        yf = y.astype(jnp.float32).reshape(B, T, H, dh)
        mu = yf.mean(-1, keepdims=True)
        var = yf.var(-1, keepdims=True)
        yf = ((yf - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, T, di)
        y = (yf * params["ln_scale"].astype(jnp.float32)).astype(cd)
        y = y * jax.nn.silu(z)
        out = jnp.dot(y, params["down_proj"].astype(cd),
                      preferred_element_type=jnp.float32).astype(cd)
        if return_state:
            return out, state
        return out

    def prefill(self, params, x, state, positions=None):
        cd = self.compute_dtype
        B, T, _ = x.shape
        K = self.cfg.conv1d_kernel
        core = {k: v for k, v in state.items() if k != "conv"} if state else None
        y, new_core = self(params, x, positions, state=core, return_state=True)
        up = jnp.dot(x.astype(cd), params["up_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
        x_inner = up[..., :self.d_inner]
        tail = jnp.zeros((B, K - 1, self.d_inner), cd)
        take = min(K - 1, T)
        if take:
            tail = tail.at[:, K - 1 - take:].set(x_inner[:, T - take:])
        return y, {**new_core, "conv": tail}

    def decode_step(self, params, x, state, positions=None):
        cd = self.compute_dtype
        B = x.shape[0]
        di, H, dh = self.d_inner, self.n_heads, self.d_head
        up = jnp.dot(x[:, 0].astype(cd), params["up_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
        x_inner, z = jnp.split(up, 2, axis=-1)
        hist = jnp.concatenate([state["conv"], x_inner[:, None]], axis=1)
        w = params["conv_w"].astype(cd)
        x_conv = jax.nn.silu((hist * w).sum(1) + params["conv_b"].astype(cd))
        q = jnp.dot(x_conv, params["wq"].astype(cd)).reshape(B, H, dh)
        k = (jnp.dot(x_conv, params["wk"].astype(cd)) * (dh ** -0.5)).reshape(B, H, dh)
        v = jnp.dot(x_inner, params["wv"].astype(cd)).reshape(B, H, dh)
        gates = jnp.dot(x_conv.astype(jnp.float32), params["w_if"]) + params["b_if"]
        it, ft = jnp.split(gates, 2, axis=-1)                # (B, H)

        logf = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(logf + state["m"], it)
        i_g = jnp.exp(it - m_new)
        f_g = jnp.exp(logf + state["m"] - m_new)
        C = f_g[..., None, None] * state["C"] + \
            i_g[..., None, None] * (v[..., :, None] * k[..., None, :]).astype(jnp.float32)
        nvec = f_g[..., None] * state["n"] + i_g[..., None] * k.astype(jnp.float32)
        qf = q.astype(jnp.float32)
        num = jnp.einsum("bhvk,bhk->bhv", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", nvec, qf)), 1.0)
        y = (num / den[..., None]).reshape(B, di)
        mu = y.reshape(B, H, dh).mean(-1, keepdims=True)
        var = y.reshape(B, H, dh).var(-1, keepdims=True)
        y = ((y.reshape(B, H, dh) - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(B, di)
        y = (y * params["ln_scale"].astype(jnp.float32)).astype(cd) * jax.nn.silu(z)
        out = jnp.dot(y, params["down_proj"].astype(cd),
                      preferred_element_type=jnp.float32).astype(cd)
        new_state = {"C": C, "n": nvec, "m": m_new, "conv": hist[:, 1:]}
        return out[:, None], new_state


@dataclasses.dataclass(frozen=True)
class SLSTMBlock:
    d_model: int
    n_heads: int
    cfg: XLSTMConfig
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32

    @property
    def d_head(self):
        return self.d_model // self.n_heads

    def init(self, key):
        h, H, dh = self.d_model, self.n_heads, self.d_head
        ks = jax.random.split(key, 4)
        std = h ** -0.5
        d_up = int(self.cfg.proj_factor_slstm * h)
        d_up -= d_up % 2
        return {
            # input weights for 4 gates (z, i, f, o)
            "w_gates": _trunc_normal(ks[0], (h, 4 * h), std, self.param_dtype),
            # block-diagonal recurrent weights per head: (4, H, dh, dh)
            "r_gates": _trunc_normal(ks[1], (4, H, dh, dh), dh ** -0.5, jnp.float32),
            "b_gates": jnp.concatenate([
                jnp.zeros((2 * h,)), jnp.linspace(3.0, 6.0, h),
                jnp.zeros((h,))]).astype(jnp.float32),
            "ln_scale": jnp.ones((h,), self.param_dtype),
            "up_proj": _trunc_normal(ks[2], (h, d_up), std, self.param_dtype),
            "down_proj": _trunc_normal(ks[3], (d_up // 2, h),
                                       (d_up // 2) ** -0.5, self.param_dtype),
        }

    def specs(self):
        return {"w_gates": logical("embed", None), "r_gates": logical(None, "heads", None, None),
                "b_gates": logical(None), "ln_scale": logical(None),
                "up_proj": logical("embed", "mlp"), "down_proj": logical("mlp", "embed")}

    def init_state(self, batch):
        h, H, dh = self.d_model, self.n_heads, self.d_head
        return {"c": jnp.zeros((batch, h), jnp.float32),
                "n": jnp.ones((batch, h), jnp.float32),
                "h": jnp.zeros((batch, h), jnp.float32),
                "m": jnp.zeros((batch, h), jnp.float32)}

    def _cell(self, params, gates_x, state):
        """One sLSTM step.  gates_x: (B, 4h) input preactivations."""
        B = gates_x.shape[0]
        h, H, dh = self.d_model, self.n_heads, self.d_head
        hprev = state["h"].reshape(B, H, dh)
        rec = jnp.einsum("ghij,bhj->gbhi", params["r_gates"], hprev)
        rec = rec.transpose(1, 0, 2, 3).reshape(B, 4 * h)
        pre = gates_x.astype(jnp.float32) + rec + params["b_gates"]
        z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        logf = -jax.nn.softplus(-f_pre)
        m_new = jnp.maximum(logf + state["m"], i_pre)
        i_g = jnp.exp(i_pre - m_new)
        f_g = jnp.exp(logf + state["m"] - m_new)
        c = f_g * state["c"] + i_g * z
        n = f_g * state["n"] + i_g
        h_new = o * c / jnp.maximum(n, 1e-6)
        return {"c": c, "n": n, "h": h_new, "m": m_new}

    def __call__(self, params, x, positions=None, state=None, return_state=False):
        cd = self.compute_dtype
        B, T, h = x.shape
        if state is None:
            state = self.init_state(B)
        gates_x = jnp.dot(x.astype(cd), params["w_gates"].astype(cd),
                          preferred_element_type=jnp.float32)
        # per-token recurrence: T must be local (a seq-sharded gates_x would
        # put a collective inside the T-step loop; §Perf it.5)
        gates_x = hints.constrain(gates_x, ("dp", None, None))

        def step(s, gx):
            s = self._cell(params, gx, s)
            return s, s["h"]

        state, hs = jax.lax.scan(step, state, gates_x.transpose(1, 0, 2))
        y = hs.transpose(1, 0, 2)                            # (B, T, h) fp32
        mu = y.mean(-1, keepdims=True)
        var = y.var(-1, keepdims=True)
        y = (y - mu) * jax.lax.rsqrt(var + 1e-6) * params["ln_scale"].astype(jnp.float32)
        y = y.astype(cd)
        up = jnp.dot(y, params["up_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
        a, b = jnp.split(up, 2, axis=-1)
        y = jax.nn.gelu(a) * b
        out = jnp.dot(y, params["down_proj"].astype(cd),
                      preferred_element_type=jnp.float32).astype(cd)
        if return_state:
            return out, state
        return out

    def prefill(self, params, x, state, positions=None):
        return self(params, x, positions, state=state, return_state=True)

    def decode_step(self, params, x, state, positions=None):
        cd = self.compute_dtype
        gates_x = jnp.dot(x[:, 0].astype(cd), params["w_gates"].astype(cd),
                          preferred_element_type=jnp.float32)
        state = self._cell(params, gates_x, state)
        y = state["h"]
        mu = y.mean(-1, keepdims=True)
        var = y.var(-1, keepdims=True)
        y = ((y - mu) * jax.lax.rsqrt(var + 1e-6) *
             params["ln_scale"].astype(jnp.float32)).astype(cd)
        up = jnp.dot(y, params["up_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
        a, b = jnp.split(up, 2, axis=-1)
        out = jnp.dot(jax.nn.gelu(a) * b, params["down_proj"].astype(cd),
                      preferred_element_type=jnp.float32).astype(cd)
        return out[:, None], state
