"""Mamba-1 selective SSM block (for the Jamba hybrid).

TPU adaptation: the CUDA selective-scan kernel is replaced by a *chunked*
scan — ``lax.scan`` over chunks of the sequence, each chunk processed with an
inner (rematerialized) scan.  The carry between chunks is just the SSM state
(B, d_inner, d_state), so activation memory is O(T/chunk · state) + O(chunk)
instead of O(T · state).

Decode is the natural O(1) recurrent step on the same state, used by
``serve_step`` for the long_500k shape.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig
from repro.dist import hints
from repro.nn.layers import _trunc_normal
from repro.nn.module import logical


@dataclasses.dataclass(frozen=True)
class MambaBlock:
    d_model: int
    cfg: MambaConfig
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    chunk: int = 128

    @property
    def d_inner(self):
        return self.cfg.expand * self.d_model

    @property
    def dt_rank(self):
        return self.cfg.dt_rank or -(-self.d_model // 16)

    def init(self, key):
        c = self.cfg
        di, ds, dr = self.d_inner, c.d_state, self.dt_rank
        ks = jax.random.split(key, 7)
        std = self.d_model ** -0.5
        # S4D-real initialization for A.
        a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
        dt = jnp.exp(jax.random.uniform(ks[0], (di,), jnp.float32) *
                     (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
        inv_softplus = dt + jnp.log(-jnp.expm1(-dt))
        return {
            "in_proj": _trunc_normal(ks[1], (self.d_model, 2 * di), std, self.param_dtype),
            "conv_w": _trunc_normal(ks[2], (c.d_conv, di), c.d_conv ** -0.5, self.param_dtype),
            "conv_b": jnp.zeros((di,), self.param_dtype),
            "x_proj": _trunc_normal(ks[3], (di, dr + 2 * ds), di ** -0.5, self.param_dtype),
            "dt_proj_w": _trunc_normal(ks[4], (dr, di), dr ** -0.5, self.param_dtype),
            "dt_proj_b": inv_softplus.astype(jnp.float32),
            "a_log": jnp.log(a),
            "d_skip": jnp.ones((di,), jnp.float32),
            "out_proj": _trunc_normal(ks[5], (di, self.d_model), di ** -0.5, self.param_dtype),
        }

    def specs(self):
        return {
            "in_proj": logical("embed", "mlp"),
            "conv_w": logical(None, "mlp"),
            "conv_b": logical("mlp"),
            "x_proj": logical("mlp", None),
            "dt_proj_w": logical(None, "mlp"),
            "dt_proj_b": logical("mlp"),
            "a_log": logical("mlp", None),
            "d_skip": logical("mlp"),
            "out_proj": logical("mlp", "embed"),
        }

    def _ssm_inputs(self, params, xz):
        """xz: (B, L, 2*di) -> (x_conv, z, dt, Bc, Cc) all (B, L, ...)."""
        c = self.cfg
        cd = self.compute_dtype
        di, ds, dr = self.d_inner, c.d_state, self.dt_rank
        x, z = jnp.split(xz, 2, axis=-1)
        # causal depthwise conv along L
        w = params["conv_w"].astype(cd)                      # (K, di)
        K = c.d_conv
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        x_conv = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
        x_conv = jax.nn.silu(x_conv + params["conv_b"].astype(cd))
        proj = jnp.dot(x_conv, params["x_proj"].astype(cd),
                       preferred_element_type=jnp.float32)
        dt_in, Bc, Cc = jnp.split(proj, [dr, dr + ds], axis=-1)
        dt = jax.nn.softplus(
            jnp.dot(dt_in, params["dt_proj_w"].astype(jnp.float32)) +
            params["dt_proj_b"])                             # (B, L, di) fp32
        return x_conv, z, dt, Bc.astype(jnp.float32), Cc.astype(jnp.float32)

    def _scan_chunk(self, a_neg, x_conv, dt, Bc, Cc, state):
        """Sequential inner scan over one chunk.  state: (B, di, ds)."""

        def step(s, inp):
            xt, dtt, bt, ct = inp          # (B,di), (B,di), (B,ds), (B,ds)
            da = jnp.exp(dtt[..., None] * a_neg)             # (B, di, ds)
            db = dtt[..., None] * bt[:, None, :]             # (B, di, ds)
            s = da * s + db * xt[..., None].astype(jnp.float32)
            y = jnp.einsum("bds,bs->bd", s, ct)
            return s, y

        inputs = (x_conv.transpose(1, 0, 2), dt.transpose(1, 0, 2),
                  Bc.transpose(1, 0, 2), Cc.transpose(1, 0, 2))
        state, ys = jax.lax.scan(step, state, inputs)
        return state, ys.transpose(1, 0, 2)                  # (B, L, di)

    def __call__(self, params, x, positions=None, state=None, return_state=False):
        """x: (B, T, h).  T must be a multiple of ``chunk`` or < chunk."""
        cd = self.compute_dtype
        B, T, _ = x.shape
        di, ds = self.d_inner, self.cfg.d_state
        xz = jnp.dot(x.astype(cd), params["in_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
        # whole sequence, channels sharded: the chunk scan slices T locally
        xz = hints.constrain(xz, ("dp", None, "tp"))
        x_conv, z, dt, Bc, Cc = self._ssm_inputs(params, xz)
        a_neg = -jnp.exp(params["a_log"])                    # (di, ds)

        if state is None:
            state = jnp.zeros((B, di, ds), jnp.float32)

        chunk = min(self.chunk, T)
        n = -(-T // chunk)
        pad = n * chunk - T
        if pad:
            pz = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
            x_conv, dt, Bc, Cc = pz(x_conv), pz(dt), pz(Bc), pz(Cc)

        def outer(state, inp):
            xc, dtc, bc, cc = inp
            state, y = self._scan_chunk(a_neg, xc, dtc, bc, cc, state)
            return state, y

        xs = tuple(t.reshape(B, n, chunk, -1).transpose(1, 0, 2, 3)
                   for t in (x_conv, dt, Bc, Cc))
        state, ys = jax.lax.scan(
            jax.checkpoint(outer, policy=jax.checkpoint_policies.nothing_saveable),
            state, xs)
        y = ys.transpose(1, 0, 2, 3).reshape(B, n * chunk, di)[:, :T]

        y = y + x_conv.astype(jnp.float32)[:, :T] * params["d_skip"]
        y = y.astype(cd) * jax.nn.silu(z[:, :T])
        out = jnp.dot(y, params["out_proj"].astype(cd),
                      preferred_element_type=jnp.float32).astype(cd)
        if return_state:
            return out, state
        return out

    # ---------------------------------------------------------------- serving
    def prefill(self, params, x, state, positions=None):
        """Process a prompt; returns (y, full recurrent state incl conv tail)."""
        cd = self.compute_dtype
        B, T, _ = x.shape
        K = self.cfg.d_conv
        y, ssm = self(params, x, positions, state=state.get("ssm") if
                      isinstance(state, dict) else None, return_state=True)
        xz = jnp.dot(x.astype(cd), params["in_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
        x_in = xz[..., :self.d_inner]
        tail = jnp.zeros((B, K - 1, self.d_inner), cd)
        take = min(K - 1, T)
        if take:
            tail = tail.at[:, K - 1 - take:].set(x_in[:, T - take:])
        return y, {"ssm": ssm, "conv": tail}

    def init_state(self, batch):
        """Recurrent state: SSM state + conv tail."""
        di, ds = self.d_inner, self.cfg.d_state
        return {"ssm": jnp.zeros((batch, di, ds), jnp.float32),
                "conv": jnp.zeros((batch, self.cfg.d_conv - 1, di),
                                  self.compute_dtype)}

    def decode_step(self, params, x, state, positions=None):
        """x: (B, 1, h) -> (B, 1, h); O(1) state update."""
        c, cd = self.cfg, self.compute_dtype
        B = x.shape[0]
        di, ds, dr = self.d_inner, c.d_state, self.dt_rank
        xz = jnp.dot(x[:, 0].astype(cd), params["in_proj"].astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
        xt, z = jnp.split(xz, 2, axis=-1)
        hist = jnp.concatenate([state["conv"], xt[:, None]], axis=1)  # (B,K,di)
        w = params["conv_w"].astype(cd)
        x_conv = jax.nn.silu((hist * w).sum(1) + params["conv_b"].astype(cd))
        proj = jnp.dot(x_conv, params["x_proj"].astype(cd),
                       preferred_element_type=jnp.float32)
        dt_in, Bc, Cc = jnp.split(proj, [dr, dr + ds], axis=-1)
        dt = jax.nn.softplus(
            jnp.dot(dt_in, params["dt_proj_w"].astype(jnp.float32)) +
            params["dt_proj_b"])
        a_neg = -jnp.exp(params["a_log"])
        da = jnp.exp(dt[..., None] * a_neg)
        db = dt[..., None] * Bc[:, None, :].astype(jnp.float32)
        s = da * state["ssm"] + db * x_conv[..., None].astype(jnp.float32)
        y = jnp.einsum("bds,bs->bd", s, Cc.astype(jnp.float32))
        y = y + x_conv.astype(jnp.float32) * params["d_skip"]
        y = y.astype(cd) * jax.nn.silu(z)
        out = jnp.dot(y, params["out_proj"].astype(cd),
                      preferred_element_type=jnp.float32).astype(cd)
        new_state = {"ssm": s, "conv": hist[:, 1:]}
        return out[:, None], new_state
