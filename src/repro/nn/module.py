"""Minimal functional module system (no flax dependency).

A *module* is a plain Python object that knows how to:

  * ``init(key) -> params``    — build its parameter pytree (nested dicts of
    jnp arrays);
  * ``specs() -> spec tree``   — return a pytree with the *same structure*
    whose leaves are tuples of **logical axis names** (or ``None`` entries),
    one name per tensor dimension;
  * ``__call__(params, ...)``  — apply itself.

Logical axis names decouple model code from the mesh: a rules table maps each
logical axis to a mesh axis (or ``None`` for replicated).  ``resolve_specs``
turns a (params, specs, rules) triple into concrete
``jax.sharding.PartitionSpec`` / ``NamedSharding`` trees, dropping any mapping
whose dimension is not divisible by the mesh-axis size (replicate instead of
fail — this is what lets GQA KV heads ride on a 16-way model axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any  # nested dict pytree of arrays
Specs = Any   # matching pytree of LogicalSpec


@dataclasses.dataclass(frozen=True)
class LogicalSpec:
    """Per-parameter logical sharding annotation: one name (or None) per dim."""

    axes: tuple  # tuple[str | None, ...]

    def __iter__(self):
        return iter(self.axes)

    def __len__(self):
        return len(self.axes)


def logical(*axes) -> LogicalSpec:
    return LogicalSpec(tuple(axes))


def _is_leaf_spec(x) -> bool:
    return isinstance(x, LogicalSpec)


def resolve_spec(
    shape: Sequence[int],
    spec: LogicalSpec,
    rules: Mapping[str, Any],
    mesh: Mesh,
) -> P:
    """Resolve one logical spec against a concrete shape.

    Divisibility-safe: any axis whose size is not divisible by the product of
    the mapped mesh axes is replicated instead.
    """
    if spec is None:
        return P()
    out = []
    used: set = set()
    for dim, name in zip(shape, spec.axes):
        mesh_axes = rules.get(name) if name is not None else None
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        # Filter out mesh axes already used by an earlier dim of this tensor.
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        if not mesh_axes:
            out.append(None)
            continue
        total = 1
        for a in mesh_axes:
            total *= mesh.shape[a]
        if total == 0 or dim == 0 or dim % total != 0:
            out.append(None)
            continue
        used.update(mesh_axes)
        out.append(mesh_axes[0] if len(mesh_axes) == 1 else mesh_axes)
    # Trim trailing Nones for tidier HLO.
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def resolve_specs(params_shapes, specs, rules: Mapping[str, Any], mesh: Mesh):
    """Map a (shape-tree, logical-spec-tree) pair to a PartitionSpec tree.

    ``params_shapes`` may contain arrays, ShapeDtypeStructs, or anything with
    ``.shape``.
    """

    def one(p, s):
        return resolve_spec(p.shape, s, rules, mesh)

    return jax.tree.map(one, params_shapes, specs, is_leaf=lambda x: _is_leaf_spec(x) or x is None)


def named_shardings(params_shapes, specs, rules, mesh: Mesh):
    ptree = resolve_specs(params_shapes, specs, rules, mesh)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), ptree,
                        is_leaf=lambda x: isinstance(x, P))


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params))


def shape_tree(params: Params):
    """Replace arrays by ShapeDtypeStructs (for lowering without allocation)."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)


def init_shapes(module, key=None) -> Params:
    """Get the parameter shape tree of a module *without allocating memory*.

    Uses ``jax.eval_shape`` around ``module.init`` so even multi-billion
    parameter configs can be "initialized" abstractly for the dry-run.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: module.init(k), key)


def cast_tree(params: Params, dtype) -> Params:
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(c, params)
