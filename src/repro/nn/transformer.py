"""Transformer assembly: blocks, periodic-pattern scan, LM head, serving.

``TransformerLM`` is driven entirely by ``ModelConfig``:

  * each layer is a ``BlockSpec`` (mixer kind x ffn kind);
  * the layer pattern's smallest period is detected and the periodic prefix is
    compiled as ONE super-block scanned ``n_units`` times (stacked params) —
    an 80-layer uniform model compiles a single layer body, Jamba compiles an
    8-layer super-block, gemma3 a (5 local + 1 global) super-block;
  * the non-periodic tail is applied unrolled;
  * MoE aux losses are accumulated through the scan carry;
  * serving: ``init_cache`` / ``prefill`` / ``decode_step`` thread per-layer
    caches (stacked for the scanned prefix) of whatever type each mixer needs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.core.attention import MLAAttention, MultiHeadAttention
from repro.core.hybrid import HybridAttention
from repro.core.kv_cache import DenseKVCache, MLAKVCache, WindowKVCache
from repro.nn.ffn import MLP, MoEFFN
from repro.nn.layers import Embedding, LayerNorm, RMSNorm
from repro.nn.mamba import MambaBlock
from repro.nn.module import logical
from repro.nn.xlstm import MLSTMBlock, SLSTMBlock


def sample_logits(logits, key, temperature=0.0, top_k: int = 0):
    """Sample next tokens from (B, V) logits entirely on-device.

    ``top_k`` is STATIC (it sizes a ``lax.top_k``); ``temperature`` may be a
    Python float (greedy argmax is then selected at trace time) or a traced
    scalar — a serving loop can sweep temperatures without recompiling the
    fused decode program (``lax.cond`` picks greedy vs categorical
    on-device).  Returns (B,) int32.
    """
    logits = logits.astype(jnp.float32)
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if isinstance(temperature, (int, float)):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature).astype(jnp.int32)
    temp = jnp.asarray(temperature, jnp.float32)
    return jax.lax.cond(
        temp > 0.0,
        lambda: jax.random.categorical(key, logits / jnp.maximum(temp, 1e-6)),
        lambda: jnp.argmax(logits, axis=-1)).astype(jnp.int32)


def find_period(pattern, max_head: int = 4):
    """Locate the largest scannable periodic run, allowing a few unrolled
    *head* layers before it (e.g. deepseek's dense-FFN first layer — without
    the offset all 27 layers unroll: 600 s compiles and every layer's MoE
    dispatch buffers live simultaneously; §Perf cell-1 it.9).

    Returns (head_end, p, n_units, tail_start): layers [0, head_end) and
    [tail_start, n) are unrolled; [head_end, tail_start) is scanned as
    ``n_units`` super-blocks of period ``p``.  (0, 0, 0, 0) = all unrolled.
    """
    n = len(pattern)
    best = (0, 0, 0, 0, 0)  # coverage, -head, head, p, units
    for head in range(0, min(max_head, n) + 1):
        sub = pattern[head:]
        m = len(sub)
        for p in range(1, m // 2 + 1):
            units = m // p
            if units < 2:
                break
            prefix = units * p
            if all(sub[i] == sub[i % p] for i in range(prefix)):
                cand = (prefix, -head, head, p, units)
                if cand > best:
                    best = cand
                break  # smallest p for this head is the best for this head
    if best[0] == 0:
        return 0, 0, 0, 0
    _, _, head, p, units = best
    return head, p, units, head + p * units


@dataclasses.dataclass(frozen=True)
class Block:
    """norm -> mixer -> +residual; norm -> ffn -> +residual (pre-LN)."""

    cfg: ModelConfig
    spec: BlockSpec

    def _norm(self):
        cls = RMSNorm if self.cfg.norm == "rmsnorm" else LayerNorm
        return cls(self.cfg.d_model, param_dtype=self.cfg.pdtype,
                   compute_dtype=self.cfg.cdtype)

    def mixer_module(self):
        c = self.cfg
        kind = self.spec.mixer
        if kind in ("attn", "attn_local"):
            acfg = c.attention
            if kind == "attn" and acfg.window:
                acfg = dataclasses.replace(acfg, window=0)
            if kind == "attn_local" and not acfg.window:
                acfg = dataclasses.replace(acfg, window=1024)
            if c.attention.kind == "mla":
                return MLAAttention(c.d_model, acfg, c.pdtype, c.cdtype)
            return MultiHeadAttention(c.d_model, acfg, c.pdtype, c.cdtype,
                                      rotary_frac=1.0)
        if kind == "mosa":
            return HybridAttention(c.d_model, c.mosa, c.attention.rope_theta,
                                   rotary_frac=0.5, param_dtype=c.pdtype,
                                   compute_dtype=c.cdtype,
                                   variant=c.sparse_variant,
                                   impl=c.mosa.impl)
        if kind == "mamba":
            return MambaBlock(c.d_model, c.mamba, c.pdtype, c.cdtype)
        if kind == "mlstm":
            return MLSTMBlock(c.d_model, c.attention.n_heads, c.xlstm,
                              c.pdtype, c.cdtype)
        if kind == "slstm":
            return SLSTMBlock(c.d_model, c.attention.n_heads, c.xlstm,
                              c.pdtype, c.cdtype)
        raise ValueError(kind)

    def ffn_module(self):
        c = self.cfg
        if self.spec.ffn == "dense":
            return MLP(c.d_model, c.d_ff, c.ffn_act, c.pdtype, c.cdtype)
        if self.spec.ffn == "moe":
            return MoEFFN(c.d_model, c.moe, param_dtype=c.pdtype,
                          compute_dtype=c.cdtype)
        return None

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"norm1": self._norm().init(k1),
             "mixer": self.mixer_module().init(k2)}
        ffn = self.ffn_module()
        if ffn is not None:
            p["norm2"] = self._norm().init(k3)
            p["ffn"] = ffn.init(k4)
        return p

    def specs(self):
        s = {"norm1": self._norm().specs(),
             "mixer": self.mixer_module().specs()}
        ffn = self.ffn_module()
        if ffn is not None:
            s["norm2"] = self._norm().specs()
            s["ffn"] = ffn.specs()
        return s

    def __call__(self, params, x, positions=None, segments=None):
        norm = self._norm()
        mixer = self.mixer_module()
        aux = jnp.zeros((), jnp.float32)
        xin = norm(params["norm1"], x)
        if segments is None:
            h = mixer(params["mixer"], xin, positions)
        else:
            # packed rows (data/pipeline.py): attention mixers mask
            # cross-document pairs; recurrent mixers have no reset story.
            if self.spec.mixer not in ("attn", "attn_local", "mosa"):
                raise ValueError(
                    f"packed segments unsupported for {self.spec.mixer!r} "
                    "mixers (recurrent state crosses document boundaries)")
            h = mixer(params["mixer"], xin, positions, segments=segments)
        x = x + h
        ffn = self.ffn_module()
        if ffn is not None:
            h = ffn(params["ffn"], norm(params["norm2"], x))
            if isinstance(h, tuple):
                h, aux = h
            x = x + h
        return x, aux

    # ---------------------------------------------------------------- serving
    def init_cache(self, batch, max_len, dtype, paged=None):
        """``paged``: optional ``repro.serve.paged_kv.PagedConfig`` — dense
        and window caches become block-paged pools (DESIGN §7).  MLA keeps
        its contiguous latent cache (already rank-compressed; paging it is
        an open item), MoSA and SSM states are O(k)/O(1) by construction.
        """
        c = self.cfg
        kind = self.spec.mixer
        m = self.mixer_module()
        if kind == "mosa":
            return m.init_cache(batch, max_len, dtype, paged=paged)
        if kind in ("attn", "attn_local"):
            if c.attention.kind == "mla":
                ml = c.attention.mla
                return MLAKVCache.create(batch, max_len, ml.kv_lora_rank,
                                         ml.rope_head_dim, dtype)
            if m.cfg.window:
                W = min(m.cfg.window, max_len)
                if paged is not None:
                    from repro.serve.paged_kv import PagedWindowKVCache
                    return PagedWindowKVCache.create(
                        batch, W, c.attention.n_kv_heads, c.attention.d_head,
                        dtype, block_size=paged.block_size,
                        num_blocks=paged.num_window_blocks,
                        identity_tables=paged.num_window_blocks == 0)
                return WindowKVCache.create(batch, W,
                                            c.attention.n_kv_heads,
                                            c.attention.d_head, dtype)
            if paged is not None:
                from repro.serve.paged_kv import PagedDenseKVCache
                return PagedDenseKVCache.create(
                    batch, max_len, c.attention.n_kv_heads,
                    c.attention.d_head, dtype, block_size=paged.block_size,
                    num_blocks=paged.num_blocks,
                    identity_tables=paged.num_blocks == 0)
            return DenseKVCache.create(batch, max_len, c.attention.n_kv_heads,
                                       c.attention.d_head, dtype)
        if kind in ("mamba", "mlstm", "slstm"):
            return m.init_state(batch)
        raise ValueError(kind)

    def prefill(self, params, x, cache, positions=None, valid=None,
                continued=False):
        norm = self._norm()
        m = self.mixer_module()
        kind = self.spec.mixer
        xin = norm(params["norm1"], x)
        if kind == "mosa":
            h, cache = m.prefill(params["mixer"], xin, cache, positions,
                                 valid=valid, continued=continued)
        elif kind in ("attn", "attn_local"):
            h, cache = m.prefill(params["mixer"], xin, cache, positions,
                                 valid=valid)
        else:
            # SSM/xLSTM prefill has no pad story (recurrent state would need
            # a step-masked scan) — callers right-pad only attention stacks.
            h, cache = m.prefill(params["mixer"], xin, cache, positions)
        x = x + h
        ffn = self.ffn_module()
        aux = jnp.zeros((), jnp.float32)
        if ffn is not None:
            h = ffn(params["ffn"], norm(params["norm2"], x))
            if isinstance(h, tuple):
                h, aux = h
            x = x + h
        return x, cache, aux

    def prefill_packed(self, params, x, cache, positions=None, *, meta):
        """Packed multi-segment chunked prefill (DESIGN §9); ``positions``
        is unused (per-token positions live in ``meta``) but kept so
        ``_serving_pass`` can call every step uniformly."""
        kind = self.spec.mixer
        if kind not in ("attn", "attn_local", "mosa"):
            raise ValueError(
                f"packed prefill unsupported for {kind!r} mixers")
        if kind != "mosa" and self.cfg.attention.kind == "mla":
            raise ValueError(
                "packed prefill unsupported for MLA (contiguous latent "
                "cache; paging it is an open item)")
        norm = self._norm()
        m = self.mixer_module()
        xin = norm(params["norm1"], x)
        h, cache = m.prefill_packed(params["mixer"], xin, cache, meta)
        x = x + h
        ffn = self.ffn_module()
        aux = jnp.zeros((), jnp.float32)
        if ffn is not None:
            h = ffn(params["ffn"], norm(params["norm2"], x))
            if isinstance(h, tuple):
                h, aux = h
            x = x + h
        return x, cache, aux

    def decode_step(self, params, x, cache, positions=None):
        norm = self._norm()
        m = self.mixer_module()
        kind = self.spec.mixer
        xin = norm(params["norm1"], x)
        if kind in ("mamba", "mlstm", "slstm"):
            h, cache = m.decode_step(params["mixer"], xin, cache, positions)
        else:
            h, cache = m.decode_step(params["mixer"], xin, cache, positions)
        x = x + h
        ffn = self.ffn_module()
        if ffn is not None:
            h = ffn(params["ffn"], norm(params["norm2"], x))
            if isinstance(h, tuple):
                h, _ = h
            x = x + h
        return x, cache


@dataclasses.dataclass(frozen=True)
class TransformerLM:
    cfg: ModelConfig
    # Optional PartitionSpec applied to the residual stream at block
    # boundaries (sequence-parallel activation sharding for big configs; set
    # by the launcher, e.g. P(("pod","data"), "model")).
    act_spec: Any = None

    def _constrain(self, x):
        if self.act_spec is not None:
            return jax.lax.with_sharding_constraint(x, self.act_spec)
        return x

    # ------------------------------------------------------------------ build
    def _embed(self):
        c = self.cfg
        return Embedding(c.vocab, c.d_model, c.pdtype, c.cdtype)

    def _final_norm(self):
        c = self.cfg
        cls = RMSNorm if c.norm == "rmsnorm" else LayerNorm
        return cls(c.d_model, param_dtype=c.pdtype, compute_dtype=c.cdtype)

    def _blocks(self):
        return [Block(self.cfg, s) for s in self.cfg.resolved_pattern()]

    def _layout(self):
        """(head_end, p, units, tail_start, pattern) — see find_period."""
        pattern = self.cfg.resolved_pattern()
        if not self.cfg.scan_layers:
            return 0, 0, 0, 0, pattern
        head, p, units, tail_start = find_period(pattern)
        return head, p, units, tail_start, pattern

    def _unrolled_indices(self):
        head, p, units, tail_start, pattern = self._layout()
        return list(range(0, head)) + list(range(tail_start, len(pattern)))

    def init(self, key):
        c = self.cfg
        head, p, units, tail_start, pattern = self._layout()
        ke, kb, kn, ku = jax.random.split(key, 4)
        params: dict = {"embed": self._embed().init(ke)}

        blocks = self._blocks()
        layer_params: dict = {}
        if units:
            scan_p = {}
            for j in range(p):
                block = blocks[head + j]
                keys = jax.random.split(
                    jax.random.fold_in(kb, j), units)
                scan_p[f"pos{j}"] = jax.vmap(block.init)(keys)
            layer_params["scan"] = scan_p
        tail = {}
        for i in self._unrolled_indices():
            tail[f"layer{i}"] = blocks[i].init(jax.random.fold_in(kb, 10_000 + i))
        if tail:
            layer_params["tail"] = tail
        params["layers"] = layer_params
        params["final_norm"] = self._final_norm().init(kn)
        if not c.tie_embeddings:
            from repro.nn.layers import Linear
            params["unembed"] = Linear(
                c.d_model, c.vocab, param_dtype=c.pdtype,
                compute_dtype=c.cdtype).init(ku)
        return params

    def specs(self):
        c = self.cfg
        head, p, units, tail_start, pattern = self._layout()
        blocks = self._blocks()
        specs: dict = {"embed": self._embed().specs()}
        layer_specs: dict = {}
        if units:
            scan_s = {}
            for j in range(p):
                s = blocks[head + j].specs()
                # prepend the stacked (layer) axis to every leaf
                scan_s[f"pos{j}"] = jax.tree.map(
                    lambda ls: logical(*((None,) + tuple(ls))),
                    s, is_leaf=lambda x: hasattr(x, "axes"))
            layer_specs["scan"] = scan_s
        tail = {}
        for i in self._unrolled_indices():
            tail[f"layer{i}"] = blocks[i].specs()
        if tail:
            layer_specs["tail"] = tail
        specs["layers"] = layer_specs
        specs["final_norm"] = self._final_norm().specs()
        if not c.tie_embeddings:
            from repro.nn.layers import Linear
            specs["unembed"] = Linear(c.d_model, c.vocab).specs()
            specs["unembed"]["w"] = logical("embed", "vocab")
        return specs

    # ---------------------------------------------------------------- forward
    def _maybe_remat(self, fn):
        if self.cfg.remat == "full":
            return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
        if self.cfg.remat == "dots_saveable":
            return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
        if self.cfg.remat == "mosa":
            # Checkpoint AROUND the sparse gather (repro.core.mosa tags the
            # gathered activations and selected router scores with
            # checkpoint_name): the gather/scatter pair is memory-bound and
            # saved; projections, the kxk attention, and the FFN recompute.
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.save_only_these_names(
                    "mosa_gather", "mosa_router"))
        return fn

    _HEALTH_KEYS = ("sel_entropy", "drop_rate", "head_util")

    def _is_routed(self, block) -> bool:
        """Static (spec + variant decide it): does this block's mixer carry
        a learned sparse router with health telemetry?"""
        if block.spec.mixer != "mosa":
            return False
        m = block.mixer_module()
        return hasattr(m, "router_health") and \
            hasattr(m._sparse(), "router_health")

    def _block_health(self, block, bp, x):
        """Router health of one block given its REAL input ``x`` (the
        pre-norm residual stream), stop-gradiented: telemetry must never
        feed the loss or widen the remat save set."""
        xin = block._norm()(bp["norm1"], x)
        s = block.mixer_module().router_health(bp["mixer"], xin)
        return {k: jax.lax.stop_gradient(s[k]) for k in self._HEALTH_KEYS}

    def backbone(self, params, x, positions=None, segments=None,
                 collect_health: bool = False):
        """(B, T, h) -> (B, T, h) hidden states + aux loss.

        ``segments``: optional (B, T) int32 document ids for packed rows —
        threaded to every attention mixer so no probability mass crosses a
        document boundary (data/pipeline.py packed mode).

        ``collect_health=True`` (a STATIC flag) additionally returns the
        expert-choice router health averaged over every MoSA layer
        (``repro.core.router.router_health_stats`` keys), computed from
        each routed layer's real input as the walk passes it — the extra
        cost is one router scores+top_k per MoSA layer, riding the SAME
        forward instead of a second one (DESIGN §11 device-metrics
        pattern).  Scanned super-blocks accumulate stop-gradiented totals
        through the scan carry.  Returns ``(x, aux)`` normally,
        ``(x, aux, health_dict_or_empty)`` when collecting."""
        head, p, units, tail_start, pattern = self._layout()
        blocks = self._blocks()
        aux_total = jnp.zeros((), jnp.float32)
        KEYS = self._HEALTH_KEYS
        totals = ({k: jnp.zeros((), jnp.float32) for k in KEYS}
                  if collect_health else {})
        n_routed = 0

        def add(tot, s):
            return {k: tot[k] + s[k] for k in KEYS}

        for i in range(head):
            bp = params["layers"]["tail"][f"layer{i}"]
            if collect_health and self._is_routed(blocks[i]):
                totals = add(totals, self._block_health(blocks[i], bp, x))
                n_routed += 1
            blk = self._maybe_remat(blocks[i].__call__)
            x, a = blk(bp, x, positions, segments)
            x = self._constrain(x)
            aux_total = aux_total + a

        if units:
            unit_blocks = blocks[head:head + p]
            mosa_pos = [j for j in range(p)
                        if collect_health and self._is_routed(unit_blocks[j])]

            def superblock(x, unit_params):
                aux = jnp.zeros((), jnp.float32)
                tot = ({k: jnp.zeros((), jnp.float32) for k in KEYS}
                       if mosa_pos else {})
                for j in range(p):
                    if j in mosa_pos:
                        tot = add(tot, self._block_health(
                            unit_blocks[j], unit_params[f"pos{j}"], x))
                    x, a = unit_blocks[j](unit_params[f"pos{j}"], x, positions,
                                          segments)
                    x = self._constrain(x)
                    aux = aux + a
                return x, aux, tot

            superblock = self._maybe_remat(superblock)

            def scan_body(carry, unit_params):
                x, aux, tot = carry
                x, a, t = superblock(x, unit_params)
                if mosa_pos:
                    tot = add(tot, t)
                return (x, aux + a, tot), None

            (x, aux_total, totals), _ = jax.lax.scan(
                scan_body, (x, aux_total, totals), params["layers"]["scan"])
            n_routed += units * len(mosa_pos)

        for i in range(tail_start, len(pattern)):
            bp = params["layers"]["tail"][f"layer{i}"]
            if collect_health and self._is_routed(blocks[i]):
                totals = add(totals, self._block_health(blocks[i], bp, x))
                n_routed += 1
            blk = self._maybe_remat(blocks[i].__call__)
            x, a = blk(bp, x, positions, segments)
            x = self._constrain(x)
            aux_total = aux_total + a

        if not collect_health:
            return x, aux_total
        health = ({k: v / n_routed for k, v in totals.items()}
                  if n_routed else {})
        return x, aux_total, health

    def router_health(self, params, tokens=None, positions=None,
                      inputs_embeds=None):
        """Expert-choice router health averaged over every MoSA layer —
        the standalone-forward face of ``backbone(collect_health=True)``
        (one walk, no duplicated traversal to keep in sync).  Returns {}
        for models with no learned sparse router."""
        x = self._embed_tokens(params, tokens, inputs_embeds)
        _, _, health = self.backbone(params, x, positions,
                                     collect_health=True)
        return health

    def _embed_tokens(self, params, tokens=None, inputs_embeds=None):
        c = self.cfg
        if inputs_embeds is not None:
            return inputs_embeds.astype(c.cdtype)
        x = self._embed()(params["embed"], tokens)
        if c.norm == "rmsnorm" and c.name.startswith("gemma"):
            x = x * jnp.asarray(c.d_model ** 0.5, x.dtype)  # gemma convention
        return x

    def _forward(self, params, tokens=None, positions=None,
                 inputs_embeds=None, segments=None,
                 collect_health: bool = False):
        c = self.cfg
        x = self._embed_tokens(params, tokens, inputs_embeds)
        if collect_health:
            x, aux, health = self.backbone(params, x, positions, segments,
                                           collect_health=True)
        else:
            x, aux = self.backbone(params, x, positions, segments)
            health = {}
        x = self._final_norm()(params["final_norm"], x)
        if c.tie_embeddings:
            logits = self._embed().attend(params["embed"], x)
        else:
            w = params["unembed"]["w"].astype(c.cdtype)
            logits = jnp.dot(x.astype(c.cdtype), w,
                             preferred_element_type=jnp.float32)
        return logits, aux, health

    def __call__(self, params, tokens=None, positions=None, inputs_embeds=None,
                 segments=None):
        """Returns (logits fp32 (B, T, vocab), aux_loss scalar)."""
        logits, aux, _ = self._forward(params, tokens, positions,
                                       inputs_embeds, segments)
        return logits, aux

    def loss(self, params, batch, with_health: bool = False):
        """batch: {"tokens" (B,T) or "embeds" (B,T,h), "labels" (B,T)}.
        labels < 0 are masked.  Packed batches (data/pipeline.py) add
        "segments" (B,T) int32 doc ids and per-doc "positions"; attention is
        then segment-masked so packed documents never see each other.
        Returns (loss, metrics).

        ``with_health=True`` (static) folds the router-health stats of
        ``backbone(collect_health=True)`` into the metrics dict — the
        in-step telemetry path (DESIGN §11) that replaces the train loop's
        former second full forward per log interval."""
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        labels = batch["labels"]
        positions = batch.get("positions")
        segments = batch.get("segments")
        logits, aux, health = self._forward(params, tokens, positions,
                                            inputs_embeds=embeds,
                                            segments=segments,
                                            collect_health=with_health)
        logits = logits.astype(jnp.float32)
        V = logits.shape[-1]
        mask = (labels >= 0).astype(jnp.float32)
        labels_c = jnp.clip(labels, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # Gold logit via a fused one-hot reduction instead of
        # take_along_axis: a gather across the vocab-sharded dim would
        # all-gather the logits (measured 40 GB/dev on qwen2-vl; §Perf it.2).
        iota_v = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
        gold = jnp.sum(jnp.where(iota_v == labels_c[..., None], logits, 0.0),
                       axis=-1)
        nll = (logz - gold) * mask
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = nll.sum() / denom
        loss = ce + aux
        metrics = {"ce": ce, "aux": aux, "ppl": jnp.exp(ce),
                   "tokens": denom}
        if with_health:
            metrics.update(health)
        return loss, metrics

    # ---------------------------------------------------------------- serving
    def init_cache(self, batch, max_len, dtype=None, paged=None):
        """``paged``: optional ``PagedConfig`` — see ``Block.init_cache``."""
        dtype = dtype or self.cfg.cdtype
        head, p, units, tail_start, pattern = self._layout()
        blocks = self._blocks()
        caches: dict = {}
        if units:
            scan_c = {}
            for j in range(p):
                one = blocks[head + j].init_cache(batch, max_len, dtype,
                                                  paged=paged)
                scan_c[f"pos{j}"] = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (units,) + t.shape)
                    if hasattr(t, "shape") else t, one)
            caches["scan"] = scan_c
        tail = {}
        for i in self._unrolled_indices():
            tail[f"layer{i}"] = blocks[i].init_cache(batch, max_len, dtype,
                                                     paged=paged)
        if tail:
            caches["tail"] = tail
        return caches

    def _serving_pass(self, params, x, caches, positions, step_fn_name,
                      **step_kw):
        head, p, units, tail_start, pattern = self._layout()
        blocks = self._blocks()

        new_tail = {}

        def run_unrolled(i, x, caches):
            fn = getattr(blocks[i], step_fn_name)
            res = fn(params["layers"]["tail"][f"layer{i}"], x,
                     caches["tail"][f"layer{i}"], positions, **step_kw)
            if step_fn_name in ("prefill", "prefill_packed"):
                x, c_new, _ = res
            else:
                x, c_new = res
            new_tail[f"layer{i}"] = c_new
            return x

        for i in range(head):
            x = run_unrolled(i, x, caches)

        if units:
            unit_blocks = blocks[head:head + p]

            def scan_body(x, xs):
                unit_params, unit_caches = xs
                new_caches = {}
                for j in range(p):
                    fn = getattr(unit_blocks[j], step_fn_name)
                    res = fn(unit_params[f"pos{j}"], x,
                             unit_caches[f"pos{j}"], positions, **step_kw)
                    if step_fn_name in ("prefill", "prefill_packed"):
                        x, c_new, _ = res
                    else:
                        x, c_new = res
                    new_caches[f"pos{j}"] = c_new
                return x, new_caches

            x, new_scan = jax.lax.scan(
                scan_body, x, (params["layers"]["scan"], caches["scan"]))
            caches = dict(caches, scan=new_scan)

        for i in range(tail_start, len(pattern)):
            x = run_unrolled(i, x, caches)
        if new_tail:
            caches = dict(caches, tail={**caches["tail"], **new_tail})
        return x, caches

    def prefill(self, params, tokens, caches, positions=None,
                inputs_embeds=None, valid=None, last_pos=None,
                continued=False):
        """``valid``: (B, T) bool — False marks right-pad tokens (bucketed
        prefill; pads never enter MoSA selection and never advance cache
        lengths).  ``last_pos``: (B,) int32 — per-row index of the last REAL
        token, whose logits are returned (None = T-1, the unpadded case).
        ``continued`` (static): caches hold a restored prompt prefix and the
        tokens are the suffix (prefix-cache hit, DESIGN §7)."""
        c = self.cfg
        x = self._embed_tokens(params, tokens, inputs_embeds)
        x, caches = self._serving_pass(params, x, caches, positions,
                                       "prefill", valid=valid,
                                       continued=continued)
        x = self._final_norm()(params["final_norm"], x)
        if last_pos is None:
            xl = x[:, -1:]
        else:
            xl = jnp.take_along_axis(
                x, last_pos.astype(jnp.int32)[:, None, None], axis=1)
        if c.tie_embeddings:
            logits = self._embed().attend(params["embed"], xl)
        else:
            logits = jnp.dot(xl.astype(c.cdtype),
                             params["unembed"]["w"].astype(c.cdtype),
                             preferred_element_type=jnp.float32)
        return logits, caches

    def prefill_packed(self, params, tokens, caches, cu_seqlens, rows,
                       past_lens):
        """Packed multi-segment chunked prefill — ONE fused program per
        mixed chunk (DESIGN §9).

        ``tokens``: (1, C) int32 — N prompt segments flattened back to back
        (tail beyond ``cu[-1]`` is padding); ``cu_seqlens``: (N+1,) int32
        offsets; ``rows``: (N,) int32 batch row per segment (-1 =
        inactive); ``past_lens``: (N,) int32 tokens already in each row's
        caches (0 for a fresh prompt's first chunk — continued prefill on
        an empty cache reproduces one-shot prefill exactly).

        The chunk geometry (C, N) is STATIC: every chunk of every prompt
        mix compiles to this single program — the replacement for the
        pow2-bucket ladder.  Returns ``(logits (N, V), caches)`` — each
        segment's logits at its LAST token in this chunk; only segments
        completing their prompt have meaningful (TTFT) logits, the
        scheduler ignores the rest.
        """
        c = self.cfg
        C = tokens.shape[1]
        cu = jnp.asarray(cu_seqlens, jnp.int32)
        rows = jnp.asarray(rows, jnp.int32)
        past = jnp.asarray(past_lens, jnp.int32)
        t = jnp.arange(C, dtype=jnp.int32)
        seg = jnp.searchsorted(cu[1:], t, side="right").astype(jnp.int32)
        seg = jnp.where(t < cu[-1], seg, -1)
        segc = jnp.maximum(seg, 0)
        local = t - cu[segc]
        row_of_tok = jnp.where(seg >= 0, rows[segc], -1)
        pos_of_tok = jnp.where(row_of_tok >= 0, past[segc] + local, 0)
        tok_idx = jnp.clip(cu[:-1, None] + t[None], 0, C - 1)   # (N, C)
        seg_len = cu[1:] - cu[:-1]
        in_seg = (t[None] < seg_len[:, None]) & (rows >= 0)[:, None]
        meta = dict(cu=cu, rows=rows, past_lens=past, seg_of_tok=seg,
                    local_of_tok=local, row_of_tok=row_of_tok,
                    pos_of_tok=pos_of_tok, tok_idx=tok_idx, in_seg=in_seg)

        x = self._embed_tokens(params, tokens)
        x, caches = self._serving_pass(params, x, caches, None,
                                       "prefill_packed", meta=meta)
        x = self._final_norm()(params["final_norm"], x)
        last = jnp.clip(cu[1:] - 1, 0, C - 1)                   # (N,)
        xl = x[0][last]                                         # (N, h)
        if c.tie_embeddings:
            logits = self._embed().attend(params["embed"], xl)
        else:
            logits = jnp.dot(xl.astype(c.cdtype),
                             params["unembed"]["w"].astype(c.cdtype),
                             preferred_element_type=jnp.float32)
        return logits, caches

    def decode_step(self, params, token, caches, positions=None):
        """token: (B, 1) int32 -> (logits (B, 1, V), caches)."""
        c = self.cfg
        x = self._embed_tokens(params, token)
        x, caches = self._serving_pass(params, x, caches, positions,
                                       "decode_step")
        x = self._final_norm()(params["final_norm"], x)
        if c.tie_embeddings:
            logits = self._embed().attend(params["embed"], x)
        else:
            logits = jnp.dot(x.astype(c.cdtype),
                             params["unembed"]["w"].astype(c.cdtype),
                             preferred_element_type=jnp.float32)
        return logits, caches

    def decode_many(self, params, tok, caches, key, n: int,
                    temperature: float = 0.0, top_k: int = 0,
                    return_logits: bool = False):
        """Fused multi-token decode: ``n`` decode steps inside ONE program.

        ``jax.lax.scan`` over :meth:`decode_step` with sampling on-device
        (``sample_logits``), so a jitted caller pays one dispatch per *chunk*
        instead of several per token — the decode hot path of
        DESIGN §6.  ``tok``: (B, 1) int32, the last emitted token; ``key``:
        PRNG key (may be ``None`` for greedy decoding).  ``n`` / ``top_k`` /
        ``return_logits`` are static; ``temperature`` may be traced (see
        ``sample_logits``).

        Returns ``(tokens (B, n) int32, caches)``; with
        ``return_logits=True`` returns ``(tokens, logits (B, n, V), caches)``
        (parity testing — the logits are the ones each token was sampled
        from).
        """
        if key is None:
            key = jax.random.PRNGKey(0)

        def body(carry, _):
            tok, caches, key = carry
            logits, caches = self.decode_step(params, tok, caches)
            key, sub = jax.random.split(key)
            nxt = sample_logits(logits[:, -1], sub, temperature, top_k)
            out = (nxt, logits[:, -1]) if return_logits else nxt
            return (nxt[:, None], caches, key), out

        (_, caches, _), ys = jax.lax.scan(body, (tok, caches, key), None,
                                          length=n)
        if return_logits:
            toks, logits = ys
            return toks.T, logits.transpose(1, 0, 2), caches
        return ys.T, caches
