"""Core layers: Linear, Embedding, RMSNorm, LayerNorm.

Everything follows the module protocol from ``repro.nn.module``: ``init``,
``specs``, ``__call__(params, x)``.  Parameters are stored in the dtype given
at construction (``param_dtype``); matmuls run in ``compute_dtype`` with fp32
accumulation (``preferred_element_type``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.module import logical


def _trunc_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


@dataclasses.dataclass(frozen=True)
class Linear:
    d_in: int
    d_out: int
    bias: bool = False
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    in_axis: str | None = None
    out_axis: str | None = None
    std: float | None = None  # default: 1/sqrt(d_in)

    def init(self, key):
        std = self.std if self.std is not None else self.d_in ** -0.5
        p = {"w": _trunc_normal(key, (self.d_in, self.d_out), std, self.param_dtype)}
        if self.bias:
            p["b"] = jnp.zeros((self.d_out,), self.param_dtype)
        return p

    def specs(self):
        s = {"w": logical(self.in_axis, self.out_axis)}
        if self.bias:
            s["b"] = logical(self.out_axis)
        return s

    def __call__(self, params, x):
        w = params["w"].astype(self.compute_dtype)
        y = jnp.dot(x.astype(self.compute_dtype), w,
                    preferred_element_type=jnp.float32).astype(self.compute_dtype)
        if self.bias:
            y = y + params["b"].astype(self.compute_dtype)
        return y


@dataclasses.dataclass(frozen=True)
class Embedding:
    vocab: int
    dim: int
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32

    def init(self, key):
        return {"table": _trunc_normal(key, (self.vocab, self.dim), 1.0, self.param_dtype)}

    def specs(self):
        return {"table": logical("vocab", "embed")}

    def __call__(self, params, ids):
        return params["table"].astype(self.compute_dtype)[ids]

    def attend(self, params, x):
        """Tied unembedding: logits = x @ table.T (fp32 accumulation)."""
        t = params["table"].astype(self.compute_dtype)
        return jnp.dot(x.astype(self.compute_dtype), t.T,
                       preferred_element_type=jnp.float32)


@dataclasses.dataclass(frozen=True)
class RMSNorm:
    dim: int
    eps: float = 1e-6
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32

    def init(self, key):
        del key
        return {"scale": jnp.ones((self.dim,), self.param_dtype)}

    def specs(self):
        return {"scale": logical(None)}

    def __call__(self, params, x):
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + self.eps)
        return (y * params["scale"].astype(jnp.float32)).astype(self.compute_dtype)


@dataclasses.dataclass(frozen=True)
class LayerNorm:
    dim: int
    eps: float = 1e-5
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32

    def init(self, key):
        del key
        return {"scale": jnp.ones((self.dim,), self.param_dtype),
                "bias": jnp.zeros((self.dim,), self.param_dtype)}

    def specs(self):
        return {"scale": logical(None), "bias": logical(None)}

    def __call__(self, params, x):
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + self.eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(self.compute_dtype)
