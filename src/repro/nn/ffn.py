"""Feed-forward blocks: dense MLP (SwiGLU/GELU) and token-choice MoE.

The MoE uses the sort-based, capacity-bounded dispatch that maps well onto
TPUs (static shapes, grouped einsums over the expert axis).  Expert weights
carry the ``expert`` logical axis so expert-parallelism is just a sharding
rule (experts over the ``model`` mesh axis); the scatter/gather between the
token-sharded and expert-sharded layouts lowers to the all-to-all pattern of
classic EP.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.dist import hints
from repro.nn.module import logical
from repro.nn.layers import _trunc_normal


@dataclasses.dataclass(frozen=True)
class MLP:
    d_model: int
    d_ff: int
    act: str = "swiglu"           # swiglu | gelu
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        std_in = self.d_model ** -0.5
        std_out = self.d_ff ** -0.5
        if self.act == "swiglu":
            return {
                "w_gate": _trunc_normal(k1, (self.d_model, self.d_ff), std_in, self.param_dtype),
                "w_up": _trunc_normal(k2, (self.d_model, self.d_ff), std_in, self.param_dtype),
                "w_down": _trunc_normal(k3, (self.d_ff, self.d_model), std_out, self.param_dtype),
            }
        return {
            "w_in": _trunc_normal(k1, (self.d_model, self.d_ff), std_in, self.param_dtype),
            "w_out": _trunc_normal(k2, (self.d_ff, self.d_model), std_out, self.param_dtype),
        }

    def specs(self):
        if self.act == "swiglu":
            return {"w_gate": logical("embed", "mlp"),
                    "w_up": logical("embed", "mlp"),
                    "w_down": logical("mlp", "embed")}
        return {"w_in": logical("embed", "mlp"), "w_out": logical("mlp", "embed")}

    def __call__(self, params, x):
        cd = self.compute_dtype
        x = x.astype(cd)
        if self.act == "swiglu":
            g = jnp.dot(x, params["w_gate"].astype(cd), preferred_element_type=jnp.float32)
            u = jnp.dot(x, params["w_up"].astype(cd), preferred_element_type=jnp.float32)
            h = (jax.nn.silu(g) * u).astype(cd)
            return jnp.dot(h, params["w_down"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        h = jax.nn.gelu(jnp.dot(x, params["w_in"].astype(cd),
                                preferred_element_type=jnp.float32)).astype(cd)
        return jnp.dot(h, params["w_out"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)


@dataclasses.dataclass(frozen=True)
class MoEFFN:
    """Token-choice top-k MoE with SwiGLU experts + optional shared experts."""

    d_model: int
    cfg: MoEConfig
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32

    @property
    def capacity_factor(self):
        return self.cfg.capacity_factor

    def init(self, key):
        c = self.cfg
        keys = jax.random.split(key, 5)
        std_in = self.d_model ** -0.5
        std_out = c.d_expert ** -0.5
        E = c.n_experts
        p = {
            "router": _trunc_normal(keys[0], (self.d_model, E), std_in, jnp.float32),
            "w_gate": _trunc_normal(keys[1], (E, self.d_model, c.d_expert), std_in, self.param_dtype),
            "w_up": _trunc_normal(keys[2], (E, self.d_model, c.d_expert), std_in, self.param_dtype),
            "w_down": _trunc_normal(keys[3], (E, c.d_expert, self.d_model), std_out, self.param_dtype),
        }
        if c.n_shared_experts > 0:
            d_sh = (c.d_shared or c.d_expert) * c.n_shared_experts
            shared = MLP(self.d_model, d_sh, "swiglu", self.param_dtype, self.compute_dtype)
            p["shared"] = shared.init(keys[4])
        return p

    def specs(self):
        s = {
            "router": logical("embed", None),
            "w_gate": logical("expert", "embed", "expert_mlp"),
            "w_up": logical("expert", "embed", "expert_mlp"),
            "w_down": logical("expert", "expert_mlp", "embed"),
        }
        if self.cfg.n_shared_experts > 0:
            c = self.cfg
            d_sh = (c.d_shared or c.d_expert) * c.n_shared_experts
            s["shared"] = MLP(self.d_model, d_sh, "swiglu").specs()
        return s

    def _shared(self):
        c = self.cfg
        d_sh = (c.d_shared or c.d_expert) * c.n_shared_experts
        return MLP(self.d_model, d_sh, "swiglu", self.param_dtype, self.compute_dtype)

    def _dispatch_row(self, params, xf):
        """Per-row dispatch: xf (T, h) -> (y (T, h), stats).

        The sort/cumsum run over the *row-local* token axis, which stays
        unsharded under data parallelism — a global sort over all tokens
        would force an all-gather of the whole batch (measured: 25 TB of
        collectives on deepseek-v2 train_4k; see EXPERIMENTS.md §Perf it.1).
        vmapped over the (sharded) batch dim by ``__call__``.
        """
        c = self.cfg
        cd = self.compute_dtype
        T, h = xf.shape
        E, K = c.n_experts, c.top_k

        logits = jnp.dot(xf.astype(jnp.float32), params["router"],
                         preferred_element_type=jnp.float32)          # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert_ids = jax.lax.top_k(probs, K)                    # (T, K)
        gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

        capacity = int(max(1, -(-T * K * self.capacity_factor // E)))
        flat_e = expert_ids.reshape(-1)                               # (T*K,)
        sort_idx = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[sort_idx]
        group_sizes = jnp.bincount(flat_e, length=E)
        starts = jnp.concatenate([jnp.zeros((1,), group_sizes.dtype),
                                  jnp.cumsum(group_sizes)[:-1]])
        pos = jnp.arange(T * K) - starts[sorted_e]
        keep = pos < capacity
        pos_c = jnp.where(keep, pos, 0).astype(jnp.int32)

        tok_idx = sort_idx // K
        x_sorted = xf[tok_idx] * keep[:, None].astype(cd)
        buf = jnp.zeros((E, capacity, h), cd).at[sorted_e, pos_c].add(
            x_sorted, mode="drop")

        g = jnp.einsum("ech,ehd->ecd", buf, params["w_gate"].astype(cd),
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ech,ehd->ecd", buf, params["w_up"].astype(cd),
                       preferred_element_type=jnp.float32)
        hmid = (jax.nn.silu(g) * u).astype(cd)
        out = jnp.einsum("ecd,edh->ech", hmid, params["w_down"].astype(cd),
                         preferred_element_type=jnp.float32).astype(cd)

        y_sorted = out[sorted_e, pos_c] * keep[:, None].astype(cd)    # (T*K, h)
        y_flat = jnp.zeros((T * K, h), cd).at[sort_idx].set(y_sorted)
        y = (y_flat.reshape(T, K, h) *
             gate.astype(cd).reshape(T, K, 1)).sum(axis=1)

        me = probs.mean(axis=0)                                       # (E,)
        ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * K)
        return y, me, ce

    # ------------------------------------------------------------ EP path
    def _ep_local(self, router_w, w_gate, w_up, w_down, xf, axis: str):
        """Expert-parallel body (inside shard_map over ``axis``).

        Key insight (§Perf cell-1 it.11): the activations are replicated over
        the model axis anyway, so each expert shard just *filters* the tokens
        routed to its local experts — dispatch needs NO communication; the
        only collective is the standard output psum.  GSPMD could not infer
        this from the scatter formulation (it all-reduced dispatch-buffer-
        sized tensors: ~75 GB/layer-pass on deepseek train_4k).
        """
        c = self.cfg
        cd = self.compute_dtype
        N, h = xf.shape
        E, K = c.n_experts, c.top_k
        n_shards = jax.lax.psum(1, axis)
        E_loc = w_gate.shape[0]                               # E / n_shards
        m = jax.lax.axis_index(axis)
        lo = m * E_loc

        logits = jnp.dot(xf.astype(jnp.float32), router_w,
                         preferred_element_type=jnp.float32)  # (N, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert_ids = jax.lax.top_k(probs, K)
        gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

        # keep only copies routed to local experts; rest -> drop bucket E_loc
        flat_e = expert_ids.reshape(-1) - lo                  # (N*K,)
        local = (flat_e >= 0) & (flat_e < E_loc)
        flat_e = jnp.where(local, flat_e, E_loc)
        capacity = int(max(1, -(-N * K * self.capacity_factor // E)))

        sort_idx = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[sort_idx]
        group_sizes = jnp.bincount(flat_e, length=E_loc + 1)
        starts = jnp.concatenate([jnp.zeros((1,), group_sizes.dtype),
                                  jnp.cumsum(group_sizes)[:-1]])
        pos = jnp.arange(N * K) - starts[sorted_e]
        keep = (pos < capacity) & (sorted_e < E_loc)
        pos_c = jnp.where(keep, pos, 0).astype(jnp.int32)
        e_c = jnp.where(keep, sorted_e, 0).astype(jnp.int32)

        tok_idx = sort_idx // K
        x_sorted = xf[tok_idx] * keep[:, None].astype(cd)
        buf = jnp.zeros((E_loc, capacity, h), cd).at[e_c, pos_c].add(
            x_sorted, mode="drop")

        g = jnp.einsum("ech,ehd->ecd", buf, w_gate.astype(cd),
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ech,ehd->ecd", buf, w_up.astype(cd),
                       preferred_element_type=jnp.float32)
        hmid = (jax.nn.silu(g) * u).astype(cd)
        out = jnp.einsum("ecd,edh->ech", hmid, w_down.astype(cd),
                         preferred_element_type=jnp.float32).astype(cd)

        y_sorted = out[e_c, pos_c] * keep[:, None].astype(cd)
        y_flat = jnp.zeros((N * K, h), cd).at[sort_idx].set(y_sorted)
        y = (y_flat.reshape(N, K, h) *
             gate.astype(cd).reshape(N, K, 1)).sum(axis=1)
        y = jax.lax.psum(y, axis)                 # combine expert shards

        me = probs.mean(axis=0)                                # (E,) replicated
        ce = jnp.zeros((E,), jnp.float32).at[
            (expert_ids.reshape(-1))].add(1.0) / (N * K)
        return y, me, ce

    def _ep_call(self, params, x, mesh, dp_axes, axis: str):
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        B, T, h = x.shape
        # divisibility-safe DP: drop axes until their product divides B
        # (long_500k has batch=1 — the whole row set is then replicated)
        dp_axes = tuple(dp_axes or ())
        while dp_axes:
            total = 1
            for a in dp_axes:
                total *= mesh.shape[a]
            if B % total == 0:
                break
            dp_axes = dp_axes[:-1]
        xs_spec = P(dp_axes if dp_axes else None, None, None)

        @partial(shard_map, mesh=mesh,
                 in_specs=(P(), P(axis), P(axis), P(axis), xs_spec),
                 out_specs=(xs_spec, P(), P()), check_rep=False)
        def run(router_w, w_gate, w_up, w_down, xb):
            Bl, Tl, _ = xb.shape
            y, me, ce = self._ep_local(router_w, w_gate, w_up, w_down,
                                       xb.reshape(Bl * Tl, h), axis)
            # me/ce identical on every model shard; average over data shards
            n_dp = 1
            for a in (dp_axes or ()):
                n_dp *= mesh.shape[a]
            if dp_axes:
                me = jax.lax.pmean(me, dp_axes[0] if len(dp_axes) == 1
                                   else dp_axes)
                ce = jax.lax.pmean(ce, dp_axes[0] if len(dp_axes) == 1
                                   else dp_axes)
            return y.reshape(Bl, Tl, h), me, ce

        x = jax.lax.with_sharding_constraint(x, xs_spec)
        return run(params["router"], params["w_gate"], params["w_up"],
                   params["w_down"], x)

    def __call__(self, params, x):
        """x: (B, T, h) -> (y, aux_loss)."""
        c = self.cfg
        B, T, h = x.shape
        state = hints.current()
        mesh = state["mesh"] if state else None
        tp = state["tp"] if state else None
        use_ep = (mesh is not None and tp in mesh.shape
                  and c.n_experts % mesh.shape[tp] == 0)
        if use_ep:
            y, me, ce = self._ep_call(params, x, mesh, state["dp"], tp)
        else:
            y, me, ce = jax.vmap(self._dispatch_row,
                                 in_axes=(None, 0))(params, x)
            me, ce = me.mean(0), ce.mean(0)
        aux = c.n_experts * jnp.sum(me * ce) * c.router_aux_loss
        if c.n_shared_experts > 0:
            y = y + self._shared()(params["shared"], x.astype(self.compute_dtype))
        return y, aux
