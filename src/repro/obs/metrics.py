"""Process-local metrics registry: counters, gauges, histograms (DESIGN §11).

The serving and training paths both report through one ``Registry`` of named
metrics so the paper's systems claims (TTFT/TPOT, tokens/s, block-pool
pressure, router health) are measured in one place instead of scattered
ad-hoc dicts.  Three deliberate constraints shape the design:

  * **Pure-Python hot path** — recording is a dict lookup plus a float op;
    no numpy, no jax, no locks on the observe path.  The modules that
    instrument per-block allocator operations (``repro.serve.paged_kv``)
    and per-chunk scheduling (``repro.serve.scheduler``) call into this on
    every event, and the bench gate holds obs-enabled serving within 2% of
    obs-disabled (``BENCH_serve.json: obs_overhead``).
  * **Zero writes when disabled** — ``Registry.enabled = False`` makes
    every convenience call (``inc``/``set``/``observe``) return before
    touching any state, and the factory methods hand back a shared no-op
    metric that is never stored.  ``tests/test_obs.py`` asserts the
    snapshot stays empty.
  * **Fixed-bucket streaming quantiles** — histograms keep a bounded
    vector of bucket counts (no sample retention), and p50/p90/p99 are
    interpolated within the covering bucket, clamped to the observed
    min/max.  Memory is O(buckets) regardless of request count — the fix
    for the ``Scheduler.ttft`` dict that grew per request forever.

Device-metrics pattern (the jit half): values produced INSIDE jitted code
(train-step loss/grad-norm, in-step router health) must not force an extra
device→host transfer.  The pattern is: the jitted function returns them as
extra outputs (aux metrics riding the existing step outputs), the caller
host-syncs them where it already syncs (the ``float(v)`` conversion after
the step), and then calls ``publish`` with the resulting floats.
``publish`` itself only calls ``float()`` — on an already-fetched numpy
scalar that is free; on a device array it would BE the transfer, so keep
feeding it from the existing sync point (``repro.train.loop`` is the
reference user; parity under jit + donated buffers is tested).
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence


def _geometric_bounds(lo: float, hi: float, per_decade: int = 4) -> tuple:
    """Geometric bucket bounds from ``lo`` to ``hi`` (inclusive-ish)."""
    import math
    n = int(math.ceil((math.log10(hi) - math.log10(lo)) * per_decade))
    return tuple(lo * (10.0 ** (i / per_decade)) for i in range(n + 1))


# Default bounds cover microseconds..minutes in seconds AND dimensionless
# ratios (packing efficiency, entropy in [0, 1]) with ~78%-wide buckets.
DEFAULT_BOUNDS = _geometric_bounds(1e-6, 1e3)

# Linear [0, 1] bounds for ratio-valued histograms (efficiency, drop rate,
# normalized entropy) where geometric spacing would waste resolution.
UNIT_BOUNDS = tuple(i / 20.0 for i in range(21))


class Counter:
    """Monotone float counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-value gauge; ``set_max`` keeps a high-water mark instead."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def set_max(self, v: float) -> None:
        if v > self.value:
            self.value = v


class Histogram:
    """Fixed-bucket streaming histogram with interpolated quantiles.

    ``bounds`` are the bucket upper edges; values land in the first bucket
    whose edge is >= v, with one overflow bucket past the last edge.
    ``quantile(q)`` walks the cumulative counts to the covering bucket and
    interpolates linearly inside it, clamping to the observed min/max — so
    a single observation reports itself exactly and bucket-width error is
    bounded by the bucket, never by the sample count.
    """

    __slots__ = ("name", "bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds = tuple(bounds) if bounds is not None else DEFAULT_BOUNDS
        assert all(a < b for a, b in zip(self.bounds, self.bounds[1:])), (
            "histogram bounds must be strictly increasing")
        self.counts = [0] * (len(self.bounds) + 1)   # +1 overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        lo, hi = 0, len(self.bounds)                 # bisect, inlined: the
        while lo < hi:                               # hot path stays free of
            mid = (lo + hi) // 2                     # module lookups
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                b_lo = self.bounds[i - 1] if i > 0 else self.min
                b_hi = self.bounds[i] if i < len(self.bounds) else self.max
                b_lo = max(b_lo, self.min)
                b_hi = min(b_hi, self.max)
                if b_hi <= b_lo:
                    return b_lo
                frac = (target - cum) / c
                return b_lo + frac * (b_hi - b_lo)
            cum += c
        return self.max

    def percentiles(self) -> Dict[str, float]:
        return {"p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}

    def summary(self) -> dict:
        out = {"count": self.count, "sum": self.sum}
        if self.count:
            out.update(min=self.min, max=self.max,
                       mean=self.sum / self.count, **self.percentiles())
        return out


class _Null:
    """Shared no-op metric handed out by a disabled registry (never stored)."""

    name = "<disabled>"
    value = 0.0

    def inc(self, v: float = 1.0) -> None: pass
    def set(self, v: float) -> None: pass
    def set_max(self, v: float) -> None: pass
    def observe(self, v: float) -> None: pass
    def quantile(self, q: float) -> float: return 0.0
    def percentiles(self) -> dict: return {}
    def summary(self) -> dict: return {}


_NULL = _Null()


class Registry:
    """Name -> metric map with a fast-exit ``enabled`` switch.

    Creation is lock-guarded (instrumented code may run under the data
    pipeline's prefetch thread); the record path is a plain attribute
    update, safe under the GIL for the float ops used here.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------- factories
    def _get(self, name: str, cls, *args):
        if not self.enabled:
            return _NULL
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name, *args)
                    self._metrics[name] = m
        assert isinstance(m, cls), (
            f"metric {name!r} already registered as {type(m).__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(name, Histogram, bounds)

    # ---------------------------------------------------------- convenience
    def inc(self, name: str, v: float = 1.0) -> None:
        if self.enabled:
            self.counter(name).inc(v)

    def set(self, name: str, v: float) -> None:
        if self.enabled:
            self.gauge(name).set(v)

    def set_max(self, name: str, v: float) -> None:
        if self.enabled:
            self.gauge(name).set_max(v)

    def observe(self, name: str, v: float,
                bounds: Optional[Sequence[float]] = None) -> None:
        if self.enabled:
            self.histogram(name, bounds).observe(v)

    # -------------------------------------------------------------- reading
    def get(self, name: str):
        return self._metrics.get(name)

    def snapshot(self) -> dict:
        """{"counters": {name: value}, "gauges": {...},
        "histograms": {name: summary}} — JSON-ready."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.summary()
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


# The process-global default registry every instrumented module reports to.
REGISTRY = Registry()


def registry() -> Registry:
    return REGISTRY


def publish(values: dict, prefix: str = "",
            reg: Optional[Registry] = None, kind: str = "gauge") -> dict:
    """Host half of the device-metrics pattern (module docstring): record a
    dict of scalars under ``prefix``.  Call it with values you have ALREADY
    host-synced (the step's existing ``float(v)`` point) — ``float()`` here
    is then free; on a still-on-device array it would itself be the
    transfer.  ``kind``: "gauge" (last value) or "histogram" (distribution).
    Returns the recorded {name: float} map."""
    reg = reg if reg is not None else REGISTRY
    if not reg.enabled:
        return {}
    rec = reg.observe if kind == "histogram" else reg.set
    out = {}
    for k, v in values.items():
        f = float(v)
        rec(prefix + k, f)
        out[prefix + k] = f
    return out
