"""Process-local metrics registry: counters, gauges, histograms (DESIGN §11),
with label sets and cross-process snapshot aggregation (DESIGN §12).

The serving and training paths both report through one ``Registry`` of named
metrics so the paper's systems claims (TTFT/TPOT, tokens/s, block-pool
pressure, router health) are measured in one place instead of scattered
ad-hoc dicts.  Three deliberate constraints shape the design:

  * **Pure-Python hot path** — recording is a dict lookup plus a float op;
    no numpy, no jax, no locks on the observe path.  The modules that
    instrument per-block allocator operations (``repro.serve.paged_kv``)
    and per-chunk scheduling (``repro.serve.scheduler``) call into this on
    every event, and the bench gate holds obs-enabled serving within 2% of
    obs-disabled (``BENCH_serve.json: obs_overhead``).
  * **Zero writes when disabled** — ``Registry.enabled = False`` makes
    every convenience call (``inc``/``set``/``observe``) return before
    touching any state, and the factory methods hand back a shared no-op
    metric that is never stored.  ``tests/test_obs.py`` asserts the
    snapshot stays empty.
  * **Fixed-bucket streaming quantiles** — histograms keep a bounded
    vector of bucket counts (no sample retention), and p50/p90/p99 are
    interpolated within the covering bucket, clamped to the observed
    min/max.  Memory is O(buckets) regardless of request count — the fix
    for the ``Scheduler.ttft`` dict that grew per request forever.

Device-metrics pattern (the jit half): values produced INSIDE jitted code
(train-step loss/grad-norm, in-step router health) must not force an extra
device→host transfer.  The pattern is: the jitted function returns them as
extra outputs (aux metrics riding the existing step outputs), the caller
host-syncs them where it already syncs (the ``float(v)`` conversion after
the step), and then calls ``publish`` with the resulting floats.
``publish`` itself only calls ``float()`` — on an already-fetched numpy
scalar that is free; on a device array it would BE the transfer, so keep
feeding it from the existing sync point (``repro.train.loop`` is the
reference user; parity under jit + donated buffers is tested).

Labels (DESIGN §12): every factory/convenience call takes ``**labels``
(``registry.counter("serve.finished", tenant="a")``) — each distinct label
set is its own series, keyed in the registry (and in snapshots) by the
Prometheus-style rendering ``name{k="v",...}`` with sorted keys and escaped
values.  The unlabeled hot path is untouched (labels arrive as an empty
kwargs dict), and a disabled registry hands back the same shared no-op for
labeled calls — zero writes either way.

Aggregation: ``merge_snapshots`` merges per-process ``Registry.snapshot()``
dicts (the JSONL lines ``export.write_metrics_jsonl`` appends) into one
view — counters and histogram buckets ADD; gauges merge per kind
(``set_max`` high-waters take the max, last-value gauges take the value
with the newest update stamp).  The merge is commutative and associative,
so N replica processes can each dump a snapshot and any aggregation order
yields the same result — parity vs one shared registry is property-tested
in ``tests/test_slo.py``.
"""

from __future__ import annotations

import itertools
import threading
import time
from typing import Dict, List, Optional, Sequence


def _geometric_bounds(lo: float, hi: float, per_decade: int = 4) -> tuple:
    """Geometric bucket bounds from ``lo`` to ``hi`` (inclusive-ish)."""
    import math
    n = int(math.ceil((math.log10(hi) - math.log10(lo)) * per_decade))
    return tuple(lo * (10.0 ** (i / per_decade)) for i in range(n + 1))


# Default bounds cover microseconds..minutes in seconds AND dimensionless
# ratios (packing efficiency, entropy in [0, 1]) with ~78%-wide buckets.
DEFAULT_BOUNDS = _geometric_bounds(1e-6, 1e3)

# Linear [0, 1] bounds for ratio-valued histograms (efficiency, drop rate,
# normalized entropy) where geometric spacing would waste resolution.
UNIT_BOUNDS = tuple(i / 20.0 for i in range(21))


def escape_label_value(v: str) -> str:
    """Prometheus exposition escaping for label values: backslash, double
    quote, and newline.  Shared by the registry's series keys and the text
    exporter so a snapshot key IS the rendered series name."""
    return str(v).replace("\\", "\\\\").replace('"', '\\"') \
        .replace("\n", "\\n")


def series_key(name: str, labels: Optional[dict]) -> str:
    """Registry/snapshot key of one series: the bare name, or
    ``name{k="v",...}`` with sorted keys — identical across processes, so
    ``merge_snapshots`` matches series by string equality."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{escape_label_value(v)}"'
                     for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


# Monotone per-process sequence for gauge update stamps: ``time.time()``
# orders updates across processes (coarsely — wall clock), the sequence
# breaks ties within one (the property test's single-process registries
# update faster than the clock ticks).
_STAMP_SEQ = itertools.count(1)


def _stamp() -> list:
    return [time.time(), next(_STAMP_SEQ)]


class Counter:
    """Monotone float counter."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Optional[dict] = None):
        self.name = name
        self.labels = labels or {}
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-value gauge; ``set_max`` keeps a high-water mark instead.

    Each update records a ``stamp`` ([wall time, process-monotone seq]) and
    the gauge's merge ``kind`` ("last" or "max") so ``merge_snapshots`` can
    combine per-process values commutatively: high-waters take the max,
    last-value gauges take the newest stamp's value."""

    __slots__ = ("name", "labels", "value", "stamp", "kind")

    def __init__(self, name: str, labels: Optional[dict] = None):
        self.name = name
        self.labels = labels or {}
        self.value = 0.0
        self.stamp = [0.0, 0]
        self.kind = "last"

    def set(self, v: float) -> None:
        self.value = v
        self.stamp = _stamp()

    def set_max(self, v: float) -> None:
        self.kind = "max"
        if v > self.value:
            self.value = v
            self.stamp = _stamp()


class Histogram:
    """Fixed-bucket streaming histogram with interpolated quantiles.

    ``bounds`` are the bucket upper edges; values land in the first bucket
    whose edge is >= v, with one overflow bucket past the last edge.
    ``quantile(q)`` walks the cumulative counts to the covering bucket and
    interpolates linearly inside it, clamping to the observed min/max — so
    a single observation reports itself exactly and bucket-width error is
    bounded by the bucket, never by the sample count.
    """

    __slots__ = ("name", "labels", "bounds", "counts", "count", "sum",
                 "min", "max")

    def __init__(self, name: str, bounds: Optional[Sequence[float]] = None,
                 labels: Optional[dict] = None):
        self.name = name
        self.labels = labels or {}
        self.bounds = tuple(bounds) if bounds is not None else DEFAULT_BOUNDS
        assert all(a < b for a, b in zip(self.bounds, self.bounds[1:])), (
            "histogram bounds must be strictly increasing")
        self.counts = [0] * (len(self.bounds) + 1)   # +1 overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        lo, hi = 0, len(self.bounds)                 # bisect, inlined: the
        while lo < hi:                               # hot path stays free of
            mid = (lo + hi) // 2                     # module lookups
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                b_lo = self.bounds[i - 1] if i > 0 else self.min
                b_hi = self.bounds[i] if i < len(self.bounds) else self.max
                b_lo = max(b_lo, self.min)
                b_hi = min(b_hi, self.max)
                if b_hi <= b_lo:
                    return b_lo
                frac = (target - cum) / c
                return b_lo + frac * (b_hi - b_lo)
            cum += c
        return self.max

    def percentiles(self) -> Dict[str, float]:
        return {"p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}

    def summary(self) -> dict:
        """JSON summary.  Carries the raw ``bounds``/``counts`` vectors —
        not just the interpolated quantiles — so snapshots from different
        processes can be bucket-added by ``merge_snapshots`` and the merged
        quantiles recomputed exactly as a shared registry would report
        them."""
        out = {"count": self.count, "sum": self.sum,
               "bounds": list(self.bounds), "counts": list(self.counts)}
        if self.count:
            out.update(min=self.min, max=self.max,
                       mean=self.sum / self.count, **self.percentiles())
        return out


class _Null:
    """Shared no-op metric handed out by a disabled registry (never stored)."""

    name = "<disabled>"
    labels: dict = {}
    value = 0.0

    def inc(self, v: float = 1.0) -> None: pass
    def set(self, v: float) -> None: pass
    def set_max(self, v: float) -> None: pass
    def observe(self, v: float) -> None: pass
    def quantile(self, q: float) -> float: return 0.0
    def percentiles(self) -> dict: return {}
    def summary(self) -> dict: return {}


_NULL = _Null()


class _Timer:
    """``Registry.timer`` scope: measures always, records iff the registry
    handed it a live histogram."""

    __slots__ = ("hist", "t0", "dt")

    def __init__(self, hist):
        self.hist = hist
        self.t0 = 0.0
        self.dt = 0.0

    def __enter__(self) -> "_Timer":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.dt = time.perf_counter() - self.t0
        if self.hist is not None:
            self.hist.observe(self.dt)


class Registry:
    """Name -> metric map with a fast-exit ``enabled`` switch.

    Creation is lock-guarded (instrumented code may run under the data
    pipeline's prefetch thread); the record path is a plain attribute
    update, safe under the GIL for the float ops used here.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------- factories
    def _get(self, name: str, cls, labels: dict, *args):
        if not self.enabled:
            return _NULL
        key = series_key(name, labels) if labels else name
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, *args, labels=labels)
                    self._metrics[key] = m
        assert isinstance(m, cls), (
            f"metric {key!r} already registered as {type(m).__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, Counter, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, Gauge, labels)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self._get(name, Histogram, labels, bounds)

    # ---------------------------------------------------------- convenience
    def inc(self, name: str, v: float = 1.0, **labels) -> None:
        if self.enabled:
            self.counter(name, **labels).inc(v)

    def set(self, name: str, v: float, **labels) -> None:
        if self.enabled:
            self.gauge(name, **labels).set(v)

    def set_max(self, name: str, v: float, **labels) -> None:
        if self.enabled:
            self.gauge(name, **labels).set_max(v)

    def observe(self, name: str, v: float,
                bounds: Optional[Sequence[float]] = None, **labels) -> None:
        if self.enabled:
            self.histogram(name, bounds, **labels).observe(v)

    def timer(self, name: str, bounds: Optional[Sequence[float]] = None,
              **labels) -> "_Timer":
        """Context manager that observes its scope's elapsed seconds into
        histogram ``name`` and exposes the measurement as ``.dt`` — the
        replacement for hand-rolled ``t0 = time.monotonic()`` pairs.  The
        clock always runs (callers use ``.dt`` for throughput math and
        straggler detection even with obs off); only the histogram write is
        gated on ``enabled`` — the write-free-when-disabled invariant."""
        return _Timer(self.histogram(name, bounds, **labels)
                      if self.enabled else None)

    # -------------------------------------------------------------- reading
    def get(self, name: str, **labels):
        return self._metrics.get(series_key(name, labels))

    def snapshot(self) -> dict:
        """{"counters": {key: value}, "gauges": {...}, "gauges_meta": {...},
        "histograms": {key: summary}} — JSON-ready.  Keys are series keys
        (``series_key``: bare names, or ``name{k="v"}`` for labeled
        series).  ``gauges_meta`` carries each gauge's merge kind and
        update stamp for ``merge_snapshots``."""
        out: dict = {"counters": {}, "gauges": {}, "gauges_meta": {},
                     "histograms": {}}
        for key, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][key] = m.value
                out["gauges_meta"][key] = {"kind": m.kind,
                                           "stamp": list(m.stamp)}
            else:
                out["histograms"][key] = m.summary()
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


# The process-global default registry every instrumented module reports to.
REGISTRY = Registry()


def registry() -> Registry:
    return REGISTRY


def publish(values: dict, prefix: str = "",
            reg: Optional[Registry] = None, kind: str = "gauge") -> dict:
    """Host half of the device-metrics pattern (module docstring): record a
    dict of scalars under ``prefix``.  Call it with values you have ALREADY
    host-synced (the step's existing ``float(v)`` point) — ``float()`` here
    is then free; on a still-on-device array it would itself be the
    transfer.  ``kind``: "gauge" (last value) or "histogram" (distribution).
    Returns the recorded {name: float} map."""
    reg = reg if reg is not None else REGISTRY
    if not reg.enabled:
        return {}
    rec = reg.observe if kind == "histogram" else reg.set
    out = {}
    for k, v in values.items():
        f = float(v)
        rec(prefix + k, f)
        out[prefix + k] = f
    return out


# ------------------------------------------------- cross-process aggregation
def _merged_quantile(bounds: List[float], counts: List[int], count: int,
                     vmin: float, vmax: float, q: float) -> float:
    """``Histogram.quantile`` over merged bucket vectors (same math,
    operating on snapshot data instead of a live metric)."""
    if count == 0:
        return 0.0
    target = q * count
    cum = 0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if cum + c >= target:
            b_lo = bounds[i - 1] if i > 0 else vmin
            b_hi = bounds[i] if i < len(bounds) else vmax
            b_lo = max(b_lo, vmin)
            b_hi = min(b_hi, vmax)
            if b_hi <= b_lo:
                return b_lo
            frac = (target - cum) / c
            return b_lo + frac * (b_hi - b_lo)
        cum += c
    return vmax


def merge_snapshots(snaps: Sequence[dict]) -> dict:
    """Commutative merge of ``Registry.snapshot()`` dicts from N processes
    into one aggregate view (DESIGN §12) — the per-replica JSONL lines of
    ``export.write_metrics_jsonl`` are exactly this shape.

    Per series (matched by snapshot key, labels included): counters SUM;
    histograms bucket-add (bounds must agree — the same code registered
    them) with count/sum added, min/max combined, and quantiles recomputed
    from the merged buckets; gauges merge per recorded kind — ``max``
    (high-waters) take the max value, ``last`` take the value carrying the
    newest update stamp (ties break toward the larger value, keeping the
    merge order-independent).  Associative and commutative: any merge
    order over any grouping yields the same result, which is what lets a
    tree of per-replica aggregators exist."""
    out: dict = {"counters": {}, "gauges": {}, "gauges_meta": {},
                 "histograms": {}}
    for snap in snaps:
        for k, v in snap.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0.0) + v
        meta = snap.get("gauges_meta", {})
        for k, v in snap.get("gauges", {}).items():
            m = meta.get(k, {"kind": "last", "stamp": [0.0, 0]})
            if k not in out["gauges"]:
                out["gauges"][k] = v
                out["gauges_meta"][k] = {"kind": m["kind"],
                                         "stamp": list(m["stamp"])}
                continue
            have = out["gauges_meta"][k]
            if m["kind"] == "max":
                have["kind"] = "max"
            if have["kind"] == "max":
                if v > out["gauges"][k]:
                    out["gauges"][k] = v
                    have["stamp"] = list(m["stamp"])
            else:
                key_new = (list(m["stamp"]), v)
                key_old = (list(have["stamp"]), out["gauges"][k])
                if key_new > key_old:
                    out["gauges"][k] = v
                    have["stamp"] = list(m["stamp"])
        for k, h in snap.get("histograms", {}).items():
            if k not in out["histograms"]:
                out["histograms"][k] = {
                    "count": h["count"], "sum": h["sum"],
                    "bounds": list(h["bounds"]),
                    "counts": list(h["counts"]),
                    "min": h.get("min", float("inf")),
                    "max": h.get("max", float("-inf"))}
                continue
            a = out["histograms"][k]
            assert a["bounds"] == list(h["bounds"]), (
                f"histogram {k!r}: differing bucket bounds across "
                "snapshots cannot be merged")
            a["counts"] = [x + y for x, y in zip(a["counts"], h["counts"])]
            a["count"] += h["count"]
            a["sum"] += h["sum"]
            a["min"] = min(a["min"], h.get("min", float("inf")))
            a["max"] = max(a["max"], h.get("max", float("-inf")))
    for k, a in out["histograms"].items():
        if a["count"]:
            a["mean"] = a["sum"] / a["count"]
            for nm, q in (("p50", 0.50), ("p90", 0.90), ("p99", 0.99)):
                a[nm] = _merged_quantile(a["bounds"], a["counts"],
                                         a["count"], a["min"], a["max"], q)
        else:
            a.pop("min", None)
            a.pop("max", None)
    return out
