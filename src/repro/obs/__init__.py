"""repro.obs — unified observability: metrics, tracing, exporters, SLOs
(DESIGN §11–12).

Pure-Python, jax-free at import time (jax is only touched inside the
optional profiler passthrough), so any module — including repro.core,
which must never pull Pallas — can import it.

    from repro import obs
    obs.registry().observe("serve.ttft_s", dt)
    obs.registry().inc("serve.finished", tenant="a")   # labeled series
    with obs.registry().timer("train.step_time_s") as t:
        ...
    with obs.tracer().span("prefill_chunk", track="sched", segs=3):
        ...
    obs.dump(metrics_path="m.jsonl", trace_path="trace.json")
    obs.merge_snapshot_files(["r0.jsonl", "r1.jsonl"])  # N replicas -> 1
    obs.set_enabled(False)      # all of the above become no-ops
"""

from repro.obs.export import (dump, merge_snapshot_files, prometheus_text,
                              read_last_snapshot, write_metrics_json,
                              write_metrics_jsonl, write_prometheus)
from repro.obs.metrics import (DEFAULT_BOUNDS, UNIT_BOUNDS, Counter, Gauge,
                               Histogram, Registry, escape_label_value,
                               merge_snapshots, publish, registry,
                               series_key)
from repro.obs.slo import SLOSpec, evaluate, records_from_spans
from repro.obs.tracing import (Span, Tracer, start_profiler, stop_profiler,
                               tracer)


def set_enabled(flag: bool) -> None:
    """Toggle the global registry AND tracer in one call — the single
    switch Scheduler/Trainer/bench obs flags map onto."""
    registry().enabled = flag
    tracer().enabled = flag


def enabled() -> bool:
    return registry().enabled


__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "SLOSpec", "Span",
    "Tracer", "DEFAULT_BOUNDS", "UNIT_BOUNDS",
    "dump", "enabled", "escape_label_value", "evaluate",
    "merge_snapshot_files", "merge_snapshots", "prometheus_text", "publish",
    "read_last_snapshot", "records_from_spans", "registry", "series_key",
    "set_enabled", "start_profiler", "stop_profiler", "tracer",
    "write_metrics_json", "write_metrics_jsonl", "write_prometheus",
]
