"""repro.obs — unified observability: metrics, tracing, exporters
(DESIGN §11).

Pure-Python, jax-free at import time (jax is only touched inside the
optional profiler passthrough), so any module — including repro.core,
which must never pull Pallas — can import it.

    from repro import obs
    obs.registry().observe("serve.ttft_s", dt)
    with obs.tracer().span("prefill_chunk", track="sched", segs=3):
        ...
    obs.dump(metrics_path="m.jsonl", trace_path="trace.json")
    obs.set_enabled(False)      # all of the above become no-ops
"""

from repro.obs.export import (dump, prometheus_text, write_metrics_json,
                              write_metrics_jsonl, write_prometheus)
from repro.obs.metrics import (DEFAULT_BOUNDS, UNIT_BOUNDS, Counter, Gauge,
                               Histogram, Registry, publish, registry)
from repro.obs.tracing import (Span, Tracer, start_profiler, stop_profiler,
                               tracer)


def set_enabled(flag: bool) -> None:
    """Toggle the global registry AND tracer in one call — the single
    switch Scheduler/Trainer/bench obs flags map onto."""
    registry().enabled = flag
    tracer().enabled = flag


def enabled() -> bool:
    return registry().enabled


__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "Span", "Tracer",
    "DEFAULT_BOUNDS", "UNIT_BOUNDS",
    "dump", "enabled", "prometheus_text", "publish", "registry",
    "set_enabled", "start_profiler", "stop_profiler", "tracer",
    "write_metrics_json", "write_metrics_jsonl", "write_prometheus",
]
