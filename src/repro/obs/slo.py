"""SLO specs and goodput accounting over per-request records (DESIGN §12).

Throughput under overload is a vanity metric: a saturated server can post
high tokens/s while every request blows its latency budget in the queue.
The number the paper's serving claims should be judged by — and the one
the multi-host tier (ROADMAP item 3) will be gated on — is **goodput**:
the fraction of offered requests that finish AND meet every SLO
(TTFT ≤ x, TPOT ≤ y).  A scheduler that sheds or preempts excess load
keeps goodput near capacity through overload; one that admits everything
collapses TTFT for all requests at once, and goodput falls off a cliff.
``benchmarks/serve_bench.py``'s ``slo_family`` sweeps arrival rate through
saturation and gates on exactly this shape.

The unit of account is a per-request **record** dict::

    {"rid": int, "tenant": str, "outcome": "finished" | "shed",
     "t_arrival": float, "queue_delay_s": float,
     "ttft_s": float | None, "tpot_s": float | None, "new_tokens": int}

Two independent producers emit the same schema, and parity between them is
tested (``tests/test_slo.py``):

  * ``Scheduler.records`` — written live at finish/shed time (bounded);
  * ``records_from_spans(tracer.spans())`` — reconstructed offline from
    the span lifecycle (queued → prefill → decode → finish), so a
    Chrome-trace artifact alone is enough to recompute goodput after the
    fact.

Semantics: TTFT is **arrival-based** (first token minus ``submit()``
time — queue wait included, surviving preemption), because under load the
queue IS the latency.  A shed request counts against goodput (it was
offered and not served within SLO) but not against ``served_goodput``
(quality of service for admitted work — the "degrade gracefully" half of
the overload gate).  Single-token requests carry no TPOT obligation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Declarative SLO: a request meets it iff it finished, its TTFT is
    within ``ttft_s``, and (when ``tpot_s`` is set and the request decoded
    ≥ 2 tokens) its per-token decode latency is within ``tpot_s``."""

    ttft_s: float
    tpot_s: Optional[float] = None
    name: str = "slo"

    def met(self, rec: dict) -> bool:
        if rec.get("outcome") != "finished":
            return False
        ttft = rec.get("ttft_s")
        if ttft is None or ttft > self.ttft_s:
            return False
        if self.tpot_s is not None:
            tpot = rec.get("tpot_s")
            if tpot is not None and tpot > self.tpot_s:
                return False
        return True

    def as_dict(self) -> dict:
        return {"name": self.name, "ttft_s": self.ttft_s,
                "tpot_s": self.tpot_s}


def _pct(xs: List[float], q: float) -> float:
    """Linear-interpolated percentile of a non-empty sorted list."""
    if len(xs) == 1:
        return xs[0]
    pos = q * (len(xs) - 1)
    i = int(pos)
    frac = pos - i
    if i + 1 >= len(xs):
        return xs[-1]
    return xs[i] + frac * (xs[i + 1] - xs[i])


def _latency_summary(values: List[float]) -> dict:
    xs = sorted(v for v in values if v is not None)
    if not xs:
        return {"count": 0}
    return {"count": len(xs), "mean": sum(xs) / len(xs),
            "p50": _pct(xs, 0.50), "p90": _pct(xs, 0.90),
            "p99": _pct(xs, 0.99), "max": xs[-1]}


def _bucket_stats(records: Sequence[dict], spec: SLOSpec) -> dict:
    finished = [r for r in records if r.get("outcome") == "finished"]
    shed = [r for r in records if r.get("outcome") == "shed"]
    met = sum(1 for r in records if spec.met(r))
    total = len(records)
    return {
        "total": total,
        "finished": len(finished),
        "shed": len(shed),
        "slo_met": met,
        "goodput": met / total if total else 0.0,
        "served_goodput": met / len(finished) if finished else 0.0,
        "ttft": _latency_summary([r.get("ttft_s") for r in finished]),
        "tpot": _latency_summary([r.get("tpot_s") for r in finished]),
        "queue_delay": _latency_summary(
            [r.get("queue_delay_s") for r in finished]),
        "new_tokens": sum(r.get("new_tokens", 0) for r in finished),
    }


def evaluate(records: Sequence[dict], spec: SLOSpec) -> dict:
    """Goodput + latency summary of ``records`` against ``spec``, with a
    per-tenant breakdown (records with an empty tenant group under "")."""
    out = _bucket_stats(records, spec)
    out["spec"] = spec.as_dict()
    tenants: Dict[str, list] = {}
    for r in records:
        tenants.setdefault(r.get("tenant", ""), []).append(r)
    if len(tenants) > 1 or (tenants and "" not in tenants):
        out["per_tenant"] = {t: _bucket_stats(rs, spec)
                             for t, rs in sorted(tenants.items())}
    return out


def records_from_spans(spans) -> List[dict]:
    """Reconstruct per-request records from tracer spans — the offline twin
    of ``Scheduler.records`` (same schema, parity-tested bit-exact on fully
    drained runs).

    Per ``req<rid>`` track: the earliest "queued" span's start is the
    arrival, the last one's duration the (final) queue delay; TTFT is the
    end of the last non-preempted "prefill" minus arrival; TPOT is the
    "decode" span's duration over its ``tokens - 1`` inter-token gaps;
    outcome comes from the "finish"/"shed" instant (requests that left no
    terminal instant — still queued or in flight when the trace was cut —
    report ``outcome="incomplete"``)."""
    tracks: Dict[int, list] = {}
    for s in spans:
        if s.track.startswith("req"):
            try:
                rid = int(s.track[3:])
            except ValueError:
                continue
            tracks.setdefault(rid, []).append(s)
    records = []
    for rid in sorted(tracks):
        ss = tracks[rid]
        queued = [s for s in ss if s.name == "queued"]
        # TTFT comes off the prefill that produced the FIRST token: skip
        # preempted partials and post-preemption re-prefills (resumed).
        prefills = [s for s in ss if s.name == "prefill"
                    and not s.args.get("preempted")
                    and not s.args.get("resumed")]
        decodes = [s for s in ss if s.name == "decode"
                   and not s.args.get("preempted")]
        finish = next((s for s in ss if s.name == "finish"), None)
        shed = next((s for s in ss if s.name == "shed"), None)
        term = finish or shed
        rec = {"rid": rid,
               "tenant": term.args.get("tenant", "") if term else "",
               "outcome": ("finished" if finish is not None
                           else "shed" if shed is not None
                           else "incomplete"),
               "t_arrival": (min(s.t0 for s in queued) if queued
                             else shed.t0 if shed else 0.0),
               "queue_delay_s": queued[-1].dur if queued else 0.0,
               "ttft_s": None, "tpot_s": None,
               "new_tokens": finish.args.get("tokens", 0) if finish else 0}
        if finish is not None and prefills:
            p = prefills[-1]
            rec["ttft_s"] = (p.t0 + p.dur) - rec["t_arrival"]
        if finish is not None and decodes:
            d = decodes[-1]
            toks = d.args.get("tokens", 0)
            if toks >= 2:
                rec["tpot_s"] = d.dur / (toks - 1)
        records.append(rec)
    return records
