"""Span tracing with ring-buffer retention and Chrome-trace export
(DESIGN §11).

A ``Span`` is one named interval on one named ``track`` — per-request
tracks ("req3") give every request its own row in ``chrome://tracing`` /
Perfetto, so the admission→chunked-prefill→decode→finish lifecycle reads
left-to-right per request while scheduler-wide work ("sched") stacks on
its own row.

Two recording styles, because the Scheduler interleaves requests:

  * ``with tracer.span("prefill_chunk", track="sched", segs=3):`` — for
    code where the interval IS a lexical scope;
  * ``tracer.add("prefill", t0, t1, track="req3", ...)`` — explicit
    timestamps for phases that open in one scheduler iteration and close
    many iterations later (a request's prefill spans multiple chunks
    while other requests decode in between).  ``tracer.now()`` supplies
    the monotonic, tracer-epoch-relative clock for saved timestamps.

Retention is a bounded deque (default 65536 spans): tracing a long serve
run costs O(ring) memory and the newest spans win, matching the metrics
module's O(buckets) stance.  Exporters: ``chrome_trace()`` → the Trace
Event Format dict (ph:"X" complete events, µs), ``export_jsonl()`` → one
span per line for ad-hoc grepping.

``jax.profiler`` passthrough: setting ``tracer.annotate = True`` wraps
every ``span()`` scope in ``jax.profiler.TraceAnnotation`` so host-side
spans land on the device timeline too, and ``start_profiler(logdir)`` /
``stop_profiler()`` bracket a run with ``jax.profiler.start_trace`` when
the profiler is importable (silently skipped otherwise — CPU smoke images
stay dependency-free).
"""

from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import NamedTuple, Optional


class Span(NamedTuple):
    name: str
    t0: float          # seconds since tracer epoch
    dur: float         # seconds
    track: str
    args: dict


class Tracer:
    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.enabled = enabled
        self.annotate = False      # jax.profiler.TraceAnnotation passthrough
        self._epoch = time.perf_counter()
        self._spans: deque = deque(maxlen=capacity)
        self._dropped = 0          # spans overwritten by the ring (§12)

    def _push(self, span: "Span") -> None:
        """Ring append with drop accounting: a full deque silently evicts
        its oldest span, which used to be invisible — exporters now surface
        the count (``dropped_spans``) so a truncated trace is never
        mistaken for a complete one."""
        if len(self._spans) == self._spans.maxlen:
            self._dropped += 1
        self._spans.append(span)

    @property
    def dropped_spans(self) -> int:
        return self._dropped

    # ------------------------------------------------------------ recording
    def now(self) -> float:
        """Monotonic seconds since tracer epoch (feed back into ``add``)."""
        return time.perf_counter() - self._epoch

    @contextmanager
    def span(self, name: str, track: str = "main", **args):
        if not self.enabled:
            yield
            return
        ann = self._annotation(name)
        if ann is not None:
            ann.__enter__()
        t0 = self.now()
        try:
            yield
        finally:
            self._push(Span(name, t0, self.now() - t0, track, args))
            if ann is not None:
                ann.__exit__(None, None, None)

    def add(self, name: str, t0: float, t1: float,
            track: str = "main", **args) -> None:
        """Record a completed interval from saved ``now()`` timestamps."""
        if self.enabled:
            self._push(Span(name, t0, max(t1 - t0, 0.0), track, args))

    def instant(self, name: str, track: str = "main", **args) -> None:
        """Zero-duration marker (finish, preempt, evict...)."""
        if self.enabled:
            self._push(Span(name, self.now(), 0.0, track, args))

    def _annotation(self, name: str):
        if not self.annotate:
            return None
        try:
            from jax.profiler import TraceAnnotation
            return TraceAnnotation(name)
        except Exception:
            return None

    # ------------------------------------------------------------- reading
    def spans(self) -> list:
        return list(self._spans)

    def __len__(self) -> int:
        return len(self._spans)

    def reset(self) -> None:
        self._spans.clear()
        self._dropped = 0
        self._epoch = time.perf_counter()

    # ------------------------------------------------------------ exporters
    def chrome_trace(self) -> dict:
        """Trace Event Format: one pid, one tid per track, ph:"X" events in
        µs.  Load via chrome://tracing or https://ui.perfetto.dev."""
        tids: dict = {}
        events = []
        for s in self._spans:
            tid = tids.setdefault(s.track, len(tids))
            ev = {"name": s.name, "ph": "X", "pid": 0, "tid": tid,
                  "ts": round(s.t0 * 1e6, 3), "dur": round(s.dur * 1e6, 3)}
            if s.args:
                ev["args"] = s.args
            events.append(ev)
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                 "args": {"name": track}} for track, tid in tids.items()]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"dropped_spans": self._dropped}}

    def export_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
            f.write("\n")

    def export_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for s in self._spans:
                f.write(json.dumps({"name": s.name, "t0": round(s.t0, 6),
                                    "dur": round(s.dur, 6),
                                    "track": s.track, **s.args}) + "\n")


# Process-global default tracer, mirroring metrics.REGISTRY.
TRACER = Tracer()


def tracer() -> Tracer:
    return TRACER


def start_profiler(logdir: str, annotate: bool = True) -> bool:
    """Begin a ``jax.profiler`` device trace into ``logdir`` (TensorBoard
    format) and turn on span annotation.  Returns False (no-op) when the
    profiler is unavailable."""
    try:
        import jax.profiler
        jax.profiler.start_trace(logdir)
    except Exception:
        return False
    TRACER.annotate = annotate
    return True


def stop_profiler() -> bool:
    TRACER.annotate = False
    try:
        import jax.profiler
        jax.profiler.stop_trace()
    except Exception:
        return False
    return True
