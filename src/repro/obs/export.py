"""Exporters: metrics snapshots to JSON/JSONL and Prometheus text, plus a
one-call ``dump()`` used by Scheduler/Trainer shutdown paths (DESIGN §11).

Formats:
  * JSON / JSONL — ``Registry.snapshot()`` verbatim; the JSONL writer
    APPENDS one snapshot object per call so a long run leaves a time
    series (each line stamped with wall time and an optional caller tag).
  * Prometheus exposition text — counters as ``# TYPE c counter``, gauges
    as gauges, histograms as the conventional ``_bucket{le=...}`` /
    ``_sum`` / ``_count`` triplet with cumulative bucket counts, so the
    artifact can be diffed against any promtool-era tooling.  Metric
    names sanitize ``.``/``-`` to ``_`` (dots namespace the registry,
    underscores namespace Prometheus).
"""

from __future__ import annotations

import json
import time
from typing import Optional

from repro.obs.metrics import Counter, Gauge, Registry, registry
from repro.obs.tracing import Tracer, tracer


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def prometheus_text(reg: Optional[Registry] = None) -> str:
    """Render the registry in Prometheus exposition format."""
    reg = reg if reg is not None else registry()
    lines = []
    for name, m in sorted(reg._metrics.items()):
        pn = _prom_name(name)
        if isinstance(m, Counter):
            lines += [f"# TYPE {pn} counter", f"{pn} {m.value:g}"]
        elif isinstance(m, Gauge):
            lines += [f"# TYPE {pn} gauge", f"{pn} {m.value:g}"]
        else:                                   # Histogram
            lines.append(f"# TYPE {pn} histogram")
            cum = 0
            for edge, c in zip(m.bounds, m.counts):
                cum += c
                lines.append(f'{pn}_bucket{{le="{edge:g}"}} {cum}')
            lines.append(f'{pn}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{pn}_sum {m.sum:g}")
            lines.append(f"{pn}_count {m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(path: str, reg: Optional[Registry] = None) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(reg))


def write_metrics_json(path: str, reg: Optional[Registry] = None,
                       extra: Optional[dict] = None) -> None:
    reg = reg if reg is not None else registry()
    snap = reg.snapshot()
    if extra:
        snap["extra"] = dict(extra)
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")


def write_metrics_jsonl(path: str, reg: Optional[Registry] = None,
                        tag: str = "", extra: Optional[dict] = None) -> None:
    """Append one snapshot line — repeated calls build a time series."""
    reg = reg if reg is not None else registry()
    line = {"time": round(time.time(), 3), **reg.snapshot()}
    if tag:
        line["tag"] = tag
    if extra:
        line["extra"] = dict(extra)
    with open(path, "a") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")


def dump(metrics_path: Optional[str] = None,
         trace_path: Optional[str] = None,
         prom_path: Optional[str] = None,
         reg: Optional[Registry] = None,
         tr: Optional[Tracer] = None,
         tag: str = "") -> None:
    """Write whichever artifacts were configured.  ``metrics_path`` ending
    in ``.jsonl`` appends a snapshot line (time series); any other suffix
    overwrites with a pretty JSON snapshot.  ``trace_path`` gets the
    Chrome-trace JSON."""
    if metrics_path:
        if metrics_path.endswith(".jsonl"):
            write_metrics_jsonl(metrics_path, reg, tag=tag)
        else:
            write_metrics_json(metrics_path, reg)
    if trace_path:
        (tr if tr is not None else tracer()).export_chrome(trace_path)
    if prom_path:
        write_prometheus(prom_path, reg)
