"""Exporters: metrics snapshots to JSON/JSONL and Prometheus text, plus a
one-call ``dump()`` used by Scheduler/Trainer shutdown paths (DESIGN §11),
and the JSONL-side half of cross-process aggregation (DESIGN §12).

Formats:
  * JSON / JSONL — ``Registry.snapshot()`` verbatim; the JSONL writer
    APPENDS one snapshot object per call so a long run leaves a time
    series (each line stamped with wall time and an optional caller tag).
    ``read_last_snapshot`` / ``merge_snapshot_files`` are the read side:
    each replica process dumps its own JSONL, the aggregator reads the
    last line of each and folds them through
    ``metrics.merge_snapshots`` into one view.
  * Prometheus exposition text — one ``# HELP``/``# TYPE`` header per
    metric FAMILY (bare dotted name) followed by every series in that
    family with its label set rendered (values escaped per the
    exposition format); histograms emit the conventional
    ``_bucket{le=...}`` / ``_sum`` / ``_count`` triplet with cumulative
    bucket counts and labels merged into the bucket line.  Metric names
    sanitize ``.``/``-`` to ``_`` (dots namespace the registry,
    underscores namespace Prometheus).
"""

from __future__ import annotations

import json
import time
from typing import List, Optional

from repro.obs.metrics import (Counter, Gauge, Registry, escape_label_value,
                               merge_snapshots, registry)
from repro.obs.tracing import Tracer, tracer


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _prom_labels(labels: dict, extra: Optional[dict] = None) -> str:
    """Render a label set as ``{k="v",...}`` (sorted keys, escaped values);
    empty string for no labels.  ``extra`` merges in exporter-owned labels
    like a histogram bucket's ``le``."""
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(f'{k}="{escape_label_value(v)}"'
                     for k, v in sorted(merged.items()))
    return "{" + inner + "}"


def prometheus_text(reg: Optional[Registry] = None) -> str:
    """Render the registry in Prometheus exposition format.

    Series are grouped into families by bare metric name — labeled series
    of one family share a single ``# HELP``/``# TYPE`` header, per the
    format.  HELP carries the dotted registry name so the original
    namespacing survives the ``.`` → ``_`` sanitization."""
    reg = reg if reg is not None else registry()
    families: dict = {}
    for _, m in sorted(reg._metrics.items()):
        families.setdefault(m.name, []).append(m)
    lines: List[str] = []
    for name in sorted(families):
        pn = _prom_name(name)
        series = families[name]
        kind = ("counter" if isinstance(series[0], Counter)
                else "gauge" if isinstance(series[0], Gauge)
                else "histogram")
        lines.append(f"# HELP {pn} {name}")
        lines.append(f"# TYPE {pn} {kind}")
        for m in series:
            lab = _prom_labels(m.labels)
            if kind in ("counter", "gauge"):
                lines.append(f"{pn}{lab} {m.value:g}")
            else:                               # Histogram
                cum = 0
                for edge, c in zip(m.bounds, m.counts):
                    cum += c
                    ble = _prom_labels(m.labels, {"le": f"{edge:g}"})
                    lines.append(f"{pn}_bucket{ble} {cum}")
                binf = _prom_labels(m.labels, {"le": "+Inf"})
                lines.append(f"{pn}_bucket{binf} {m.count}")
                lines.append(f"{pn}_sum{lab} {m.sum:g}")
                lines.append(f"{pn}_count{lab} {m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(path: str, reg: Optional[Registry] = None) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(reg))


def write_metrics_json(path: str, reg: Optional[Registry] = None,
                       extra: Optional[dict] = None) -> None:
    reg = reg if reg is not None else registry()
    snap = reg.snapshot()
    if extra:
        snap["extra"] = dict(extra)
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")


def write_metrics_jsonl(path: str, reg: Optional[Registry] = None,
                        tag: str = "", extra: Optional[dict] = None) -> None:
    """Append one snapshot line — repeated calls build a time series."""
    reg = reg if reg is not None else registry()
    line = {"time": round(time.time(), 3), **reg.snapshot()}
    if tag:
        line["tag"] = tag
    if extra:
        line["extra"] = dict(extra)
    with open(path, "a") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")


def read_last_snapshot(path: str) -> dict:
    """Last snapshot line of a metrics JSONL file — a process's final state
    (every line is a full snapshot, so the last one supersedes the rest)."""
    last: Optional[dict] = None
    with open(path) as f:
        for raw in f:
            raw = raw.strip()
            if raw:
                last = json.loads(raw)
    if last is None:
        raise ValueError(f"no snapshot lines in {path!r}")
    return last


def merge_snapshot_files(paths) -> dict:
    """Aggregate N per-process metrics JSONL dumps into one snapshot view:
    read each file's last line, fold through ``merge_snapshots``.  This is
    the replica-aggregation entry point item 3's tier composes on."""
    return merge_snapshots([read_last_snapshot(p) for p in paths])


def dump(metrics_path: Optional[str] = None,
         trace_path: Optional[str] = None,
         prom_path: Optional[str] = None,
         reg: Optional[Registry] = None,
         tr: Optional[Tracer] = None,
         tag: str = "") -> None:
    """Write whichever artifacts were configured.  ``metrics_path`` ending
    in ``.jsonl`` appends a snapshot line (time series); any other suffix
    overwrites with a pretty JSON snapshot.  ``trace_path`` gets the
    Chrome-trace JSON.  The tracer's ring-drop count is published as the
    ``tracer.dropped_spans`` gauge first, so every artifact records whether
    the trace it sits next to is complete."""
    reg = reg if reg is not None else registry()
    t = tr if tr is not None else tracer()
    if reg.enabled and t.dropped_spans:
        reg.set("tracer.dropped_spans", float(t.dropped_spans))
    if metrics_path:
        if metrics_path.endswith(".jsonl"):
            write_metrics_jsonl(metrics_path, reg, tag=tag)
        else:
            write_metrics_json(metrics_path, reg)
    if trace_path:
        t.export_chrome(trace_path)
    if prom_path:
        write_prometheus(prom_path, reg)
