"""Pallas kernels for BLOCK-CHOICE MoSA attention (DESIGN §10).

Token-choice MoSA (``mosa_attention.py``) carries one index per selected
TOKEN, so the kernel's address stream is a scattered S-wide gather.  The
block-choice variant selects contiguous KV blocks of ``sel_block_size``
tokens (sized to the paged ``BlockPool`` block), so the kernels here take
  * ``bidx``: (B, H, NB) int32 — one index per selected BLOCK (-1 = empty),
  * ``rblk``: (B, H, NB) fp32 — one router score per block,
and expand them to per-token positions IN-KERNEL (``pos = bidx*bs + off``).
The index traffic shrinks by ``bs`` and the layer-side gather that fills
q/k/v reads ``bs`` consecutive rows per index — the same memory motion as
``serve/paged_attention.py``'s block-table DMA, instead of token gathering.

Everything else — tiling, streaming-softmax order, mask structure, the
residual (``o_pre``/``lse``) layout and the recompute-style backward — is
kept OPERATION-FOR-OPERATION identical to the token kernels, because the
maintained invariant (tests/test_block_choice.py) is that
``sel_block_size=1`` reproduces token-choice BIT-EXACTLY: at bs=1 the
expansion is the identity, the pair masks take the same boolean values for
every surviving lane, and the float sequence is unchanged.

Validity: a block slot is empty (``bidx < 0``, padding) or ragged (its tail
positions ``>= T`` when ``bs`` does not divide the true length T).  Invalid
KEYS are masked like token padding; invalid QUERY rows are zeroed in the
outputs (and their cotangent is zeroed by the VJP wrapper) so the layer's
clamped gather never leaks gradient into the clamp target.

The ``custom_vjp`` mirrors ``mosa_vjp.py`` but its router cotangent is
PER-BLOCK: the wrapper computes the per-token ``dr`` and sums it over each
block (``dr_blk``), which the layer's mean-pool (``block_pool_scores``)
then distributes back onto token scores — expert choice over blocks stays
learnable end-to-end.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _expand_blocks(bidx_blk, bs: int, T: int):
    """(nb,) block indices -> ((nb*bs,) positions, (nb*bs,) validity)."""
    nb = bidx_blk.shape[0]
    off = jax.lax.broadcasted_iota(jnp.int32, (nb, bs), 1)
    pos = bidx_blk[:, None] * bs + off
    ok = (bidx_blk[:, None] >= 0) & (pos < T)
    return pos.reshape(nb * bs), ok.reshape(nb * bs)


def _block_pair_mask(pos_q, pos_k, ok_k, seg_q, seg_k):
    """Causal-by-original-position AND same-segment AND valid-key mask.

    Identical truth table to ``mosa_attention._pair_mask`` on real lanes:
    token padding there carries idx=+INT_MAX (killed by causality), block
    padding here carries bidx=-1 (killed by ``ok_k``)."""
    return ((seg_q[:, None] == seg_k[None, :])
            & (pos_q[:, None] >= pos_k[None, :])
            & ok_k[None, :])


def _mosa_block_kernel(bidx_ref, seg_ref, rblk_ref, q_ref, k_ref, v_ref,
                       o_ref, *, block_k: int, scale: float, bs: int, T: int):
    """Grid: (BH, S // block_q).  Refs (VMEM blocks):

    bidx_ref: (1, NB)      — selected block indices (whole row; NB = S/bs)
    seg_ref:  (1, S)       — per-token segment ids (whole row)
    rblk_ref: (1, NB)      — per-block router scores (whole row)
    q_ref:    (1, block_q, d)
    k_ref:    (1, S, d)    — all selected keys, block-major
    v_ref:    (1, S, d)
    o_ref:    (1, block_q, d)
    """
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    S = k_ref.shape[1]
    n_kb = S // block_k
    nbq, nbk = block_q // bs, block_k // bs

    q = q_ref[0].astype(jnp.float32) * scale                  # (bq, d)
    qi = pl.program_id(1)
    bidx_q = jax.lax.dynamic_slice(bidx_ref[0], (qi * nbq,), (nbq,))
    rblk_q = jax.lax.dynamic_slice(rblk_ref[0], (qi * nbq,), (nbq,))
    seg_q = jax.lax.dynamic_slice(seg_ref[0], (qi * block_q,), (block_q,))
    pos_q, ok_q = _expand_blocks(bidx_q, bs, T)
    r_q = jnp.broadcast_to(rblk_q[:, None], (nbq, bs)).reshape(block_q)

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        bidx_k = jax.lax.dynamic_slice(bidx_ref[0], (kb * nbk,), (nbk,))
        seg_k = jax.lax.dynamic_slice(seg_ref[0], (kb * block_k,), (block_k,))
        pos_k, ok_k = _expand_blocks(bidx_k, bs, T)

        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        mask = _block_pair_mask(pos_q, pos_k, ok_k, seg_q, seg_k)
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))

    out = acc / jnp.maximum(l, 1e-30)[:, None]
    out = out * r_q[:, None]                                   # router scaling
    out = jnp.where(ok_q[:, None], out, 0.0)                   # ragged tails
    o_ref[0] = out.astype(o_ref.dtype)


def _mosa_block_fwd_res_kernel(bidx_ref, seg_ref, rblk_ref, q_ref, k_ref,
                               v_ref, o_ref, lse_ref, *, block_k: int,
                               scale: float, bs: int, T: int):
    """Training forward: emits ``o_pre`` (pre-scale, zeroed on invalid query
    rows so the wrapper's ``o_pre * r`` never resurrects a ragged tail) and
    ``lse = m + log(l)``.  ``rblk_ref`` rides along unused so both forward
    kernels share one BlockSpec layout."""
    del rblk_ref
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    S = k_ref.shape[1]
    n_kb = S // block_k
    nbq, nbk = block_q // bs, block_k // bs

    q = q_ref[0].astype(jnp.float32) * scale                  # (bq, d)
    qi = pl.program_id(1)
    bidx_q = jax.lax.dynamic_slice(bidx_ref[0], (qi * nbq,), (nbq,))
    seg_q = jax.lax.dynamic_slice(seg_ref[0], (qi * block_q,), (block_q,))
    pos_q, ok_q = _expand_blocks(bidx_q, bs, T)

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        bidx_k = jax.lax.dynamic_slice(bidx_ref[0], (kb * nbk,), (nbk,))
        seg_k = jax.lax.dynamic_slice(seg_ref[0], (kb * block_k,), (block_k,))
        pos_k, ok_k = _expand_blocks(bidx_k, bs, T)

        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _block_pair_mask(pos_q, pos_k, ok_k, seg_q, seg_k)
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))

    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0] = jnp.where(ok_q[:, None], acc / l_safe[:, None], 0.0)
    lse_ref[0] = m + jnp.log(l_safe)


def _mosa_block_bwd_dq_kernel(bidx_ref, seg_ref, q_ref, k_ref, v_ref, gt_ref,
                              lse_ref, delta_ref, dq_ref, *, block_k: int,
                              scale: float, bs: int, T: int):
    """Grid (BH, S // block_q); same math as ``_mosa_bwd_dq_kernel`` with
    in-kernel block expansion.  Invalid query rows arrive with gt == 0 and
    delta == 0 (wrapper zeroes them), so their ds vanishes term-by-term."""
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    S = k_ref.shape[1]
    n_kb = S // block_k
    nbq, nbk = block_q // bs, block_k // bs

    q = q_ref[0].astype(jnp.float32)                           # (bq, d)
    gt = gt_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]
    qi = pl.program_id(1)
    bidx_q = jax.lax.dynamic_slice(bidx_ref[0], (qi * nbq,), (nbq,))
    seg_q = jax.lax.dynamic_slice(seg_ref[0], (qi * block_q,), (block_q,))
    pos_q, _ = _expand_blocks(bidx_q, bs, T)

    def body(kb, acc):
        k_blk = jax.lax.dynamic_slice(
            k_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        bidx_k = jax.lax.dynamic_slice(bidx_ref[0], (kb * nbk,), (nbk,))
        seg_k = jax.lax.dynamic_slice(seg_ref[0], (kb * block_k,), (block_k,))
        pos_k, ok_k = _expand_blocks(bidx_k, bs, T)

        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _block_pair_mask(pos_q, pos_k, ok_k, seg_q, seg_k)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)    # (bq, bk)
        dp = jax.lax.dot_general(gt, v_blk, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        return acc + jax.lax.dot_general(
            ds, k_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    dq = jax.lax.fori_loop(0, n_kb, body, acc0) * scale
    dq_ref[0] = dq.astype(dq_ref.dtype)


def _mosa_block_bwd_dkv_kernel(bidx_ref, seg_ref, q_ref, k_ref, v_ref,
                               gt_ref, lse_ref, delta_ref, dk_ref, dv_ref, *,
                               block_q: int, scale: float, bs: int, T: int):
    """Grid (BH, S // block_k); block-expanded ``_mosa_bwd_dkv_kernel``."""
    block_k, d = k_ref.shape[1], k_ref.shape[2]
    S = q_ref.shape[1]
    n_qb = S // block_q
    nbq, nbk = block_q // bs, block_k // bs

    k = k_ref[0].astype(jnp.float32)                           # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    ki = pl.program_id(1)
    bidx_k = jax.lax.dynamic_slice(bidx_ref[0], (ki * nbk,), (nbk,))
    seg_k = jax.lax.dynamic_slice(seg_ref[0], (ki * block_k,), (block_k,))
    pos_k, ok_k = _expand_blocks(bidx_k, bs, T)

    def body(qb, carry):
        dk_acc, dv_acc = carry
        q_blk = jax.lax.dynamic_slice(
            q_ref[0], (qb * block_q, 0), (block_q, d)).astype(jnp.float32)
        gt_blk = jax.lax.dynamic_slice(
            gt_ref[0], (qb * block_q, 0), (block_q, d)).astype(jnp.float32)
        lse_blk = jax.lax.dynamic_slice(lse_ref[0], (qb * block_q,),
                                        (block_q,))
        delta_blk = jax.lax.dynamic_slice(delta_ref[0], (qb * block_q,),
                                          (block_q,))
        bidx_q = jax.lax.dynamic_slice(bidx_ref[0], (qb * nbq,), (nbq,))
        seg_q = jax.lax.dynamic_slice(seg_ref[0], (qb * block_q,), (block_q,))
        pos_q, _ = _expand_blocks(bidx_q, bs, T)

        s = jax.lax.dot_general(q_blk, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _block_pair_mask(pos_q, pos_k, ok_k, seg_q, seg_k)
        p = jnp.where(mask, jnp.exp(s - lse_blk[:, None]), 0.0)  # (bq, bk)
        dv_acc = dv_acc + jax.lax.dot_general(
            p, gt_blk, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(gt_blk, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_blk[:, None])
        dk_acc = dk_acc + jax.lax.dot_general(
            ds, q_blk, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return dk_acc, dv_acc

    z = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, n_qb, body, (z, z))
    dk_ref[0] = (dk * scale).astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _specs(S, NB, block_q, block_k, d, fwd: bool):
    row = lambda b, i: (b, 0)
    blk1 = lambda b, i: (b, i)
    rowd = lambda b, i: (b, 0, 0)
    blkd = lambda b, i: (b, i, 0)
    if fwd:
        return [
            pl.BlockSpec((1, NB), row),                # bidx
            pl.BlockSpec((1, S), row),                 # seg
            pl.BlockSpec((1, NB), row),                # rblk
            pl.BlockSpec((1, block_q, d), blkd),       # q
            pl.BlockSpec((1, S, d), rowd),             # k
            pl.BlockSpec((1, S, d), rowd),             # v
        ]
    return row, blk1, rowd, blkd


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "scale",
                                             "bs", "T", "interpret"))
def mosa_block_attention_pallas(q, k, v, bidx, seg, rblk, *,
                                block_q: int = 128, block_k: int = 128,
                                scale: float | None = None, bs: int = 16,
                                T: int = 0, interpret: bool = False):
    """q, k, v: (B, H, S, d) block-major selected tokens (S = NB*bs);
    bidx, rblk: (B, H, NB); seg: (B, H, S) int32.  ``T`` is the true
    sequence length (positions >= T in the last block are masked).

    Preconditions (ops.py guarantees them): S % block_q == 0,
    S % block_k == 0, bs divides both block sizes, d padded to 128 lanes.
    """
    B, H, S, d = q.shape
    NB = S // bs
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    assert block_q % bs == 0 and block_k % bs == 0, (block_q, block_k, bs)
    scale = scale if scale is not None else d ** -0.5
    BH = B * H
    qf, kf, vf = (x.reshape(BH, S, d) for x in (q, k, v))
    bidxf = bidx.reshape(BH, NB)
    segf = seg.reshape(BH, S)
    rf = rblk.reshape(BH, NB).astype(jnp.float32)

    kernel = functools.partial(_mosa_block_kernel, block_k=block_k,
                               scale=scale, bs=bs, T=T)
    out = pl.pallas_call(
        kernel,
        grid=(BH, S // block_q),
        in_specs=_specs(S, NB, block_q, block_k, d, fwd=True),
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        interpret=interpret,
    )(bidxf, segf, rf, qf, kf, vf)
    return out.reshape(B, H, S, d)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "scale",
                                             "bs", "T", "interpret"))
def mosa_block_attention_fwd_res(q, k, v, bidx, seg, rblk, *,
                                 block_q: int = 128, block_k: int = 128,
                                 scale: float | None = None, bs: int = 16,
                                 T: int = 0, interpret: bool = False):
    """Training-path forward; returns ``(o_pre, lse)`` like
    ``mosa_attention_fwd_res`` (o_pre zeroed on invalid query rows)."""
    B, H, S, d = q.shape
    NB = S // bs
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = scale if scale is not None else d ** -0.5
    BH = B * H
    qf, kf, vf = (x.reshape(BH, S, d) for x in (q, k, v))
    bidxf = bidx.reshape(BH, NB)
    segf = seg.reshape(BH, S)
    rf = rblk.reshape(BH, NB).astype(jnp.float32)

    kernel = functools.partial(_mosa_block_fwd_res_kernel, block_k=block_k,
                               scale=scale, bs=bs, T=T)
    o_pre, lse = pl.pallas_call(
        kernel,
        grid=(BH, S // block_q),
        in_specs=_specs(S, NB, block_q, block_k, d, fwd=True),
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, d), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ],
        interpret=interpret,
    )(bidxf, segf, rf, qf, kf, vf)
    return o_pre.reshape(B, H, S, d), lse.reshape(B, H, S)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "scale",
                                             "bs", "T", "interpret"))
def mosa_block_attention_bwd_pallas(q, k, v, bidx, seg, gt, lse, delta, *,
                                    block_q: int = 128, block_k: int = 128,
                                    scale: float | None = None, bs: int = 16,
                                    T: int = 0, interpret: bool = False):
    """Backward dispatch: dq kernel blocked over queries, dk/dv over keys."""
    B, H, S, d = q.shape
    NB = S // bs
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = scale if scale is not None else d ** -0.5
    BH = B * H
    qf, kf, vf = (x.reshape(BH, S, d) for x in (q, k, v))
    gtf = gt.reshape(BH, S, d).astype(jnp.float32)
    bidxf = bidx.reshape(BH, NB)
    segf = seg.reshape(BH, S)
    lsef = lse.reshape(BH, S)
    deltaf = delta.reshape(BH, S)

    row, blk1, rowd, blkd = _specs(S, NB, block_q, block_k, d, fwd=False)

    dq = pl.pallas_call(
        functools.partial(_mosa_block_bwd_dq_kernel, block_k=block_k,
                          scale=scale, bs=bs, T=T),
        grid=(BH, S // block_q),
        in_specs=[
            pl.BlockSpec((1, NB), row),                # bidx
            pl.BlockSpec((1, S), row),                 # seg
            pl.BlockSpec((1, block_q, d), blkd),       # q
            pl.BlockSpec((1, S, d), rowd),             # k
            pl.BlockSpec((1, S, d), rowd),             # v
            pl.BlockSpec((1, block_q, d), blkd),       # gt
            pl.BlockSpec((1, block_q), blk1),          # lse
            pl.BlockSpec((1, block_q), blk1),          # delta
        ],
        out_specs=pl.BlockSpec((1, block_q, d), blkd),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        interpret=interpret,
    )(bidxf, segf, qf, kf, vf, gtf, lsef, deltaf)

    dk, dv = pl.pallas_call(
        functools.partial(_mosa_block_bwd_dkv_kernel, block_q=block_q,
                          scale=scale, bs=bs, T=T),
        grid=(BH, S // block_k),
        in_specs=[
            pl.BlockSpec((1, NB), row),                # bidx
            pl.BlockSpec((1, S), row),                 # seg
            pl.BlockSpec((1, S, d), rowd),             # q
            pl.BlockSpec((1, block_k, d), blkd),       # k
            pl.BlockSpec((1, block_k, d), blkd),       # v
            pl.BlockSpec((1, S, d), rowd),             # gt
            pl.BlockSpec((1, S), row),                 # lse
            pl.BlockSpec((1, S), row),                 # delta
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), blkd),
            pl.BlockSpec((1, block_k, d), blkd),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, d), k.dtype),
            jax.ShapeDtypeStruct((BH, S, d), v.dtype),
        ],
        interpret=interpret,
    )(bidxf, segf, qf, kf, vf, gtf, lsef, deltaf)

    return (dq.reshape(B, H, S, d), dk.reshape(B, H, S, d),
            dv.reshape(B, H, S, d))


@functools.lru_cache(maxsize=None)
def _build(block_q: int, block_k: int, scale: float, bs: int, T: int,
           interpret: bool):
    @jax.custom_vjp
    def fused(q, k, v, bidx, seg, rblk):
        return mosa_block_attention_pallas(
            q, k, v, bidx, seg, rblk, block_q=block_q, block_k=block_k,
            scale=scale, bs=bs, T=T, interpret=interpret)

    def fwd(q, k, v, bidx, seg, rblk):
        o_pre, lse = mosa_block_attention_fwd_res(
            q, k, v, bidx, seg, rblk, block_q=block_q, block_k=block_k,
            scale=scale, bs=bs, T=T, interpret=interpret)
        rf = rblk.astype(jnp.float32)
        B, H, NB = rf.shape
        r_tok = jnp.broadcast_to(rf[..., None],
                                 (B, H, NB, bs)).reshape(B, H, NB * bs)
        out = (o_pre * r_tok[..., None]).astype(q.dtype)
        return out, (q, k, v, bidx, seg, rf, o_pre, lse)

    def bwd(res, g):
        q, k, v, bidx, seg, rf, o_pre, lse = res
        B, H, NB = rf.shape
        g32 = g.astype(jnp.float32)
        r_tok = jnp.broadcast_to(rf[..., None],
                                 (B, H, NB, bs)).reshape(B, H, NB * bs)
        # token validity from the block table: invalid rows carry zero
        # cotangent so no gradient flows toward the layer's clamped gather
        off = jnp.arange(bs, dtype=jnp.int32)
        pos = bidx[..., None] * bs + off
        ok = ((bidx[..., None] >= 0) & (pos < T)).reshape(B, H, NB * bs)
        gt = jnp.where(ok[..., None], g32 * r_tok[..., None], 0.0)
        dr_tok = jnp.sum(g32 * o_pre, axis=-1)         # (B,H,S) fp32
        delta = jnp.sum(gt * o_pre, axis=-1)
        dq, dk, dv = mosa_block_attention_bwd_pallas(
            q, k, v, bidx, seg, gt, lse, delta, block_q=block_q,
            block_k=block_k, scale=scale, bs=bs, T=T, interpret=interpret)
        # block-score cotangent: per-token dr summed over each block (the
        # layer's mean-pool VJP then spreads it back onto token scores)
        dr_blk = dr_tok.reshape(B, H, NB, bs).sum(-1)
        dbidx = np.zeros(bidx.shape, jax.dtypes.float0)  # int input: no grad
        dseg = np.zeros(seg.shape, jax.dtypes.float0)
        return dq, dk, dv, dbidx, dseg, dr_blk.astype(jnp.float32)

    fused.defvjp(fwd, bwd)
    return fused


def mosa_block_attention_trainable(q, k, v, bidx, rblk, *, seg=None,
                                   block_q: int = 128, block_k: int = 128,
                                   scale: float | None = None, bs: int = 16,
                                   T: int = 0, interpret: bool = False):
    """Differentiable fused block-choice MoSA attention.  Same contract as
    ``mosa_block_attention_pallas``; additionally supports ``jax.grad``
    w.r.t. q, k, v and the PER-BLOCK router scores ``rblk``."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if seg is None:
        seg = jnp.zeros(q.shape[:3], jnp.int32)
    return _build(block_q, block_k, float(scale), int(bs), int(T),
                  bool(interpret))(q, k, v, bidx, seg,
                                   rblk.astype(jnp.float32))
