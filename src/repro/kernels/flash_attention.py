"""Pallas TPU kernel: causal (optionally sliding-window) flash attention, GQA.

Serves the dense / local heads of the hybrid layer.  Standard flash-v2
streaming softmax with BlockSpec VMEM tiling:

  grid = (B, Hq, Tq // block_q); KV streamed in ``block_k`` tiles with the
  block range cut to [lo, hi) by causality (and the sliding window), so the
  work per query block is O(min(q_end, window) ) rather than O(Tk).

GQA is expressed in the BlockSpec index_map: the KV block for query head h is
loaded from kv head h // (Hq // Hkv) — no materialized repeat.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float,
                  window: int, q_offset: int):
    """Refs: q (1, 1, bq, d); k/v (1, 1, Tk, d); o (1, 1, bq, d)."""
    block_q, d = q_ref.shape[2], q_ref.shape[3]
    Tk = k_ref.shape[2]

    qi = pl.program_id(2)
    q_start = qi * block_q + q_offset          # absolute position of q row 0
    q = q_ref[0, 0].astype(jnp.float32) * scale
    q_pos = q_start + jax.lax.iota(jnp.int32, block_q)

    # causal upper bound: last query in the block attends up to q_end
    q_end = q_start + block_q                  # exclusive
    hi = jnp.minimum(pl.cdiv(q_end, block_k), Tk // block_k)
    lo = 0
    if window > 0:
        lo = jnp.maximum((q_start - window + 1) // block_k, 0)

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[0, 0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[0, 0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)

        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "scale",
                                             "window", "q_offset", "interpret"))
def flash_attention_pallas(q, k, v, *, block_q: int = 128, block_k: int = 128,
                           scale: float | None = None, window: int = 0,
                           q_offset: int | None = None,
                           interpret: bool = False):
    """q: (B, Hq, Tq, d); k, v: (B, Hkv, Tk, d).  ``q_offset`` is the absolute
    position of q row 0 (default: Tk - Tq, i.e. q rows are the last Tq
    positions of the context — pass it explicitly when shapes are padded).
    Preconditions (ops.py): Tq % block_q == 0, Tk % block_k == 0, d a
    multiple of 128.
    """
    B, Hq, Tq, d = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    assert Tq % block_q == 0 and Tk % block_k == 0
    assert Hq % Hkv == 0
    n_rep = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    if q_offset is None:
        q_offset = Tk - Tq

    grid = (B, Hq, Tq // block_q)
    kernel = functools.partial(_flash_kernel, block_k=block_k, scale=scale,
                               window=window, q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Tk, d), lambda b, h, i: (b, h // n_rep, 0, 0)),
            pl.BlockSpec((1, 1, Tk, d), lambda b, h, i: (b, h // n_rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Tq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out


# --------------------------------------------------------------- packed varlen
def _flash_varlen_kernel(seg_smem_ref, cu_ref, seg_ref, q_ref, k_ref, v_ref,
                         o_ref, *, block_k: int, scale: float, window: int):
    """Packed ragged self-attention over a flattened token stream.

    Grid: (Hq, Tp // block_q).  Scalar-prefetch (SMEM):
      seg_smem_ref: (Tp,)  — segment id per packed token (-1 = padding)
      cu_ref:       (N+1,) — cu_seqlens, segment s spans [cu[s], cu[s+1])
    VMEM refs:
      seg_ref: (1, Tp)          — same segment ids, vector-readable
      q_ref:   (1, block_q, d); k_ref, v_ref: (1, Tp, d); o_ref like q_ref.

    The causal mask uses GLOBAL packed positions — within a segment global
    order equals local order, and the (seg_q == seg_k) term removes every
    cross-segment pair, so this is per-sequence causal attention with zero
    cross-contamination.  The KV block range is cut to
    [segment start of the block's first query, query block end), so work per
    query block is O(its own segment), not O(total).
    """
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    Tp = k_ref.shape[1]

    qi = pl.program_id(1)
    q_start = qi * block_q
    q = q_ref[0].astype(jnp.float32) * scale
    q_pos = q_start + jax.lax.iota(jnp.int32, block_q)
    seg_q = jax.lax.dynamic_slice(seg_ref[0], (q_start,), (block_q,))

    # first query's segment start bounds every key this block can see
    # (padding rows have seg = -1: clamp to 0 so the SMEM read stays in range)
    first_seg = jnp.maximum(seg_smem_ref[q_start], 0)
    seg_lo = cu_ref[first_seg]
    lo = seg_lo // block_k
    if window > 0:
        lo = jnp.maximum(lo, (q_start - window + 1) // block_k)
    hi = jnp.minimum(pl.cdiv(q_start + block_q, block_k), Tp // block_k)

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        seg_k = jax.lax.dynamic_slice(seg_ref[0], (kb * block_k,), (block_k,))

        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = (seg_q[:, None] == seg_k[None, :]) & \
            (q_pos[:, None] >= k_pos[None, :])
        if window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "scale",
                                             "window", "interpret"))
def flash_attention_varlen_pallas(q, k, v, seg, cu_seqlens, *,
                                  block_q: int = 128, block_k: int = 128,
                                  scale: float | None = None, window: int = 0,
                                  interpret: bool = False):
    """Packed ragged (cu_seqlens) causal attention, GQA.

    q: (Hq, Tp, d); k, v: (Hkv, Tp, d) — Tp = padded total token count of the
    flattened stream.  seg: (Tp,) int32 segment ids (-1 on padding rows);
    cu_seqlens: (N+1,) int32 cumulative offsets, prefetched to SMEM so the
    per-block KV range is cut before the DMA is issued.
    Preconditions (ops.py): Tp % block_q == 0 == Tp % block_k, d % 128 == 0.
    """
    Hq, Tp, d = q.shape
    Hkv = k.shape[0]
    assert Tp % block_q == 0 and Tp % block_k == 0
    assert Hq % Hkv == 0
    n_rep = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    seg2d = seg.reshape(1, Tp)

    kernel = functools.partial(_flash_varlen_kernel, block_k=block_k,
                               scale=scale, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Hq, Tp // block_q),
        in_specs=[
            pl.BlockSpec((1, Tp), lambda h, i, sg, cu: (0, 0)),       # seg
            pl.BlockSpec((1, block_q, d), lambda h, i, sg, cu: (h, i, 0)),
            pl.BlockSpec((1, Tp, d), lambda h, i, sg, cu: (h // n_rep, 0, 0)),
            pl.BlockSpec((1, Tp, d), lambda h, i, sg, cu: (h // n_rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda h, i, sg, cu: (h, i, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hq, Tp, d), q.dtype),
        interpret=interpret,
    )(seg, cu_seqlens.astype(jnp.int32), seg2d, q, k, v)
    return out
