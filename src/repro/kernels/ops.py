"""Jit'd public wrappers around the Pallas kernels.

Handles shape hygiene (padding S and d_head to MXU-aligned tiles, unpadding
outputs) and platform dispatch: on TPU the kernels lower natively; elsewhere
they run through the Pallas interpreter (set ``REPRO_PALLAS_INTERPRET=0`` to
force native lowering, e.g. inside TPU tests).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import (flash_attention_pallas,
                                           flash_attention_varlen_pallas)
from repro.kernels.mosa_vjp import mosa_attention_trainable

LANE = 128


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, mult, value=0.0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def mosa_attention(q, k, v, idx, r, *, seg=None, block_q: int = 128,
                   block_k: int = 128, interpret: bool | None = None):
    """MoSA inner attention (see kernels/mosa_attention.py).

    q,k,v: (B,H,S,d); idx: (B,H,S) sorted ascending; r: (B,H,S) fp32.
    ``seg``: optional (B,H,S) int32 segment ids for packed-varlen streams —
    selected tokens only attend selected tokens of the SAME segment (None =
    one segment per row, the dense behaviour, bit-for-bit unchanged).
    Returns (B,H,S,d) in q.dtype.

    Differentiable: routed through the ``jax.custom_vjp`` in
    ``kernels/mosa_vjp.py`` — forward-only callers run the original fused
    kernel; under ``jax.grad`` the Pallas backward kernels produce
    dq/dk/dv/dr (pad/slice shape hygiene here differentiates transparently:
    cotangents of the output slice arrive zero-padded).
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, H, S, d = q.shape
    bq = min(block_q, max(8, 1 << (S - 1).bit_length()))
    bk = min(block_k, bq)
    scale = d ** -0.5  # scale on the TRUE head dim, before padding

    qp = _pad_to(_pad_to(q, 3, LANE), 2, bq)
    kp = _pad_to(_pad_to(k, 3, LANE), 2, bk)
    vp = _pad_to(_pad_to(v, 3, LANE), 2, bk)
    Sp = qp.shape[2]
    # pad idx with INT_MAX (mask kills padded keys), r with 0 (zero output)
    idxp = _pad_to(idx, 2, bq, value=jnp.iinfo(jnp.int32).max)
    rp = _pad_to(r, 2, bq, value=0.0)
    segp = None if seg is None else _pad_to(seg, 2, bq, value=-1)

    out = mosa_attention_trainable(qp, kp, vp, idxp, rp, seg=segp,
                                   block_q=bq, block_k=bk, scale=scale,
                                   interpret=interpret)
    return out[:, :, :S, :d]


def mosa_block_attention(q, k, v, bidx, rblk, *, sel_block_size: int,
                         T: int, seg=None, block_q: int = 128,
                         block_k: int = 128, interpret: bool | None = None):
    """Block-choice MoSA inner attention (see kernels/mosa_block.py).

    q,k,v: (B,H,S,d) block-major selected tokens, S = NB * sel_block_size;
    bidx: (B,H,NB) selected block indices sorted ascending (-1 = empty);
    rblk: (B,H,NB) fp32 per-block router scores; ``T`` the true sequence
    length (ragged tail of the last block is masked in-kernel).  ``seg``:
    optional per-token (B,H,S) segment ids.  Returns (B,H,S,d) in q.dtype.

    Differentiable via the ``jax.custom_vjp`` in ``mosa_block.py`` — the
    router cotangent comes back PER BLOCK.  At ``sel_block_size=1`` this
    reproduces ``mosa_attention`` bit-for-bit (the maintained invariant:
    identical tile sizes, identical mask truth table — token padding's
    idx=+INT_MAX and block padding's bidx=-1 kill the same lanes).
    """
    from repro.kernels.mosa_block import mosa_block_attention_trainable

    interpret = _interpret_default() if interpret is None else interpret
    bs = sel_block_size
    assert bs >= 1 and (bs & (bs - 1)) == 0 and bs <= LANE, (
        f"sel_block_size must be a power of two <= {LANE}, got {bs}")
    B, H, S, d = q.shape
    assert S % bs == 0, (S, bs)
    bq = min(block_q, max(8, 1 << (S - 1).bit_length()))
    bk = min(block_k, bq)
    # bs is a pow2 <= 128 and bq is a pow2 in [max(8, bs), 128]: bs | bq | bk
    scale = d ** -0.5  # scale on the TRUE head dim, before padding

    qp = _pad_to(_pad_to(q, 3, LANE), 2, bq)
    kp = _pad_to(_pad_to(k, 3, LANE), 2, bk)
    vp = _pad_to(_pad_to(v, 3, LANE), 2, bk)
    # padded block slots: bidx = -1 (mask kills them), rblk = 0 (zero output)
    bidxp = _pad_to(bidx, 2, bq // bs, value=-1)
    rblkp = _pad_to(rblk, 2, bq // bs, value=0.0)
    segp = None if seg is None else _pad_to(seg, 2, bq, value=-1)

    out = mosa_block_attention_trainable(qp, kp, vp, bidxp, rblkp, seg=segp,
                                         block_q=bq, block_k=bk, scale=scale,
                                         bs=bs, T=T, interpret=interpret)
    return out[:, :, :S, :d]


def segments_from_cu_seqlens(cu_seqlens, total: int):
    """(seg, pos) per packed token from cumulative offsets.

    cu_seqlens: (N+1,) int32 with cu[0] == 0 and cu[N] <= total.  Tokens in
    [cu[s], cu[s+1]) get seg = s and pos = their LOCAL offset within the
    segment; tokens >= cu[N] (padding tail) get seg = -1, pos = 0.
    """
    cu = jnp.asarray(cu_seqlens, jnp.int32)
    t = jnp.arange(total, dtype=jnp.int32)
    seg = jnp.searchsorted(cu[1:], t, side="right").astype(jnp.int32)
    in_range = t < cu[-1]
    seg = jnp.where(in_range, seg, -1)
    pos = jnp.where(in_range, t - cu[jnp.maximum(seg, 0)], 0)
    return seg, pos


def flash_attention_varlen(q, k, v, cu_seqlens, *, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool | None = None):
    """Packed ragged (cu_seqlens) causal/windowed GQA flash attention.

    q: (total, Hq, d); k, v: (total, Hkv, d) — ONE flattened token stream
    holding N back-to-back sequences; cu_seqlens: (N+1,) int32 cumulative
    offsets (cu[0] = 0, cu[N] = total).  Attention is causal within each
    segment and never crosses a boundary.  Returns (total, Hq, d) in q.dtype.
    """
    interpret = _interpret_default() if interpret is None else interpret
    total, Hq, d = q.shape
    bq = min(block_q, max(8, 1 << (total - 1).bit_length()))
    bk = min(block_k, bq)
    scale = d ** -0.5

    # head-major layout for the kernel: (H, total, d)
    qh = _pad_to(_pad_to(q.transpose(1, 0, 2), 2, LANE), 1, bq)
    kh = _pad_to(_pad_to(k.transpose(1, 0, 2), 2, LANE), 1, bk)
    vh = _pad_to(_pad_to(v.transpose(1, 0, 2), 2, LANE), 1, bk)
    Tp = qh.shape[1]
    seg, _ = segments_from_cu_seqlens(cu_seqlens, Tp)

    out = flash_attention_varlen_pallas(qh, kh, vh, seg,
                                        jnp.asarray(cu_seqlens, jnp.int32),
                                        block_q=bq, block_k=bk, scale=scale,
                                        window=window, interpret=interpret)
    return out[:, :total, :d].transpose(1, 0, 2)


def flash_attention(q, k, v, *, window: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """Causal/windowed GQA flash attention.  q: (B,Hq,Tq,d), k/v (B,Hkv,Tk,d)."""
    interpret = _interpret_default() if interpret is None else interpret
    B, Hq, Tq, d = q.shape
    Tk = k.shape[2]
    bq = min(block_q, max(8, 1 << (Tq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (Tk - 1).bit_length()))
    scale = d ** -0.5

    qp = _pad_to(_pad_to(q, 3, LANE), 2, bq)
    kp = _pad_to(_pad_to(k, 3, LANE), 2, bk)
    vp = _pad_to(_pad_to(v, 3, LANE), 2, bk)
    # NOTE: padded KV rows sit at positions >= Tk; causal masking with
    # absolute positions already excludes them for all real queries because
    # real q positions are < Tk.  Padded q rows are sliced off below.
    out = flash_attention_pallas(qp, kp, vp, block_q=bq, block_k=bk,
                                 scale=scale, window=window,
                                 q_offset=Tk - Tq, interpret=interpret)
    return out[:, :, :Tq, :d]
