"""Jit'd public wrappers around the Pallas kernels.

Handles shape hygiene (padding S and d_head to MXU-aligned tiles, unpadding
outputs) and platform dispatch: on TPU the kernels lower natively; elsewhere
they run through the Pallas interpreter (set ``REPRO_PALLAS_INTERPRET=0`` to
force native lowering, e.g. inside TPU tests).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mosa_vjp import mosa_attention_trainable

LANE = 128


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, mult, value=0.0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def mosa_attention(q, k, v, idx, r, *, block_q: int = 128, block_k: int = 128,
                   interpret: bool | None = None):
    """MoSA inner attention (see kernels/mosa_attention.py).

    q,k,v: (B,H,S,d); idx: (B,H,S) sorted ascending; r: (B,H,S) fp32.
    Returns (B,H,S,d) in q.dtype.

    Differentiable: routed through the ``jax.custom_vjp`` in
    ``kernels/mosa_vjp.py`` — forward-only callers run the original fused
    kernel; under ``jax.grad`` the Pallas backward kernels produce
    dq/dk/dv/dr (pad/slice shape hygiene here differentiates transparently:
    cotangents of the output slice arrive zero-padded).
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, H, S, d = q.shape
    bq = min(block_q, max(8, 1 << (S - 1).bit_length()))
    bk = min(block_k, bq)
    scale = d ** -0.5  # scale on the TRUE head dim, before padding

    qp = _pad_to(_pad_to(q, 3, LANE), 2, bq)
    kp = _pad_to(_pad_to(k, 3, LANE), 2, bk)
    vp = _pad_to(_pad_to(v, 3, LANE), 2, bk)
    Sp = qp.shape[2]
    # pad idx with INT_MAX (mask kills padded keys), r with 0 (zero output)
    idxp = _pad_to(idx, 2, bq, value=jnp.iinfo(jnp.int32).max)
    rp = _pad_to(r, 2, bq, value=0.0)

    out = mosa_attention_trainable(qp, kp, vp, idxp, rp, block_q=bq,
                                   block_k=bk, scale=scale,
                                   interpret=interpret)
    return out[:, :, :S, :d]


def flash_attention(q, k, v, *, window: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """Causal/windowed GQA flash attention.  q: (B,Hq,Tq,d), k/v (B,Hkv,Tk,d)."""
    interpret = _interpret_default() if interpret is None else interpret
    B, Hq, Tq, d = q.shape
    Tk = k.shape[2]
    bq = min(block_q, max(8, 1 << (Tq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (Tk - 1).bit_length()))
    scale = d ** -0.5

    qp = _pad_to(_pad_to(q, 3, LANE), 2, bq)
    kp = _pad_to(_pad_to(k, 3, LANE), 2, bk)
    vp = _pad_to(_pad_to(v, 3, LANE), 2, bk)
    # NOTE: padded KV rows sit at positions >= Tk; causal masking with
    # absolute positions already excludes them for all real queries because
    # real q positions are < Tk.  Padded q rows are sliced off below.
    out = flash_attention_pallas(qp, kp, vp, block_q=bq, block_k=bk,
                                 scale=scale, window=window,
                                 q_offset=Tk - Tq, interpret=interpret)
    return out[:, :, :Tq, :d]
