"""Pallas TPU backward kernels for the fused MoSA inner attention.

Recompute-style (flash-attention bwd): neither kernel reads the O(S^2)
probability matrix from memory — scores are recomputed from Q/K and the
saved per-query log-sum-exp (``lse``), so the only extra residuals the
forward keeps are ``o_pre`` (B,H,S,d fp32) and ``lse`` (B,H,S fp32).

Math (S_ij = scale * q_i.k_j masked by I_q >= I_k and seg_q == seg_k;
P = softmax rows;
o_pre_i = sum_j P_ij v_j; out_i = r_i * o_pre_i; g = d out):

  dr_i   = g_i . o_pre_i                       (router-score gradient — the
                                                expert-choice learning path)
  g~_i   = r_i * g_i
  dV_j   = sum_i P_ij g~_i
  dS_ij  = P_ij * (g~_i . v_j - delta_i),  delta_i = g~_i . o_pre_i
  dQ_i   = scale * sum_j dS_ij k_j
  dK_j   = scale * sum_i dS_ij q_i

``delta`` and ``dr`` are O(S*d) elementwise reductions computed in plain jnp
by the wrapper (``mosa_vjp.py``); the two kernels here carry the O(S^2*d)
work and parallelize the same way the forward does — one (batch*head) slice
per grid step, the dq kernel blocked over QUERIES, the dk/dv kernel blocked
over KEYS, each streaming the opposite operand through VMEM:

  _mosa_bwd_dq_kernel   grid (BH, S // block_q) -> dq block
  _mosa_bwd_dkv_kernel  grid (BH, S // block_k) -> dk, dv blocks

Masking note: rows ops.py padded (idx = +INT_MAX) see a garbage-but-finite
``lse``; their cotangent ``g~`` arrives as exact zeros (the output slice
pads cotangents with 0), so every term they touch vanishes — but ``P`` must
still be recomputed with the explicit mask, because exp(NEG_INF - lse) is
NOT ~0 when lse itself is ~NEG_INF (the empty-row case).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.mosa_attention import _pair_mask

NEG_INF = -1e30


def _mosa_bwd_dq_kernel(idx_ref, seg_ref, q_ref, k_ref, v_ref, gt_ref,
                        lse_ref, delta_ref, dq_ref, *, block_k: int,
                        scale: float):
    """Grid (BH, S // block_q).  Refs (VMEM blocks):

    idx_ref:   (1, S)
    seg_ref:   (1, S)
    q_ref:     (1, block_q, d)
    k_ref:     (1, S, d)
    v_ref:     (1, S, d)
    gt_ref:    (1, block_q, d) — g~ = r * g, fp32
    lse_ref:   (1, block_q)    fp32
    delta_ref: (1, block_q)    fp32
    dq_ref:    (1, block_q, d)
    """
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    S = k_ref.shape[1]
    n_kb = S // block_k

    q = q_ref[0].astype(jnp.float32)                           # (bq, d)
    gt = gt_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]
    qi = pl.program_id(1)
    idx_q = jax.lax.dynamic_slice(idx_ref[0], (qi * block_q,), (block_q,))
    seg_q = jax.lax.dynamic_slice(seg_ref[0], (qi * block_q,), (block_q,))

    def body(kb, acc):
        k_blk = jax.lax.dynamic_slice(
            k_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        idx_k = jax.lax.dynamic_slice(idx_ref[0], (kb * block_k,), (block_k,))
        seg_k = jax.lax.dynamic_slice(seg_ref[0], (kb * block_k,), (block_k,))

        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _pair_mask(idx_q, idx_k, seg_q, seg_k)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)    # (bq, bk)
        dp = jax.lax.dot_general(gt, v_blk, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        return acc + jax.lax.dot_general(
            ds, k_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    dq = jax.lax.fori_loop(0, n_kb, body, acc0) * scale
    dq_ref[0] = dq.astype(dq_ref.dtype)


def _mosa_bwd_dkv_kernel(idx_ref, seg_ref, q_ref, k_ref, v_ref, gt_ref,
                         lse_ref, delta_ref, dk_ref, dv_ref, *, block_q: int,
                         scale: float):
    """Grid (BH, S // block_k).  Refs:

    idx_ref:   (1, S)
    seg_ref:   (1, S)
    q_ref:     (1, S, d) — all queries
    k_ref:     (1, block_k, d)
    v_ref:     (1, block_k, d)
    gt_ref:    (1, S, d) fp32
    lse_ref:   (1, S)    fp32
    delta_ref: (1, S)    fp32
    dk_ref:    (1, block_k, d)
    dv_ref:    (1, block_k, d)
    """
    block_k, d = k_ref.shape[1], k_ref.shape[2]
    S = q_ref.shape[1]
    n_qb = S // block_q

    k = k_ref[0].astype(jnp.float32)                           # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    ki = pl.program_id(1)
    idx_k = jax.lax.dynamic_slice(idx_ref[0], (ki * block_k,), (block_k,))
    seg_k = jax.lax.dynamic_slice(seg_ref[0], (ki * block_k,), (block_k,))

    def body(qb, carry):
        dk_acc, dv_acc = carry
        q_blk = jax.lax.dynamic_slice(
            q_ref[0], (qb * block_q, 0), (block_q, d)).astype(jnp.float32)
        gt_blk = jax.lax.dynamic_slice(
            gt_ref[0], (qb * block_q, 0), (block_q, d)).astype(jnp.float32)
        lse_blk = jax.lax.dynamic_slice(lse_ref[0], (qb * block_q,),
                                        (block_q,))
        delta_blk = jax.lax.dynamic_slice(delta_ref[0], (qb * block_q,),
                                          (block_q,))
        idx_q = jax.lax.dynamic_slice(idx_ref[0], (qb * block_q,), (block_q,))
        seg_q = jax.lax.dynamic_slice(seg_ref[0], (qb * block_q,), (block_q,))

        s = jax.lax.dot_general(q_blk, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _pair_mask(idx_q, idx_k, seg_q, seg_k)
        p = jnp.where(mask, jnp.exp(s - lse_blk[:, None]), 0.0)  # (bq, bk)
        dv_acc = dv_acc + jax.lax.dot_general(
            p, gt_blk, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(gt_blk, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_blk[:, None])
        dk_acc = dk_acc + jax.lax.dot_general(
            ds, q_blk, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return dk_acc, dv_acc

    z = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, n_qb, body, (z, z))
    dk_ref[0] = (dk * scale).astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "scale",
                                             "interpret"))
def mosa_attention_bwd_pallas(q, k, v, idx, seg, gt, lse, delta, *,
                              block_q: int = 128, block_k: int = 128,
                              scale: float | None = None,
                              interpret: bool = False):
    """Backward dispatch: two pallas_calls sharing one residual layout.

    q, k, v: (B, H, S, d) (padded, see ops.py); idx, seg: (B, H, S) int32;
    gt (= r * g): (B, H, S, d) fp32; lse, delta: (B, H, S) fp32.
    Returns (dq, dk, dv) in the dtypes of (q, k, v).
    """
    B, H, S, d = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = scale if scale is not None else d ** -0.5
    BH = B * H
    qf, kf, vf = (x.reshape(BH, S, d) for x in (q, k, v))
    gtf = gt.reshape(BH, S, d).astype(jnp.float32)
    idxf = idx.reshape(BH, S)
    segf = seg.reshape(BH, S)
    lsef = lse.reshape(BH, S)
    deltaf = delta.reshape(BH, S)

    row = lambda b, i: (b, 0)
    blk1 = lambda b, i: (b, i)
    rowd = lambda b, i: (b, 0, 0)
    blkd = lambda b, i: (b, i, 0)

    dq = pl.pallas_call(
        functools.partial(_mosa_bwd_dq_kernel, block_k=block_k, scale=scale),
        grid=(BH, S // block_q),
        in_specs=[
            pl.BlockSpec((1, S), row),                 # idx
            pl.BlockSpec((1, S), row),                 # seg
            pl.BlockSpec((1, block_q, d), blkd),       # q
            pl.BlockSpec((1, S, d), rowd),             # k
            pl.BlockSpec((1, S, d), rowd),             # v
            pl.BlockSpec((1, block_q, d), blkd),       # gt
            pl.BlockSpec((1, block_q), blk1),          # lse
            pl.BlockSpec((1, block_q), blk1),          # delta
        ],
        out_specs=pl.BlockSpec((1, block_q, d), blkd),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        interpret=interpret,
    )(idxf, segf, qf, kf, vf, gtf, lsef, deltaf)

    dk, dv = pl.pallas_call(
        functools.partial(_mosa_bwd_dkv_kernel, block_q=block_q, scale=scale),
        grid=(BH, S // block_k),
        in_specs=[
            pl.BlockSpec((1, S), row),                 # idx
            pl.BlockSpec((1, S), row),                 # seg
            pl.BlockSpec((1, S, d), rowd),             # q
            pl.BlockSpec((1, block_k, d), blkd),       # k
            pl.BlockSpec((1, block_k, d), blkd),       # v
            pl.BlockSpec((1, S, d), rowd),             # gt
            pl.BlockSpec((1, S), row),                 # lse
            pl.BlockSpec((1, S), row),                 # delta
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), blkd),
            pl.BlockSpec((1, block_k, d), blkd),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, d), k.dtype),
            jax.ShapeDtypeStruct((BH, S, d), v.dtype),
        ],
        interpret=interpret,
    )(idxf, segf, qf, kf, vf, gtf, lsef, deltaf)

    return (dq.reshape(B, H, S, d), dk.reshape(B, H, S, d),
            dv.reshape(B, H, S, d))
