"""Pallas TPU kernel: MoSA inner attention over expert-choice-selected tokens.

The hot spot the paper leaves to "future CUDA kernels": attention over the k
selected tokens of each head, with
  * the index-derived causal mask (I_q >= I_k) fused in,
  * an optional segment mask (seg_q == seg_k) for packed varlen streams, so
    selected tokens of different documents / requests sharing one flattened
    stream never attend across a sequence boundary,
  * the router scaling (diag(r) A) fused into the output,
  * flash-style streaming softmax (fp32 running max / denom),
  * BlockSpec VMEM tiling: one (batch*head) slice per grid step, queries in
    MXU-aligned blocks of ``block_q``, K/V streamed in blocks of ``block_k``.

Shapes are MXU-friendly by construction: ops.py pads d_head to a multiple of
128 lanes and S (selected count) to a multiple of the block size; padded KV
slots carry idx = +INT_MAX and seg = -1 so the mask kills them, padded
queries are sliced off by the wrapper.  The dense (single-segment) path
passes seg = 0 everywhere, which makes the segment term a constant-true and
reproduces the original mask bit-for-bit.

VMEM budget per grid step (defaults bq=bk=128, d<=128 padded):
  q block 128x128x4B = 64 KiB; k/v blocks 2x64 KiB; scores 128x128x4B = 64 KiB
  + accumulators — well under the ~16 MiB/core VMEM of v5e.

Two entry points:
  * ``mosa_attention_pallas``      — inference forward (router scaling fused),
  * ``mosa_attention_fwd_res``     — training forward: emits the PRE-scale
    output ``o_pre`` (fp32) and the per-query log-sum-exp ``lse`` (fp32), the
    residuals the recompute-style backward kernels in ``mosa_backward.py``
    need.  ``mosa_vjp.py`` stitches the two into a ``jax.custom_vjp``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _pair_mask(idx_q, idx_k, seg_q, seg_k):
    """Causal-by-original-position AND same-segment AND valid-key mask.

    idx carries the token's ORIGINAL position (within its own sequence);
    seg carries the segment id of the packed stream (-1 = padding).
    """
    return ((seg_q[:, None] == seg_k[None, :])
            & (idx_q[:, None] >= idx_k[None, :])
            & (idx_k >= 0)[None, :])


def _mosa_kernel(idx_ref, seg_ref, r_ref, q_ref, k_ref, v_ref, o_ref, *,
                 block_k: int, scale: float):
    """Grid: (BH, S // block_q).  Refs (VMEM blocks):

    idx_ref: (1, S)       — selected-token original positions (whole row)
    seg_ref: (1, S)       — selected-token segment ids (whole row)
    r_ref:   (1, block_q) — router scores for this query block
    q_ref:   (1, block_q, d)
    k_ref:   (1, S, d)    — all selected keys for this (b, h)
    v_ref:   (1, S, d)
    o_ref:   (1, block_q, d)
    """
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    S = k_ref.shape[1]
    n_kb = S // block_k

    q = q_ref[0].astype(jnp.float32) * scale                  # (bq, d)
    qi = pl.program_id(1)
    idx_q = jax.lax.dynamic_slice(idx_ref[0], (qi * block_q,), (block_q,))
    seg_q = jax.lax.dynamic_slice(seg_ref[0], (qi * block_q,), (block_q,))

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        idx_k = jax.lax.dynamic_slice(idx_ref[0], (kb * block_k,), (block_k,))
        seg_k = jax.lax.dynamic_slice(seg_ref[0], (kb * block_k,), (block_k,))

        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        mask = _pair_mask(idx_q, idx_k, seg_q, seg_k)
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))

    out = acc / jnp.maximum(l, 1e-30)[:, None]
    out = out * r_ref[0][:, None]                              # router scaling
    o_ref[0] = out.astype(o_ref.dtype)


def _mosa_fwd_res_kernel(idx_ref, seg_ref, r_ref, q_ref, k_ref, v_ref,
                         o_ref, lse_ref, *, block_k: int, scale: float):
    """Training forward: same streaming softmax as ``_mosa_kernel`` but emits
    the residuals the backward pass needs — the UNSCALED output ``o_pre``
    (router scaling applied outside so ``o_pre`` survives ``r == 0`` rows)
    and ``lse = m + log(l)`` per query.  ``r_ref`` rides along unused so both
    forward kernels share one BlockSpec layout."""
    del r_ref
    block_q, d = q_ref.shape[1], q_ref.shape[2]
    S = k_ref.shape[1]
    n_kb = S // block_k

    q = q_ref[0].astype(jnp.float32) * scale                  # (bq, d)
    qi = pl.program_id(1)
    idx_q = jax.lax.dynamic_slice(idx_ref[0], (qi * block_q,), (block_q,))
    seg_q = jax.lax.dynamic_slice(seg_ref[0], (qi * block_q,), (block_q,))

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[0], (kb * block_k, 0), (block_k, d)).astype(jnp.float32)
        idx_k = jax.lax.dynamic_slice(idx_ref[0], (kb * block_k,), (block_k,))
        seg_k = jax.lax.dynamic_slice(seg_ref[0], (kb * block_k,), (block_k,))

        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _pair_mask(idx_q, idx_k, seg_q, seg_k)
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))

    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0] = acc / l_safe[:, None]
    lse_ref[0] = m + jnp.log(l_safe)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "scale",
                                             "interpret"))
def mosa_attention_pallas(q, k, v, idx, seg, r, *, block_q: int = 128,
                          block_k: int = 128, scale: float | None = None,
                          interpret: bool = False):
    """q, k, v: (B, H, S, d); idx, seg: (B, H, S) int32; r: (B, H, S) fp32.

    Preconditions (ops.py guarantees them): S % block_q == 0,
    S % block_k == 0, d padded to 128 lanes.
    """
    B, H, S, d = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = scale if scale is not None else d ** -0.5
    BH = B * H
    qf = q.reshape(BH, S, d)
    kf = k.reshape(BH, S, d)
    vf = v.reshape(BH, S, d)
    idxf = idx.reshape(BH, S)
    segf = seg.reshape(BH, S)
    rf = r.reshape(BH, S).astype(jnp.float32)

    grid = (BH, S // block_q)
    kernel = functools.partial(_mosa_kernel, block_k=block_k, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S), lambda b, i: (b, 0)),            # idx
            pl.BlockSpec((1, S), lambda b, i: (b, 0)),            # seg
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),      # r
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),  # q
            pl.BlockSpec((1, S, d), lambda b, i: (b, 0, 0)),      # k
            pl.BlockSpec((1, S, d), lambda b, i: (b, 0, 0)),      # v
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        interpret=interpret,
    )(idxf, segf, rf, qf, kf, vf)
    return out.reshape(B, H, S, d)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "scale",
                                             "interpret"))
def mosa_attention_fwd_res(q, k, v, idx, seg, r, *, block_q: int = 128,
                           block_k: int = 128, scale: float | None = None,
                           interpret: bool = False):
    """Training-path forward.  Same preconditions as ``mosa_attention_pallas``
    (padded shapes from ops.py); returns ``(o_pre, lse)``:

      o_pre: (B, H, S, d) fp32 — softmax(QK^T masked) V, BEFORE router scaling
      lse:   (B, H, S)    fp32 — per-query log-sum-exp of the masked scores

    The caller applies ``out = o_pre * r`` (XLA fuses the scale into the
    kernel's consumer) and keeps both tensors as VJP residuals.
    """
    B, H, S, d = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = scale if scale is not None else d ** -0.5
    BH = B * H
    qf = q.reshape(BH, S, d)
    kf = k.reshape(BH, S, d)
    vf = v.reshape(BH, S, d)
    idxf = idx.reshape(BH, S)
    segf = seg.reshape(BH, S)
    rf = r.reshape(BH, S).astype(jnp.float32)

    grid = (BH, S // block_q)
    kernel = functools.partial(_mosa_fwd_res_kernel, block_k=block_k,
                               scale=scale)
    o_pre, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S), lambda b, i: (b, 0)),            # idx
            pl.BlockSpec((1, S), lambda b, i: (b, 0)),            # seg
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),      # r (unused)
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),  # q
            pl.BlockSpec((1, S, d), lambda b, i: (b, 0, 0)),      # k
            pl.BlockSpec((1, S, d), lambda b, i: (b, 0, 0)),      # v
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, d), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ],
        interpret=interpret,
    )(idxf, segf, rf, qf, kf, vf)
    return o_pre.reshape(B, H, S, d), lse.reshape(B, H, S)
