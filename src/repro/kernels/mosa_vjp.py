"""``jax.custom_vjp`` around the fused MoSA attention kernels.

This is what makes the Pallas path TRAINABLE end-to-end: the primal call is
the inference kernel (``mosa_attention_pallas``, router scaling fused, zero
overhead when nobody differentiates), while under ``jax.grad`` the forward
switches to ``mosa_attention_fwd_res`` (which also emits the ``o_pre``/
``lse`` residuals) and the backward runs the recompute-style Pallas kernels
in ``mosa_backward.py``.

Gradients produced: dq, dk, dv AND dr — the router-score cotangent.  dr is
the gradient path that makes expert-choice selection learnable: upstream it
flows through ``take_along_axis`` into the selected tokens' sigmoid scores
and on into the router weights, exactly like autodiff of the einsum
reference (the parity oracle in tests/test_train_grad.py).

Static config (block sizes, scale, interpret) is closed over by a cached
factory instead of ``nondiff_argnums``, so each static combination builds
its ``custom_vjp`` once.

Wrapper-level math kept OUT of the kernels (cheap O(S*d) elementwise):

  g~    = r * g                 (router scaling of the cotangent)
  delta = rowsum(g~ * o_pre)    (the flash-bwd softmax correction term)
  dr    = rowsum(g  * o_pre)

``idx``/``seg`` are integer (non-differentiable): their cotangents are
``float0`` zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mosa_attention import (mosa_attention_fwd_res,
                                          mosa_attention_pallas)
from repro.kernels.mosa_backward import mosa_attention_bwd_pallas


@functools.lru_cache(maxsize=None)
def _build(block_q: int, block_k: int, scale: float, interpret: bool):
    @jax.custom_vjp
    def fused(q, k, v, idx, seg, r):
        return mosa_attention_pallas(q, k, v, idx, seg, r, block_q=block_q,
                                     block_k=block_k, scale=scale,
                                     interpret=interpret)

    def fwd(q, k, v, idx, seg, r):
        o_pre, lse = mosa_attention_fwd_res(q, k, v, idx, seg, r,
                                            block_q=block_q, block_k=block_k,
                                            scale=scale, interpret=interpret)
        rf = r.astype(jnp.float32)
        out = (o_pre * rf[..., None]).astype(q.dtype)
        return out, (q, k, v, idx, seg, rf, o_pre, lse)

    def bwd(res, g):
        q, k, v, idx, seg, rf, o_pre, lse = res
        g32 = g.astype(jnp.float32)
        gt = g32 * rf[..., None]                       # (B,H,S,d) fp32
        dr = jnp.sum(g32 * o_pre, axis=-1)             # router-score grad
        delta = jnp.sum(gt * o_pre, axis=-1)
        dq, dk, dv = mosa_attention_bwd_pallas(
            q, k, v, idx, seg, gt, lse, delta, block_q=block_q,
            block_k=block_k, scale=scale, interpret=interpret)
        didx = np.zeros(idx.shape, jax.dtypes.float0)  # int input: no grad
        dseg = np.zeros(seg.shape, jax.dtypes.float0)
        return dq, dk, dv, didx, dseg, dr.astype(jnp.float32)

    fused.defvjp(fwd, bwd)
    return fused


def mosa_attention_trainable(q, k, v, idx, r, *, seg=None,
                             block_q: int = 128, block_k: int = 128,
                             scale: float | None = None,
                             interpret: bool = False):
    """Differentiable fused MoSA attention.  Same contract and preconditions
    as ``mosa_attention_pallas`` (ops.py handles padding); additionally
    supports ``jax.grad`` w.r.t. q, k, v and r.  ``seg`` (B, H, S) int32
    carries packed-varlen segment ids (None = single segment)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if seg is None:
        seg = jnp.zeros(idx.shape, jnp.int32)
    return _build(block_q, block_k, float(scale), bool(interpret))(
        q, k, v, idx, seg, r.astype(jnp.float32))
