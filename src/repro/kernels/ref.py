"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mosa_attention_ref(q, k, v, idx, r, scale=None, seg=None):
    """MoSA inner attention over selected tokens.

    q, k, v: (B, H, S, d) — S = number of selected tokens (the paper's k)
    idx:     (B, H, S) int32 original positions (sorted ascending); -1 = pad
    r:       (B, H, S) fp32 router scores for the *query* tokens
    seg:     optional (B, H, S) int32 segment ids (packed varlen streams);
             attention additionally requires seg_q == seg_k
    out:     (B, H, S, d) = softmax(q k^T masked by idx_q >= idx_k) v * r_q
    """
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid_k = idx >= 0
    mask = (idx[..., :, None] >= idx[..., None, :]) & valid_k[..., None, :]
    if seg is not None:
        mask &= seg[..., :, None] == seg[..., None, :]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    att = jnp.einsum("bhqk,bhkd->bhqd", p / denom, v.astype(jnp.float32))
    return (att * r[..., None]).astype(q.dtype)


def mosa_block_attention_ref(q, k, v, bidx, rblk, bs: int, T: int,
                             scale=None, seg=None):
    """Block-choice MoSA inner attention oracle (DESIGN §10).

    q, k, v: (B, H, S, d) — S = NB*bs block-major selected tokens
    bidx:    (B, H, NB) int32 selected block indices (ascending); -1 = empty
    rblk:    (B, H, NB) fp32 per-block router scores
    T:       true sequence length (tail positions >= T are invalid)
    seg:     optional (B, H, S) per-token segment ids

    Expands block indices to per-token positions and applies the identical
    mask family as the fused kernels: same-segment AND causal-by-position
    AND valid-key; invalid query rows produce exact zeros.
    """
    B, H, NB = bidx.shape
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    off = jnp.arange(bs, dtype=jnp.int32)
    pos = (bidx[..., None] * bs + off)
    ok = ((bidx[..., None] >= 0) & (pos < T)).reshape(B, H, NB * bs)
    pos = pos.reshape(B, H, NB * bs)
    r_tok = jnp.broadcast_to(rblk[..., None].astype(jnp.float32),
                             (B, H, NB, bs)).reshape(B, H, NB * bs)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = (pos[..., :, None] >= pos[..., None, :]) & ok[..., None, :]
    if seg is not None:
        mask &= seg[..., :, None] == seg[..., None, :]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    att = jnp.einsum("bhqk,bhkd->bhqd", p / denom, v.astype(jnp.float32))
    att = att * r_tok[..., None] * ok[..., None]
    return att.astype(q.dtype)


def flash_attention_ref(q, k, v, scale=None, window: int = 0, k_len=None):
    """Causal (optionally sliding-window) GQA attention.

    q: (B, Hq, Tq, d); k, v: (B, Hkv, Tk, d); Hq % Hkv == 0.
    q rows are the *last* Tq positions of the Tk-long context
    (Tq == Tk for training; Tq == 1 for decode).
    k_len: optional (B,) valid KV length (defaults to Tk).
    """
    B, Hq, Tq, d = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    q_pos = jnp.arange(Tk - Tq, Tk)
    k_pos = jnp.arange(Tk)
    ok = q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    ok = jnp.broadcast_to(ok, (B, Hq, Tq, Tk))
    if k_len is not None:
        ok = ok & (k_pos[None, None, None, :] < k_len[:, None, None, None])
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(ok, p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_varlen_ref(q, k, v, cu_seqlens, scale=None,
                               window: int = 0):
    """Packed ragged causal attention oracle.

    q: (total, Hq, d); k, v: (total, Hkv, d); cu_seqlens: (N+1,) int32.
    Runs each segment through ``flash_attention_ref`` independently and
    re-concatenates — the definitional per-row baseline the packed kernel
    must match.
    """
    import numpy as np
    cu = np.asarray(cu_seqlens)
    outs = []
    for s in range(len(cu) - 1):
        a, b = int(cu[s]), int(cu[s + 1])
        qs = q[a:b].transpose(1, 0, 2)[None]     # (1, Hq, T, d)
        ks = k[a:b].transpose(1, 0, 2)[None]
        vs = v[a:b].transpose(1, 0, 2)[None]
        o = flash_attention_ref(qs, ks, vs, scale=scale, window=window)
        outs.append(o[0].transpose(1, 0, 2))
    return jnp.concatenate(outs, axis=0)
