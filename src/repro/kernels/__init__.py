"""Pallas kernel package: fused MoSA attention (fwd + custom-VJP bwd) and
flash attention, with pure-jnp oracles in ``ref``.

Exports resolve lazily (PEP 562, the ``repro.serve`` pattern): importing
``repro.core`` — whose MoSA layer only *conditionally* dispatches here under
``impl="pallas"`` — must never pull ``jax.experimental.pallas`` eagerly.
Leaf modules stay importable directly (``repro.kernels.ops`` etc.).
"""

_EXPORTS = {
    "mosa_attention": "ops",
    "flash_attention": "ops",
    "mosa_attention_pallas": "mosa_attention",
    "mosa_attention_fwd_res": "mosa_attention",
    "mosa_attention_bwd_pallas": "mosa_backward",
    "mosa_attention_trainable": "mosa_vjp",
    "mosa_block_attention": "ops",
    "mosa_block_attention_pallas": "mosa_block",
    "mosa_block_attention_fwd_res": "mosa_block",
    "mosa_block_attention_bwd_pallas": "mosa_block",
    "mosa_block_attention_trainable": "mosa_block",
    "flash_attention_pallas": "flash_attention",
    "mosa_attention_ref": "ref",
    "mosa_block_attention_ref": "ref",
    "flash_attention_ref": "ref",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(f"repro.kernels.{_EXPORTS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.kernels' has no attribute {name!r}")
