import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); do not move them.

For each cell this driver:
  1. builds the full-size config (long_500k switches pure-attention archs to
     the paper's mosa_hybrid mode — MoSA global heads + sliding-window local
     heads; ssm/hybrid archs run natively);
  2. lowers the right step with ShapeDtypeStruct inputs (no allocation):
       train_4k    -> train_step (fwd + bwd + AdamW update)
       prefill_32k -> model.prefill (forward + cache write)
       decode_*    -> serve_step (one token against a seq_len KV cache)
  3. ``.compile()``s it for the production mesh (16x16 or 2x16x16),
  4. records memory_analysis / cost_analysis / parsed collective bytes into
     ``experiments/dryrun/<mesh>/<arch>__<shape>.json``.

CLI:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, config_names
from repro.configs.shapes import SHAPES, input_specs
from repro.dist import sharding as shd
from repro.dist import hints
from repro.launch.mesh import make_production_mesh
from repro.nn.module import init_shapes
from repro.nn.transformer import TransformerLM
from repro.optim import schedules
from repro.optim.optimizer import adamw, apply_updates

ARCHS = [
    "granite-moe-1b-a400m", "deepseek-v2-lite-16b", "jamba-v0.1-52b",
    "musicgen-large", "yi-34b", "yi-9b", "gemma3-4b", "qwen2-1.5b",
    "xlstm-125m", "qwen2-vl-72b",
]

# archs whose long_500k cell runs natively (recurrent state); everything else
# switches to the paper's MoSA+local mode for that shape.
NATIVE_LONG = {"xlstm-125m", "jamba-v0.1-52b"}

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
OPNAME_RE = re.compile(r'op_name="([^"]*)"')
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3fn": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2}
# effective per-chip traffic multiplier on the printed (per-shard) shape
ALGO_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def parse_collective_bytes(hlo_text: str, trip_counts=(1,)):
    """Per-device collective traffic bytes by op kind, from partitioned HLO.

    XLA prints each while (scan) body once; an op whose op_name metadata
    contains d occurrences of "/while/" executes prod(trip_counts[:d]) times
    per step.  ``trip_counts[d-1]`` is the trip count of loop nesting level d
    (level 1 = the layer scan).  Bytes are also recorded per depth so the
    correction's impact is auditable.
    """
    out = {}
    by_depth = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if m is None or "=" not in line:
            continue
        kind = m.group(1)
        rhs = line.split("=", 1)[1]
        head = rhs.split(m.group(0))[0]
        # Result type only.  For tuple results (e.g. all-gather-start's
        # (operand, result) pair) take the LARGEST element — summing every
        # annotation double-counts the traffic.
        sizes = []
        for dt, dims in SHAPE_RE.findall(head):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            sizes.append(n * DTYPE_BYTES[dt])
        bytes_ = max(sizes) if sizes else 0
        opn = OPNAME_RE.search(line)
        depth = opn.group(1).count("/while/") if opn else 0
        mult = 1.0
        for lvl in range(min(depth, len(trip_counts))):
            mult *= trip_counts[lvl]
        eff = bytes_ * ALGO_FACTOR[kind] * mult
        out[kind] = out.get(kind, 0) + eff
        by_depth[depth] = by_depth.get(depth, 0) + eff
        out.setdefault("_ops", 0)
        out["_ops"] += 1
    out["total"] = sum(v for k, v in out.items() if not k.startswith("_"))
    out["_by_depth"] = by_depth
    out["_trip_counts"] = list(trip_counts)
    return out


def build_cfg(arch: str, shape_name: str, mosa: bool = False,
              remat: str | None = None):
    cfg = get_config(arch, preset="full")
    shape = SHAPES[shape_name]
    note = ""
    if shape_name == "long_500k" and arch not in NATIVE_LONG:
        cfg = cfg.with_mosa(sparsity=32, n_mosa_heads=cfg.attention.n_heads,
                            local_window=4096, k_fixed=512)
        note = "mosa_hybrid long-context mode (paper §3.4): " \
               "k_fixed=512, local window 4096"
    elif mosa:
        cfg = cfg.with_mosa(sparsity=32,
                            n_mosa_heads=4 * cfg.attention.n_heads)
        note = "mosa_hybrid variant (paper technique): rho=32, " \
               f"{4 * cfg.attention.n_heads} sparse + 4 dense heads"
    if shape.kind == "train":
        cfg = dataclasses.replace(cfg, remat=remat or "full")
    elif remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    return cfg, shape, note


def build_model(cfg, mesh, rule_set: str, act_seq_shard: bool):
    act_spec = None
    if act_seq_shard:
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        act_spec = P(dp if dp else None, "model")
    return TransformerLM(cfg, act_spec=act_spec)


def lower_cell(arch: str, shape_name: str, mesh, rule_set: str = "fsdp_tp",
               act_seq_shard: bool = True, mosa: bool = False,
               remat: str | None = None, use_hints: bool = True):
    cfg, shape, note = build_cfg(arch, shape_name, mosa=mosa, remat=remat)
    model = build_model(cfg, mesh, rule_set,
                        act_seq_shard and shape.kind == "train")
    shapes = init_shapes(model)
    param_sh = shd.param_shardings(model, mesh, rule_set, shapes)
    specs = input_specs(cfg, shape)
    batch_sh = shd.batch_sharding(mesh, rule_set, batch=shape.global_batch)
    emb_sh = NamedSharding(mesh, P(*(batch_sh.spec + (None,))))

    def in_sh(spec_dict):
        return {k: emb_sh if k == "embeds" else batch_sh
                for k in spec_dict}

    import contextlib
    hint_ctx = hints.sharding_hints(mesh=mesh) if use_hints else \
        contextlib.nullcontext()
    with mesh, hint_ctx:
        if shape.kind == "train":
            opt = adamw(schedules.linear_warmup(2.5e-4, 400), clip_norm=0.25)
            opt_shapes = jax.eval_shape(opt.init, shapes)
            opt_sh = {"mu": param_sh, "nu": param_sh}

            def train_step(params, opt_state, step, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch)
                updates, opt_state, _ = opt.update(grads, opt_state, params,
                                                   step)
                params = apply_updates(params, updates)
                return params, opt_state, step + 1, loss

            lowered = jax.jit(
                train_step,
                in_shardings=(param_sh, opt_sh, None, in_sh(specs)),
                out_shardings=(param_sh, opt_sh, None, None),
                donate_argnums=(0, 1),
            ).lower(shapes, opt_shapes,
                    jax.ShapeDtypeStruct((), jnp.int32), specs)

        elif shape.kind == "prefill":
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sh = shd.cache_shardings(cache_shapes, mesh, rule_set,
                                           seq_sharded=shape.global_batch == 1)

            def prefill_step(params, batch, caches):
                tokens = batch.get("tokens")
                embeds = batch.get("embeds")
                return model.prefill(params, tokens, caches,
                                     inputs_embeds=embeds)

            pf_specs = {k: v for k, v in specs.items() if k != "labels"}
            lowered = jax.jit(
                prefill_step,
                in_shardings=(param_sh, in_sh(pf_specs), cache_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(2,),
            ).lower(shapes, pf_specs, cache_shapes)

        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sh = shd.cache_shardings(cache_shapes, mesh, rule_set,
                                           seq_sharded=shape.global_batch == 1)

            def serve_step(params, token, caches):
                return model.decode_step(params, token, caches)

            lowered = jax.jit(
                serve_step,
                in_shardings=(param_sh, batch_sh, cache_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(2,),
            ).lower(shapes, specs["token"], cache_shapes)

    return lowered, cfg, shape, note, model


def _trip_counts(model, shape):
    """(layer-scan trips, inner-loop trips, inner-inner) for collective
    correction.  Inner trips are the chunked-scan counts of the mixers."""
    head, p, units, tail_start, pattern = model._layout()
    if shape.kind == "decode":
        return (max(units, 1), 1, 1)
    T = shape.seq_len
    inner = 1
    kinds = {b.mixer for b in pattern}
    if kinds & {"attn", "attn_local", "mosa"}:
        inner = max(inner, -(-T // 512))        # chunked attention
    if "mamba" in kinds:
        inner = max(inner, -(-T // 128))        # mamba chunk scan
    if "mlstm" in kinds:
        inner = max(inner, -(-T // 64))
    inner2 = 128 if (kinds & {"mamba", "mlstm"}) else 1
    if "slstm" in kinds:
        inner = max(inner, T)                   # per-token recurrence
    return (max(units, 1), inner, inner2)


def analyze(lowered, compiled, n_devices: int, trip_counts=(1,),
            cfg=None, shape=None):
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # jax < 0.5 returns [dict]
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    coll = parse_collective_bytes(compiled.as_text(), trip_counts)
    per_dev_flops = float(ca.get("flops", 0.0))
    per_dev_bytes = float(ca.get("bytes accessed", 0.0))
    mem = {}
    if ma is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes"):
            mem[f] = int(getattr(ma, f, 0))
        mem["total_per_device"] = (mem["argument_size_in_bytes"] +
                                   mem["temp_size_in_bytes"] +
                                   mem["output_size_in_bytes"])
    rec = {
        "n_devices": n_devices,
        # NOTE: cost_analysis counts while(scan) bodies once — these raw HLO
        # numbers are diagnostics; the roofline uses the analytic block.
        "per_device_flops_hlo_raw": per_dev_flops,
        "per_device_bytes_hlo_raw": per_dev_bytes,
        "collective_bytes_per_device": coll,
        "memory": mem,
    }
    if cfg is not None and shape is not None:
        from benchmarks.analytic import cell_cost
        cc = cell_cost(cfg, shape)
        rec["analytic"] = {
            "flops_global": cc.flops_global,
            "bytes_global": cc.bytes_global,
            "model_flops": cc.model_flops,
            "n_params": cc.n_params,
            "n_active": cc.n_active,
        }
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             rule_set: str = "fsdp_tp", out_dir: str = "experiments/dryrun",
             act_seq_shard: bool = True, tag: str = "", mosa: bool = False,
             remat: str | None = None, use_hints: bool = True):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 512 if multi_pod else 256
    t0 = time.time()
    lowered, cfg, shape, note, model = lower_cell(arch, shape_name, mesh,
                                                  rule_set, act_seq_shard,
                                                  mosa=mosa, remat=remat,
                                                  use_hints=use_hints)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    trips = _trip_counts(model, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "rule_set": rule_set, "model_name": cfg.name, "note": note,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        **analyze(lowered, compiled, n_dev, trips, cfg, shape),
    }
    sub = os.path.join(out_dir, mesh_name + (f"_{tag}" if tag else ""))
    os.makedirs(sub, exist_ok=True)
    with open(os.path.join(sub, f"{arch}__{shape_name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    mem = rec["memory"].get("total_per_device", 0) / 2**30
    print(f"[ok] {arch:24s} {shape_name:12s} {mesh_name}  "
          f"compile {t_compile:6.1f}s  mem/dev {mem:7.2f} GiB  "
          f"flops/dev {rec['analytic']['flops_global']/n_dev:.3e}  "
          f"coll/dev {rec['collective_bytes_per_device']['total']/2**20:9.1f} MiB")
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--rule-set", default="fsdp_tp")
    p.add_argument("--no-act-shard", action="store_true")
    p.add_argument("--out-dir", default="experiments/dryrun")
    p.add_argument("--tag", default="")
    p.add_argument("--mosa", action="store_true",
                   help="apply the paper's MoSA hybrid to the arch")
    p.add_argument("--remat", default=None,
                   choices=[None, "full", "dots_saveable", "none"])
    p.add_argument("--no-hints", action="store_true")
    args = p.parse_args(argv)

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    run_cell(arch, shape, multi_pod=mp,
                             rule_set=args.rule_set, out_dir=args.out_dir,
                             act_seq_shard=not args.no_act_shard,
                             tag=args.tag, mosa=args.mosa, remat=args.remat,
                             use_hints=not args.no_hints)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[FAIL] {arch} {shape} multi_pod={mp}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall cells compiled OK")


if __name__ == "__main__":
    main()
