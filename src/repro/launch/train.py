"""End-to-end distributed training driver.

Builds the full stack for one (arch, shape, mesh) choice:
  data pipeline -> sharded init -> pjit'd train_step (fwd+bwd+AdamW) ->
  checkpoint/restart -> straggler monitor -> preemption handling.

Usable as a library (``Trainer``) and as a CLI:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \\
      --preset smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs.base import ModelConfig, get_config
from repro.data.pipeline import PackedLMDataset, Prefetcher, SyntheticCorpus
from repro.dist import sharding as shd
from repro.dist import hints
from repro.dist.fault_tolerance import (Heartbeat, PreemptionHandler,
                                        StragglerMonitor, elastic_plan)
from repro.launch import mesh as mesh_lib
from repro.nn.module import init_shapes
from repro.nn.transformer import TransformerLM
from repro.optim import schedules
from repro.optim.optimizer import adamw, apply_updates


@dataclasses.dataclass
class TrainConfig:
    arch: str = "mosa-paper"
    preset: str = "full"
    seq_len: int = 1024
    global_batch: int = 64
    steps: int = 100
    lr: float = 2.5e-4
    warmup: int = 400
    clip_norm: float = 0.25
    weight_decay: float = 0.0
    seed: int = 0
    rule_set: str = "tp"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    keep_last: int = 3
    log_every: int = 10
    mesh_shape: Optional[tuple] = None   # None = all local devices
    arch_kwargs: dict = dataclasses.field(default_factory=dict)


def make_train_step(model: TransformerLM, optimizer):
    def train_step(params, opt_state, step, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        updates, opt_state, opt_m = optimizer.update(grads, opt_state,
                                                     params, step)
        params = apply_updates(params, updates)
        metrics = {**metrics, **opt_m, "loss": loss}
        return params, opt_state, step + 1, metrics

    return train_step


class Trainer:
    def __init__(self, cfg: TrainConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg or get_config(cfg.arch, preset=cfg.preset,
                                                 **cfg.arch_kwargs)
        self.model = TransformerLM(self.model_cfg)
        if cfg.mesh_shape:
            axes = ("pod", "data", "model")[-len(cfg.mesh_shape):]
            self.mesh = mesh_lib.make_mesh(cfg.mesh_shape, axes)
        else:
            plan = elastic_plan(len(jax.devices()), tp=1)
            self.mesh = mesh_lib.make_mesh(plan["shape"], plan["axes"])
        self.optimizer = adamw(
            schedules.linear_warmup(cfg.lr, cfg.warmup),
            weight_decay=cfg.weight_decay, clip_norm=cfg.clip_norm)

        # shardings
        shapes = init_shapes(self.model)
        self.param_sh = shd.param_shardings(self.model, self.mesh,
                                            cfg.rule_set, shapes)
        opt_shapes = jax.eval_shape(self.optimizer.init, shapes)
        self.opt_sh = {
            "mu": self.param_sh, "nu": self.param_sh,
        } if set(opt_shapes) == {"mu", "nu"} else jax.tree.map(
            lambda _: shd.replicated(self.mesh), opt_shapes)
        self.batch_sh = shd.batch_sharding(self.mesh, cfg.rule_set)
        self.scalar_sh = shd.replicated(self.mesh)

        step_fn = make_train_step(self.model, self.optimizer)
        self.train_step = jax.jit(
            step_fn,
            in_shardings=(self.param_sh, self.opt_sh, self.scalar_sh,
                          jax.tree.map(lambda _: self.batch_sh,
                                       {"tokens": 0, "labels": 0})),
            out_shardings=(self.param_sh, self.opt_sh, self.scalar_sh, None),
            donate_argnums=(0, 1),
        )

        # data
        n_data = 1
        for a in ("pod", "data"):
            n_data *= self.mesh.shape.get(a, 1)
        self.dataset = PackedLMDataset(
            SyntheticCorpus(vocab=self.model_cfg.vocab, seed=cfg.seed),
            seq_len=cfg.seq_len, global_batch=cfg.global_batch,
            shard_index=0, shard_count=1)  # single-host: full batch here

        self.monitor = StragglerMonitor()
        self.preempt: Optional[PreemptionHandler] = None

    # ------------------------------------------------------------------ state
    def init_state(self):
        key = jax.random.PRNGKey(self.cfg.seed)
        with self.mesh, hints.sharding_hints(mesh=self.mesh):
            params = jax.jit(self.model.init,
                             out_shardings=self.param_sh)(key)
            opt_state = jax.jit(self.optimizer.init,
                                out_shardings=self.opt_sh)(params)
        step = jnp.zeros((), jnp.int32)
        return params, opt_state, step

    def restore_or_init(self):
        cfg = self.cfg
        if cfg.ckpt_dir and ckpt_lib.latest_step(cfg.ckpt_dir) is not None:
            shapes = init_shapes(self.model)
            opt_shapes = jax.eval_shape(self.optimizer.init, shapes)
            tree = {"params": shapes, "opt": opt_shapes}
            sh = {"params": self.param_sh, "opt": self.opt_sh}
            restored, extra = ckpt_lib.restore(cfg.ckpt_dir, tree,
                                               shardings=sh)
            step = jnp.asarray(extra.get("step", 0), jnp.int32)
            return restored["params"], restored["opt"], step, int(extra.get("step", 0))
        params, opt, step = self.init_state()
        return params, opt, step, 0

    # ------------------------------------------------------------------ train
    def run(self, steps: Optional[int] = None, install_signals: bool = True):
        cfg = self.cfg
        steps = steps if steps is not None else cfg.steps
        params, opt_state, step, start = self.restore_or_init()
        self.preempt = PreemptionHandler() if install_signals else None
        checkpointer = (ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep_last)
                        if cfg.ckpt_dir else None)
        hb = Heartbeat(cfg.ckpt_dir, rank=0) if cfg.ckpt_dir else None
        prefetch = Prefetcher(self.dataset, start_step=start)
        history = []
        try:
            with self.mesh, hints.sharding_hints(mesh=self.mesh):
                for i in range(start, steps):
                    data_step, batch = prefetch.next()
                    batch = {k: jnp.asarray(v) for k, v in batch.items()}
                    t0 = time.perf_counter()
                    params, opt_state, step, metrics = self.train_step(
                        params, opt_state, step, batch)
                    metrics = {k: float(v) for k, v in metrics.items()}
                    dt = time.perf_counter() - t0
                    straggler = self.monitor.record(i, dt)
                    if hb:
                        hb.beat(i)
                    if i % cfg.log_every == 0 or i == steps - 1:
                        history.append({"step": i, "dt": dt, **metrics})
                        print(f"step {i:6d} loss {metrics['loss']:.4f} "
                              f"ppl {metrics['ppl']:.2f} "
                              f"gnorm {metrics['grad_norm']:.3f} "
                              f"{dt*1e3:.0f}ms"
                              + (" [straggler]" if straggler else ""))
                    want_ckpt = checkpointer and (
                        (i + 1) % cfg.ckpt_every == 0 or i == steps - 1 or
                        (self.preempt and self.preempt.requested))
                    if want_ckpt:
                        checkpointer.save(
                            i + 1, {"params": params, "opt": opt_state},
                            extra_meta={"step": i + 1,
                                        "model": self.model_cfg.name})
                    if self.preempt and self.preempt.requested:
                        print(f"preemption requested; checkpointed at {i+1}")
                        break
        finally:
            prefetch.close()
            if checkpointer:
                checkpointer.wait()
            if self.preempt:
                self.preempt.restore()
        return params, opt_state, history


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mosa-paper")
    p.add_argument("--preset", default="smoke")
    p.add_argument("--variant", default=None,
                   help="mosa-paper variant: dense|mosa|fixed|routing|pure")
    p.add_argument("--sparsity", type=int, default=None)
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=2.5e-4)
    p.add_argument("--warmup", type=int, default=100)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=200)
    p.add_argument("--rule-set", default="tp")
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    akw = {}
    if args.variant is not None:
        akw["variant"] = args.variant
    if args.sparsity is not None:
        akw["sparsity"] = args.sparsity
    cfg = TrainConfig(arch=args.arch, preset=args.preset, steps=args.steps,
                      global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                      warmup=args.warmup, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, rule_set=args.rule_set,
                      log_every=args.log_every, arch_kwargs=akw)
    trainer = Trainer(cfg)
    _, _, history = trainer.run()
    print(json.dumps({"final": history[-1] if history else None,
                      "straggler": trainer.monitor.summary()}))


if __name__ == "__main__":
    main()
