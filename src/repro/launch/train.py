"""CLI face of the training subsystem (``repro.train``, DESIGN §8).

The driver itself — resumable loop, donated train step, microbatch
accumulation, mixed precision, router telemetry — lives in
``repro.train.loop`` / ``repro.train.step``; this module parses flags,
builds a ``TrainConfig``, and runs it.  ``TrainConfig`` / ``Trainer`` /
``make_train_step`` are re-exported here for compatibility (they moved in
the PR that introduced ``repro.train``).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \\
      --preset smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

  # the paper's IsoFLOP smoke sweep (dense vs MoSA at one matched budget):
  PYTHONPATH=src python -m repro.launch.train --isoflop --steps 20 \\
      --batch 4 --seq 64
"""

from __future__ import annotations

import argparse
import json

from repro.train.loop import TrainConfig, Trainer          # noqa: F401
from repro.train.step import make_train_step               # noqa: F401


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mosa-paper")
    p.add_argument("--preset", default="smoke")
    p.add_argument("--variant", default=None,
                   help="mosa-paper variant: dense|mosa|fixed|routing|pure")
    p.add_argument("--sparsity", type=int, default=None)
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=2.5e-4)
    p.add_argument("--warmup", type=int, default=100)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=200)
    p.add_argument("--rule-set", default="tp")
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--microbatch", type=int, default=1,
                   help="gradient-accumulation splits per step")
    p.add_argument("--compute", default=None, choices=[None, "bfloat16",
                                                       "float32"],
                   help="bfloat16 = bf16-compute/fp32-master")
    p.add_argument("--remat", default=None,
                   choices=[None, "none", "full", "dots_saveable", "mosa"])
    p.add_argument("--mosa-impl", default=None,
                   choices=[None, "einsum", "pallas"],
                   help="pallas = fused fwd + custom-VJP bwd kernels")
    p.add_argument("--isoflop", action="store_true",
                   help="run the FLOP-matched dense-vs-MoSA sweep instead "
                        "of a single config")
    p.add_argument("--metrics-path", default=None,
                   help="write an obs metrics snapshot here on exit "
                        "(.jsonl appends; DESIGN §11)")
    p.add_argument("--trace-path", default=None,
                   help="write a Chrome-trace JSON of the run here on exit")
    p.add_argument("--no-health-in-step", action="store_true",
                   help="router health via a standalone forward at log "
                        "time instead of in-step aux outputs")
    args = p.parse_args(argv)

    if args.isoflop:
        from repro.train.isoflop import isoflop_sweep, run_isoflop
        points = isoflop_sweep(
            preset=args.preset, T=args.seq,
            sparsities=(args.sparsity,) if args.sparsity else (8,))
        results = run_isoflop(
            points, steps=args.steps, seq_len=args.seq,
            global_batch=args.batch, ckpt_root=args.ckpt_dir,
            train_kw={"lr": args.lr, "warmup": args.warmup,
                      "rule_set": args.rule_set,
                      "log_every": args.log_every,
                      "microbatch": args.microbatch,
                      "compute": args.compute, "remat": args.remat,
                      "mosa_impl": args.mosa_impl})
        print(json.dumps(results, indent=2, default=float))
        return

    akw = {}
    if args.variant is not None:
        akw["variant"] = args.variant
    if args.sparsity is not None:
        akw["sparsity"] = args.sparsity
    cfg = TrainConfig(arch=args.arch, preset=args.preset, steps=args.steps,
                      global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                      warmup=args.warmup, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, rule_set=args.rule_set,
                      log_every=args.log_every, arch_kwargs=akw,
                      microbatch=args.microbatch, compute=args.compute,
                      remat=args.remat, mosa_impl=args.mosa_impl,
                      health_in_step=not args.no_health_in_step,
                      metrics_path=args.metrics_path,
                      trace_path=args.trace_path)
    trainer = Trainer(cfg)
    _, _, history = trainer.run()
    print(json.dumps({"final": history[-1] if history else None,
                      "straggler": trainer.monitor.summary()}))


if __name__ == "__main__":
    main()
