"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def _auto(n):
    return (AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=_auto(len(shape)))


def make_host_mesh(tp: int = 1):
    """Mesh over whatever devices exist (CPU tests, elastic restarts)."""
    n = len(jax.devices())
    tp = min(tp, n)
    return jax.make_mesh((n // tp, tp), ("data", "model"),
                         axis_types=_auto(2))
