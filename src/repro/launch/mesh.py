"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first init).

Mesh building goes through ``repro.dist.sharding.make_mesh``, which handles
the jax-version differences around ``axis_types`` (absent before jax 0.5).
NOTE: importing that module (and hence this one) enables
``jax_threefry_partitionable`` — required so sharded param init reproduces
single-device init bit-for-bit; it changes RNG streams vs stock jax defaults.
"""

from __future__ import annotations

import jax

from repro.dist.sharding import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    return _make_mesh(shape, axes)


def make_host_mesh(tp: int = 1):
    """Mesh over whatever devices exist (CPU tests, elastic restarts)."""
    n = len(jax.devices())
    tp = min(tp, n)
    return _make_mesh((n // tp, tp), ("data", "model"))
