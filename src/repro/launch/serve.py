"""Batched serving driver.

Prefill + decode with per-layer caches; the MoSA layers realize the paper's
KV-cache reduction at serve time (streaming top-k cache, DESIGN §5).  The
decode hot path is the scan-fused chunk decoder of DESIGN §6: one jit
dispatch per *chunk* of tokens instead of several dispatches per token,
sampling on-device, caches donated, and (under the ``tp`` rule sets) the
MoSA KV caches head-sharded over the ``model`` mesh axis.

Library entry points:
  * ``Server`` — holds jit'd ``prefill`` / ``decode_step`` /
    ``decode_many`` with per-cache-type shardings; ``generate`` runs
    greedy / temperature / top-k decoding for a batch in one fused program;
    ``generate_stepwise`` keeps the legacy one-dispatch-per-token loop (the
    benchmark baseline).  ``Server(paged=PagedConfig(...))`` switches the
    dense/window KV caches to the block-paged pools of
    ``repro.serve.paged_kv`` and exposes the per-row ops
    (``prefill_row`` / ``snapshot_row`` / ``restore_row`` /
    ``grow_tables``) the paged scheduler drives (DESIGN §7).
  * ``RequestPool`` — contiguous-slab continuous batching: requests occupy
    batch slots; finished slots are refilled between fused decode chunks
    (single-row masked prefill written into the batched caches) and EOS is
    honored.  This is the NON-PAGED fallback; the paged path is
    ``repro.serve.Scheduler`` (block-granular admission, prefix cache,
    preempt-to-recompute), re-exported here as ``Scheduler``.

CLI (smoke-scale):
  PYTHONPATH=src python -m repro.launch.serve --arch mosa-paper \\
      --preset smoke --variant mosa --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import get_config
from repro.dist import sharding as shd
from repro.dist import hints
from repro.dist.fault_tolerance import elastic_plan
from repro.launch import mesh as mesh_lib
from repro.nn.module import init_shapes
from repro.nn.transformer import TransformerLM, sample_logits
from repro.serve.paged_kv import (PAGED_CACHE_TYPES, POOL_FIELDS,
                                  PagedConfig, PagedDenseKVCache,
                                  PagedWindowKVCache)
from repro.serve.scheduler import Scheduler  # noqa: F401  (re-export)


# ---------------------------------------------------- batch-row cache ops
# The serving caches are one pytree holding B rows; continuous batching
# needs to prefill / snapshot / restore ONE row without touching the
# others.  For contiguous caches a row is just index b of every leaf; for
# paged caches the POOL fields (see ``paged_kv.POOL_FIELDS``) are shared by
# all rows and pass through whole, while tables / positions / lengths are
# per-row.  Layer-stacked ``scan`` caches shift the batch dim right by the
# layer axis (DESIGN §2), handled here by vmapping the per-type op over the
# layer axis.

def _is_stacked(path) -> bool:
    return any(getattr(e, "key", None) == "scan" for e in path)


def _is_paged(x) -> bool:
    return isinstance(x, PAGED_CACHE_TYPES)


def row_slice(caches, b):
    """A batch-of-1 view of row ``b``: row fields sliced, pools shared —
    ``model.prefill`` on the view writes through to the shared pools."""
    def one(path, leaf):
        ax = 1 if _is_stacked(path) else 0
        if _is_paged(leaf):
            return type(leaf)(*(
                arr if name in POOL_FIELDS
                else jax.lax.dynamic_slice_in_dim(arr, b, 1, ax)
                for name, arr in zip(leaf._fields, leaf)))
        return jax.lax.dynamic_slice_in_dim(leaf, b, 1, ax)
    return jax.tree_util.tree_map_with_path(one, caches, is_leaf=_is_paged)


def row_write(caches, row, b):
    """Write a batch-of-1 row view back at row ``b``.  Paged pools REPLACE
    the batched pools (the view's writes only touched this row's blocks);
    row fields update in place."""
    def one(path, dst, src):
        ax = 1 if _is_stacked(path) else 0
        if _is_paged(dst):
            return type(dst)(*(
                s if name in POOL_FIELDS
                else jax.lax.dynamic_update_slice_in_dim(
                    d, s.astype(d.dtype), b, ax)
                for name, d, s in zip(dst._fields, dst, src)))
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), b, ax)
    return jax.tree_util.tree_map_with_path(one, caches, row,
                                            is_leaf=_is_paged)


def _snap_paged(leaf, b):
    """Row snapshot of one UNSTACKED paged cache: per-row metadata plus —
    for the window ring, whose blocks are mutated in place and therefore
    never shared — the gathered ring CONTENT (bounded by W)."""
    if isinstance(leaf, PagedDenseKVCache):
        return {"block_table": leaf.block_table[b], "length": leaf.length[b]}
    bt = jnp.clip(leaf.block_table[b], 0)
    k = leaf.k[bt].reshape(leaf.window, *leaf.k.shape[2:])
    v = leaf.v[bt].reshape(leaf.window, *leaf.v.shape[2:])
    return {"block_table": leaf.block_table[b], "k": k, "v": v,
            "positions": leaf.positions[b], "length": leaf.length[b]}


def _restore_paged(leaf, snap, b):
    """Inverse of ``_snap_paged`` at row ``b``.  The caller (Scheduler) has
    rewritten ``snap["block_table"]`` to freshly owned / incref'd block ids;
    window ring content is scattered into the NEW blocks."""
    if isinstance(leaf, PagedDenseKVCache):
        return leaf._replace(
            block_table=leaf.block_table.at[b].set(snap["block_table"]),
            length=leaf.length.at[b].set(snap["length"]))
    W, bs = leaf.window, leaf.block_size
    slots = jnp.arange(W, dtype=jnp.int32)
    blk = snap["block_table"][slots // bs]
    blk = jnp.where(blk < 0, leaf.k.shape[0], blk)
    off = slots % bs
    return leaf._replace(
        k=leaf.k.at[blk, off].set(snap["k"].astype(leaf.k.dtype),
                                  mode="drop"),
        v=leaf.v.at[blk, off].set(snap["v"].astype(leaf.v.dtype),
                                  mode="drop"),
        block_table=leaf.block_table.at[b].set(snap["block_table"]),
        positions=leaf.positions.at[b].set(snap["positions"]),
        length=leaf.length.at[b].set(snap["length"]))


def row_snapshot(caches, b):
    """Host-restorable state of row ``b``: paged metadata + ring content,
    full rows of every unpaged leaf (MoSA caches, SSM states).  Everything
    bounded — the quadratic dense KV stays behind block ids."""
    def one(path, leaf):
        if _is_paged(leaf):
            if _is_stacked(path):
                return jax.vmap(_snap_paged, in_axes=(0, None))(leaf, b)
            return _snap_paged(leaf, b)
        ax = 1 if _is_stacked(path) else 0
        return jax.lax.dynamic_slice_in_dim(leaf, b, 1, ax)
    return jax.tree_util.tree_map_with_path(one, caches, is_leaf=_is_paged)


def row_restore(caches, snap, b):
    """Write a ``row_snapshot`` back at row ``b`` (admission reset, prefix
    restore, preempt-resume)."""
    def one(path, dst, s):
        if _is_paged(dst):
            if _is_stacked(path):
                return jax.vmap(_restore_paged, in_axes=(0, 0, None))(
                    dst, s, b)
            return _restore_paged(dst, s, b)
        ax = 1 if _is_stacked(path) else 0
        return jax.lax.dynamic_update_slice_in_dim(
            dst, s.astype(dst.dtype), b, ax)
    return jax.tree_util.tree_map_with_path(one, caches, snap,
                                            is_leaf=_is_paged)


def set_dense_tables(caches, dense_row, b):
    """Point row ``b``'s dense block tables (every paged dense layer shares
    one logical chain) at ``dense_row`` — the decode-time growth write."""
    def one(path, leaf):
        if not isinstance(leaf, PagedDenseKVCache):
            return leaf
        if _is_stacked(path):
            bt = leaf.block_table.at[:, b].set(dense_row[None])
        else:
            bt = leaf.block_table.at[b].set(dense_row)
        return leaf._replace(block_table=bt)
    return jax.tree_util.tree_map_with_path(one, caches, is_leaf=_is_paged)


def set_window_tables(caches, window_row, b):
    """Point row ``b``'s window-ring block tables at ``window_row`` — the
    lazy-ring growth write (rings allocate blocks on first write, not at
    admission; -1 tail entries mean 'not yet written this far')."""
    def one(path, leaf):
        if not isinstance(leaf, PagedWindowKVCache):
            return leaf
        if _is_stacked(path):
            bt = leaf.block_table.at[:, b].set(window_row[None])
        else:
            bt = leaf.block_table.at[b].set(window_row)
        return leaf._replace(block_table=bt)
    return jax.tree_util.tree_map_with_path(one, caches, is_leaf=_is_paged)


class Server:
    def __init__(self, model_cfg, mesh=None, rule_set: str = "tp",
                 max_len: int = 256, batch: int = 4, params=None,
                 seq_sharded: bool = False,
                 paged: Optional[PagedConfig] = None):
        """``paged``: switch the dense/window KV caches to the block-paged
        pools of ``repro.serve.paged_kv`` (DESIGN §7).  With the default
        auto-sized pools (``num_blocks == 0``) every row owns a worst-case
        identity chain and ``generate`` works unchanged; with explicit
        budgets the block tables start unallocated and admission is the
        ``repro.serve.Scheduler``'s job."""
        self.model_cfg = model_cfg
        self.model = TransformerLM(model_cfg)
        if mesh is None:
            plan = elastic_plan(len(jax.devices()), tp=1)
            mesh = mesh_lib.make_mesh(plan["shape"], plan["axes"])
        self.mesh = mesh
        self.max_len = max_len
        self.batch = batch
        self.paged = paged

        shapes = init_shapes(self.model)
        self.param_sh = shd.param_shardings(self.model, mesh, rule_set, shapes)
        cache_shapes = jax.eval_shape(
            lambda: self.model.init_cache(batch, max_len, paged=paged))
        self.cache_sh = shd.cache_shardings(cache_shapes, mesh, rule_set,
                                            seq_sharded=seq_sharded)
        tok_sh = shd.batch_sharding(mesh, rule_set, batch=batch)

        self.prefill = jax.jit(
            self.model.prefill,
            in_shardings=(self.param_sh, tok_sh, self.cache_sh),
            out_shardings=(None, self.cache_sh))
        # decode-time ``tok`` inherits its sharding (see _decode_many below).
        self.decode_step = jax.jit(
            self.model.decode_step,
            in_shardings=(self.param_sh, None, self.cache_sh),
            out_shardings=(None, self.cache_sh),
            donate_argnums=(2,))
        # The fused chunk decoder: n decode steps + on-device sampling in one
        # program; caches donated so XLA updates them in place.
        # static_argnums + positional calls: jit rejects kwargs outright when
        # in_shardings is given (jax 0.4.x), so (n, top_k, return_logits)
        # travel positionally.  ``temperature`` stays TRACED so sweeping it
        # never recompiles the n-step program.  ``tok`` inherits its incoming
        # sharding (None): it is a committed on-device array sampled from the
        # previous chunk's (replicated) logits, and pinning it to the batch
        # sharding makes pjit reject the replicated layout outright.
        self._decode_many = jax.jit(
            self.model.decode_many,
            static_argnums=(4, 6, 7),
            in_shardings=(self.param_sh, None, self.cache_sh, None, None),
            out_shardings=(None, self.cache_sh),
            donate_argnums=(2,))
        self.sample = jax.jit(sample_logits, static_argnames=("top_k",))

        def decode_many(params, tok, caches, key, n, temperature=0.0,
                        top_k=0):
            reg = obs.registry()
            if reg.enabled:       # dispatch counters only — no device sync
                reg.inc("server.decode_dispatches")
                reg.inc("server.decode_steps", n)
                reg.set("server.decode_batch", tok.shape[0])
            return self._decode_many(params, tok, caches, key, n,
                                     jnp.float32(temperature), top_k, False)
        self.decode_many = decode_many

        # Single-row prefill + slot write: continuous batching refills one
        # finished slot without touching the other rows' caches.  The
        # prompt arrives RIGHT-padded to its bucket with a ``valid`` mask
        # and per-row ``last_pos`` — causality keeps pads out of real
        # tokens' attention, MoSA masks them out of selection, and cache
        # lengths advance by real tokens only (the masked-prefill fix;
        # DESIGN §7).
        cache_shapes1 = jax.eval_shape(
            lambda: self.model.init_cache(1, max_len))
        self.cache_sh1 = shd.cache_shardings(cache_shapes1, mesh, rule_set,
                                             seq_sharded=seq_sharded)

        def _prefill_one(params, tokens, caches, valid, last_pos):
            return self.model.prefill(params, tokens, caches, None, None,
                                      valid, last_pos)

        self.prefill_one = jax.jit(
            _prefill_one,
            in_shardings=(self.param_sh, None, self.cache_sh1, None, None),
            out_shardings=(None, self.cache_sh1))

        def _write_slot(batched, row, b):
            return row_write(batched, row, b)

        self.write_slot = jax.jit(_write_slot, donate_argnums=(0,),
                                  out_shardings=self.cache_sh)

        # Paged row ops (Scheduler path, DESIGN §7): prefill one row IN
        # PLACE of the batched caches — the row view shares the pools, so
        # appended KV lands directly in this row's allocated blocks —
        # plus snapshot / restore / table-growth writes.
        def _prefill_row(params, prompt, caches, b, valid, last_pos,
                         continued):
            row = row_slice(caches, b)
            logits, row = self.model.prefill(params, prompt, row, None, None,
                                             valid, last_pos, continued)
            return logits, row_write(caches, row, b)

        self.prefill_row = jax.jit(
            _prefill_row, static_argnums=(6,),
            in_shardings=(self.param_sh, None, self.cache_sh, None, None,
                          None),
            out_shardings=(None, self.cache_sh), donate_argnums=(2,))

        # Packed multi-segment chunked prefill (DESIGN §9): ONE program for
        # every chunk of every prompt mix — (C, N) are static, raggedness
        # lives in cu_seqlens/rows/past_lens data.  This replaces the
        # Scheduler's former pow2-bucket prefill ladder (log2(max_len)
        # compiles) with a single compile.
        def _prefill_packed(params, tokens, caches, cu, rows, past_lens):
            return self.model.prefill_packed(params, tokens, caches, cu,
                                             rows, past_lens)

        _prefill_packed_jit = jax.jit(
            _prefill_packed,
            in_shardings=(self.param_sh, None, self.cache_sh, None, None,
                          None),
            out_shardings=(None, self.cache_sh), donate_argnums=(2,))

        def prefill_packed(params, tokens, caches, cu, rows, past_lens):
            reg = obs.registry()
            if reg.enabled:
                reg.inc("server.prefill_dispatches")
                reg.set("server.prefill_tokens", tokens.shape[-1])
            return _prefill_packed_jit(params, tokens, caches, cu, rows,
                                       past_lens)
        self.prefill_packed = prefill_packed
        self.snapshot_row = jax.jit(row_snapshot)
        self.restore_row = jax.jit(row_restore, donate_argnums=(0,),
                                   out_shardings=self.cache_sh)
        self.grow_tables = jax.jit(set_dense_tables, donate_argnums=(0,),
                                   out_shardings=self.cache_sh)
        self.grow_window_tables = jax.jit(set_window_tables,
                                          donate_argnums=(0,),
                                          out_shardings=self.cache_sh)

        if params is None:
            with mesh:
                params = jax.jit(self.model.init,
                                 out_shardings=self.param_sh)(
                    jax.random.PRNGKey(0))
        self.params = params

    def new_cache(self, batch: Optional[int] = None):
        batch = self.batch if batch is None else batch
        sh = self.cache_sh if batch == self.batch else self.cache_sh1
        paged = self.paged if batch == self.batch else None
        with self.mesh:
            return jax.jit(
                lambda: self.model.init_cache(batch, self.max_len,
                                              paged=paged),
                out_shardings=sh)()

    def generate(self, prompts: jnp.ndarray, gen_len: int,
                 temperature: float = 0.0, key=None, top_k: int = 0):
        """prompts: (B, P) int32 -> ((B, gen_len) int32, caches).

        One prefill dispatch + ONE fused decode dispatch for the whole
        completion; greedy when ``temperature == 0``.
        """
        B, P = prompts.shape
        assert B == self.batch
        assert self.paged is None or (self.paged.num_blocks == 0 and
                                      self.paged.num_window_blocks == 0), (
            "generate needs auto-sized paged pools (identity block tables);"
            " budgeted pools are managed by repro.serve.Scheduler")
        assert P + gen_len - 1 <= self.max_len, (
            f"prompt ({P}) + {gen_len - 1} decode steps exceeds max_len "
            f"{self.max_len}: appends past the cache end are silently "
            f"dropped (masked update never matches)")
        caches = self.new_cache()
        if key is None:
            key = jax.random.PRNGKey(0)
        k0, kd = jax.random.split(key)
        with self.mesh, hints.sharding_hints(mesh=self.mesh):
            logits, caches = self.prefill(self.params, prompts, caches)
            tok0 = self.sample(logits[:, -1], k0, jnp.float32(temperature),
                               top_k=top_k)
            toks, caches = self.decode_many(
                self.params, tok0[:, None], caches, kd, gen_len - 1,
                temperature, top_k)
        return jnp.concatenate([tok0[:, None], toks], axis=1), caches

    def generate_stepwise(self, prompts: jnp.ndarray, gen_len: int,
                          temperature: float = 0.0, key=None, top_k: int = 0):
        """Legacy per-token loop (one jit dispatch + eagerly dispatched
        sampling ops per token; jax's async dispatch means the host blocks
        only at the end, so the fused path's win over this baseline is
        per-token dispatch overhead, not removed host syncs).

        Kept as the benchmark baseline for the fused path — see
        ``benchmarks/serve_bench.py`` and DESIGN §6.  Sampling goes through
        the same jitted ``sample_logits`` as the fused path.
        """
        B, P = prompts.shape
        assert B == self.batch
        assert P + gen_len - 1 <= self.max_len, (
            f"prompt ({P}) + {gen_len - 1} decode steps exceeds max_len "
            f"{self.max_len}")
        caches = self.new_cache()
        if key is None:
            key = jax.random.PRNGKey(0)
        temp = jnp.float32(temperature)
        with self.mesh, hints.sharding_hints(mesh=self.mesh):
            logits, caches = self.prefill(self.params, prompts, caches)
            key, sub = jax.random.split(key)
            tok = self.sample(logits[:, -1], sub, temp, top_k=top_k)[:, None]
            out = [tok]
            for i in range(gen_len - 1):
                logits, caches = self.decode_step(self.params, tok, caches)
                key, sub = jax.random.split(key)
                tok = self.sample(logits[:, -1], sub, temp,
                                  top_k=top_k)[:, None]
                out.append(tok)
        return jnp.concatenate(out, axis=1), caches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class RequestPool:
    """Continuous batching: fixed B slots over one batched cache.

    Decode runs in fused chunks (``Server.decode_many``).  Between chunks,
    requests that finished (EOS, per-request ``max_new``, or the global
    ``max_steps`` budget) free their slot, and queued requests take it over:
    the new prompt is prefilled batch-of-one and written into that row of
    the batched caches (every cache keeps per-row ``length``, so rows at
    different sequence positions coexist).  Prompts are left-padded to a
    fixed bucket so the single-row prefill compiles once.

    Length policy (clamps, not errors): each request prefills at its own
    power-of-two bucket (``prefill_len`` pins a fixed bucket instead; both
    capped at the server's ``max_len``), so a request's output never
    depends on what else is queued and at most log2(max_len) prefill
    programs compile.  Prompts longer than the bucket are LEFT-truncated to
    their most recent tokens; shorter prompts are RIGHT-padded with a
    ``valid`` mask and per-row ``last_pos`` — causality keeps pads out of
    every real token's attention, MoSA masks them out of expert-choice
    selection, and cache lengths advance by real tokens only, so decode
    overwrites the pad tail in place (masked prefill, DESIGN §7; the
    former LEFT-pad scheme attended pads and is gone).  ``max_new`` is
    clamped so prompt + completion fits ``max_len`` — against the REAL
    prompt length, so padding no longer costs cache capacity.

    This pool is the NON-PAGED fallback: slots reserve worst-case
    contiguous slabs and the pow2 bucket doubles as the admission
    granularity.  With ``Server(paged=...)`` use ``repro.serve.Scheduler``
    instead — admission there is block-granular (the bucket only caps how
    many prefill programs compile) and exhaustion preempts-to-recompute
    rather than queueing forever.

    ``eos``: token id that ends a request (included in its output); ``< 0``
    disables EOS stopping.
    """

    def __init__(self, server: Server, eos: int = -1, chunk: int = 8,
                 prefill_len: Optional[int] = None):
        assert server.paged is None, (
            "RequestPool is the contiguous-slab fallback; a paged Server "
            "is driven by repro.serve.Scheduler instead")
        self.server = server
        self.eos = eos
        self.chunk = chunk
        self.prefill_len = prefill_len
        self.queue: list = []

    def submit(self, prompt, max_new: int):
        rid = len(self.queue)
        self.queue.append(Request(rid, jnp.asarray(prompt, jnp.int32), max_new))
        return rid

    def _bucket(self, prompt_len: int) -> int:
        """Pow2 prefill bucket — kept ONLY for this non-paged pool, where
        the bucket doubles as the slot's cache reservation.  The paged
        ``repro.serve.Scheduler`` admits block-granularly and buckets only
        to bound how many prefill programs compile."""
        if self.prefill_len:
            return min(self.prefill_len, self.server.max_len)
        b = 1
        while b < max(prompt_len, 1):
            b *= 2
        return min(b, self.server.max_len)

    def run(self, max_steps: int = 1000):
        """Serve every queued request; returns {rid: generated tokens}.

        ``max_steps`` caps the total number of decode steps across the whole
        pool — when the budget runs out, in-flight requests return whatever
        they generated so far and the remaining queue is left unserved.
        """
        srv = self.server
        B = srv.batch
        results: dict = {}
        slots: list = [None] * B
        caches = srv.new_cache()
        cur = jnp.zeros((B, 1), jnp.int32)
        key = jax.random.PRNGKey(0)
        steps = 0

        def finish(b):
            r = slots[b]
            r.done = True
            results[r.rid] = jnp.asarray(r.generated, jnp.int32)
            slots[b] = None

        with srv.mesh, hints.sharding_hints(mesh=srv.mesh):
            while self.queue or any(s is not None for s in slots):
                # Refill free slots: single-row prefill -> write into row b.
                for b in range(B):
                    if slots[b] is None and self.queue and steps < max_steps:
                        r = self.queue.pop(0)
                        bucket = self._bucket(len(r.prompt))
                        prompt = r.prompt[-bucket:]
                        P = len(prompt)
                        # clamp so the completion fits the cache: positions
                        # P..max_len-1 hold the decoded tokens' KV (pads
                        # cost nothing — decode overwrites them)
                        r.max_new = min(r.max_new, srv.max_len - P + 1)
                        prompt = jnp.pad(prompt, (0, bucket - P))
                        valid = (jnp.arange(bucket) < P)[None]
                        row = srv.new_cache(batch=1)
                        logits, row = srv.prefill_one(
                            srv.params, prompt[None], row, valid,
                            jnp.full((1,), P - 1, jnp.int32))
                        caches = srv.write_slot(caches, row, b)
                        tok0 = srv.sample(logits[:, -1], key)
                        cur = cur.at[b, 0].set(tok0[0])
                        slots[b] = r
                        r.generated.append(int(tok0[0]))
                        if r.max_new <= 1 or int(tok0[0]) == self.eos:
                            finish(b)
                if not any(s is not None for s in slots):
                    if steps >= max_steps:
                        break
                    continue
                if steps >= max_steps:
                    for b in range(B):
                        if slots[b] is not None:
                            finish(b)
                    break

                # One fused decode chunk for all live rows.  Chunk length is
                # clamped to the longest remaining request so a nearly-done
                # cohort doesn't burn a full chunk (n stays in [1, chunk], so
                # at most `chunk` distinct programs ever compile).
                need = max(r.max_new - len(r.generated)
                           for r in slots if r is not None)
                n = max(min(self.chunk, max_steps - steps, need), 1)
                key, sub = jax.random.split(key)
                toks, caches = srv.decode_many(srv.params, cur, caches, sub, n)
                steps += n
                host = jax.device_get(toks)
                cur = toks[:, -1:]
                for b in range(B):
                    r = slots[b]
                    if r is None:
                        continue
                    for t in host[b]:
                        r.generated.append(int(t))
                        if int(t) == self.eos or len(r.generated) >= r.max_new:
                            finish(b)
                            break
        return results


def _load_run(cfg, args):
    """``--load-rate``: seeded open-loop traffic through the timed paged
    Scheduler, SLO/goodput summary on stdout (DESIGN §12)."""
    import json

    from repro.obs.slo import SLOSpec, evaluate
    from repro.serve.loadgen import (OpenLoopSource, bursty_workload,
                                     poisson_workload)

    nb = -(-args.max_len // 16)
    server = Server(cfg, batch=args.batch, max_len=args.max_len,
                    paged=PagedConfig(block_size=16,
                                      num_blocks=args.batch * nb,
                                      num_window_blocks=4 * args.batch))
    build = bursty_workload if args.bursty else poisson_workload
    wl = build(args.load_rate, args.load_n, args.load_seed, cfg.vocab)
    sched = Scheduler(server, max_queue=args.max_queue or None,
                      metrics_path=args.metrics_path,
                      trace_path=args.trace_path)
    t0 = time.perf_counter()
    sched.run(max_steps=100_000, source=OpenLoopSource(wl))
    dt = time.perf_counter() - t0
    spec = SLOSpec(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo or None)
    ev = evaluate(list(sched.records.values()), spec)
    ev["offered_req_s"] = args.load_rate
    ev["duration_s"] = round(dt, 3)
    print(json.dumps(ev, indent=2, sort_keys=True))


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mosa-paper")
    p.add_argument("--preset", default="smoke")
    p.add_argument("--variant", default=None)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--top-k", type=int, default=0)
    p.add_argument("--stepwise", action="store_true",
                   help="use the legacy per-token loop instead of the "
                        "fused chunk decoder")
    p.add_argument("--metrics-path", default=None,
                   help="write an obs metrics snapshot here on exit "
                        "(.jsonl appends; DESIGN §11)")
    p.add_argument("--trace-path", default=None,
                   help="write a Chrome-trace JSON of the run here on exit")
    p.add_argument("--load-rate", type=float, default=0.0,
                   help="instead of one batch generate, drive the timed "
                        "Scheduler with a seeded open-loop arrival stream "
                        "at this rate (req/s) and print the SLO/goodput "
                        "summary (DESIGN §12)")
    p.add_argument("--load-n", type=int, default=32,
                   help="requests in the load run")
    p.add_argument("--load-seed", type=int, default=0)
    p.add_argument("--bursty", action="store_true",
                   help="Gamma (CV=3) interarrivals instead of Poisson")
    p.add_argument("--max-queue", type=int, default=0,
                   help="shed arrivals past this queue depth "
                        "(0 = never shed)")
    p.add_argument("--ttft-slo", type=float, default=0.5,
                   help="TTFT SLO in seconds for the load-run goodput")
    p.add_argument("--tpot-slo", type=float, default=0.0,
                   help="TPOT SLO in seconds (0 = no TPOT obligation)")
    args = p.parse_args(argv)

    akw = {"variant": args.variant} if args.variant else {}
    cfg = get_config(args.arch, preset=args.preset, **akw)
    if args.load_rate > 0:
        return _load_run(cfg, args)
    server = Server(cfg, batch=args.batch, max_len=args.max_len)
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 2,
                                 cfg.vocab)
    gen = server.generate_stepwise if args.stepwise else server.generate
    toks, caches = gen(prompts, args.gen, temperature=args.temperature,
                       key=key, top_k=args.top_k)
    jax.block_until_ready(toks)   # warm (compile) outside the timing
    t0 = time.perf_counter()
    toks, caches = gen(prompts, args.gen, temperature=args.temperature,
                       key=key, top_k=args.top_k)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s, "
          f"{'stepwise' if args.stepwise else 'fused'})")
    print(toks[0])
    # report the paper's KV metric if the model has MoSA layers
    if cfg.mosa is not None:
        from repro.core.hybrid import HybridAttention
        hy = HybridAttention(cfg.d_model, cfg.mosa)
        print(f"KV entries per MoSA layer: {hy.kv_total(args.max_len)} "
              f"(dense equivalent: "
              f"{args.max_len * (cfg.mosa.n_dense_heads + cfg.mosa.n_mosa_heads)})")
    obs.dump(args.metrics_path, args.trace_path, tag="serve-cli")


if __name__ == "__main__":
    main()
