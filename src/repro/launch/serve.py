"""Batched serving driver.

Prefill + decode with per-layer caches; the MoSA layers realize the paper's
KV-cache reduction at serve time (streaming top-k cache, DESIGN §5).

Library entry points:
  * ``Server`` — holds jit'd ``prefill`` / ``decode_step`` with cache
    shardings; ``generate`` runs greedy/temperature decoding for a batch.
  * ``RequestPool`` — minimal continuous-batching front end: requests join a
    fixed-size batch; finished slots are refilled between decode steps.

CLI (smoke-scale):
  PYTHONPATH=src python -m repro.launch.serve --arch mosa-paper \\
      --preset smoke --variant mosa --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.dist import sharding as shd
from repro.dist import hints
from repro.dist.fault_tolerance import elastic_plan
from repro.launch import mesh as mesh_lib
from repro.nn.module import init_shapes
from repro.nn.transformer import TransformerLM


class Server:
    def __init__(self, model_cfg, mesh=None, rule_set: str = "tp",
                 max_len: int = 256, batch: int = 4, params=None,
                 seq_sharded: bool = False):
        self.model_cfg = model_cfg
        self.model = TransformerLM(model_cfg)
        if mesh is None:
            plan = elastic_plan(len(jax.devices()), tp=1)
            mesh = mesh_lib.make_mesh(plan["shape"], plan["axes"])
        self.mesh = mesh
        self.max_len = max_len
        self.batch = batch

        shapes = init_shapes(self.model)
        self.param_sh = shd.param_shardings(self.model, mesh, rule_set, shapes)
        cache_shapes = jax.eval_shape(
            lambda: self.model.init_cache(batch, max_len))
        self.cache_sh = shd.cache_shardings(cache_shapes, mesh, rule_set,
                                            seq_sharded=seq_sharded)
        tok_sh = shd.batch_sharding(mesh, rule_set, batch=batch)

        self.prefill = jax.jit(
            self.model.prefill,
            in_shardings=(self.param_sh, tok_sh, self.cache_sh),
            out_shardings=(None, self.cache_sh))
        self.decode_step = jax.jit(
            self.model.decode_step,
            in_shardings=(self.param_sh, tok_sh, self.cache_sh),
            out_shardings=(None, self.cache_sh),
            donate_argnums=(2,))

        if params is None:
            with mesh:
                params = jax.jit(self.model.init,
                                 out_shardings=self.param_sh)(
                    jax.random.PRNGKey(0))
        self.params = params

    def new_cache(self):
        with self.mesh:
            return jax.jit(
                lambda: self.model.init_cache(self.batch, self.max_len),
                out_shardings=self.cache_sh)()

    def generate(self, prompts: jnp.ndarray, gen_len: int,
                 temperature: float = 0.0, key=None):
        """prompts: (B, P) int32 -> (B, gen_len) int32 greedy/temp sampling."""
        B, P = prompts.shape
        assert B == self.batch
        caches = self.new_cache()
        with self.mesh, hints.sharding_hints(mesh=self.mesh):
            logits, caches = self.prefill(self.params, prompts, caches)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            out = [tok]
            for i in range(gen_len - 1):
                logits, caches = self.decode_step(self.params, tok, caches)
                if temperature > 0:
                    key, sub = jax.random.split(key)
                    tok = jax.random.categorical(
                        sub, logits[:, -1] / temperature).astype(jnp.int32)[:, None]
                else:
                    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
                out.append(tok)
        return jnp.concatenate(out, axis=1), caches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class RequestPool:
    """Continuous-batching-lite: fixed B slots, refill when a request ends."""

    def __init__(self, server: Server, eos: int = 0):
        self.server = server
        self.eos = eos
        self.queue: list = []
        self.slots: list = [None] * server.batch

    def submit(self, prompt, max_new: int):
        rid = len(self.queue)
        self.queue.append(Request(rid, jnp.asarray(prompt, jnp.int32), max_new))
        return rid

    def run(self, max_steps: int = 1000):
        """Simplified loop: drains the queue batch-by-batch (prefill per
        cohort, decode until every member finishes or hits max_new)."""
        results = {}
        while self.queue:
            cohort = [self.queue.pop(0) for _ in
                      range(min(self.server.batch, len(self.queue)))]
            while len(cohort) < self.server.batch:  # pad with a dummy
                cohort.append(Request(-1, cohort[0].prompt, 1))
            P = max(len(r.prompt) for r in cohort)
            prompts = jnp.stack([
                jnp.pad(r.prompt, (P - len(r.prompt), 0)) for r in cohort])
            gen = max(r.max_new for r in cohort)
            toks, _ = self.server.generate(prompts, gen)
            for b, r in enumerate(cohort):
                if r.rid >= 0:
                    seq = toks[b, :r.max_new]
                    results[r.rid] = seq
        return results


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mosa-paper")
    p.add_argument("--preset", default="smoke")
    p.add_argument("--variant", default=None)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--max-len", type=int, default=128)
    args = p.parse_args(argv)

    akw = {"variant": args.variant} if args.variant else {}
    cfg = get_config(args.arch, preset=args.preset, **akw)
    server = Server(cfg, batch=args.batch, max_len=args.max_len)
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 2,
                                 cfg.vocab)
    t0 = time.perf_counter()
    toks, caches = server.generate(prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(toks[0])
    # report the paper's KV metric if the model has MoSA layers
    if cfg.mosa is not None:
        from repro.core.hybrid import HybridAttention
        hy = HybridAttention(cfg.d_model, cfg.mosa)
        print(f"KV entries per MoSA layer: {hy.kv_total(args.max_len)} "
              f"(dense equivalent: "
              f"{args.max_len * (cfg.mosa.n_dense_heads + cfg.mosa.n_mosa_heads)})")


if __name__ == "__main__":
    main()
