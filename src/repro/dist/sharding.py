"""Sharding rule sets: logical model axes -> concrete mesh shardings.

Mesh-axis naming convention (see ``repro.dist.__init__``): data parallelism
lives on ``("pod", "data")`` (outer to inner), tensor parallelism on
``"model"``.  Model code only names *logical* axes (``embed``, ``heads``,
``mlp``, ``expert``, ...); a rule set maps each logical axis to mesh axes, and
``repro.nn.module.resolve_spec`` applies the mapping divisibility-safely —
any dimension not divisible by the mapped mesh-axis product is replicated
instead of failing (the GQA kv-heads case: 6 kv heads on an 8-way model axis
simply stay replicated).

Two rule sets:

  * ``"tp"``      — tensor parallelism only: width-like axes (mlp, heads,
                    experts, vocab) shard over ``model``; everything else is
                    replicated.
  * ``"fsdp_tp"`` — ``"tp"`` plus ZeRO/FSDP-style sharding of the ``embed``
                    axis over the data-parallel axes.

Also hosts the jax-version compat shims (``AxisType``, ``make_mesh``) so the
rest of the codebase never touches ``jax.sharding`` feature-detection.
"""

from __future__ import annotations

import inspect
from typing import Any, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

try:  # jax >= 0.5 (explicit-sharding axis types)
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    class AxisType:
        """Stand-in for ``jax.sharding.AxisType`` on older jax releases."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

from repro.core import kv_cache as _kvc
from repro.nn.module import (LogicalSpec, init_shapes, logical,  # noqa: F401
                             named_shardings, resolve_spec, resolve_specs)

P = PartitionSpec

# Sharding-invariant RNG: with the legacy (non-partitionable) threefry that
# older jax defaults to, jit with sharded out_shardings generates DIFFERENT
# random values than the same program unsharded — sharded init would diverge
# from single-device init.  Partitionable threefry makes random bits a pure
# function of (key, position), independent of the mesh.
jax.config.update("jax_threefry_partitionable", True)

# Data-parallel mesh axes, outermost first; tensor-parallel axis name.
DP_AXES = ("pod", "data")
TP_AXIS = "model"

_TP_RULES = {
    "embed": None,
    "vocab": TP_AXIS,
    "mlp": TP_AXIS,
    "heads": TP_AXIS,
    "kv_heads": TP_AXIS,
    "mosa_heads": TP_AXIS,
    "expert": TP_AXIS,
    "expert_mlp": None,
    "batch": DP_AXES,
}

RULE_SETS: Mapping[str, Mapping[str, Any]] = {
    "tp": _TP_RULES,
    "fsdp_tp": {**_TP_RULES, "embed": DP_AXES},
}


# --------------------------------------------------------------- mesh compat
def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with ``axis_types=Auto`` where the jax supports it."""
    kwargs = {}
    try:
        if "axis_types" in inspect.signature(jax.make_mesh).parameters:
            kwargs["axis_types"] = (AxisType.Auto,) * len(shape)
    except (TypeError, ValueError):  # pragma: no cover
        pass
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


# ------------------------------------------------------------- axis fitting
def fit_axes(dim: int, axes: Sequence[str], mesh: Mesh) -> tuple:
    """Largest prefix of ``axes`` whose mesh-size product divides ``dim``.

    Trims from the *right* (innermost axis first) so the outer data-parallel
    axis survives longest — a batch of 16 on a (pod=2, data=16) mesh shards
    over ``pod`` alone rather than replicating.
    """
    axes = tuple(a for a in axes if a in mesh.shape)
    while axes and dim > 0:
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if total > 0 and dim % total == 0:
            return axes
        axes = axes[:-1]
    return ()


def dp_axes(mesh: Mesh, rule_set: str = "fsdp_tp",
            batch: Optional[int] = None) -> tuple:
    """Data-parallel axes of ``mesh``, trimmed so they divide ``batch``.

    Driven by the rule set's ``batch`` mapping, restricted to axes present
    on the mesh.
    """
    if rule_set not in RULE_SETS:
        raise KeyError(f"unknown rule set {rule_set!r}; have {list(RULE_SETS)}")
    ruled = RULE_SETS[rule_set].get("batch") or ()
    if isinstance(ruled, str):
        ruled = (ruled,)
    axes = tuple(a for a in ruled if a in mesh.shape)
    if batch is None:
        return axes
    return fit_axes(batch, axes, mesh)


def tp_axis(mesh: Mesh) -> Optional[str]:
    return TP_AXIS if TP_AXIS in mesh.shape else None


def mesh_rules(mesh: Mesh, rule_set: str) -> dict:
    """RULE_SETS entry restricted to axes that exist on ``mesh``."""
    if rule_set not in RULE_SETS:
        raise KeyError(f"unknown rule set {rule_set!r}; have {list(RULE_SETS)}")
    out = {}
    for name, axes in RULE_SETS[rule_set].items():
        if axes is None:
            out[name] = None
            continue
        if isinstance(axes, str):
            axes = (axes,)
        present = tuple(a for a in axes if a in mesh.shape)
        out[name] = present if present else None
    return out


# ------------------------------------------------------------ public makers
def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, rule_set: str = "fsdp_tp",
                   batch: Optional[int] = None) -> NamedSharding:
    """Sharding for a batch-leading tensor: dim 0 over the dp axes."""
    axes = dp_axes(mesh, rule_set, batch)
    if not axes:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(axes[0] if len(axes) == 1 else axes))


def _axes_product(axes, mesh: Mesh) -> int:
    total = 1
    for a in axes or ():
        total *= mesh.shape[a]
    return total


def param_shardings(model, mesh: Mesh, rule_set: str = "fsdp_tp",
                    shapes=None):
    """NamedSharding tree for ``model``'s parameters (one leaf per param).

    The ``heads``/``kv_heads`` logical axes usually label FUSED
    ``n_heads * d_head`` projection dims, so plain dim-divisibility is not
    enough: 2 GQA kv heads of d_head=16 give a 32-wide dim that a 4-way model
    axis *can* split — but only by splitting ``d_head`` itself, which breaks
    head-local ops (RoPE's rotate-half permutes within d_head).  When the
    model config is visible, those rules are dropped unless the *head count*
    divides the mapped axes (head-granular fallback to replication).
    """
    if shapes is None:
        shapes = init_shapes(model)
    rules = dict(mesh_rules(mesh, rule_set))
    att = getattr(getattr(model, "cfg", None), "attention", None)
    if att is not None:
        for rule, n in (("heads", getattr(att, "n_heads", None)),
                        ("kv_heads", getattr(att, "n_kv_heads", None))):
            if n and rules.get(rule) and n % _axes_product(rules[rule], mesh):
                rules[rule] = None
    return named_shardings(shapes, model.specs(), rules, mesh)


def opt_shardings(param_sh, opt_shapes, mesh: Mesh):
    """NamedSharding tree for an optimizer state built over ``param_sh``.

    Moment-style states (``{"mu": ..., "nu": ...}`` — AdamW, or ``{"mom"}``
    — SGD) carry one fp32 buffer per parameter and shard EXACTLY like the
    parameter they track (so the update is local everywhere the param is);
    anything unrecognized replicates.
    """
    moment_keys = {"mu", "nu", "mom"}
    if isinstance(opt_shapes, dict) and set(opt_shapes) <= moment_keys:
        return {k: param_sh for k in opt_shapes}
    return jax.tree.map(lambda _: replicated(mesh), opt_shapes)


def train_state_shardings(model, mesh: Mesh, rule_set: str, optimizer,
                          shapes=None):
    """(param_sh, opt_sh, scalar_sh) for the donated train step — the one
    call ``repro.train.loop`` needs to place the whole training state."""
    if shapes is None:
        shapes = init_shapes(model)
    param_sh = param_shardings(model, mesh, rule_set, shapes)
    opt_shapes = jax.eval_shape(optimizer.init, shapes)
    return param_sh, opt_shardings(param_sh, opt_shapes, mesh), \
        replicated(mesh)


# ------------------------------------------------------- cache spec table
# Per-cache-type logical axes, one name (or None) per tensor dim, mirroring
# each NamedTuple's field layout in ``repro.core.kv_cache``.  Resolution:
#
#   * ``"batch"``                     -> the rule set's data-parallel axes;
#   * ``"seq"``                       -> ``model``, only under
#                                        ``seq_sharded`` (the batch==1
#                                        long-context layout);
#   * ``"kv_heads"`` / ``"mosa_heads"`` -> whatever the rule set maps them to
#     (``model`` under ``tp``/``fsdp_tp``) — unlike the *parameter* specs,
#     cache head dims hold the literal head count (never fused with d_head),
#     so plain dim-divisibility is the correct guard here.
#
# This is what lets MoSA's (B, H, k, d) cache shard its HEAD dim over the
# tensor-parallel axis at decode time (head-parallel decode, DESIGN §6): the
# positional heuristic this table replaced could only name "the dim after
# batch", which for MoSA is heads but for dense caches is sequence.
CACHE_AXES: Mapping[type, Mapping[str, tuple]] = {
    _kvc.DenseKVCache: {
        "k": ("batch", "seq", "kv_heads", None),
        "v": ("batch", "seq", "kv_heads", None),
        "length": ("batch",),
    },
    _kvc.WindowKVCache: {
        "k": ("batch", "seq", "kv_heads", None),
        "v": ("batch", "seq", "kv_heads", None),
        "positions": ("batch", "seq"),
        "length": ("batch",),
    },
    _kvc.MLAKVCache: {
        "latent": ("batch", "seq", None),
        "k_rope": ("batch", "seq", None),
        "length": ("batch",),
    },
    _kvc.MoSAKVCache: {
        "k": ("batch", "mosa_heads", None, None),
        "v": ("batch", "mosa_heads", None, None),
        "scores": ("batch", "mosa_heads", None),
        "idx": ("batch", "mosa_heads", None),
        "length": ("batch",),
    },
    _kvc.MoSABlockKVCache: {
        "k": ("batch", "mosa_heads", None, None),
        "v": ("batch", "mosa_heads", None, None),
        "pos": ("batch", "mosa_heads", None),
        "bscore": ("batch", "mosa_heads", None),
        "bidx": ("batch", "mosa_heads", None),
        "bsum": ("batch", "mosa_heads"),
        "length": ("batch",),
    },
}
# The paged cache types of ``repro.serve.paged_kv`` register their entries
# here at import time (``register_cache_axes``) — serve depends on dist,
# never the reverse; any code holding a paged cache instance has necessarily
# imported the module that registered it.


def register_cache_axes(cache_type, table) -> None:
    """Add a cache family's logical-axis table (used by serve.paged_kv)."""
    CACHE_AXES[cache_type] = dict(table)


def cache_spec(cache, mesh: Mesh, rule_set: str = "fsdp_tp",
               seq_sharded: bool = False, stacked: bool = False):
    """PartitionSpec for one typed cache from the ``CACHE_AXES`` table.

    ``cache`` is a KV-cache NamedTuple (arrays or ShapeDtypeStructs);
    ``stacked`` marks layer-stacked ``scan`` caches (every dim shifted right
    by the layer axis, which stays replicated).  Returns a same-type
    NamedTuple of PartitionSpecs.  Divisibility-safe: any dim the mapped
    axes do not divide is replicated; a mesh axis is used at most once per
    tensor (``seq`` wins over heads when ``seq_sharded`` requests both).
    """
    rules = mesh_rules(mesh, rule_set)
    dp = dp_axes(mesh, rule_set)
    tp = tp_axis(mesh)
    table = CACHE_AXES[type(cache)]

    def one_field(leaf, names):
        shape = tuple(getattr(leaf, "shape", ()))
        off = 1 if stacked else 0
        spec = [None] * len(shape)
        used: set = set()
        for i, name in enumerate(names):
            d = off + i
            if name is None or d >= len(shape):
                continue
            dim = shape[d]
            if name == "batch":
                axes = fit_axes(dim, tuple(a for a in dp if a not in used),
                                mesh)
            elif name == "seq":
                axes = (tp,) if (seq_sharded and tp and tp not in used
                                 and dim > 0
                                 and dim % mesh.shape[tp] == 0) else ()
            else:
                axes = rules.get(name) or ()
                if isinstance(axes, str):
                    axes = (axes,)
                axes = tuple(a for a in axes if a not in used)
                if _axes_product(axes, mesh) == 0 or dim == 0 \
                        or dim % _axes_product(axes, mesh):
                    axes = ()
            if axes:
                spec[d] = axes[0] if len(axes) == 1 else axes
                used.update(axes)
        while spec and spec[-1] is None:
            spec.pop()
        return P(*spec)

    return type(cache)(*(one_field(getattr(cache, f), table[f])
                         for f in cache._fields))


def cache_shardings(cache_shapes, mesh: Mesh, rule_set: str = "fsdp_tp",
                    seq_sharded: bool = False):
    """NamedSharding tree for serving caches.

    Typed KV caches (Dense/Window/MLA/MoSA) resolve through the
    ``CACHE_AXES`` spec table — each cache family declares the logical axis
    of every dim.  Under the tp rule sets BOTH MoSA and dense/window caches
    head-shard over ``model`` by default; ``seq_sharded`` makes dense
    caches seq-shard instead (a mesh axis is used at most once per tensor,
    and ``seq`` wins).  Remaining leaves (SSM / xLSTM recurrent states,
    which are plain array pytrees) keep the positional fallback:

      * the batch dim (0; 1 for layer-stacked ``scan`` caches) shards over
        the data-parallel axes;
      * with ``seq_sharded`` the following dim (channels for SSM state)
        shards over ``model``.

    All mappings are divisibility-safe (non-dividing dims replicate).
    """
    dp = dp_axes(mesh, rule_set)
    tp = tp_axis(mesh)

    def is_cache(x):
        return type(x) in CACHE_AXES

    def one(path, leaf):
        stacked = any(getattr(entry, "key", None) == "scan" for entry in path)
        if is_cache(leaf):
            specs = cache_spec(leaf, mesh, rule_set, seq_sharded, stacked)
            return type(leaf)(*(NamedSharding(mesh, s) for s in specs))
        shape = tuple(getattr(leaf, "shape", ()))
        b = 1 if stacked else 0
        spec = [None] * len(shape)
        if len(shape) > b:
            axes = fit_axes(shape[b], dp, mesh)
            if axes:
                spec[b] = axes[0] if len(axes) == 1 else axes
        if seq_sharded and tp is not None and len(shape) > b + 1 \
                and shape[b + 1] % mesh.shape[tp] == 0 and shape[b + 1] > 0:
            spec[b + 1] = tp
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes,
                                            is_leaf=is_cache)
