"""Ambient activation-sharding hints.

Model code runs mesh-agnostic; launchers that *do* have a mesh open a
``sharding_hints(mesh=...)`` context, and layers mark their key activations
with ``constrain(x, roles)`` where each role names a *class* of mesh axes
rather than a concrete axis (mesh-axis convention: ``pod``/``data`` are data
parallel, ``model`` is tensor parallel — see ``repro.dist.__init__``):

  * ``"dp"``  — the data-parallel axes of the ambient mesh (``pod``/``data``);
  * ``"tp"``  — the tensor-parallel axis (``model``);
  * ``None``  — replicated;
  * a literal mesh-axis name (or tuple of names) passes through.

Outside a context — or when no mapped axis divides the dimension —
``constrain`` is the identity, so the same layer code serves single-device
tests and 512-chip dry-runs.  The context also carries the resolved
``{"mesh", "dp", "tp"}`` state (``current()``) for layers that need to branch
on topology, e.g. the expert-parallel MoE dispatch.
"""

from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd

# Ambient hint state: None, or {"mesh": Mesh, "dp": tuple, "tp": str | None}.
_HINTS: ContextVar = ContextVar("repro_sharding_hints", default=None)


@contextmanager
def sharding_hints(mesh=None, dp=None, tp=None):
    """Install ambient sharding hints for the enclosed region.

    ``dp``/``tp`` default to the conventional axes present on ``mesh``
    (``("pod", "data")`` and ``"model"``); pass them explicitly to override.
    """
    if dp is None:
        dp = tuple(a for a in shd.DP_AXES
                   if mesh is not None and a in mesh.shape)
    elif isinstance(dp, str):
        dp = (dp,)
    if tp is None and mesh is not None:
        tp = shd.tp_axis(mesh)
    token = _HINTS.set({"mesh": mesh, "dp": tuple(dp), "tp": tp})
    try:
        yield _HINTS.get()
    finally:
        _HINTS.reset(token)


def current() -> Optional[dict]:
    """The active hint state, or None outside any ``sharding_hints``."""
    return _HINTS.get()


def resolve(shape, roles) -> Optional[P]:
    """Resolve per-dim roles to a PartitionSpec under the ambient mesh.

    Returns None when there is nothing to constrain (no context, or every
    role resolves to replication).  Divisibility-safe, and never maps one
    mesh axis to two dims of the same tensor.
    """
    state = _HINTS.get()
    if state is None or state.get("mesh") is None:
        return None
    mesh = state["mesh"]
    used: set = set()
    out = []
    for dim, role in zip(shape, roles):
        if role is None:
            out.append(None)
            continue
        if role == "dp":
            axes = state["dp"]
        elif role == "tp":
            axes = (state["tp"],) if state["tp"] is not None else ()
        elif isinstance(role, str):
            axes = (role,)
        else:
            axes = tuple(role)
        axes = shd.fit_axes(dim, tuple(a for a in axes if a not in used),
                            mesh)
        if not axes:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    while out and out[-1] is None:
        out.pop()
    if not out:
        return None
    return P(*out)


def constrain(x, roles):
    """``with_sharding_constraint`` under the ambient hints; identity when
    no context is active or nothing resolves (divisibility fallback)."""
    spec = resolve(x.shape, roles)
    if spec is None:
        return x
    mesh = _HINTS.get()["mesh"]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
