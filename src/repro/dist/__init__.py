"""Distributed substrate for the MoSA reproduction.

Four modules, all mesh-driven:

  * ``sharding``        — logical-axis rule sets -> concrete NamedShardings
                          for params, batches, and serving caches.
  * ``hints``           — ambient activation-sharding hints (``constrain``)
                          used inside model code without threading a mesh.
  * ``fault_tolerance`` — heartbeats, straggler detection, preemption
                          handling, and elastic mesh (re)planning.
  * ``pipeline``        — layer-stacked GPipe pipeline parallelism.

Mesh-axis naming convention (shared by every module):

  ``pod``   — outermost data-parallel axis (across pods);
  ``data``  — within-pod data-parallel axis (batch, FSDP shards);
  ``model`` — tensor/model-parallel axis (heads, mlp, experts, vocab);
  ``pipe``  — pipeline-stage axis (only on dedicated pipeline meshes).

Submodules are imported explicitly (``from repro.dist import sharding``);
this ``__init__`` stays empty of imports so no consumer pays for machinery
it does not use and no import cycles can form through the package root.
"""

__all__ = ["sharding", "hints", "fault_tolerance", "pipeline"]
