"""Layer-stacked pipeline parallelism (GPipe schedule).

Runs on a dedicated mesh whose stage axis is named ``pipe`` (the usual
``pod``/``data``/``model`` convention does not apply here — pipeline meshes
are built separately, e.g. ``make_mesh((4,), ("pipe",))``).

``stack_stage_params`` stacks per-stage parameter pytrees along a leading
stage dim; ``pipeline_forward`` shards that dim over the ``pipe`` axis with
``shard_map`` so each device holds exactly one stage, then runs the classic
GPipe fill/steady/drain schedule: ``n_microbatches + n_stages - 1`` ticks,
activations hopping stage-to-stage via ``collective_permute``.  Stage 0 feeds
microbatch ``t`` at tick ``t``; the last stage emits microbatch ``t-(S-1)``
at tick ``t``; a masked ``psum`` replicates the final outputs (only the last
stage contributes non-zeros).  Everything is differentiable — ``ppermute``
and ``psum`` have exact transposes — so the same schedule serves training.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def stack_stage_params(stage_params: list):
    """Stack a list of per-stage pytrees along a new leading stage dim."""
    if not stage_params:
        raise ValueError("need at least one stage")
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *stage_params)


def pipeline_forward(stage_fn, stage_params, x, *, mesh, n_microbatches: int,
                     axis: str = "pipe"):
    """Apply ``n_stages`` copies of ``stage_fn`` as a pipeline over ``axis``.

    stage_fn:      ``(params, activations) -> activations`` (shape-preserving)
    stage_params:  pytree whose leaves have leading dim == mesh.shape[axis]
                   (see ``stack_stage_params``)
    x:             (B, ...) global batch; B % n_microbatches == 0

    Returns the replicated (B, ...) output, equal to applying the stages
    sequentially.
    """
    if axis not in mesh.shape:
        raise ValueError(f"mesh has no {axis!r} axis: {dict(mesh.shape)}")
    n_stages = mesh.shape[axis]
    leading = {leaf.shape[0] for leaf in jax.tree.leaves(stage_params)}
    if leading != {n_stages}:
        raise ValueError(f"stage_params leading dims {leading} != mesh "
                         f"{axis} size {n_stages}")
    B = x.shape[0]
    if n_microbatches < 1 or B % n_microbatches != 0:
        raise ValueError(f"batch {B} not divisible into {n_microbatches} "
                         "microbatches")
    mb = B // n_microbatches

    @partial(shard_map, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(),
             check_rep=False)
    def run(params, xfull):
        local = jax.tree.map(lambda p: p[0], params)   # this device's stage
        stage = jax.lax.axis_index(axis)
        micro = xfull.reshape((n_microbatches, mb) + xfull.shape[1:])
        buf = jnp.zeros_like(micro[0])
        shift = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        outs = []
        for t in range(n_microbatches + n_stages - 1):
            feed = micro[t] if t < n_microbatches else jnp.zeros_like(buf)
            inp = jnp.where(stage == 0, feed, buf)
            out = stage_fn(local, inp)
            if t >= n_stages - 1:
                outs.append(jnp.where(stage == n_stages - 1, out,
                                      jnp.zeros_like(out)))
            buf = jax.lax.ppermute(out, axis, shift)
        y = jax.lax.psum(jnp.stack(outs), axis)        # non-zero on last stage
        return y.reshape((n_microbatches * mb,) + y.shape[2:])

    return run(stage_params, x)
