"""Fault tolerance: heartbeats, straggler detection, preemption, elasticity.

All components are host-side (no jax state) and assume the mesh-axis naming
convention ``pod``/``data``/``model`` (see ``repro.dist.__init__``):

  * ``Heartbeat``         — file-based liveness: each rank touches one JSON
                            file under ``<dir>/heartbeats/``; any rank (or an
                            external watchdog) lists stale peers by mtime.
                            No collective, so it keeps working while the
                            failed rank is wedged inside a collective.
  * ``StragglerMonitor``  — flags step-time outliers by z-score against a
                            running mean/std of healthy steps.
  * ``PreemptionHandler`` — SIGNAL-based (SIGTERM/SIGINT set a flag; the
                            train loop checkpoints at the next step
                            boundary), not polled from a metadata service.
  * ``elastic_plan``      — picks a ``(data[, pod], model)`` mesh shape for
                            whatever device count survived, shrinking the
                            data axis first (host loss inside a pod) and
                            reporting chips it had to leave idle.
"""

from __future__ import annotations

import json
import os
import signal
import time
from typing import List, Optional


# ------------------------------------------------------------------ heartbeat
class Heartbeat:
    """File-based liveness beacon, one file per rank.

    ``beat`` atomically rewrites ``<dir>/heartbeats/rank_<r>.json``; staleness
    is judged by file mtime so readers need no clock agreement with writers
    beyond the shared filesystem's.
    """

    SUBDIR = "heartbeats"

    def __init__(self, directory: str, rank: int):
        self.rank = int(rank)
        self.dir = os.path.join(directory, self.SUBDIR)
        os.makedirs(self.dir, exist_ok=True)
        self.path = os.path.join(self.dir, f"rank_{self.rank}.json")

    def beat(self, step: int) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"rank": self.rank, "step": int(step),
                       "time": time.time()}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def stale_ranks(directory: str, timeout_s: float) -> List[int]:
        """Ranks whose last beat is at least ``timeout_s`` seconds old."""
        hb_dir = os.path.join(directory, Heartbeat.SUBDIR)
        if not os.path.isdir(hb_dir):
            return []
        now = time.time()
        stale = []
        for name in os.listdir(hb_dir):
            if not (name.startswith("rank_") and name.endswith(".json")):
                continue
            try:
                rank = int(name[len("rank_"):-len(".json")])
            except ValueError:
                continue
            try:
                age = now - os.path.getmtime(os.path.join(hb_dir, name))
            except OSError:
                age = float("inf")
            if age >= timeout_s:
                stale.append(rank)
        return sorted(stale)


# ----------------------------------------------------------------- stragglers
class StragglerMonitor:
    """Z-score step-time outlier detector.

    Keeps a running mean/variance (Welford) of *healthy* step times; a step is
    a straggler when, after ``warmup_steps`` healthy samples, its one-sided
    z-score exceeds ``z_threshold``.  Flagged steps are excluded from the
    statistics so a long stall does not raise the baseline and mask the next
    one.  A relative floor on the std keeps near-constant step times (var ~ 0)
    from turning measurement noise into infinite z-scores.
    """

    def __init__(self, z_threshold: float = 3.0, warmup_steps: int = 10):
        self.z_threshold = float(z_threshold)
        self.warmup_steps = int(warmup_steps)
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0
        self.events: list = []

    def _std(self) -> float:
        var = self._m2 / self.n if self.n > 0 else 0.0
        std = max(var, 0.0) ** 0.5
        return max(std, 1e-2 * abs(self.mean), 1e-9)

    def record(self, step: int, dt: float) -> bool:
        """Record one step time; True iff this step is flagged a straggler."""
        dt = float(dt)
        flagged = False
        if self.n >= self.warmup_steps:
            z = (dt - self.mean) / self._std()
            flagged = z > self.z_threshold
        if flagged:
            self.events.append({"step": int(step), "dt": dt})
            return True
        self.n += 1
        delta = dt - self.mean
        self.mean += delta / self.n
        self._m2 += delta * (dt - self.mean)
        return False

    def summary(self) -> dict:
        return {
            "straggler_events": len(self.events),
            "healthy_steps": self.n,
            "mean_step_s": self.mean,
            "std_step_s": (self._m2 / self.n) ** 0.5 if self.n else 0.0,
            "events": list(self.events),
        }


# ----------------------------------------------------------------- preemption
class PreemptionHandler:
    """Convert SIGTERM/SIGINT into a cooperative ``requested`` flag.

    Signal-based, not polled: the handler only sets a flag; the training loop
    checks it at step boundaries and checkpoints before exiting.  ``restore``
    reinstates the previous handlers (and is safe to call twice).
    """

    SIGNALS = (signal.SIGTERM, signal.SIGINT)

    def __init__(self, signals=SIGNALS):
        self.requested = False
        self._prev = {}
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._on_signal)
            except (ValueError, OSError):  # non-main thread / exotic platform
                pass

    def _on_signal(self, signum, frame):
        self.requested = True

    def restore(self) -> None:
        for sig, prev in self._prev.items():
            try:
                signal.signal(sig, prev)
            except (ValueError, OSError):
                pass
        self._prev = {}


# ----------------------------------------------------------------- elasticity
def elastic_plan(n_devices: int, tp: int = 16, want_pods: bool = False,
                 pod_data: int = 16) -> dict:
    """Mesh shape for ``n_devices`` surviving chips.

    Policy: tensor parallelism is load-bearing (it sets the per-device weight
    shard sizes a restored checkpoint expects), so ``tp`` is preserved when
    possible — shrunk only when fewer than ``tp`` devices remain — and host
    loss shrinks the *data* axis.  Devices beyond ``data * tp`` idle (a lost
    host inside a pod leaves a ragged remainder: 248 chips at tp=16 run as a
    (15, 16) mesh with 8 idle).  With ``want_pods`` a large data axis splits
    into ``(pod, data)`` with ``data == pod_data`` when it divides evenly.

    Returns ``{"shape", "axes", "devices_idle", "n_devices", "tp"}`` ready
    for ``repro.launch.mesh.make_mesh(plan["shape"], plan["axes"])``.
    """
    n = int(n_devices)
    if n < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    tp_eff = max(1, min(int(tp), n))
    data = n // tp_eff
    shape = (data, tp_eff)
    axes = ("data", "model")
    if want_pods and data > pod_data and data % pod_data == 0:
        shape = (data // pod_data, pod_data, tp_eff)
        axes = ("pod", "data", "model")
    used = 1
    for s in shape:
        used *= s
    return {"shape": shape, "axes": axes, "devices_idle": n - used,
            "n_devices": n, "tp": tp_eff}
