"""Resumable, sharded LM data pipeline.

The container is offline, so the corpus source is a deterministic synthetic
generator (``SyntheticCorpus``) with realistic statistics: zipfian unigram
distribution + a Markov backbone + copy/recall spans (the structure MoSA's
router can exploit, mirroring why content-based sparsity wins on C4).  The
pipeline itself is source-agnostic — any iterator of token id arrays works.

Production features:
  * **determinism & resume**: the stream is a pure function of
    (seed, step) — checkpointing just the step counter resumes bit-exactly;
  * **host sharding**: each data-parallel host takes its slice of the global
    batch (``shard_index / shard_count``);
  * **packing**: documents are packed into fixed (B, T+1) blocks, split into
    inputs/labels;
  * **background prefetch**: a bounded queue on a producer thread.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    """Deterministic document stream with zipf + markov + recall structure."""

    vocab: int = 8000
    seed: int = 0
    mean_doc_len: int = 512
    copy_frac: float = 0.15   # fraction of a doc that repeats an earlier span

    def doc(self, index: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, index]))
        n = max(16, int(rng.exponential(self.mean_doc_len)))
        n = min(n, 4 * self.mean_doc_len)
        # zipfian unigrams over the vocab (reserve 0 for padding/bos)
        ranks = rng.zipf(1.3, size=n)
        toks = (ranks % (self.vocab - 2)) + 2
        # markov smoothing: with p=0.3, next token = f(prev) (bigram structure)
        follow = (np.arange(self.vocab) * 2654435761 % (self.vocab - 2)) + 2
        chain = rng.random(n) < 0.3
        toks[1:] = np.where(chain[1:], follow[toks[:-1]], toks[1:])
        # recall spans: copy an earlier chunk verbatim (needle structure)
        if n > 64 and self.copy_frac > 0:
            span = max(8, int(n * self.copy_frac / 2))
            src = rng.integers(0, n - 2 * span)
            dst = rng.integers(src + span, n - span)
            toks[dst:dst + span] = toks[src:src + span]
        toks[0] = 1  # BOS
        return toks.astype(np.int32)


@dataclasses.dataclass
class PackedLMDataset:
    """Packs documents into (B, T+1) blocks -> {"tokens", "labels"}.

    ``segmented=True`` additionally emits per-token document metadata so the
    model can mask cross-document attention (TransformerLM.loss threads it
    to every attention mixer):

      * ``"segments"``  (B, T) int32 — document id of each input token
        (ids are distinct per document within a row; a document spanning a
        row boundary keeps its id, which is harmless — rows never interact);
      * ``"positions"`` (B, T) int32 — LOCAL offset within the document, so
        RoPE restarts at every boundary;
      * boundary labels are masked to -1: the label of a document's last
        token is the next document's first token — an unlearnable target
        that polluted the loss in the unsegmented scheme.

    ``segmented=False`` (default) is byte-identical to the historical
    batches — existing training runs resume unchanged.
    """

    corpus: SyntheticCorpus
    seq_len: int
    global_batch: int
    shard_index: int = 0
    shard_count: int = 1
    segmented: bool = False

    def __post_init__(self):
        assert self.global_batch % self.shard_count == 0, \
            (self.global_batch, self.shard_count)
        self.local_batch = self.global_batch // self.shard_count

    def batch_at(self, step: int) -> dict:
        """Pure function of step — the resume guarantee."""
        B, T = self.local_batch, self.seq_len
        need = B * (T + 1)
        out = np.empty((need,), np.int32)
        seg = np.empty((need,), np.int32)
        pos = np.empty((need,), np.int32)
        filled = 0
        # each (step, shard, i) names its own document stream
        i = 0
        while filled < need:
            doc = self.corpus.doc(
                ((step * self.shard_count + self.shard_index) << 16) + i)
            take = min(len(doc), need - filled)
            out[filled:filled + take] = doc[:take]
            seg[filled:filled + take] = i
            pos[filled:filled + take] = np.arange(take, dtype=np.int32)
            filled += take
            i += 1
        blk = out.reshape(B, T + 1)
        if not self.segmented:
            return {"tokens": blk[:, :-1].copy(), "labels": blk[:, 1:].copy()}
        sb = seg.reshape(B, T + 1)
        pb = pos.reshape(B, T + 1)
        labels = blk[:, 1:].copy()
        labels[sb[:, 1:] != sb[:, :-1]] = -1     # cross-doc target: masked
        return {"tokens": blk[:, :-1].copy(), "labels": labels,
                "segments": sb[:, :-1].copy(),
                "positions": pb[:, :-1].copy()}

    def iter_from(self, step: int) -> Iterator[dict]:
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Bounded background prefetch over any step-indexed dataset."""

    def __init__(self, dataset, start_step: int = 0, depth: int = 2):
        self.dataset = dataset
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.dataset.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


class ByteTokenizer:
    """Byte-level tokenizer with a small word cache — offline-friendly stand-in
    for SentencePiece (ids 0=pad, 1=bos, 2..257=bytes, 258+=cached words)."""

    def __init__(self, vocab: int = 8000):
        self.vocab = vocab
        self._word_to_id: dict = {}
        self._id_to_word: dict = {}

    def encode(self, text: str) -> np.ndarray:
        ids = [1]
        for word in text.split(" "):
            wid = self._word_to_id.get(word)
            if wid is None and 258 + len(self._word_to_id) < self.vocab:
                wid = 258 + len(self._word_to_id)
                self._word_to_id[word] = wid
                self._id_to_word[wid] = word
            if wid is not None:
                ids.append(wid)
            else:
                ids.extend(2 + b for b in word.encode("utf-8"))
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        words, buf = [], bytearray()
        for t in np.asarray(ids).tolist():
            if t >= 258:
                if buf:
                    words.append(buf.decode("utf-8", "replace"))
                    buf = bytearray()
                words.append(self._id_to_word.get(t, "<unk>"))
            elif t >= 2:
                buf.append(t - 2)
        if buf:
            words.append(buf.decode("utf-8", "replace"))
        return " ".join(words)
