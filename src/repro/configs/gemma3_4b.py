"""gemma3-4b [hf:google/gemma-3-1b-pt; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144; 5:1 local:global
interleave (window 1024), 128k context.  Embedding scaled by sqrt(d_model)
(gemma convention).
"""

from __future__ import annotations

from repro.configs.base import AttentionConfig, BlockSpec, ModelConfig, register


def _pattern(n_layers, period=6):
    # 5 local then 1 global per period; remainder layers local.
    return tuple(
        BlockSpec("attn" if (i % period) == period - 1 else "attn_local",
                  "dense")
        for i in range(n_layers))


def _full():
    return ModelConfig(
        name="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, d_ff=10240, vocab=262144,
        pattern=_pattern(34),
        attention=AttentionConfig(kind="gqa", n_heads=8, n_kv_heads=4,
                                  d_head=256, rope_theta=1000000.0,
                                  window=1024),
        ffn_act="gelu", tie_embeddings=True, max_seq_len=131072,
        notes="local layers window=1024; global layers full attention. "
              "long_500k: global layers switch to MoSA (mosa_hybrid).")


def _smoke():
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        n_layers=6, d_model=64, d_ff=128, vocab=512,
        pattern=_pattern(6),
        attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2,
                                  d_head=16, window=16),
        ffn_act="gelu", tie_embeddings=True,
        max_seq_len=256, param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("gemma3-4b", config)
