"""xlstm-125m [arXiv:2405.04517; unverified].

12L d_model=768 4H vocab=50304; sLSTM + mLSTM blocks.  The assignment tier is
"unverified"; we use an xLSTM[5:1]-style layout (period 6: five mLSTM then
one sLSTM) so the pattern is periodic and scans as one super-block — noted in
DESIGN.md as an adaptation of the paper's [7:1] ratio to 12 layers.

MoSA is INAPPLICABLE here (attention-free) — see DESIGN §Arch-applicability.
"""

from __future__ import annotations

from repro.configs.base import (AttentionConfig, BlockSpec, ModelConfig,
                                XLSTMConfig, register)


def _pattern(n_layers, period=6):
    return tuple(
        BlockSpec("slstm" if (i % period) == period - 1 else "mlstm", "none")
        for i in range(n_layers))


def _full():
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, d_ff=0, vocab=50304,
        pattern=_pattern(12),
        attention=AttentionConfig(kind="none", n_heads=4, n_kv_heads=4,
                                  d_head=192),
        xlstm=XLSTMConfig(proj_factor_mlstm=2.0, proj_factor_slstm=1.333,
                          conv1d_kernel=4),
        tie_embeddings=True, max_seq_len=524288,
        notes="attention-free; long_500k native (O(1) recurrent state). "
              "MoSA inapplicable.")


def _smoke():
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=6, d_model=64, d_ff=0, vocab=512,
        pattern=_pattern(6),
        attention=AttentionConfig(kind="none", n_heads=4, n_kv_heads=4,
                                  d_head=16),
        xlstm=XLSTMConfig(),
        tie_embeddings=True, max_seq_len=256,
        param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("xlstm-125m", config)
