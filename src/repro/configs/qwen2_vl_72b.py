"""qwen2-vl-72b [arXiv:2409.12191; hf] — VLM backbone with M-RoPE.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.  The vision frontend
(dynamic-resolution ViT) is a STUB per the assignment: ``input_specs``
supplies precomputed patch embeddings (B, T, d_model); M-RoPE (t,h,w) runs in
the backbone with text positions lifted to 3 components.
"""

from __future__ import annotations

from repro.configs.base import AttentionConfig, ModelConfig, register


def _full():
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, d_ff=29568, vocab=152064,
        attention=AttentionConfig(kind="gqa", n_heads=64, n_kv_heads=8,
                                  d_head=128, qkv_bias=True,
                                  rope_theta=1000000.0,
                                  mrope_sections=(16, 24, 24)),
        max_seq_len=32768, frontend="vision_stub",
        notes="M-RoPE sections (16,24,24) over d_head/2=64 freq pairs; "
              "vision frontend stubbed. long_500k in mosa_hybrid mode.")


def _smoke():
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=64, d_ff=128, vocab=512,
        attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2,
                                  d_head=16, qkv_bias=True,
                                  mrope_sections=(2, 3, 3)),
        max_seq_len=256, frontend="vision_stub",
        param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("qwen2-vl-72b", config)
