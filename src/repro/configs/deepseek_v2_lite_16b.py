"""deepseek-v2-lite-16b [arXiv:2405.04434; hf].

27L d_model=2048 16H (MLA, kv_lora=512) vocab=102400; MoE 64 routed experts
top-6 + 2 shared, expert hidden 1408; layer 0 uses a dense FFN (10944).
"""

from __future__ import annotations

from repro.configs.base import (AttentionConfig, BlockSpec, MLAConfig,
                                ModelConfig, MoEConfig, register)


def _full():
    pattern = (BlockSpec("attn", "dense"),) + \
        tuple(BlockSpec("attn", "moe") for _ in range(26))
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, d_ff=10944, vocab=102400,
        pattern=pattern,
        attention=AttentionConfig(
            kind="mla", n_heads=16, n_kv_heads=16, d_head=192,
            rope_theta=10000.0,
            mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
                          v_head_dim=128, nope_head_dim=128)),
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408,
                      n_shared_experts=2),
        max_seq_len=32768,
        notes="MLA latent cache 512+64/token; first layer dense FFN.")


def _smoke():
    pattern = (BlockSpec("attn", "dense"), BlockSpec("attn", "moe"))
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        n_layers=2, d_model=64, d_ff=128, vocab=512, pattern=pattern,
        attention=AttentionConfig(
            kind="mla", n_heads=4, n_kv_heads=4, d_head=24,
            mla=MLAConfig(kv_lora_rank=32, rope_head_dim=8, v_head_dim=16,
                          nope_head_dim=16)),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=32, n_shared_experts=1,
                      capacity_factor=2.0),
        max_seq_len=256, param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("deepseek-v2-lite-16b", config)
