"""musicgen-large [arXiv:2306.05284; hf] — decoder backbone over EnCodec tokens.

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.  The EnCodec frontend is a
STUB per the assignment: ``input_specs`` supplies precomputed frame
embeddings (B, T, d_model); the model trains/serves over them with the
2048-way codebook head.
"""

from __future__ import annotations

from repro.configs.base import AttentionConfig, ModelConfig, register


def _full():
    return ModelConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, d_ff=8192, vocab=2048,
        attention=AttentionConfig(kind="gqa", n_heads=32, n_kv_heads=32,
                                  d_head=64, rope_theta=10000.0),
        ffn_act="gelu", norm="layernorm", frontend="audio_stub",
        max_seq_len=32768,
        notes="audio decoder backbone; EnCodec frontend stubbed")


def _smoke():
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=64, d_ff=128, vocab=128,
        attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=4, d_head=16),
        ffn_act="gelu", norm="layernorm", frontend="audio_stub",
        max_seq_len=256, param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("musicgen-large", config)
