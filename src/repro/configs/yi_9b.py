"""yi-9b [arXiv:2403.04652; hf] — llama-arch GQA dense.

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""

from __future__ import annotations

from repro.configs.base import AttentionConfig, ModelConfig, register


def _full():
    return ModelConfig(
        name="yi-9b", family="dense",
        n_layers=48, d_model=4096, d_ff=11008, vocab=64000,
        attention=AttentionConfig(kind="gqa", n_heads=32, n_kv_heads=4,
                                  d_head=128, rope_theta=10000.0),
        max_seq_len=32768,
        notes="pure full attention; long_500k in mosa_hybrid mode.")


def _smoke():
    return ModelConfig(
        name="yi-9b-smoke", family="dense",
        n_layers=2, d_model=64, d_ff=128, vocab=512,
        attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=1, d_head=16),
        max_seq_len=256, param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("yi-9b", config)
