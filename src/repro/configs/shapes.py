"""Assigned input shapes (one set for all LM-family archs) + input specs.

``train_*`` lowers ``train_step``; ``prefill_*`` lowers the prefill forward;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def input_specs(cfg, shape: ShapeCfg):
    """ShapeDtypeStruct stand-ins for the model inputs of one cell.

    For train/prefill:
      * token archs: {"tokens": (B,T) i32, "labels": (B,T) i32}
      * stub-frontend archs (audio/vlm): {"embeds": (B,T,h) bf16, "labels"}
        — the modality frontend supplies precomputed frame/patch embeddings.
    For decode: {"token": (B,1) i32} (the cache is threaded separately).
    """
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
    specs = {"labels": jax.ShapeDtypeStruct((B, T), i32)}
    if cfg.frontend in ("audio_stub", "vision_stub"):
        specs["embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), cfg.cdtype)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, T), i32)
    return specs
