"""The paper's own model family (App. C Table 4) as configs.

``mosa-paper-<size>`` with presets:
  * variant="dense"    — the dense baseline (sparsity 1)
  * variant="mosa"     — hybrid: 4 dense heads + FLOP-matched MoSA heads
  * variant="fixed"    — hybrid with fixed sparse attention baseline
  * variant="routing"  — hybrid with Routing Attention baseline
  * variant="pure"     — pure MoSA (App. B ablation)

Head counts come from the IsoFLOP solver in repro.core.flops, which
reproduces Table 5 exactly.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import (AttentionConfig, BlockSpec, ModelConfig,
                                MoSAConfig, register)
from repro.core.flops import PAPER_MODELS


def paper_config(size: str = "tiny", variant: str = "dense",
                 sparsity: int = 32, seq_len: int = 1024,
                 n_mosa_heads: int | None = None,
                 local_window: int = 0, dtype: str = "float32",
                 selection_granularity: str = "token",
                 sel_block_size: int = 16) -> ModelConfig:
    pm = PAPER_MODELS[size]
    base = dict(
        family="dense", n_layers=pm.n_layers, d_model=pm.h, d_ff=pm.d_ff,
        vocab=8000, max_seq_len=seq_len,
        param_dtype=dtype, compute_dtype=dtype,
        attention=AttentionConfig(kind="gqa", n_heads=pm.n_heads,
                                  n_kv_heads=pm.n_heads, d_head=pm.hp),
        ffn_act="gelu", tie_embeddings=False)
    if variant == "dense":
        return ModelConfig(name=f"mosa-paper-{size}", **base)

    if variant == "pure":
        n_sparse = n_mosa_heads or pm.pure_mosa_heads(sparsity, seq_len)
        n_dense = 0
    else:
        n_sparse = n_mosa_heads or pm.hybrid_mosa_heads(sparsity, seq_len)
        n_dense = 4
    mosa = MoSAConfig(n_mosa_heads=max(n_sparse, 1), sparsity=sparsity,
                      n_dense_heads=n_dense, d_head=pm.hp,
                      local_window=local_window,
                      selection_granularity=selection_granularity,
                      sel_block_size=sel_block_size)
    pattern = tuple(BlockSpec("mosa", "dense") for _ in range(pm.n_layers))
    name = f"mosa-paper-{size}-{variant}{sparsity}"
    sparse_variant = variant if variant in ("fixed", "routing") else "mosa"
    return ModelConfig(name=name, pattern=pattern, mosa=mosa,
                       sparse_variant=sparse_variant, **base)


def config(preset: str = "full", size: str = "tiny", variant: str = "dense",
           **kw):
    if preset == "smoke":
        cfg = paper_config("tiny", variant, sparsity=kw.pop("sparsity", 8),
                           seq_len=kw.pop("seq_len", 128), **kw)
        return dataclasses.replace(cfg, n_layers=2, vocab=512,
                                   name=cfg.name + "-smoke",
                                   pattern=cfg.pattern[:2] if cfg.pattern else ())
    return paper_config(size, variant, **kw)


register("mosa-paper", config)
