"""Model configuration dataclasses + the architecture registry.

Every assigned architecture is a ``ModelConfig`` built in its own module under
``repro.configs``; ``get_config(name)`` resolves them, and
``get_config(name, preset="smoke")`` returns the reduced config used by the
CPU smoke tests.

The layer structure is expressed as a *pattern*: a list of ``BlockSpec``
(mixer kind + ffn kind), one per layer.  ``repro.nn.transformer`` detects the
smallest period of the pattern and scans over super-blocks, so an 80-layer
uniform model compiles a single layer body and Jamba compiles one 8-layer
super-block.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # hidden dim of each expert FFN
    n_shared_experts: int = 0     # DeepSeek-style always-on shared experts
    d_shared: int = 0             # hidden dim of the shared expert (0 = same as d_expert)
    router_aux_loss: float = 0.01
    capacity_factor: float = 1.25  # set to n_experts/top_k for lossless dispatch


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 = dense q projection (v2-lite uses dense q)
    rope_head_dim: int = 64       # decoupled RoPE key dim
    v_head_dim: int = 128
    nope_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoSAConfig:
    """The paper's technique as a first-class feature.

    ``n_mosa_heads`` sparse heads with expert-choice routing (k = T/sparsity
    tokens per head) ride alongside ``n_dense_heads`` dense heads (the paper's
    hybrid; App. B shows 4 dense heads is optimal and sparsity-agnostic).
    """

    n_mosa_heads: int
    sparsity: int = 32            # rho = T / k
    n_dense_heads: int = 4
    d_head: int = 64
    force_first_token: bool = True
    min_k: int = 2                # downstream-eval floor (paper §3.5)
    local_window: int = 0         # >0: dense heads become sliding-window (paper §3.4)
    k_fixed: int = 0              # >0: constant k regardless of T (paper §3.4 long-seq)
    impl: str = "einsum"          # inner-attention impl: einsum | pallas
                                  # (pallas = fused fwd + custom-VJP bwd kernels)
    selection_granularity: str = "token"  # token | block (expert choice over
                                  # KV blocks; sel_block_size=1 == token mode)
    sel_block_size: int = 16      # block-choice KV block size; defaults to the
                                  # paged BlockPool block size (PagedConfig);
                                  # power of two <= 128 (kernel tile constraint)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 = ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # which layer indices are sLSTM (rest mLSTM), following xLSTM [a:b] notation
    slstm_layers: tuple = ()
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.333
    conv1d_kernel: int = 4
    qkv_block_size: int = 4


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    kind: str = "gqa"             # "gqa" | "mla" | "none"
    n_heads: int = 8
    n_kv_heads: int = 8
    d_head: int = 64
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0               # sliding-window size for local layers (0 = global)
    mrope_sections: tuple = ()    # qwen2-vl M-RoPE (t, h, w) dim split; () = standard RoPE
    softmax_scale: Optional[float] = None
    mla: Optional[MLAConfig] = None


# ---------------------------------------------------------------------------
# Block / model
# ---------------------------------------------------------------------------

# mixer kinds: attn | attn_local | mosa | mamba | slstm | mlstm
# ffn kinds:   dense | moe | none
@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str
    ffn: str


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attention: AttentionConfig
    pattern: tuple = ()           # tuple[BlockSpec]; () = uniform (attn, dense/moe)
    moe: Optional[MoEConfig] = None
    mosa: Optional[MoSAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    norm: str = "rmsnorm"
    ffn_act: str = "swiglu"       # swiglu | gelu
    tie_embeddings: bool = False
    max_seq_len: int = 4096
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    frontend: str = "none"        # none | audio_stub | vision_stub
    remat: str = "none"           # none | full | dots_saveable
    scan_layers: bool = True
    sparse_variant: str = "mosa"  # mosa | fixed | routing (hybrid sparse side)
    notes: str = ""

    def resolved_pattern(self) -> tuple:
        if self.pattern:
            assert len(self.pattern) == self.n_layers, (
                f"{self.name}: pattern length {len(self.pattern)} != n_layers {self.n_layers}")
            return self.pattern
        ffn = "moe" if self.moe is not None else "dense"
        mixer = "mosa" if self.mosa is not None else "attn"
        return tuple(BlockSpec(mixer, ffn) for _ in range(self.n_layers))

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def with_mosa(self, sparsity: int = 32, n_mosa_heads: int | None = None,
                  local_window: int = 0, k_fixed: int = 0,
                  selection_granularity: str = "token",
                  sel_block_size: int = 16) -> "ModelConfig":
        """Return a MoSA-hybrid variant of this config (paper's technique).

        Replaces every softmax-attention mixer with a ``mosa`` hybrid mixer
        (4 dense + N sparse heads).  Attention-free mixers (mamba/slstm/
        mlstm) are untouched; raises if the config has no attention at all.
        """
        pat = self.resolved_pattern()
        kinds = {b.mixer for b in pat}
        if not (kinds & {"attn", "attn_local"}):
            raise ValueError(f"{self.name}: MoSA inapplicable (attention-free)")
        if n_mosa_heads is None:
            # FLOP-matched default: solved properly in repro.core.hybrid
            n_mosa_heads = max(1, self.attention.n_heads - 4) * sparsity // 2
        mosa = MoSAConfig(n_mosa_heads=n_mosa_heads, sparsity=sparsity,
                          n_dense_heads=4, d_head=self.attention.d_head,
                          local_window=local_window, k_fixed=k_fixed,
                          selection_granularity=selection_granularity,
                          sel_block_size=sel_block_size)
        new_pat = tuple(
            dataclasses.replace(b, mixer="mosa") if b.mixer in ("attn", "attn_local") else b
            for b in pat)
        return dataclasses.replace(
            self, name=self.name + f"-mosa{sparsity}", pattern=new_pat, mosa=mosa)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(name: str, fn: Callable[..., ModelConfig]):
    _REGISTRY[name] = fn
    return fn


def config_names():
    _load_all()
    return sorted(_REGISTRY)


def get_config(name: str, preset: str = "full", **kw) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name](preset=preset, **kw)


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # Import every config module so they register themselves.
    from repro.configs import (  # noqa: F401
        granite_moe_1b_a400m, deepseek_v2_lite_16b, jamba_v0_1_52b,
        musicgen_large, yi_34b, yi_9b, gemma3_4b, qwen2_1_5b,
        xlstm_125m, qwen2_vl_72b, mosa_paper,
    )
