"""qwen2-1.5b [arXiv:2407.10671; hf] — dense GQA with QKV bias.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; tied embeddings.
"""

from __future__ import annotations

from repro.configs.base import AttentionConfig, ModelConfig, register


def _full():
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, d_ff=8960, vocab=151936,
        attention=AttentionConfig(kind="gqa", n_heads=12, n_kv_heads=2,
                                  d_head=128, qkv_bias=True,
                                  rope_theta=1000000.0),
        tie_embeddings=True, max_seq_len=32768,
        notes="QKV bias; long_500k in mosa_hybrid mode.")


def _smoke():
    return ModelConfig(
        name="qwen2-smoke", family="dense",
        n_layers=2, d_model=64, d_ff=128, vocab=512,
        attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2,
                                  d_head=16, qkv_bias=True),
        tie_embeddings=True, max_seq_len=256,
        param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("qwen2-1.5b", config)
