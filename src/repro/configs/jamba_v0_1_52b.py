"""jamba-v0.1-52b [arXiv:2403.19887; hf].

32L d_model=4096 32H (GQA kv=8) d_ff(expert)=14336 vocab=65536; Mamba:attn
interleave 1:7 (one attention layer per 8-layer Jamba block, at in-block
index 4); MoE 16 experts top-2 on every other layer (e=2).
"""

from __future__ import annotations

from repro.configs.base import (AttentionConfig, BlockSpec, MambaConfig,
                                ModelConfig, MoEConfig, register)


def _pattern(n_layers, attn_at=4, period=8, moe_every=2):
    out = []
    for i in range(n_layers):
        mixer = "attn" if (i % period) == attn_at else "mamba"
        ffn = "moe" if (i % moe_every) == 1 else "dense"
        out.append(BlockSpec(mixer, ffn))
    return tuple(out)


def _full():
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, d_ff=14336, vocab=65536,
        pattern=_pattern(32),
        attention=AttentionConfig(kind="gqa", n_heads=32, n_kv_heads=8,
                                  d_head=128, rope_theta=10000.0),
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        max_seq_len=524288,
        notes="8-layer Jamba block scanned as one super-block; "
              "long_500k runs natively (Mamba state + 4 attn layers).")


def _smoke():
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=8, d_model=64, d_ff=128, vocab=512,
        pattern=_pattern(8),
        attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=32, capacity_factor=2.0),
        mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
        max_seq_len=256, param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("jamba-v0.1-52b", config)
