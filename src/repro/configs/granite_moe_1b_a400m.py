"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L d_model=1024 16H (GQA kv=8) vocab=49155; MoE 32 experts top-8,
expert hidden 512.
"""

from __future__ import annotations

from repro.configs.base import (AttentionConfig, ModelConfig, MoEConfig,
                                register)


def _full():
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, d_ff=512, vocab=49155,
        attention=AttentionConfig(kind="gqa", n_heads=16, n_kv_heads=8,
                                  d_head=64, rope_theta=10000.0),
        moe=MoEConfig(n_experts=32, top_k=8, d_expert=512),
        tie_embeddings=True, max_seq_len=4096,
        notes="MoE every layer; GQA 16q/8kv; d_ff is the per-expert hidden.")


def _smoke():
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=64, d_ff=32, vocab=512,
        attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=32, capacity_factor=2.0),
        tie_embeddings=True, max_seq_len=256,
        param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("granite-moe-1b-a400m", config)
