"""yi-34b [arXiv:2403.04652; hf] — llama-arch GQA dense.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""

from __future__ import annotations

from repro.configs.base import AttentionConfig, ModelConfig, register


def _full():
    return ModelConfig(
        name="yi-34b", family="dense",
        n_layers=60, d_model=7168, d_ff=20480, vocab=64000,
        attention=AttentionConfig(kind="gqa", n_heads=56, n_kv_heads=8,
                                  d_head=128, rope_theta=5000000.0),
        max_seq_len=32768,
        notes="pure full attention: long_500k runs in mosa_hybrid mode "
              "(MoSA global + sliding-window local), see DESIGN §5.")


def _smoke():
    return ModelConfig(
        name="yi-34b-smoke", family="dense",
        n_layers=2, d_model=64, d_ff=128, vocab=512,
        attention=AttentionConfig(kind="gqa", n_heads=8, n_kv_heads=2, d_head=8),
        max_seq_len=256, param_dtype="float32", compute_dtype="float32")


def config(preset: str = "full", **kw):
    return _full() if preset == "full" else _smoke()


register("yi-34b", config)
