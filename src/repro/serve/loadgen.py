"""Seeded load generation for the timed Scheduler mode (DESIGN §12).

Drain-a-preloaded-queue runs measure capacity, not service: every serving
claim that matters under traffic (TTFT/TPOT tails, goodput through
overload) depends on the ARRIVAL PROCESS, which pre-queueing erases.
This module builds seeded, reproducible request streams and the source
objects ``Scheduler.run(source=...)`` pumps them through:

  * **Open loop** — arrivals at predetermined times, independent of how
    the server keeps up (the overload-honest discipline: a slow server
    faces a growing queue, exactly like production).  Poisson arrivals
    (``poisson_workload``) model independent users; Gamma interarrivals
    with CV > 1 (``bursty_workload``) model correlated bursts.
  * **Closed loop** — a fixed number of outstanding requests; each
    completion immediately triggers the next submit.  Self-throttling, so
    it cannot show overload — its role is measuring the *sustainable*
    service rate the open-loop sweep is then scaled against.

Per-tenant mixes: each ``TenantSpec`` carries a sampling weight plus
prompt-length / max-new ranges, and every arrival is tagged with its
tenant name — the Scheduler threads it into labeled metrics and SLO
records.  Everything is driven by one ``numpy`` Generator seed: the same
(seed, rate, n, tenants) produces the identical stream, so bench numbers
are replayable.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic mix: relative arrival ``weight`` and inclusive
    ``(lo, hi)`` ranges for prompt length and decode budget."""

    name: str
    weight: float = 1.0
    prompt_len: tuple = (8, 48)
    max_new: tuple = (4, 24)


DEFAULT_TENANTS = (TenantSpec("default"),)


class Arrival(NamedTuple):
    t: float              # seconds since run start (0.0 for closed loop)
    tenant: str
    prompt: np.ndarray    # int32 token ids
    max_new: int


def _gen_requests(rng: np.random.Generator, n: int,
                  tenants: Sequence[TenantSpec], vocab: int):
    w = np.asarray([t.weight for t in tenants], np.float64)
    w = w / w.sum()
    out = []
    for _ in range(n):
        t = tenants[int(rng.choice(len(tenants), p=w))]
        plen = int(rng.integers(t.prompt_len[0], t.prompt_len[1] + 1))
        mnew = int(rng.integers(t.max_new[0], t.max_new[1] + 1))
        prompt = rng.integers(0, vocab, size=(plen,), dtype=np.int32)
        out.append((t.name, prompt, mnew))
    return out


def poisson_workload(rate: float, n: int, seed: int, vocab: int,
                     tenants: Optional[Sequence[TenantSpec]] = None
                     ) -> List[Arrival]:
    """``n`` arrivals with exponential interarrival times (mean ``1/rate``
    req/s) — the memoryless independent-users model."""
    assert rate > 0 and n > 0
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate, size=n))
    reqs = _gen_requests(rng, n, tenants or DEFAULT_TENANTS, vocab)
    return [Arrival(float(t), name, p, m)
            for t, (name, p, m) in zip(times, reqs)]


def bursty_workload(rate: float, n: int, seed: int, vocab: int,
                    tenants: Optional[Sequence[TenantSpec]] = None,
                    cv: float = 3.0) -> List[Arrival]:
    """``n`` arrivals with Gamma interarrivals: mean ``1/rate`` but
    coefficient of variation ``cv`` (> 1 ⇒ burstier than Poisson — long
    gaps punctuated by clumps, the tail-stressing traffic shape)."""
    assert rate > 0 and n > 0 and cv > 0
    rng = np.random.default_rng(seed)
    shape = 1.0 / (cv * cv)
    times = np.cumsum(rng.gamma(shape, 1.0 / (rate * shape), size=n))
    reqs = _gen_requests(rng, n, tenants or DEFAULT_TENANTS, vocab)
    return [Arrival(float(t), name, p, m)
            for t, (name, p, m) in zip(times, reqs)]


def closed_workload(n: int, seed: int, vocab: int,
                    tenants: Optional[Sequence[TenantSpec]] = None
                    ) -> List[Arrival]:
    """``n`` requests with no arrival times (t=0) — feed a
    ``ClosedLoopSource``.  Same per-tenant sampling as the open-loop
    builders, so closed-loop calibration and the open-loop sweep measure
    the same request population."""
    rng = np.random.default_rng(seed)
    reqs = _gen_requests(rng, n, tenants or DEFAULT_TENANTS, vocab)
    return [Arrival(0.0, name, p, m) for name, p, m in reqs]


class OpenLoopSource:
    """Submit each arrival when its timestamp comes due, regardless of
    server progress."""

    def __init__(self, arrivals: Sequence[Arrival]):
        self.arrivals = sorted(arrivals, key=lambda a: a.t)
        self.submitted_rids: List[int] = []
        self._i = 0

    def pump(self, sched, now: float) -> None:
        while (self._i < len(self.arrivals)
               and self.arrivals[self._i].t <= now):
            a = self.arrivals[self._i]
            self.submitted_rids.append(
                sched.submit(a.prompt, a.max_new, tenant=a.tenant))
            self._i += 1

    def exhausted(self) -> bool:
        return self._i >= len(self.arrivals)

    def next_arrival_in(self, now: float) -> Optional[float]:
        if self.exhausted():
            return None
        return max(self.arrivals[self._i].t - now, 0.0)


class ClosedLoopSource:
    """Hold ``concurrency`` requests outstanding: every completion (or
    shed) frees a slot that the next request immediately fills."""

    def __init__(self, requests: Sequence[Arrival], concurrency: int):
        assert concurrency > 0
        self.requests = list(requests)
        self.concurrency = concurrency
        self.submitted_rids: List[int] = []
        self._i = 0

    def pump(self, sched, now: float) -> None:
        done = sum(1 for rid in self.submitted_rids
                   if rid in sched.results)
        while (self._i < len(self.requests)
               and len(self.submitted_rids) - done < self.concurrency):
            a = self.requests[self._i]
            self.submitted_rids.append(
                sched.submit(a.prompt, a.max_new, tenant=a.tenant))
            self._i += 1
            done = sum(1 for rid in self.submitted_rids
                       if rid in sched.results)

    def exhausted(self) -> bool:
        return self._i >= len(self.requests)

    def next_arrival_in(self, now: float) -> Optional[float]:
        # The next submit is triggered by a completion, not by the clock —
        # there is in-flight work whenever we are not exhausted.
        return None if self.exhausted() else 0.0
