"""Block-paged KV caches: pool tensors, block tables, and the host allocator.

The contiguous caches in ``repro.core.kv_cache`` reserve a
``(B, max_len, H, d)`` slab per batch slot, so server capacity is bound by
the WORST-CASE prompt even when most requests are short — the fragmentation
problem paged attention solves.  Here KV lives in fixed-size *blocks* of
``block_size`` tokens inside a shared pool tensor ``(N, block_size, H, d)``;
a per-row *block table* maps logical block ``pos // block_size`` to a
physical block id.  Rows own only the blocks their tokens actually fill, and
identical prompt prefixes can map to the SAME physical blocks
(``repro.serve.prefix_cache``).

Two layers, deliberately separated:

  * ``BlockPool`` — the host-side allocator: free list, per-block refcounts
    (shared prefix blocks), copy-on-write ``ensure_owned``.  Pure Python;
    never traced.
  * ``PagedDenseKVCache`` / ``PagedWindowKVCache`` — fixed-shape device
    pytrees (jit/pjit friendly).  Their ``append`` / ``gather`` reproduce the
    contiguous ``DenseKVCache`` / ``WindowKVCache`` semantics bit-for-bit:
    ``gather()`` of a paged cache equals the contiguous cache's ``k``/``v``
    arrays at every valid position, so the decode math can be shared between
    the two layouts (``repro.core.attention``) and paged decode is
    numerically exact.

MoSA caches stay UNPAGED on purpose: they are already O(k) per head,
independent of context length — there is no quadratic slab to page (DESIGN
§7).  The same applies to SSM/xLSTM recurrent states (O(1)).

Writes to unallocated rows are dropped, not clobbered: block id ``< 0``
(no block) is remapped past the pool end and scattered with ``mode="drop"``,
so an inactive batch row or a right-padded prefill tail can never corrupt
another row's blocks.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.obs.metrics import registry as _obs_registry


@dataclasses.dataclass(frozen=True)
class PagedConfig:
    """Static paged-cache geometry.

    ``num_blocks == 0`` auto-sizes the pool to the contiguous worst case
    (``batch * ceil(max_len / block_size)`` for dense, ``batch * W /
    block_size`` for window caches) so ``paged=True`` is a drop-in; the
    serving win comes from passing a TIGHTER budget and letting the
    ``Scheduler`` admit block-granularly.
    """

    block_size: int = 16
    num_blocks: int = 0          # dense-group pool size (0 = worst case)
    num_window_blocks: int = 0   # window-group pool size (0 = worst case)


# --------------------------------------------------------------- allocator
class BlockPool:
    """Host-side free-list allocator with refcounted blocks.

    Refcounts implement prefix sharing: a block referenced by the prefix
    trie AND by live requests has ``ref > 1``; freeing decrements and the
    block returns to the free list only at zero.  ``ensure_owned`` is the
    copy-on-write primitive: a caller about to MUTATE a block (the window
    ring overwrites slots in place) gets a fresh private id back — plus a
    flag telling it to copy the payload — whenever the block is shared.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 name: str = "blocks"):
        assert num_blocks >= 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.name = name             # metrics namespace: pool.<name>.*
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = [0] * num_blocks

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def refcount(self, bid: int) -> int:
        return self._ref[bid]

    def _publish(self) -> None:
        """Mirror occupancy into the obs registry (DESIGN §11): utilization
        gauges plus a live-blocks high-water mark.  One enabled check, then
        plain gauge sets — the allocator stays pure Python and untraced."""
        reg = _obs_registry()
        if not reg.enabled:
            return
        live = self.num_blocks - len(self._free)
        reg.set(f"pool.{self.name}.free_blocks", len(self._free))
        reg.set(f"pool.{self.name}.live_blocks", live)
        reg.set_max(f"pool.{self.name}.live_high_water", live)

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` fresh blocks at ref 1, or None (all-or-nothing)."""
        if n < 0 or n > len(self._free):
            _obs_registry().inc(f"pool.{self.name}.alloc_failures")
            return None
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            self._ref[b] = 1
        _obs_registry().inc(f"pool.{self.name}.allocs", n)
        self._publish()
        return ids

    def incref(self, ids: Sequence[int]) -> None:
        hi = 0
        for b in ids:
            assert self._ref[b] > 0, f"incref of free block {b}"
            self._ref[b] += 1
            if self._ref[b] > hi:
                hi = self._ref[b]
        if ids:
            _obs_registry().set_max(
                f"pool.{self.name}.refcount_high_water", hi)

    def decref(self, ids: Sequence[int]) -> None:
        for b in ids:
            assert self._ref[b] > 0, f"double free of block {b}"
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free.append(b)
        if ids:
            self._publish()

    def ensure_owned(self, bid: int) -> Optional[tuple]:
        """(owned_id, needs_copy).  Copy-on-write: shared blocks come back as
        a fresh allocation (caller copies ``bid`` -> ``owned_id`` on device
        and swaps its table entry); exclusive blocks come back unchanged.
        None if the pool is exhausted (caller preempts)."""
        assert self._ref[bid] > 0, f"ensure_owned of free block {bid}"
        if self._ref[bid] == 1:
            return bid, False
        got = self.alloc(1)
        if got is None:
            return None
        self.decref([bid])
        _obs_registry().inc(f"pool.{self.name}.cow_copies")
        return got[0], True


# ------------------------------------------------------------ device caches
def _blocks_for(tokens: int, block_size: int) -> int:
    return -(-tokens // block_size)


def _pool_scatter(pool, blk, off, vals):
    """Scatter ``vals`` at ``(blk, off)``; ``blk < 0`` (unallocated) drops.

    pool: (N, bs, ...); blk/off: (...idx) int32; vals: (...idx, ...).
    """
    n = pool.shape[0]
    blk = jnp.where(blk < 0, n, blk)   # out of bounds -> mode="drop"
    return pool.at[blk, off].set(vals.astype(pool.dtype), mode="drop")


class PagedDenseKVCache(NamedTuple):
    """Paged counterpart of ``DenseKVCache``.

    ``gather()`` reconstructs the contiguous ``(B, S, Hkv, d)`` layout
    (``S = max_blocks * block_size``); positions ``>= length`` hold stale or
    zero payload exactly like the contiguous cache's unwritten tail, and the
    decode math masks them identically — see ``repro.core.attention``.
    """

    k: jnp.ndarray            # (N, bs, Hkv, d) physical pool
    v: jnp.ndarray            # (N, bs, Hkv, d)
    block_table: jnp.ndarray  # (B, max_blocks) int32; -1 = unallocated
    length: jnp.ndarray       # (B,) int32 — tokens filled

    @classmethod
    def create(cls, batch, max_len, n_kv_heads, d_head, dtype=jnp.bfloat16,
               *, block_size: int = 16, num_blocks: int = 0,
               identity_tables: bool = False):
        nb = _blocks_for(max_len, block_size)
        n = num_blocks or batch * nb
        z = jnp.zeros((n, block_size, n_kv_heads, d_head), dtype)
        if identity_tables:
            # row r owns blocks [r*nb, (r+1)*nb) — the no-allocator layout
            # Server.generate uses for whole-batch prefill+decode.
            assert n >= batch * nb, (n, batch, nb)
            table = (jnp.arange(batch * nb, dtype=jnp.int32)
                     .reshape(batch, nb))
        else:
            table = jnp.full((batch, nb), -1, jnp.int32)
        return cls(z, z, table, jnp.zeros((batch,), jnp.int32))

    @property
    def block_size(self) -> int:
        return self.k.shape[1]

    @property
    def max_len(self) -> int:
        return self.block_table.shape[1] * self.k.shape[1]

    def append(self, k_new, v_new, n_valid=None):
        """k_new/v_new: (B, Tnew, Hkv, d); same semantics as
        ``DenseKVCache.append`` with per-row lengths throughout.

        ``n_valid`` (B,) — number of REAL tokens per row (right-padded
        prefill): writes past ``length + n_valid`` are dropped and ``length``
        advances by ``n_valid`` instead of ``Tnew``, so pad KV never lands in
        the pool (the masked-prefill fix, DESIGN §7).
        """
        B, Tnew = k_new.shape[:2]
        bs = self.block_size
        pos = self.length[:, None] + jnp.arange(Tnew, dtype=jnp.int32)  # (B,T)
        blk = jnp.take_along_axis(
            self.block_table, jnp.clip(pos // bs, 0,
                                       self.block_table.shape[1] - 1), axis=1)
        blk = jnp.where(pos // bs < self.block_table.shape[1], blk, -1)
        if n_valid is not None:
            nv = jnp.asarray(n_valid, jnp.int32)
            blk = jnp.where(jnp.arange(Tnew) < nv[:, None], blk, -1)
            adv = nv
        else:
            adv = jnp.full((B,), Tnew, jnp.int32)
        off = pos % bs
        k = _pool_scatter(self.k, blk, off, k_new)
        v = _pool_scatter(self.v, blk, off, v_new)
        return PagedDenseKVCache(k, v, self.block_table, self.length + adv)

    def append_packed(self, k_new, v_new, row_of_tok, pos_of_tok):
        """Packed-varlen append: scatter a flattened multi-row token stream.

        k_new/v_new: (total, Hkv, d); row_of_tok: (total,) int32 batch row
        per token (-1 = padding, dropped); pos_of_tok: (total,) int32 the
        token's absolute position in its row's KV space.  Each row's
        ``length`` advances by the number of its tokens in the stream —
        the packed counterpart of ``append`` with ``n_valid`` masking, and
        the write primitive of chunked prefill (DESIGN §9).
        """
        bs = self.block_size
        B, nbt = self.block_table.shape
        row = jnp.asarray(row_of_tok, jnp.int32)
        pos = jnp.asarray(pos_of_tok, jnp.int32)
        rowc = jnp.clip(row, 0, B - 1)
        blk = self.block_table[rowc, jnp.clip(pos // bs, 0, nbt - 1)]
        blk = jnp.where((row < 0) | (pos // bs >= nbt), -1, blk)
        off = pos % bs
        k = _pool_scatter(self.k, blk, off, k_new)
        v = _pool_scatter(self.v, blk, off, v_new)
        counts = jnp.zeros((B,), jnp.int32).at[
            jnp.where(row < 0, B, row)].add(1, mode="drop")
        return PagedDenseKVCache(k, v, self.block_table,
                                 self.length + counts)

    def gather(self):
        """(k, v) in the contiguous (B, S, Hkv, d) layout."""
        bt = jnp.clip(self.block_table, 0)    # -1 -> junk, masked by length
        B, nb = bt.shape
        bs = self.block_size

        def one(table):                        # vmap keeps B a batching dim
            kk = self.k[table].reshape(nb * bs, *self.k.shape[2:])
            vv = self.v[table].reshape(nb * bs, *self.v.shape[2:])
            return kk, vv

        return jax.vmap(one)(bt)


class PagedWindowKVCache(NamedTuple):
    """Paged counterpart of ``WindowKVCache`` (ring of the last W tokens).

    The ring arithmetic is IDENTICAL to the contiguous cache — token at
    position ``p`` lives at slot ``p % W``, physical location
    ``pool[table[b, slot // bs], slot % bs]`` — so ``gather()`` returns the
    exact ``(B, W, Hkv, d)`` ring layout ``WindowKVCache.k`` holds.
    ``W = positions.shape[1]`` must be a multiple of ``block_size``.

    Unlike dense blocks (append-only, immutable once full), ring blocks are
    OVERWRITTEN in place as the window slides — a row holding blocks shared
    through the prefix cache must ``BlockPool.ensure_owned`` them before its
    next append (the scheduler's copy-on-write step).
    """

    k: jnp.ndarray            # (N, bs, Hkv, d)
    v: jnp.ndarray
    block_table: jnp.ndarray  # (B, W // bs) int32
    positions: jnp.ndarray    # (B, W) int32 original positions (-1 = empty)
    length: jnp.ndarray       # (B,) total tokens seen

    @classmethod
    def create(cls, batch, window, n_kv_heads, d_head, dtype=jnp.bfloat16,
               *, block_size: int = 16, num_blocks: int = 0,
               identity_tables: bool = False):
        assert window % block_size == 0, (
            f"window {window} must be a multiple of block_size {block_size} "
            f"(ring slots map to blocks by slot // block_size)")
        wb = window // block_size
        n = num_blocks or batch * wb
        z = jnp.zeros((n, block_size, n_kv_heads, d_head), dtype)
        if identity_tables:
            assert n >= batch * wb, (n, batch, wb)
            table = (jnp.arange(batch * wb, dtype=jnp.int32)
                     .reshape(batch, wb))
        else:
            table = jnp.full((batch, wb), -1, jnp.int32)
        pos = jnp.full((batch, window), -1, jnp.int32)
        return cls(z, z, table, pos, jnp.zeros((batch,), jnp.int32))

    @property
    def block_size(self) -> int:
        return self.k.shape[1]

    @property
    def window(self) -> int:
        return self.positions.shape[1]

    def _write(self, k_vals, v_vals, pos, drop):
        """Scatter tokens at ring slots ``pos % W``; ``drop`` masks writes."""
        W, bs = self.window, self.block_size
        slot = pos % W
        blk = jnp.take_along_axis(self.block_table, slot // bs, axis=1)
        blk = jnp.where(drop, -1, blk)
        off = slot % bs
        k = _pool_scatter(self.k, blk, off, k_vals)
        v = _pool_scatter(self.v, blk, off, v_vals)
        positions = self.positions.at[
            jnp.arange(pos.shape[0])[:, None],
            jnp.where(drop, W, slot)].set(pos, mode="drop")
        return k, v, positions

    def append_one(self, k_new, v_new):
        """k_new/v_new: (B, Hkv, d) — single decode step, per-row slots."""
        pos = self.length[:, None].astype(jnp.int32)            # (B, 1)
        k, v, positions = self._write(k_new[:, None], v_new[:, None], pos,
                                      jnp.zeros_like(pos, bool))
        return PagedWindowKVCache(k, v, self.block_table, positions,
                                  self.length + 1)

    def append(self, k_new, v_new, n_valid=None):
        """Multi-token (prefill) append: keep the last ``min(W, n)`` real
        tokens per row, drop right-pad tails and tokens a later token in the
        SAME append would overwrite (duplicate ring slots must scatter
        uniquely).  k_new/v_new: (B, T, Hkv, d)."""
        B, T = k_new.shape[:2]
        nv = (jnp.full((B,), T, jnp.int32) if n_valid is None
              else jnp.asarray(n_valid, jnp.int32))
        t = jnp.arange(T, dtype=jnp.int32)[None, :]              # (1, T)
        pos = self.length[:, None] + t                           # (B, T)
        drop = (t >= nv[:, None]) | (t < nv[:, None] - self.window)
        k, v, positions = self._write(k_new, v_new, pos, drop)
        return PagedWindowKVCache(k, v, self.block_table, positions,
                                  self.length + nv)

    def append_packed(self, k_new, v_new, row_of_tok, pos_of_tok):
        """Packed-varlen ring append (see ``PagedDenseKVCache.append_packed``).

        Tokens scatter to ring slot ``pos % W``.  A token is dropped when a
        LATER token of the same row in this stream maps to the same slot
        (only the last W tokens per row survive — duplicate ring slots must
        scatter uniquely, as in ``append``).
        """
        W, bs = self.window, self.block_size
        B = self.block_table.shape[0]
        row = jnp.asarray(row_of_tok, jnp.int32)
        pos = jnp.asarray(pos_of_tok, jnp.int32)
        rowc = jnp.clip(row, 0, B - 1)
        rowd = jnp.where(row < 0, B, row)                 # drop index
        # per-row deepest position in THIS stream; tokens more than W-1
        # behind it would be overwritten within the same scatter -> drop
        deepest = jnp.full((B,), -1, jnp.int32).at[rowd].max(
            pos, mode="drop")
        drop = (row < 0) | (deepest[rowc] - pos >= W)
        slot = pos % W
        blk = self.block_table[rowc, slot // bs]
        blk = jnp.where(drop, -1, blk)
        k = _pool_scatter(self.k, blk, slot % bs, k_new)
        v = _pool_scatter(self.v, blk, slot % bs, v_new)
        positions = self.positions.at[
            jnp.where(drop, B, rowc), slot].set(pos, mode="drop")
        counts = jnp.zeros((B,), jnp.int32).at[rowd].add(1, mode="drop")
        return PagedWindowKVCache(k, v, self.block_table, positions,
                                  self.length + counts)

    def gather(self):
        """(k, v) in the contiguous ring (B, W, Hkv, d) layout."""
        bt = jnp.clip(self.block_table, 0)
        B, wb = bt.shape
        bs = self.block_size

        def one(table):
            kk = self.k[table].reshape(wb * bs, *self.k.shape[2:])
            vv = self.v[table].reshape(wb * bs, *self.v.shape[2:])
            return kk, vv

        return jax.vmap(one)(bt)


PAGED_CACHE_TYPES = (PagedDenseKVCache, PagedWindowKVCache)

# Sharding registration (CACHE_AXES, DESIGN §6/§7): pool dim 0 is the
# PHYSICAL BLOCK dim, shared by every batch row (any row's table may point
# anywhere in the pool), so it stays replicated over the data-parallel axes;
# the head dim head-shards over ``model`` exactly like the contiguous
# caches — gather and the paged kernel keep heads a batching dim, so a
# tp-sharded pool never relayouts during decode.  Block tables and
# positions are per-row metadata and follow the batch axes.
from repro.dist.sharding import register_cache_axes  # noqa: E402

register_cache_axes(PagedDenseKVCache, {
    "k": (None, None, "kv_heads", None),
    "v": (None, None, "kv_heads", None),
    "block_table": ("batch", None),
    "length": ("batch",),
})
register_cache_axes(PagedWindowKVCache, {
    "k": (None, None, "kv_heads", None),
    "v": (None, None, "kv_heads", None),
    "block_table": ("batch", None),
    "positions": ("batch", None),
    "length": ("batch",),
})

# Fields that live in POOL space (shared by every row) vs ROW space (one
# entry per batch row).  Row-granular ops — the slot write of continuous
# batching, snapshot/restore — must slice/update only the row fields and
# pass pools through whole.
POOL_FIELDS = {"k", "v"}


def copy_blocks(cache, src, dst):
    """Copy pool blocks ``src -> dst`` (both (n,) int32) in one paged cache —
    the device half of copy-on-write (``BlockPool.ensure_owned`` is the host
    half)."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    return cache._replace(k=cache.k.at[dst].set(cache.k[src]),
                          v=cache.v.at[dst].set(cache.v[src]))
