"""Prefix cache: a hash-trie over prompt token blocks (DESIGN §7).

Requests in real serving traffic share prompt prefixes (system prompts,
few-shot preambles, multi-turn history).  With paged KV, a shared prefix can
map to SHARED physical blocks: the trie's nodes each cover one full block of
``block_size`` prompt tokens, hold the dense-layer physical block id for
that span, and are refcounted through ``BlockPool`` — a prefix-cache hit
increfs the chain and the new request's block table simply points at the
existing blocks, skipping both the HBM and the prefill compute for the
shared span.

What a node stores:

  * ``block_id``  — the dense-group physical block for this token span
    (dense blocks are append-only, hence immutable once full, hence
    shareable without copy-on-write);
  * ``snapshot``  — attached at chain tips: the host-side row snapshot
    (``launch.serve.row_snapshot``) of all BOUNDED per-row state at this
    boundary — MoSA top-k caches (O(k)), window ring content (O(W)),
    SSM states — everything a restored row needs beyond the dense blocks.
    Window ring blocks are deliberately NOT shared (they are overwritten in
    place as the window slides); their content is copied through the
    snapshot instead.

Usable hits: models whose only per-row state is paged-dense KV can reuse
ANY chain depth; models with stateful layers (MoSA / window / SSM) need the
boundary snapshot, so only snapshot-bearing nodes are usable
(``need_snapshot=True``).  The chain always covers at most the first
``P - 1`` prompt tokens so a hit still prefills >= 1 token for logits.

Eviction is leaf-first LRU: a leaf's block ref is released back to the
``BlockPool`` (physical memory survives while any live request still
references it — that is the refcount's job, not the trie's).
"""

from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

from repro.obs.metrics import registry as _obs_registry


class _Node:
    __slots__ = ("parent", "tokens", "block_id", "children", "snapshot",
                 "depth", "last_used")

    def __init__(self, parent, tokens, block_id, depth):
        self.parent = parent
        self.tokens = tokens          # tuple — this block's token span
        self.block_id = block_id      # dense-group physical block id
        self.children: dict = {}      # tokens tuple -> _Node
        self.snapshot = None          # host row snapshot at this boundary
        self.depth = depth            # tokens covered up to and incl. here
        self.last_used = 0


class PrefixCache:
    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = _Node(None, (), -1, 0)
        self._clock = itertools.count(1)
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0

    # ------------------------------------------------------------- queries
    def _chain(self, node: _Node) -> List[_Node]:
        out = []
        while node is not None and node is not self.root:
            out.append(node)
            node = node.parent
        return out[::-1]

    def chain_ids(self, node: _Node) -> List[int]:
        return [n.block_id for n in self._chain(node)]

    def lookup(self, tokens: Sequence[int],
               need_snapshot: bool = True) -> Tuple[Optional[_Node], int]:
        """Deepest usable node for ``tokens`` (full blocks of the first
        ``len(tokens) - 1`` only) and the token depth it covers.

        ``need_snapshot``: restrict to snapshot-bearing nodes (stateful
        models — see module docstring)."""
        bs = self.block_size
        toks = [int(t) for t in tokens]
        n_blocks = max(len(toks) - 1, 0) // bs
        node, best, now = self.root, None, next(self._clock)
        for i in range(n_blocks):
            key = tuple(toks[i * bs:(i + 1) * bs])
            child = node.children.get(key)
            if child is None:
                break
            node = child
            if child.snapshot is not None or not need_snapshot:
                best = child
        if best is None:
            self.misses += 1
            _obs_registry().inc("prefix.misses")
            return None, 0
        self.hits += 1
        self.hit_tokens += best.depth
        reg = _obs_registry()            # mirrors of the legacy attrs
        reg.inc("prefix.hits")
        reg.inc("prefix.hit_tokens", best.depth)
        for n in self._chain(best):
            n.last_used = now
        return best, best.depth

    def acquire(self, node: _Node, pool) -> List[int]:
        """Incref the chain's dense blocks for a request; returns the ids in
        block order.  Caller decrefs them when the request retires."""
        ids = self.chain_ids(node)
        pool.incref(ids)
        return ids

    # ------------------------------------------------------------ mutation
    def insert(self, tokens: Sequence[int], block_ids: Sequence[int],
               pool) -> Tuple[List[int], Optional[_Node]]:
        """Record a computed prefix: one node per full block of ``tokens``
        (``len(tokens)`` must be ``n * block_size``), ``block_ids`` the
        row's dense blocks for those spans.

        Existing nodes keep THEIR block id (identical content — prefill is
        deterministic in the tokens); new nodes adopt the caller's id and
        the trie takes its own ref.  Returns ``(chain, tip)``: the trie's
        authoritative chain ids — the caller rewrites its snapshot's dense
        tables to these before ``attach_snapshot``, so a later restore
        increfs exactly the blocks the trie owns — and the tip node.
        """
        bs = self.block_size
        toks = [int(t) for t in tokens]
        assert len(toks) % bs == 0, (len(toks), bs)
        n_blocks = len(toks) // bs
        assert len(block_ids) >= n_blocks, (len(block_ids), n_blocks)
        node, chain, now = self.root, [], next(self._clock)
        for i in range(n_blocks):
            key = tuple(toks[i * bs:(i + 1) * bs])
            child = node.children.get(key)
            if child is None:
                child = _Node(node, key, int(block_ids[i]), (i + 1) * bs)
                pool.incref([child.block_id])
                node.children[key] = child
            child.last_used = now
            chain.append(child.block_id)
            node = child
        reg = _obs_registry()
        if reg.enabled:
            reg.inc("prefix.inserts")
            reg.set("prefix.nodes", self.n_nodes)
        return chain, (None if node is self.root else node)

    def attach_snapshot(self, node: Optional[_Node], snapshot) -> None:
        """Attach a boundary snapshot at ``node`` (first writer wins — the
        state is a pure function of the prefix tokens)."""
        if node is not None and node.snapshot is None:
            node.snapshot = snapshot

    def evict_lru(self, pool) -> bool:
        """Drop the least-recently-used LEAF, releasing its block ref.
        Returns False when the trie is empty (nothing left to evict)."""
        leaves = [n for n in self._iter_nodes() if not n.children]
        if not leaves:
            return False
        victim = min(leaves, key=lambda n: n.last_used)
        pool.decref([victim.block_id])
        victim.parent.children.pop(victim.tokens, None)
        victim.snapshot = None
        reg = _obs_registry()
        if reg.enabled:
            reg.inc("prefix.evictions")
            reg.set("prefix.nodes", self.n_nodes)
        return True

    def _iter_nodes(self):
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    @property
    def n_nodes(self) -> int:
        return sum(1 for _ in self._iter_nodes())
