"""Block-granular continuous batching over a paged ``Server`` (DESIGN §7).

Replaces ``launch.serve.RequestPool``'s pow2-bucket slot logic for the paged
path.  Where the pool reserves a worst-case contiguous slab per slot (so
capacity is ``HBM / slab``, no matter how short requests actually are), the
scheduler admits requests while FREE BLOCKS suffice and grows each row's
dense block chain one block at a time as decode proceeds:

  * **Admission** — a request needs ``ceil(P / bs)`` dense blocks for its
    prompt (minus any prefix-cache hit) plus ring blocks covering the ring
    slots the prompt actually WRITES (``ceil(min(P, W) / bs)`` — lazy ring
    allocation; a short prompt on a large window holds a sliver of the
    ring, not all of it); if the pools cannot cover that after LRU-evicting
    unused prefix-cache entries, the request waits in the queue.
  * **Decode growth** — before each fused chunk, rows crossing a dense
    block boundary get a fresh block (``Server.grow_tables``), and rows
    whose next ``n`` tokens reach unallocated ring slots get those ring
    blocks (``Server.grow_window_tables``); once a row has seen ``W``
    tokens its ring is complete and never grows again.  The allocate-
    before-write discipline is the safety invariant: a window write
    through a ``-1`` table entry would drop the KV but still record the
    slot's position, making decode read junk — guarded by
    tests/test_paged_kv.py's lazy-ring invariant test.
  * **Preempt-to-recompute** — when growth cannot be satisfied, the
    latest-admitted victim releases all its blocks and re-enters the queue
    with ``prompt + generated`` as its new prompt (recompute, not swap:
    MoSA's O(k) caches make recompute cheap relative to reserving swap
    space), so the oldest requests always run to completion — no livelock.
    For dense/window models preemption is token-invisible (recompute is
    exact; asserted in tests); on MoSA hybrids the recomputed prefill
    replaces the streamed selection — the same approximation family as
    decode itself.
  * **Prefix cache** — prompts are matched against the block trie
    (``repro.serve.prefix_cache``); a hit increfs the shared dense blocks,
    restores the boundary snapshot (MoSA caches, window ring content), and
    prefills ONLY the unshared suffix (``continued=True`` — the exact union
    selection of ``MoSAAttention.prefill_past``).  On a miss the prefill is
    split at the shareable boundary so the inserted snapshot is a function
    of the prefix tokens alone — the causality prefix reuse requires.
    Chunk-causal note: for TOKEN-choice MoSA layers this split is the same
    approximation family as streaming decode (training-style expert choice
    is non-causal and therefore CANNOT be prefix-cached); for dense/window
    models the split is exact.  BLOCK-choice MoSA (DESIGN §10) closes the
    gap: snapshots land on ``sel_block_size`` boundaries, where the
    ``MoSABlockKVCache`` holds only completed-block means — a pure function
    of the prefix tokens — so a prefix hit reproduces the cold path
    exactly.  ``prefix_cache=False`` restores one-shot training-style
    prefill.

  * **Chunked packed prefill** (DESIGN §9) — prompts are streamed through
    ``Server.prefill_packed`` in fixed ``chunk_tokens``-sized packed
    chunks: up to ``max_prefill_segs`` pending rows' next segments are
    flattened back to back into ONE fused program per chunk, with
    ``cu_seqlens``/``rows``/``past_lens`` carrying the raggedness as data.
    Exactly one prefill program compiles — this replaces the former pow2
    bucket ladder (log2(max_len) programs, up to 2x padding waste), and a
    long prompt can no longer stall TTFT: decode chunks of live rows
    interleave between its prefill chunks (mid-prefill rows are paused —
    snapshot + empty tables so the decode dispatch's writes drop — and
    resumed before their next chunk).  Chunking is EXACT, for every chunk
    split: attention is past-aware through the paged pools and MoSA's
    capacity-wide union selection (``prefill_past``) reproduces one-shot
    selection bit-for-bit; selection width is ``k_for`` of each row's REAL
    prompt length — per segment, never per padded row.

No imports from ``repro.launch`` (the server arrives duck-typed), so the
launch layer can re-export this scheduler without a cycle.
"""

from __future__ import annotations

import copy
import dataclasses
import time
from collections import OrderedDict
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.kv_cache import MoSABlockKVCache, MoSAKVCache
from repro.dist import hints
from repro.serve.paged_kv import (BlockPool, PagedDenseKVCache,
                                  PagedWindowKVCache)
from repro.serve.prefix_cache import PrefixCache

# Bounded retention for the deprecated per-rid TTFT map (DESIGN §11): the
# histogram is the real record; this keeps only the most recent rids.
TTFT_KEEP = 4096

# Bounded retention for per-request SLO records (DESIGN §12): goodput is
# computed over a load run's worth of requests, not unbounded history.
RECORDS_KEEP = 8192


@dataclasses.dataclass
class _Request:
    rid: int
    prompt: jnp.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0        # tracer clock at submit/requeue
    t_arrival: float = 0.0       # tracer clock at ORIGINAL submit — unlike
    tenant: str = ""             # t_submit it survives preemption, so TTFT
    ttft: Optional[float] = None  # stays arrival-based (§12)


def _cache_leaves(caches):
    is_leaf = (lambda x: isinstance(x, (PagedDenseKVCache,
                                        PagedWindowKVCache, MoSAKVCache,
                                        MoSABlockKVCache)))
    return jax.tree_util.tree_leaves(caches, is_leaf=is_leaf)


def _paged_entries(snap):
    """The paged-cache dicts inside a host row snapshot (they are the only
    dicts carrying a ``block_table`` key — see ``launch.serve.row_snapshot``
    for the structure)."""
    out = []

    def walk(x):
        if isinstance(x, dict):
            if "block_table" in x:
                out.append(x)
                return
            for v in x.values():
                walk(v)
        elif hasattr(x, "_fields"):
            for v in x:
                walk(v)

    walk(snap)
    return out


def _set_snapshot_tables(snap, dense_row, window_row):
    """Point a host snapshot's block tables at ``dense_row`` /
    ``window_row`` (np int32, -1 padded).  Window entries are the ones
    carrying ring content (``"k"``); stacked tables broadcast the row over
    the layer axis."""
    for e in _paged_entries(snap):
        row = window_row if "k" in e else dense_row
        bt = e["block_table"]
        if bt.ndim == row.ndim + 1:          # layer-stacked (scan) cache
            e["block_table"] = np.broadcast_to(
                row, bt.shape).astype(np.int32).copy()
        else:
            e["block_table"] = row.astype(np.int32).copy()


def _table_row(ids: List[int], width: int) -> np.ndarray:
    row = np.full((width,), -1, np.int32)
    row[:len(ids)] = ids
    return row


# ----------------------------------------------- serve-time router health
def _selection_health(pos, wt, n_slots: int) -> dict:
    """Host-side analog of ``repro.core.router.router_health_stats`` over a
    flat list of selected slot indices ``pos`` with selection weights
    ``wt``: entropy of the weight mass over ``n_slots`` (normalized by
    ``log n_slots``), the fraction of slots selected by no head, and the
    mean selection weight."""
    n = max(int(n_slots), 2)
    keep = (pos >= 0) & (pos < n)
    pos, wt = pos[keep], wt[keep]
    counts = np.bincount(pos, minlength=n)
    mass = np.bincount(pos, weights=np.maximum(wt, 0.0), minlength=n)
    tot = mass.sum()
    p = (mass / tot) if tot > 0 else np.full(n, 1.0 / n)
    ent = float(-(p * np.log(np.maximum(p, 1e-12))).sum() / np.log(n))
    return {"sel_entropy": ent,
            "drop_rate": float((counts == 0).mean()),
            "head_util": float(wt.mean()) if wt.size else 0.0}


def _router_health_from_snapshot(snap, P: int) -> dict:
    """MoSA router health for one request, computed from the HOST row
    snapshot its prefill just produced (DESIGN §11) — numpy on data already
    fetched for snapshotting, no extra device work beyond the row gather.

    Token-choice caches score ``min(capacity, P)`` kept tokens over the
    ``P`` prompt positions; block-choice caches score their COMPLETED
    blocks (slot CB, the partial block, excluded) over ``ceil(P / bs)``
    pool blocks.  Stats are averaged across every routed layer instance
    (stacked scan layers contribute one sample per layer)."""
    samples: List[dict] = []

    def walk(x):
        if isinstance(x, MoSAKVCache):
            s = np.asarray(x.scores, np.float64)
            ix = np.asarray(x.idx, np.int64)
            s2 = s.reshape(-1, s.shape[-2] * s.shape[-1])
            i2 = ix.reshape(-1, ix.shape[-2] * ix.shape[-1])
            for l in range(s2.shape[0]):
                valid = np.isfinite(s2[l]) & (i2[l] >= 0)
                samples.append(_selection_health(
                    i2[l][valid], s2[l][valid], P))
            return
        if isinstance(x, MoSABlockKVCache):
            bsc = np.asarray(x.bscore, np.float64)[..., :-1]
            bix = np.asarray(x.bidx, np.int64)[..., :-1]
            bs = x.k.shape[-2] // x.bscore.shape[-1]
            nb = -(-int(P) // max(bs, 1))
            cb = bsc.shape[-1]
            s2 = bsc.reshape(-1, bsc.shape[-2] * cb)
            i2 = bix.reshape(-1, bix.shape[-2] * cb)
            for l in range(s2.shape[0]):
                valid = np.isfinite(s2[l]) & (i2[l] >= 0)
                samples.append(_selection_health(
                    i2[l][valid], s2[l][valid], nb))
            return
        if isinstance(x, dict):
            for v in x.values():
                walk(v)
        elif hasattr(x, "_fields"):
            for v in x:
                walk(v)

    walk(snap)
    if not samples:
        return {}
    return {k: float(np.mean([s[k] for s in samples]))
            for k in ("sel_entropy", "drop_rate", "head_util")}


class Scheduler:
    """Continuous batching with block-granular admission.

    ``server``: a ``launch.serve.Server`` built with
    ``paged=PagedConfig(num_blocks=..., num_window_blocks=...)`` — explicit
    budgets; the worst-case auto sizing would make admission vacuous.
    """

    def __init__(self, server, eos: int = -1, chunk: int = 8,
                 chunk_tokens: int = 64, max_prefill_segs: int = 4,
                 prefix_cache: bool = True,
                 metrics_path: Optional[str] = None,
                 trace_path: Optional[str] = None,
                 router_health_every: int = 4,
                 max_queue: Optional[int] = None):
        """``chunk``: decode tokens per fused decode dispatch.
        ``chunk_tokens``: the packed prefill chunk budget C — every prefill
        dispatch processes exactly C token slots (ONE compiled program);
        ``max_prefill_segs``: max pending rows packed per chunk (N).

        Observability (DESIGN §11): metrics/spans go to the global
        ``repro.obs`` registry/tracer.  ``metrics_path`` (``.jsonl``
        appends a snapshot line) and ``trace_path`` (Chrome-trace JSON)
        are written when ``run()`` drains.  ``router_health_every``: every
        Nth completed prompt on a MoSA model gets its router health
        (sel_entropy / drop_rate / head_util) sampled from the prefill's
        row snapshot — 0 disables the sampling.

        ``max_queue`` (DESIGN §12): admission-control depth — a submit
        arriving with ``max_queue`` requests already waiting is SHED
        (empty result, ``serve.shed`` counter, ``outcome="shed"`` record)
        instead of queued.  Shedding is what keeps goodput for admitted
        work through overload: without it every queued request's TTFT
        degrades together.  ``None`` (default) never sheds."""
        paged = server.paged
        assert paged is not None and paged.num_blocks > 0, (
            "Scheduler needs Server(paged=PagedConfig(num_blocks=...)) with "
            "an explicit dense-block budget")
        self.server = server
        self.eos = eos
        self.chunk = chunk
        self.chunk_tokens = min(chunk_tokens, server.max_len)
        self.max_segs = max(1, max_prefill_segs)
        self.bs = paged.block_size
        self.queue: List[_Request] = []
        self.results: dict = {}

        self.caches = server.new_cache()
        leaves = _cache_leaves(self.caches)
        dense = [x for x in leaves if isinstance(x, PagedDenseKVCache)]
        window = [x for x in leaves if isinstance(x, PagedWindowKVCache)]
        assert dense, "paged scheduler needs at least one paged dense layer"
        self.nb_max = dense[0].block_table.shape[-1]
        self.has_window = bool(window)
        self.wb = window[0].block_table.shape[-1] if window else 0
        if self.has_window:
            assert paged.num_window_blocks > 0, (
                "model has window layers: pass num_window_blocks")
        # A hit must restore per-row state beyond dense blocks (MoSA top-k
        # sets, window rings, SSM states) -> only snapshot nodes usable.
        self.need_snapshot = any(
            not isinstance(x, PagedDenseKVCache) for x in leaves)

        self.dense_pool = BlockPool(paged.num_blocks, self.bs, name="dense")
        self.window_pool = (BlockPool(paged.num_window_blocks, self.bs,
                                      name="window")
                            if self.has_window else None)
        self.prefix = PrefixCache(self.bs) if prefix_cache else None
        self._empty_row = jax.device_get(server.snapshot_row(self.caches, 0))
        # prefill_chunks * chunk_tokens is the slot count every dispatch
        # pays; prefilled_tokens / prefill_chunk_slots is the packed-token
        # efficiency the pow2 buckets never reached (BENCH_serve metric).
        self.stats = {"prefix_hits": 0, "prefix_hit_tokens": 0,
                      "prefilled_tokens": 0, "prefill_chunks": 0,
                      "prefill_chunk_slots": 0, "preemptions": 0,
                      "max_concurrent": 0}
        # rid -> TTFT seconds, bounded to the TTFT_KEEP newest rids; the
        # obs histogram serve.ttft_s is the unbounded-safe record.
        self._ttft: OrderedDict = OrderedDict()
        # rid -> per-request SLO record (obs.slo schema), bounded; written
        # at finish/shed time, consumed by obs.slo.evaluate.
        self.records: OrderedDict = OrderedDict()
        self.max_queue = max_queue
        self.metrics_path = metrics_path
        self.trace_path = trace_path
        self.router_health_every = router_health_every
        self._has_mosa = any(isinstance(x, (MoSAKVCache, MoSABlockKVCache))
                             for x in leaves)
        self._health_seen = 0

        B = server.batch
        self._slots: List[Optional[dict]] = [None] * B
        self._admit_seq = 0

    @property
    def ttft(self) -> OrderedDict:
        """Deprecated: per-rid TTFT map, now bounded to the ``TTFT_KEEP``
        most recent requests.  Read ``obs.registry()``'s ``serve.ttft_s``
        histogram (p50/p90/p99) instead."""
        return self._ttft

    def _record_ttft(self, r: _Request, dt: float) -> None:
        r.ttft = dt
        self._ttft[r.rid] = dt
        while len(self._ttft) > TTFT_KEEP:
            self._ttft.popitem(last=False)
        reg = obs.registry()
        reg.observe("serve.ttft_s", dt)
        if r.tenant:
            reg.observe("serve.ttft_s", dt, tenant=r.tenant)

    def _record(self, r: _Request, outcome: str,
                queue_delay: float = 0.0, tpot=None) -> None:
        """Append ``r``'s SLO record (obs.slo schema — parity with
        ``records_from_spans`` is tested)."""
        self.records[r.rid] = {
            "rid": r.rid, "tenant": r.tenant, "outcome": outcome,
            "t_arrival": r.t_arrival, "queue_delay_s": queue_delay,
            "ttft_s": r.ttft, "tpot_s": tpot,
            "new_tokens": len(r.generated)}
        while len(self.records) > RECORDS_KEEP:
            self.records.popitem(last=False)

    def _in_flight(self) -> int:
        return sum(s is not None for s in self._slots)

    # ----------------------------------------------------------- interface
    def submit(self, prompt, max_new: int, tenant: str = "") -> int:
        rid = len(self.results) + len(self.queue) + \
            sum(s is not None for s in self._slots)
        now = obs.tracer().now()
        reg = obs.registry()
        reg.inc("serve.submitted")
        if tenant:
            reg.inc("serve.submitted", tenant=tenant)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            # Admission control: shed rather than queue past the depth cap
            # (the queued request's TTFT would already be forfeit — see
            # __init__ docstring).  The caller still gets a result (empty).
            r = _Request(rid, jnp.zeros((0,), jnp.int32), 0,
                         t_submit=now, t_arrival=now, tenant=tenant)
            self.results[rid] = jnp.zeros((0,), jnp.int32)
            self._record(r, "shed")
            reg.inc("serve.shed")
            if tenant:
                reg.inc("serve.shed", tenant=tenant)
            obs.tracer().instant("shed", track=f"req{rid}", tenant=tenant)
            return rid
        self.queue.append(_Request(rid, jnp.asarray(prompt, jnp.int32),
                                   max_new, t_submit=now, t_arrival=now,
                                   tenant=tenant))
        reg.set("serve.queue_depth", len(self.queue))
        return rid

    # ------------------------------------------------------------- helpers
    def _alloc_dense(self, n: int):
        """All-or-nothing dense alloc, LRU-evicting prefix entries first."""
        while True:
            ids = self.dense_pool.alloc(n)
            if ids is not None:
                return ids
            if self.prefix is None or not self.prefix.evict_lru(
                    self.dense_pool):
                return None

    def _window_blocks_for(self, tokens: int) -> int:
        """Ring blocks needed once ``tokens`` tokens have been written:
        positions ``0..tokens-1`` land on ring slots ``0..min(tokens,W)-1``
        (monotone fill until the ring wraps), so coverage is a PREFIX of the
        block table — lazy allocation extends it, never punches holes."""
        W = self.wb * self.bs
        return -(-min(tokens, W) // self.bs)

    def _free_slot(self, b):
        """Release row ``b``'s blocks AND clear its device state.  The
        clear is not hygiene theater: ``decode_many`` keeps stepping every
        row, so a stale block table would scatter the dead row's KV into
        freed blocks the allocator may already have handed to a live
        request — silent cross-request corruption.  Restoring the empty
        template (-1 tables, zero lengths) makes the dead row's writes
        drop instead."""
        s = self._slots[b]
        self.dense_pool.decref(s["dense_ids"])
        if self.window_pool is not None:
            self.window_pool.decref(s["window_ids"])
        self._slots[b] = None
        self.caches = self.server.restore_row(
            self.caches, copy.deepcopy(self._empty_row), jnp.int32(b))

    def _finish(self, b):
        s = self._slots[b]
        r = s["req"]
        self.results[r.rid] = jnp.asarray(r.generated, jnp.int32)
        reg, tr = obs.registry(), obs.tracer()
        now = tr.now()
        tpot = None
        if s.get("t_first") is not None:
            tr.add("decode", s["t_first"], now, track=f"req{r.rid}",
                   tokens=len(r.generated))
            if len(r.generated) >= 2:
                # per-token decode latency over the post-first-token run
                tpot = (now - s["t_first"]) / (len(r.generated) - 1)
                reg.observe("serve.tpot_s", tpot)
                if r.tenant:
                    reg.observe("serve.tpot_s", tpot, tenant=r.tenant)
        tr.instant("finish", track=f"req{r.rid}", tokens=len(r.generated),
                   tenant=r.tenant)
        reg.inc("serve.finished")
        if r.tenant:
            reg.inc("serve.finished", tenant=r.tenant)
        reg.inc("serve.generated_tokens", len(r.generated))
        self._record(r, "finished", queue_delay=s.get("queue_delay", 0.0),
                     tpot=tpot)
        self._free_slot(b)
        reg.set("serve.in_flight", self._in_flight())

    def _preempt(self, b):
        """Preempt-to-recompute: release every block, requeue with
        prompt + generated as the new prompt."""
        s = self._slots[b]
        r = s["req"]
        if r.generated:
            r.prompt = jnp.concatenate(
                [r.prompt, jnp.asarray(r.generated, jnp.int32)])
        reg, tr = obs.registry(), obs.tracer()
        now = tr.now()
        phase_t0 = s["t_first"] if s.get("t_first") is not None \
            else s.get("t_admit", now)
        tr.add(s["phase"], phase_t0, now, track=f"req{r.rid}",
               preempted=True)
        tr.instant("preempt", track=f"req{r.rid}")
        self._free_slot(b)
        self.queue.insert(0, r)
        r.t_submit = now                 # requeue restarts the queue wait
        self.stats["preemptions"] += 1
        reg.inc("serve.preempted")
        if r.tenant:
            reg.inc("serve.preempted", tenant=r.tenant)
        reg.set("serve.in_flight", self._in_flight())

    def _pending_same_prefix(self, prompt_np, P) -> bool:
        """True when a live mid-prefill row will shortly trie-insert a
        shareable prefix of ``prompt_np`` (its forced boundary not yet
        reached) — admitting now would recompute those shared blocks."""
        n_share = ((P - 1) // self.bs) * self.bs
        for s in self._slots:
            if s is None or s["phase"] != "prefill":
                continue
            ins = s["insert_at"]
            if ins is None:
                continue
            if self.need_snapshot:
                # Hits land only on snapshot-carrying tips: useful iff our
                # prompt contains the row's FULL pending prefix.
                d = ins if ins <= n_share else 0
            else:
                # Snapshot-free (pure paged-dense): any block-aligned
                # common depth along the pending chain is a future hit.
                d = min(ins, n_share)
            if d >= self.bs and np.array_equal(s["prompt_np"][:d],
                                               prompt_np[:d]):
                return True
        return False

    # ------------------------------------------------------------ admission
    def _admit(self, b, r: _Request) -> Optional[bool]:
        """Admit ``r`` into row ``b``: allocate its blocks, restore its
        snapshot/tables, and park it in ``phase="prefill"`` — the prompt
        itself is streamed by ``_advance_prefills``.  Returns True, or None
        when the block pools cannot cover the prompt."""
        srv = self.server
        prompt_np = np.asarray(r.prompt)
        P = min(len(prompt_np), srv.max_len)
        prompt_np = prompt_np[-P:]
        remaining_cap = srv.max_len - P + 1
        r.max_new = min(r.max_new, len(r.generated) + remaining_cap)

        node, depth, chain_ids = None, 0, []
        if self.prefix is not None:
            node, depth = self.prefix.lookup(prompt_np, self.need_snapshot)
            if node is None and self._pending_same_prefix(prompt_np, P):
                # Cache-aware admission: a live mid-prefill row is about to
                # insert this very prefix (admission is no longer
                # synchronous with prefill, so the miss is transient).
                # Wait one round rather than recompute the shared blocks;
                # if that row is preempted instead, the next attempt
                # proceeds as a plain miss — no deadlock.
                return None
        n_prompt_blocks = -(-P // self.bs)
        n_new_blocks = n_prompt_blocks - depth // self.bs

        if node is not None:
            chain_ids = self.prefix.acquire(node, self.dense_pool)
        suffix_ids = self._alloc_dense(n_new_blocks)
        if suffix_ids is None:
            if chain_ids:
                self.dense_pool.decref(chain_ids)
            return None
        window_ids: List[int] = []
        if self.window_pool is not None:
            # Lazy ring: only the blocks the prompt's P tokens will write.
            window_ids = self.window_pool.alloc(self._window_blocks_for(P))
            if window_ids is None:
                self.dense_pool.decref(chain_ids + suffix_ids)
                return None
        dense_ids = chain_ids + suffix_ids

        insert_at = None
        if node is not None:
            if node.snapshot is not None:
                snap = copy.deepcopy(node.snapshot)
            else:
                # snapshot-free hit (pure paged-dense model, any depth):
                # the only per-row state is the table + length
                snap = copy.deepcopy(self._empty_row)
                for e in _paged_entries(snap):
                    if "k" not in e:
                        e["length"] = np.full_like(e["length"], depth)
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += depth
        else:
            snap = copy.deepcopy(self._empty_row)
            if self.prefix is not None and (P - 1) // self.bs > 0:
                # Miss: force a chunk boundary at the shareable depth so
                # the snapshot inserted there depends on the prefix tokens
                # alone (see module docstring).
                insert_at = ((P - 1) // self.bs) * self.bs
        _set_snapshot_tables(snap, _table_row(dense_ids, self.nb_max),
                             _table_row(window_ids, max(self.wb, 1)))
        self.caches = srv.restore_row(self.caches, snap, jnp.int32(b))

        reg, tr = obs.registry(), obs.tracer()
        now = tr.now()
        tr.add("queued", r.t_submit, now, track=f"req{r.rid}")
        reg.inc("serve.admitted")
        # Queue delay of THIS admission (t_submit restarts on requeue) —
        # the wait component §12 separates from service time.
        queue_delay = now - r.t_submit
        reg.observe("serve.queue_delay_s", queue_delay)
        if r.tenant:
            reg.observe("serve.queue_delay_s", queue_delay, tenant=r.tenant)
        if node is not None:
            reg.observe("serve.prefix_hit_frac", depth / max(P, 1),
                        bounds=obs.UNIT_BOUNDS)
        self._slots[b] = {"req": r, "dense_ids": dense_ids,
                          "window_ids": window_ids, "length": P,
                          "seq": self._admit_seq, "phase": "prefill",
                          "prompt_np": prompt_np, "done": depth,
                          "insert_at": insert_at, "paused_snap": None,
                          "t_admit": now, "t_first": None,
                          "queue_delay": queue_delay}
        self._admit_seq += 1
        self.stats["max_concurrent"] = max(
            self.stats["max_concurrent"],
            sum(s is not None for s in self._slots))
        reg.set("serve.in_flight", self._in_flight())
        reg.set_max("serve.max_concurrent", self._in_flight())
        return True

    # ------------------------------------------------------ chunked prefill
    def _advance_prefills(self, key, cur):
        """One packed prefill chunk: pack the next segments of up to
        ``max_segs`` pending rows (oldest first) into ``chunk_tokens``
        slots, dispatch ONE ``Server.prefill_packed`` program, then advance
        each row — snapshot-insert at a forced prefix boundary, or sample
        the first token and flip to decode when its prompt completes."""
        srv = self.server
        pending = sorted(
            (b for b in range(len(self._slots))
             if self._slots[b] is not None
             and self._slots[b]["phase"] == "prefill"),
            key=lambda x: self._slots[x]["seq"])
        C = self.chunk_tokens
        segs = []                            # (row, start, take)
        budget = C
        for b in pending:
            if budget == 0 or len(segs) == self.max_segs:
                break
            s = self._slots[b]
            take = min(len(s["prompt_np"]) - s["done"], budget)
            ins = s["insert_at"]
            if ins is not None and s["done"] < ins < s["done"] + take:
                take = ins - s["done"]       # stop AT the boundary
            segs.append((b, s["done"], take))
            budget -= take

        for b, _, _ in segs:                 # resume paused rows
            s = self._slots[b]
            if s["paused_snap"] is not None:
                self.caches = srv.restore_row(self.caches, s["paused_snap"],
                                              jnp.int32(b))
                s["paused_snap"] = None
                obs.registry().inc("serve.resumes")

        N = self.max_segs
        buf = np.zeros((C,), np.int32)
        cu = np.zeros((N + 1,), np.int32)
        rows = np.full((N,), -1, np.int32)
        past = np.zeros((N,), np.int32)
        off = 0
        for i, (b, start, take) in enumerate(segs):
            buf[off:off + take] = self._slots[b]["prompt_np"][start:start +
                                                              take]
            rows[i] = b
            past[i] = start
            off += take
            cu[i + 1] = off
        cu[len(segs) + 1:] = off
        reg, tr = obs.registry(), obs.tracer()
        with tr.span("prefill_chunk", track="sched", segs=len(segs),
                     tokens=off):
            logits, self.caches = srv.prefill_packed(
                srv.params, jnp.asarray(buf)[None], self.caches,
                jnp.asarray(cu), jnp.asarray(rows), jnp.asarray(past))
        self.stats["prefilled_tokens"] += off
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_chunk_slots"] += C
        reg.inc("serve.prefill_chunks")
        reg.inc("serve.prefilled_tokens", off)
        reg.observe("serve.chunk_packed_efficiency", off / C,
                    bounds=obs.UNIT_BOUNDS)

        for i, (b, start, take) in enumerate(segs):
            s = self._slots[b]
            s["done"] += take
            if s["insert_at"] is not None and s["done"] == s["insert_at"]:
                self._insert_prefix(b)
            if s["done"] == len(s["prompt_np"]):
                s["phase"] = "decode"
                s["length"] = len(s["prompt_np"])
                key, sub = jax.random.split(key)
                tok0 = srv.sample(logits[i:i + 1], sub)
                r = s["req"]
                r.generated.append(int(tok0[0]))
                now = tr.now()
                # resumed=True marks a post-preemption re-prefill (the
                # request already produced its first token in an earlier
                # life) — records_from_spans must not read TTFT off it.
                tr.add("prefill", s["t_admit"], now, track=f"req{r.rid}",
                       prompt=len(s["prompt_np"]),
                       resumed=r.ttft is not None)
                s["t_first"] = now
                if r.ttft is None:
                    # Arrival-based TTFT (§12): first token minus submit
                    # time, queue wait included — under load the queue IS
                    # the latency.  Survives preemption via t_arrival.
                    self._record_ttft(r, now - r.t_arrival)
                self._sample_router_health(b)
                cur = cur.at[b, 0].set(int(tok0[0]))
                if len(r.generated) >= r.max_new or int(tok0[0]) == self.eos:
                    self._finish(b)
        return key, cur

    def _sample_router_health(self, b) -> None:
        """Every ``router_health_every``-th completed prompt on a MoSA
        model: fetch the row snapshot its prefill just wrote and publish
        sel_entropy / drop_rate / head_util into the registry — the serve-
        side twin of the train loop's in-step router health (DESIGN §11)."""
        if not self._has_mosa or not self.router_health_every:
            return
        reg = obs.registry()
        if not reg.enabled:
            return
        self._health_seen += 1
        if (self._health_seen - 1) % self.router_health_every:
            return
        s = self._slots[b]
        snap = jax.device_get(
            self.server.snapshot_row(self.caches, jnp.int32(b)))
        stats = _router_health_from_snapshot(snap, len(s["prompt_np"]))
        for k, v in stats.items():
            reg.observe(f"serve.router.{k}", v, bounds=obs.UNIT_BOUNDS)

    def _insert_prefix(self, b):
        """Insert row ``b``'s shareable prefix into the trie.  Called when
        ``done`` hits the forced boundary: the row's device state is then
        exactly the one-shot prefill of ``prompt[:insert_at]`` (packed
        chunking is exact), i.e. a function of the prefix tokens alone."""
        srv = self.server
        s = self._slots[b]
        n_share = s["insert_at"]
        snap1 = jax.device_get(srv.snapshot_row(self.caches, jnp.int32(b)))
        chain, tip = self.prefix.insert(
            s["prompt_np"][:n_share], s["dense_ids"][:n_share // self.bs],
            self.dense_pool)
        _set_snapshot_tables(snap1, _table_row(chain, self.nb_max),
                             _table_row([], max(self.wb, 1)))
        self.prefix.attach_snapshot(tip, snap1)
        s["insert_at"] = None

    def _pause_prefills(self):
        """Park every mid-prefill row before a decode dispatch:
        ``decode_many`` steps ALL rows, so without this its writes would
        advance the row's lengths and corrupt its MoSA selection.  The host
        snapshot preserves the row; the empty template (-1 tables, zero
        lengths) makes the decode writes drop.  ``_advance_prefills``
        restores the snapshot before the row's next chunk."""
        srv = self.server
        for b, s in enumerate(self._slots):
            if s is not None and s["phase"] == "prefill" \
                    and s["paused_snap"] is None:
                s["paused_snap"] = jax.device_get(
                    srv.snapshot_row(self.caches, jnp.int32(b)))
                self.caches = srv.restore_row(
                    self.caches, copy.deepcopy(self._empty_row),
                    jnp.int32(b))
                obs.registry().inc("serve.pauses")

    # ------------------------------------------------------------- growth
    def _alloc_or_preempt(self, alloc_fn, n: int, b: int, live):
        """``alloc_fn(n)``, preempting latest-admitted victims on failure.
        Latest-admitted only: preempting a row OLDER than ``b`` would break
        the monotone-progress guarantee (the oldest request must never lose
        its blocks to a newer one); when nothing newer than ``b`` exists,
        the caller preempts ``b`` itself."""
        ids = alloc_fn(n)
        while ids is None:
            s = self._slots[b]
            victims = [x for x in live
                       if self._slots[x] is not None and x != b
                       and self._slots[x]["seq"] > s["seq"]]
            if not victims:
                return None
            victim = max(victims, key=lambda x: self._slots[x]["seq"])
            self._preempt(victim)
            ids = alloc_fn(n)
        return ids

    def _grow_row(self, b: int, n: int, live) -> bool:
        """Cover the next ``n`` decode tokens of row ``b``: dense chain
        blocks plus the window ring blocks those tokens' ring slots need
        (lazy-ring invariant: allocation always precedes the write).
        Returns False iff ``b`` itself had to be preempted."""
        srv = self.server
        s = self._slots[b]
        needed = min(-(-(s["length"] + n) // self.bs), self.nb_max)
        extra = needed - len(s["dense_ids"])
        if extra > 0:
            ids = self._alloc_or_preempt(self._alloc_dense, extra, b, live)
            if ids is None:
                self._preempt(b)
                return False
            s["dense_ids"].extend(ids)
            self.caches = srv.grow_tables(
                self.caches,
                jnp.asarray(_table_row(s["dense_ids"], self.nb_max)),
                jnp.int32(b))
        if self.window_pool is not None:
            extra_w = self._window_blocks_for(s["length"] + n) \
                - len(s["window_ids"])
            if extra_w > 0:
                ids = self._alloc_or_preempt(self.window_pool.alloc,
                                             extra_w, b, live)
                if ids is None:
                    self._preempt(b)
                    return False
                s["window_ids"].extend(ids)
                self.caches = srv.grow_window_tables(
                    self.caches,
                    jnp.asarray(_table_row(s["window_ids"],
                                           max(self.wb, 1))),
                    jnp.int32(b))
        return True

    # ---------------------------------------------------------------- run
    def run(self, max_steps: int = 1000, source=None):
        """Serve every queued request; returns {rid: generated tokens}.
        Semantics mirror ``RequestPool.run`` (EOS, per-request ``max_new``,
        global ``max_steps`` decode budget).

        **Timed mode** (DESIGN §12): ``source`` is a duck-typed arrival
        stream (``repro.serve.loadgen`` builds them) that SUBMITS requests
        at their arrival times instead of the caller pre-queueing
        everything — the closed-loop/open-loop traffic the SLO bench
        drives.  Protocol: ``pump(sched, now)`` submits every request due
        by ``now`` (seconds since ``run()`` started), ``exhausted()`` says
        no more arrivals will ever come, ``next_arrival_in(now)`` is the
        wait until the next one (None for "when in-flight work completes").
        The loop runs until the source is exhausted AND the system drains;
        while idle between arrivals it sleeps (≤50 ms slices) rather than
        spinning."""
        srv = self.server
        B = srv.batch
        cur = jnp.zeros((B, 1), jnp.int32)
        key = jax.random.PRNGKey(0)
        steps = 0
        timer = obs.registry().timer("serve.run_s")
        timer.__enter__()
        t_run0 = obs.tracer().now()

        def by_phase(phase):
            return [b for b in range(B) if self._slots[b] is not None
                    and self._slots[b]["phase"] == phase]

        with srv.mesh, hints.sharding_hints(mesh=srv.mesh):
            while True:
                if source is not None:
                    source.pump(self, obs.tracer().now() - t_run0)
                if not self.queue and \
                        all(s is None for s in self._slots):
                    if source is None or source.exhausted():
                        break
                    wait = source.next_arrival_in(
                        obs.tracer().now() - t_run0)
                    if wait is not None and wait > 0:
                        time.sleep(min(wait, 0.05))
                    continue
                for b in range(B):
                    if self._slots[b] is None and self.queue \
                            and steps < max_steps:
                        if self._admit(b, self.queue[0]) is None:
                            break               # blocks exhausted: wait
                        self.queue.pop(0)
                        obs.registry().set("serve.queue_depth",
                                           len(self.queue))
                live_pre, live_dec = by_phase("prefill"), by_phase("decode")
                if not live_pre and not live_dec:
                    if steps >= max_steps:
                        break
                    if self.queue and not any(self._slots):
                        # nothing live and head-of-queue cannot be admitted
                        raise RuntimeError(
                            "request needs more blocks than the pool has: "
                            f"free={self.dense_pool.free_blocks} of "
                            f"{self.dense_pool.num_blocks}")
                    continue
                if steps >= max_steps:
                    # Decode budget spent: wind down, but rows caught
                    # mid-prefill still stream to completion so every
                    # admitted request yields its first token.
                    for b in live_dec:
                        self._finish(b)
                    if not live_pre:
                        break

                if live_pre:
                    # One packed prefill chunk, then (at most) one decode
                    # chunk — long prompts interleave with live decodes
                    # instead of stalling them.
                    key, cur = self._advance_prefills(key, cur)
                    live_dec = by_phase("decode")
                if not live_dec or steps >= max_steps:
                    continue

                need = max(self._slots[b]["req"].max_new -
                           len(self._slots[b]["req"].generated)
                           for b in live_dec)
                n = max(min(self.chunk, max_steps - steps, need), 1)

                # Grow dense chains and (lazily) window rings of the decode
                # rows to cover the next n appended tokens; preempt
                # latest-admitted rows (mid-prefill ones included — their
                # full prompt just requeues) when a pool runs dry.
                self._pause_prefills()
                live = [b for b in range(B) if self._slots[b] is not None]
                for b in sorted(live_dec,
                                key=lambda x: self._slots[x]["seq"]):
                    if self._slots[b] is None:
                        continue
                    self._grow_row(b, n, live)
                live_dec = by_phase("decode")
                if not live_dec:
                    continue

                key, sub = jax.random.split(key)
                reg, tr = obs.registry(), obs.tracer()
                with tr.span("decode_chunk", track="sched",
                             rows=len(live_dec), n=n):
                    toks, self.caches = srv.decode_many(srv.params, cur,
                                                        self.caches, sub, n)
                    steps += n
                    host = jax.device_get(toks)
                reg.inc("serve.decode_chunks")
                reg.inc("serve.decode_tokens", n * len(live_dec))
                reg.observe("serve.decode_batch", len(live_dec))
                cur = toks[:, -1:]
                for b in live_dec:
                    s = self._slots[b]
                    if s is None:
                        continue
                    r = s["req"]
                    for t in host[b]:
                        r.generated.append(int(t))
                        s["length"] += 1
                        if int(t) == self.eos or \
                                len(r.generated) >= r.max_new:
                            self._finish(b)
                            break
        timer.__exit__(None, None, None)
        reg = obs.registry()
        if reg.enabled:
            # timer.dt is measured even with obs off (only the histogram
            # write is gated) — the registry.timer contract.
            reg.set("serve.tokens_per_s",
                    reg.counter("serve.generated_tokens").value /
                    max(timer.dt, 1e-9))
        obs.dump(self.metrics_path, self.trace_path, tag="scheduler")
        return dict(self.results)
