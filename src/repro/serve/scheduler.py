"""Block-granular continuous batching over a paged ``Server`` (DESIGN §7).

Replaces ``launch.serve.RequestPool``'s pow2-bucket slot logic for the paged
path.  Where the pool reserves a worst-case contiguous slab per slot (so
capacity is ``HBM / slab``, no matter how short requests actually are), the
scheduler admits requests while FREE BLOCKS suffice and grows each row's
dense block chain one block at a time as decode proceeds:

  * **Admission** — a request needs ``ceil(P / bs)`` dense blocks for its
    prompt (minus any prefix-cache hit) plus ring blocks covering the ring
    slots the prompt actually WRITES (``ceil(min(P, W) / bs)`` — lazy ring
    allocation; a short prompt on a large window holds a sliver of the
    ring, not all of it); if the pools cannot cover that after LRU-evicting
    unused prefix-cache entries, the request waits in the queue.
  * **Decode growth** — before each fused chunk, rows crossing a dense
    block boundary get a fresh block (``Server.grow_tables``), and rows
    whose next ``n`` tokens reach unallocated ring slots get those ring
    blocks (``Server.grow_window_tables``); once a row has seen ``W``
    tokens its ring is complete and never grows again.  The allocate-
    before-write discipline is the safety invariant: a window write
    through a ``-1`` table entry would drop the KV but still record the
    slot's position, making decode read junk — guarded by
    tests/test_paged_kv.py's lazy-ring invariant test.
  * **Preempt-to-recompute** — when growth cannot be satisfied, the
    latest-admitted victim releases all its blocks and re-enters the queue
    with ``prompt + generated`` as its new prompt (recompute, not swap:
    MoSA's O(k) caches make recompute cheap relative to reserving swap
    space), so the oldest requests always run to completion — no livelock.
    For dense/window models preemption is token-invisible (recompute is
    exact; asserted in tests); on MoSA hybrids the recomputed prefill
    replaces the streamed selection — the same approximation family as
    decode itself.
  * **Prefix cache** — prompts are matched against the block trie
    (``repro.serve.prefix_cache``); a hit increfs the shared dense blocks,
    restores the boundary snapshot (MoSA caches, window ring content), and
    prefills ONLY the unshared suffix (``continued=True`` — the exact union
    selection of ``MoSAAttention.prefill_past``).  On a miss the prefill is
    split at the shareable boundary so the inserted snapshot is a function
    of the prefix tokens alone — the causality prefix reuse requires.
    Chunk-causal note: for models with MoSA layers this split is the same
    approximation family as streaming decode (training-style expert choice
    is non-causal and therefore CANNOT be prefix-cached); for dense/window
    models the split is exact.  ``prefix_cache=False`` restores one-shot
    training-style prefill.

Prefill still pads to pow2 buckets, but ONLY to bound how many programs
compile — right-padded with a valid mask (the masked-prefill fix), never
reserving cache space.

No imports from ``repro.launch`` (the server arrives duck-typed), so the
launch layer can re-export this scheduler without a cycle.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kv_cache import MoSAKVCache
from repro.dist import hints
from repro.serve.paged_kv import (BlockPool, PagedDenseKVCache,
                                  PagedWindowKVCache)
from repro.serve.prefix_cache import PrefixCache


@dataclasses.dataclass
class _Request:
    rid: int
    prompt: jnp.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)


def _cache_leaves(caches):
    is_leaf = (lambda x: isinstance(x, (PagedDenseKVCache,
                                        PagedWindowKVCache, MoSAKVCache)))
    return jax.tree_util.tree_leaves(caches, is_leaf=is_leaf)


def _paged_entries(snap):
    """The paged-cache dicts inside a host row snapshot (they are the only
    dicts carrying a ``block_table`` key — see ``launch.serve.row_snapshot``
    for the structure)."""
    out = []

    def walk(x):
        if isinstance(x, dict):
            if "block_table" in x:
                out.append(x)
                return
            for v in x.values():
                walk(v)
        elif hasattr(x, "_fields"):
            for v in x:
                walk(v)

    walk(snap)
    return out


def _set_snapshot_tables(snap, dense_row, window_row):
    """Point a host snapshot's block tables at ``dense_row`` /
    ``window_row`` (np int32, -1 padded).  Window entries are the ones
    carrying ring content (``"k"``); stacked tables broadcast the row over
    the layer axis."""
    for e in _paged_entries(snap):
        row = window_row if "k" in e else dense_row
        bt = e["block_table"]
        if bt.ndim == row.ndim + 1:          # layer-stacked (scan) cache
            e["block_table"] = np.broadcast_to(
                row, bt.shape).astype(np.int32).copy()
        else:
            e["block_table"] = row.astype(np.int32).copy()


def _table_row(ids: List[int], width: int) -> np.ndarray:
    row = np.full((width,), -1, np.int32)
    row[:len(ids)] = ids
    return row


class Scheduler:
    """Continuous batching with block-granular admission.

    ``server``: a ``launch.serve.Server`` built with
    ``paged=PagedConfig(num_blocks=..., num_window_blocks=...)`` — explicit
    budgets; the worst-case auto sizing would make admission vacuous.
    """

    def __init__(self, server, eos: int = -1, chunk: int = 8,
                 prefill_len: Optional[int] = None,
                 prefix_cache: bool = True):
        paged = server.paged
        assert paged is not None and paged.num_blocks > 0, (
            "Scheduler needs Server(paged=PagedConfig(num_blocks=...)) with "
            "an explicit dense-block budget")
        self.server = server
        self.eos = eos
        self.chunk = chunk
        self.prefill_len = prefill_len
        self.bs = paged.block_size
        self.queue: List[_Request] = []
        self.results: dict = {}

        self.caches = server.new_cache()
        leaves = _cache_leaves(self.caches)
        dense = [x for x in leaves if isinstance(x, PagedDenseKVCache)]
        window = [x for x in leaves if isinstance(x, PagedWindowKVCache)]
        assert dense, "paged scheduler needs at least one paged dense layer"
        self.nb_max = dense[0].block_table.shape[-1]
        self.has_window = bool(window)
        self.wb = window[0].block_table.shape[-1] if window else 0
        if self.has_window:
            assert paged.num_window_blocks > 0, (
                "model has window layers: pass num_window_blocks")
        # A hit must restore per-row state beyond dense blocks (MoSA top-k
        # sets, window rings, SSM states) -> only snapshot nodes usable.
        self.need_snapshot = any(
            not isinstance(x, PagedDenseKVCache) for x in leaves)

        self.dense_pool = BlockPool(paged.num_blocks, self.bs)
        self.window_pool = (BlockPool(paged.num_window_blocks, self.bs)
                            if self.has_window else None)
        self.prefix = PrefixCache(self.bs) if prefix_cache else None
        self._empty_row = jax.device_get(server.snapshot_row(self.caches, 0))
        self.stats = {"prefix_hits": 0, "prefix_hit_tokens": 0,
                      "prefilled_tokens": 0, "preemptions": 0,
                      "max_concurrent": 0}

        B = server.batch
        self._slots: List[Optional[dict]] = [None] * B
        self._admit_seq = 0

    # ----------------------------------------------------------- interface
    def submit(self, prompt, max_new: int) -> int:
        rid = len(self.results) + len(self.queue) + \
            sum(s is not None for s in self._slots)
        self.queue.append(_Request(rid, jnp.asarray(prompt, jnp.int32),
                                   max_new))
        return rid

    # ------------------------------------------------------------- helpers
    def _bucket(self, n: int) -> int:
        if self.prefill_len:
            return min(self.prefill_len, self.server.max_len)
        b = 1
        while b < max(n, 1):
            b *= 2
        return min(b, self.server.max_len)

    def _alloc_dense(self, n: int):
        """All-or-nothing dense alloc, LRU-evicting prefix entries first."""
        while True:
            ids = self.dense_pool.alloc(n)
            if ids is not None:
                return ids
            if self.prefix is None or not self.prefix.evict_lru(
                    self.dense_pool):
                return None

    def _window_blocks_for(self, tokens: int) -> int:
        """Ring blocks needed once ``tokens`` tokens have been written:
        positions ``0..tokens-1`` land on ring slots ``0..min(tokens,W)-1``
        (monotone fill until the ring wraps), so coverage is a PREFIX of the
        block table — lazy allocation extends it, never punches holes."""
        W = self.wb * self.bs
        return -(-min(tokens, W) // self.bs)

    def _prefill(self, b, prompt_np, valid_count, continued):
        """Bucketed right-pad prefill of ``prompt_np`` into row ``b``."""
        srv = self.server
        bucket = self._bucket(valid_count)
        padded = np.zeros((bucket,), np.int32)
        padded[:valid_count] = prompt_np[:valid_count]
        valid = (np.arange(bucket) < valid_count)[None]
        logits, self.caches = srv.prefill_row(
            srv.params, jnp.asarray(padded)[None], self.caches,
            jnp.int32(b), jnp.asarray(valid),
            jnp.full((1,), valid_count - 1, jnp.int32), continued)
        self.stats["prefilled_tokens"] += valid_count
        return logits

    def _free_slot(self, b):
        """Release row ``b``'s blocks AND clear its device state.  The
        clear is not hygiene theater: ``decode_many`` keeps stepping every
        row, so a stale block table would scatter the dead row's KV into
        freed blocks the allocator may already have handed to a live
        request — silent cross-request corruption.  Restoring the empty
        template (-1 tables, zero lengths) makes the dead row's writes
        drop instead."""
        s = self._slots[b]
        self.dense_pool.decref(s["dense_ids"])
        if self.window_pool is not None:
            self.window_pool.decref(s["window_ids"])
        self._slots[b] = None
        self.caches = self.server.restore_row(
            self.caches, copy.deepcopy(self._empty_row), jnp.int32(b))

    def _finish(self, b):
        r = self._slots[b]["req"]
        self.results[r.rid] = jnp.asarray(r.generated, jnp.int32)
        self._free_slot(b)

    def _preempt(self, b):
        """Preempt-to-recompute: release every block, requeue with
        prompt + generated as the new prompt."""
        s = self._slots[b]
        r = s["req"]
        if r.generated:
            r.prompt = jnp.concatenate(
                [r.prompt, jnp.asarray(r.generated, jnp.int32)])
        self._free_slot(b)
        self.queue.insert(0, r)
        self.stats["preemptions"] += 1

    # ------------------------------------------------------------ admission
    def _admit(self, b, r: _Request, key) -> Optional[int]:
        """Admit ``r`` into row ``b``; returns its first sampled token, or
        None when the block pools cannot cover the prompt."""
        srv = self.server
        prompt_np = np.asarray(r.prompt)
        P = min(len(prompt_np), srv.max_len)
        prompt_np = prompt_np[-P:]
        remaining_cap = srv.max_len - P + 1
        r.max_new = min(r.max_new, len(r.generated) + remaining_cap)

        node, depth, chain_ids = None, 0, []
        if self.prefix is not None:
            node, depth = self.prefix.lookup(prompt_np, self.need_snapshot)
        n_prompt_blocks = -(-P // self.bs)
        n_new_blocks = n_prompt_blocks - depth // self.bs

        if node is not None:
            chain_ids = self.prefix.acquire(node, self.dense_pool)
        suffix_ids = self._alloc_dense(n_new_blocks)
        if suffix_ids is None:
            if chain_ids:
                self.dense_pool.decref(chain_ids)
            return None
        window_ids: List[int] = []
        if self.window_pool is not None:
            # Lazy ring: only the blocks the prompt's P tokens will write.
            window_ids = self.window_pool.alloc(self._window_blocks_for(P))
            if window_ids is None:
                self.dense_pool.decref(chain_ids + suffix_ids)
                return None
        dense_ids = chain_ids + suffix_ids

        if node is not None:
            if node.snapshot is not None:
                snap = copy.deepcopy(node.snapshot)
            else:
                # snapshot-free hit (pure paged-dense model, any depth):
                # the only per-row state is the table + length
                snap = copy.deepcopy(self._empty_row)
                for e in _paged_entries(snap):
                    if "k" not in e:
                        e["length"] = np.full_like(e["length"], depth)
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += depth
        else:
            snap = copy.deepcopy(self._empty_row)
        _set_snapshot_tables(snap, _table_row(dense_ids, self.nb_max),
                             _table_row(window_ids, max(self.wb, 1)))
        self.caches = srv.restore_row(self.caches, snap, jnp.int32(b))

        if node is not None:
            logits = self._prefill(b, prompt_np[depth:], P - depth, True)
        elif self.prefix is not None and (P - 1) // self.bs > 0:
            # Miss: split at the shareable boundary so the inserted
            # snapshot depends on the prefix tokens alone (see module
            # docstring), then finish the tail as a continued prefill.
            n_share = ((P - 1) // self.bs) * self.bs
            self._prefill(b, prompt_np[:n_share], n_share, False)
            snap1 = jax.device_get(srv.snapshot_row(self.caches,
                                                    jnp.int32(b)))
            chain, tip = self.prefix.insert(
                prompt_np[:n_share], dense_ids[:n_share // self.bs],
                self.dense_pool)
            _set_snapshot_tables(snap1, _table_row(chain, self.nb_max),
                                 _table_row([], max(self.wb, 1)))
            self.prefix.attach_snapshot(tip, snap1)
            logits = self._prefill(b, prompt_np[n_share:], P - n_share, True)
        else:
            logits = self._prefill(b, prompt_np, P, False)

        tok0 = srv.sample(logits[:, -1], key)
        self._slots[b] = {"req": r, "dense_ids": dense_ids,
                          "window_ids": window_ids, "length": P,
                          "seq": self._admit_seq}
        self._admit_seq += 1
        self.stats["max_concurrent"] = max(
            self.stats["max_concurrent"],
            sum(s is not None for s in self._slots))
        r.generated.append(int(tok0[0]))
        if len(r.generated) >= r.max_new or int(tok0[0]) == self.eos:
            self._finish(b)
        return int(tok0[0])

    # ------------------------------------------------------------- growth
    def _alloc_or_preempt(self, alloc_fn, n: int, b: int, live):
        """``alloc_fn(n)``, preempting latest-admitted victims on failure.
        Latest-admitted only: preempting a row OLDER than ``b`` would break
        the monotone-progress guarantee (the oldest request must never lose
        its blocks to a newer one); when nothing newer than ``b`` exists,
        the caller preempts ``b`` itself."""
        ids = alloc_fn(n)
        while ids is None:
            s = self._slots[b]
            victims = [x for x in live
                       if self._slots[x] is not None and x != b
                       and self._slots[x]["seq"] > s["seq"]]
            if not victims:
                return None
            victim = max(victims, key=lambda x: self._slots[x]["seq"])
            self._preempt(victim)
            ids = alloc_fn(n)
        return ids

    def _grow_row(self, b: int, n: int, live) -> bool:
        """Cover the next ``n`` decode tokens of row ``b``: dense chain
        blocks plus the window ring blocks those tokens' ring slots need
        (lazy-ring invariant: allocation always precedes the write).
        Returns False iff ``b`` itself had to be preempted."""
        srv = self.server
        s = self._slots[b]
        needed = min(-(-(s["length"] + n) // self.bs), self.nb_max)
        extra = needed - len(s["dense_ids"])
        if extra > 0:
            ids = self._alloc_or_preempt(self._alloc_dense, extra, b, live)
            if ids is None:
                self._preempt(b)
                return False
            s["dense_ids"].extend(ids)
            self.caches = srv.grow_tables(
                self.caches,
                jnp.asarray(_table_row(s["dense_ids"], self.nb_max)),
                jnp.int32(b))
        if self.window_pool is not None:
            extra_w = self._window_blocks_for(s["length"] + n) \
                - len(s["window_ids"])
            if extra_w > 0:
                ids = self._alloc_or_preempt(self.window_pool.alloc,
                                             extra_w, b, live)
                if ids is None:
                    self._preempt(b)
                    return False
                s["window_ids"].extend(ids)
                self.caches = srv.grow_window_tables(
                    self.caches,
                    jnp.asarray(_table_row(s["window_ids"],
                                           max(self.wb, 1))),
                    jnp.int32(b))
        return True

    # ---------------------------------------------------------------- run
    def run(self, max_steps: int = 1000):
        """Serve every queued request; returns {rid: generated tokens}.
        Semantics mirror ``RequestPool.run`` (EOS, per-request ``max_new``,
        global ``max_steps`` decode budget)."""
        srv = self.server
        B = srv.batch
        cur = jnp.zeros((B, 1), jnp.int32)
        key = jax.random.PRNGKey(0)
        steps = 0

        with srv.mesh, hints.sharding_hints(mesh=srv.mesh):
            while self.queue or any(s is not None for s in self._slots):
                for b in range(B):
                    if self._slots[b] is None and self.queue \
                            and steps < max_steps:
                        r = self.queue[0]
                        key, sub = jax.random.split(key)
                        tok = self._admit(b, r, sub)
                        if tok is None:
                            break               # blocks exhausted: wait
                        self.queue.pop(0)
                        cur = cur.at[b, 0].set(tok)
                live = [b for b in range(B) if self._slots[b] is not None]
                if not live:
                    if steps >= max_steps:
                        break
                    if self.queue and not any(self._slots):
                        # nothing live and head-of-queue cannot be admitted
                        raise RuntimeError(
                            "request needs more blocks than the pool has: "
                            f"free={self.dense_pool.free_blocks} of "
                            f"{self.dense_pool.num_blocks}")
                    continue
                if steps >= max_steps:
                    for b in live:
                        self._finish(b)
                    break

                need = max(self._slots[b]["req"].max_new -
                           len(self._slots[b]["req"].generated)
                           for b in live)
                n = max(min(self.chunk, max_steps - steps, need), 1)

                # Grow dense chains and (lazily) window rings to cover the
                # next n appended tokens; preempt latest-admitted rows when
                # a pool runs dry.
                for b in sorted(live,
                                key=lambda x: self._slots[x]["seq"]):
                    if self._slots[b] is None:
                        continue
                    self._grow_row(b, n, live)
                live = [b for b in range(B) if self._slots[b] is not None]
                if not live:
                    continue

                key, sub = jax.random.split(key)
                toks, self.caches = srv.decode_many(srv.params, cur,
                                                    self.caches, sub, n)
                steps += n
                host = jax.device_get(toks)
                cur = toks[:, -1:]
                for b in live:
                    s = self._slots[b]
                    if s is None:
                        continue
                    r = s["req"]
                    for t in host[b]:
                        r.generated.append(int(t))
                        s["length"] += 1
                        if int(t) == self.eos or \
                                len(r.generated) >= r.max_new:
                            self._finish(b)
                            break
        return dict(self.results)
