"""Paged KV-cache serving subsystem (DESIGN §7).

Layered like the serving stacks of production attention engines:

  * ``paged_kv``       — fixed-size block pools, the host-side ``BlockPool``
                         allocator (free list, refcounts, copy-on-write), and
                         the ``PagedDenseKVCache`` / ``PagedWindowKVCache``
                         device pytrees whose ``append`` / ``gather`` match
                         the contiguous caches in ``repro.core.kv_cache``
                         bit-for-bit;
  * ``paged_attention`` — the Pallas paged-attention decode kernel
                         (block-table indirect loads, online softmax) and its
                         JAX gather reference for CPU;
  * ``prefix_cache``   — hash-trie over prompt token blocks mapping shared
                         prefixes to shared physical blocks;
  * ``scheduler``      — block-granular admission / preempt-to-recompute
                         continuous batching over a paged ``Server``;
  * ``loadgen``        — seeded open-loop (Poisson/bursty) and closed-loop
                         arrival streams for the scheduler's timed mode
                         (DESIGN §12).

Layering: nothing in this package imports ``repro.launch`` (the scheduler
takes the server as a duck-typed argument), so ``repro.launch.serve`` can
build on it without an import cycle.  ``paged_kv`` / ``paged_attention``
are LEAF modules (jax + ``dist.sharding`` registration only) that
``repro.core.attention`` dispatches on; the package exports below resolve
lazily (PEP 562) so importing a leaf never drags in the scheduler stack.
"""

_EXPORTS = {
    "BlockPool": "paged_kv",
    "PagedConfig": "paged_kv",
    "PagedDenseKVCache": "paged_kv",
    "PagedWindowKVCache": "paged_kv",
    "paged_attention_decode": "paged_attention",
    "PrefixCache": "prefix_cache",
    "Scheduler": "scheduler",
    "Arrival": "loadgen",
    "TenantSpec": "loadgen",
    "OpenLoopSource": "loadgen",
    "ClosedLoopSource": "loadgen",
    "poisson_workload": "loadgen",
    "bursty_workload": "loadgen",
    "closed_workload": "loadgen",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(f"repro.serve.{_EXPORTS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
