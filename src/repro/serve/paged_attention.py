"""Paged-attention decode: block-table indirect loads + online softmax.

Decode attention over a ``PagedDenseKVCache``: queries are a single token per
row, keys/values live in pool blocks addressed through the row's block table.
Two implementations share the mask/scale conventions of
``repro.core.attention.MultiHeadAttention.decode_step`` (NEG_INF where-mask,
fp32 running max/denom), so both are numerically exact against the
contiguous decode path:

  * ``paged_attention_ref``    — gather the row's blocks back to the
    contiguous ``(B, S, Hkv, d)`` layout and run the identical einsum; the
    CPU/reference path and the oracle the kernel is tested against.
  * ``paged_attention_kernel`` — Pallas TPU kernel: grid ``(B, num_blocks)``,
    the block table and per-row lengths ride in scalar-prefetch SMEM so each
    grid step DMAs exactly one physical block ``pool[table[b, i]]`` into
    VMEM (the indirect load), with flash-style online softmax carried in
    VMEM scratch across the block-grid dimension.  No gather buffer is ever
    materialized.

``paged_attention_decode`` is the public dispatcher (same platform logic as
``repro.kernels.ops``: native on TPU, interpreter elsewhere unless
``REPRO_PALLAS_INTERPRET`` overrides).  The windowed ring cache always takes
the gather path — its KV is bounded by W, so there is no quadratic gather to
avoid (DESIGN §7).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu is importable without TPU hardware; kernels interpret on CPU
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.serve.paged_kv import PagedDenseKVCache

NEG_INF = -1e30
LANE = 128


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- reference
def paged_attention_ref(q, k_pool, v_pool, block_table, lengths, scale):
    """q: (B, Hq, d); pools (N, bs, Hkv, d); block_table (B, nb);
    lengths (B,).  Returns (B, Hq, d) in q.dtype.

    Exactly ``MultiHeadAttention.decode_step``'s cache attention on the
    gathered layout: all positions ``< length`` attend (decode is causal by
    construction — every pooled token precedes the query)."""
    B, Hq, d = q.shape
    nb, bs = block_table.shape[1], k_pool.shape[1]
    Hkv = k_pool.shape[2]
    R = Hq // Hkv
    S = nb * bs

    bt = jnp.clip(block_table, 0)
    kk = jax.vmap(lambda t: k_pool[t].reshape(S, Hkv, d))(bt)   # (B,S,Hkv,d)
    vv = jax.vmap(lambda t: v_pool[t].reshape(S, Hkv, d))(bt)

    qg = q.reshape(B, Hkv, R, 1, d).astype(jnp.float32)
    s = jnp.einsum("bgrqd,bsgd->bgrqs", qg, kk.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    k_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ok = (k_pos < lengths[:, None])[:, None, None, None, :]
    s = jnp.where(ok, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bgrqs,bsgd->bgrqd", p, vv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(p.sum(-1), 1e-30)[..., None]
    return out.reshape(B, Hq, d).astype(q.dtype)


# ------------------------------------------------------------------- kernel
def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, bs: int, scale: float):
    """Grid (B, nb).  bt/len are scalar-prefetch SMEM; k/v blocks arrive
    already indirected by the index map (``pool[bt[b, i]]``)."""
    b, i = pl.program_id(0), pl.program_id(1)
    nb = pl.num_programs(1)
    Hq, d = q_ref.shape[1], q_ref.shape[2]
    Hkv = k_ref.shape[2]
    R = Hq // Hkv
    length = len_ref[b]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i * bs < length)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale                # (Hq, d)
        k = k_ref[0].astype(jnp.float32)                        # (bs, Hkv, d)
        v = v_ref[0].astype(jnp.float32)
        qg = q.reshape(Hkv, R, d)
        kg = k.transpose(1, 0, 2)                               # (Hkv, bs, d)
        vg = v.transpose(1, 0, 2)
        s = jax.lax.dot_general(qg, kg, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (Hkv, R, bs), 2)
        s = jnp.where(pos < length, s, NEG_INF)                 # (Hkv, R, bs)

        m_prev = m_ref[:, :1].reshape(Hkv, R)
        l_prev = l_ref[:, :1].reshape(Hkv, R)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(pos < length, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        acc = acc_ref[...].reshape(Hkv, R, d)
        acc = acc * corr[..., None] + jax.lax.dot_general(
            p, vg, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(
            m_new.reshape(Hq, 1), m_ref.shape).astype(m_ref.dtype)
        l_ref[...] = jnp.broadcast_to(
            l_new.reshape(Hq, 1), l_ref.shape).astype(l_ref.dtype)
        acc_ref[...] = acc.reshape(Hq, d)

    @pl.when(i == nb - 1)
    def _finish():
        l = l_ref[:, :1]                                        # (Hq, 1)
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention_kernel(q, k_pool, v_pool, block_table, lengths, *,
                           scale: float, interpret: bool = False):
    """Pallas paged decode.  q: (B, Hq, d) with d a multiple of 128 (the
    wrapper pads); pools (N, bs, Hkv, d); block_table (B, nb); lengths (B,).
    """
    B, Hq, d = q.shape
    nb = block_table.shape[1]
    bs = k_pool.shape[1]
    Hkv = k_pool.shape[2]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # block_table, lengths
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, Hq, d), lambda b, i, bt, ln: (b, 0, 0)),
            # THE indirect load: the i-th logical block of row b is DMA'd
            # from physical block bt[b, i] (clamped; unallocated blocks are
            # masked out by `pos < length` in the kernel body).
            pl.BlockSpec((1, bs, Hkv, d),
                         lambda b, i, bt, ln: (jnp.maximum(bt[b, i], 0),
                                               0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, d),
                         lambda b, i, bt, ln: (jnp.maximum(bt[b, i], 0),
                                               0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, d), lambda b, i, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, LANE), jnp.float32),   # running max (replicated)
            pltpu.VMEM((Hq, LANE), jnp.float32),   # running denom
            pltpu.VMEM((Hq, d), jnp.float32),      # output accumulator
        ],
    )
    kernel = functools.partial(_paged_kernel, bs=bs, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, d), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, k_pool, v_pool)


def _pad_lane(x):
    pad = (-x.shape[-1]) % LANE
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[-1] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------- dispatch
def paged_attention_decode(q, cache: PagedDenseKVCache, *, scale: float,
                           impl: str | None = None,
                           interpret: bool | None = None):
    """Decode attention of one token per row over a paged dense cache.

    q: (B, Hq, d).  ``impl``: ``"kernel"`` | ``"ref"`` | None (kernel on
    TPU, ref elsewhere — the gather ref is faster than an interpreted kernel
    on CPU and bit-identical to the contiguous decode path).
    """
    if impl is None:
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return paged_attention_ref(q, cache.k, cache.v, cache.block_table,
                                   cache.length, scale)
    interpret = _interpret_default() if interpret is None else interpret
    d = q.shape[-1]
    out = paged_attention_kernel(
        _pad_lane(q), _pad_lane(cache.k), _pad_lane(cache.v),
        cache.block_table, cache.length, scale=scale, interpret=interpret)
    return out[..., :d]


# ------------------------------------------------- packed varlen prefill
def paged_prefill_attention_ref(q, k_pool, v_pool, block_table, row_of_tok,
                                pos_in_kv, scale):
    """Packed ragged prefill attention over paged pools (oracle + CPU path).

    q:          (total, Hq, d) — flattened chunk queries of N segments
    pools:      (P, bs, Hkv, d); block_table: (B, nb)
    row_of_tok: (total,) int32 — the batch row whose KV each token reads
                (-1 = padding token -> zero output)
    pos_in_kv:  (total,) int32 — the token's own absolute position in that
                row's KV space (past_len + local offset); it attends every
                key at position <= pos_in_kv (causal over past + chunk).
    Returns (total, Hq, d) in q.dtype.

    The chunk's own K/V must already be appended to the pools (the caller
    appends before attending, mirroring ``_prefill_dense_paged``).
    """
    total, Hq, d = q.shape
    nb, bs = block_table.shape[1], k_pool.shape[1]
    Hkv = k_pool.shape[2]
    R = Hq // Hkv
    S = nb * bs

    bt = jnp.clip(block_table, 0)
    kk = jax.vmap(lambda t: k_pool[t].reshape(S, Hkv, d))(bt)   # (B,S,Hkv,d)
    vv = jax.vmap(lambda t: v_pool[t].reshape(S, Hkv, d))(bt)
    row = jnp.maximum(row_of_tok, 0)
    kt = kk[row]                                                # (T,S,Hkv,d)
    vt = vv[row]

    qg = q.reshape(total, Hkv, R, d).astype(jnp.float32)
    s = jnp.einsum("tgrd,tsgd->tgrs", qg, kt.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(S)
    ok = (k_pos[None, :] <= pos_in_kv[:, None]) \
        & (row_of_tok >= 0)[:, None]                            # (T, S)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(ok[:, None, None, :], p, 0.0)
    out = jnp.einsum("tgrs,tsgd->tgrd", p, vt.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(p.sum(-1), 1e-30)[..., None]
    return out.reshape(total, Hq, d).astype(q.dtype)


def _paged_prefill_kernel(row_ref, bt_ref, q_ref, pos_ref, k_ref, v_ref,
                          o_ref, m_ref, l_ref, acc_ref, *, bs: int,
                          scale: float):
    """Grid (Hq, N, nb) — one segment x one query head per (h, n) slice, the
    row's paged KV streamed block-by-block along i with the online-softmax
    carry in VMEM scratch (same discipline as ``_paged_kernel``).

    row_ref / bt_ref ride in scalar-prefetch SMEM: the i-th KV block of
    segment n is DMA'd from physical block ``bt[row[n], i]`` by the index
    map before the body runs.  Refs: q (1, C, 1, d); pos (1, C) — the
    per-query absolute KV position (-1 = padding query); k/v (1, bs, 1, d).
    """
    i = pl.program_id(2)
    nb = pl.num_programs(2)
    C, d = q_ref.shape[1], q_ref.shape[3]
    pos = pos_ref[0]                                            # (C,)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the segment's deepest query bounds how many KV blocks matter
    @pl.when(i * bs <= jnp.max(pos))
    def _block():
        q = q_ref[0, :, 0].astype(jnp.float32) * scale          # (C, d)
        k = k_ref[0, :, 0].astype(jnp.float32)                  # (bs, d)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = i * bs + jax.lax.iota(jnp.int32, bs)
        mask = k_pos[None, :] <= pos[:, None]                   # (C, bs)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1].reshape(C)
        l_prev = l_ref[:, :1].reshape(C)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        acc = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)
        acc_ref[...] = acc

    @pl.when(i == nb - 1)
    def _finish():
        l = l_ref[:, :1]                                        # (C, 1)
        o_ref[0, :, 0] = (acc_ref[...] /
                          jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill_attention_kernel(q_seg, pos_seg, k_pool, v_pool,
                                   block_table, row_of_seg, *, scale: float,
                                   interpret: bool = False):
    """Pallas packed prefill.  q_seg: (N, C, Hq, d) — the packed chunk
    unfolded to one right-padded row per segment (d a multiple of 128);
    pos_seg: (N, C) int32 absolute KV positions (-1 on padding);
    row_of_seg: (N,) int32 batch row per segment (clamped if -1)."""
    N, C, Hq, d = q_seg.shape
    nb = block_table.shape[1]
    bs = k_pool.shape[1]
    Hkv = k_pool.shape[2]
    R = Hq // Hkv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # row_of_seg, block_table
        grid=(Hq, N, nb),
        in_specs=[
            pl.BlockSpec((1, C, 1, d),
                         lambda h, n, i, row, bt: (n, 0, h, 0)),
            pl.BlockSpec((1, C), lambda h, n, i, row, bt: (n, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda h, n, i, row, bt:
                         (jnp.maximum(bt[jnp.maximum(row[n], 0), i], 0),
                          0, h // R, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda h, n, i, row, bt:
                         (jnp.maximum(bt[jnp.maximum(row[n], 0), i], 0),
                          0, h // R, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, 1, d),
                               lambda h, n, i, row, bt: (n, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, LANE), jnp.float32),    # running max (replicated)
            pltpu.VMEM((C, LANE), jnp.float32),    # running denom
            pltpu.VMEM((C, d), jnp.float32),       # output accumulator
        ],
    )
    kernel = functools.partial(_paged_prefill_kernel, bs=bs, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, C, Hq, d), q_seg.dtype),
        interpret=interpret,
    )(row_of_seg, block_table, q_seg, pos_seg, k_pool, v_pool)


def paged_prefill_attention(q, cache: PagedDenseKVCache, cu_seqlens,
                            row_of_seg, past_lens, *, scale: float,
                            impl: str | None = None,
                            interpret: bool | None = None):
    """Packed ragged prefill over a paged dense cache (public dispatcher).

    q: (total, Hq, d) — N segments flattened back to back; cu_seqlens:
    (N+1,) int32 offsets (cu[N] may be < total: the tail is padding);
    row_of_seg: (N,) int32 batch row per segment (-1 = inactive segment);
    past_lens: (N,) int32 tokens already in the row's cache BEFORE this
    chunk.  The chunk's K/V must already be appended (``append_packed``).
    ``impl`` as in ``paged_attention_decode``.
    """
    if impl is None:
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    total, Hq, d = q.shape
    cu = jnp.asarray(cu_seqlens, jnp.int32)
    t = jnp.arange(total, dtype=jnp.int32)
    seg = jnp.searchsorted(cu[1:], t, side="right").astype(jnp.int32)
    seg = jnp.where(t < cu[-1], seg, -1)
    segc = jnp.maximum(seg, 0)
    local = t - cu[segc]
    row_of_tok = jnp.where(seg >= 0, row_of_seg[segc], -1)
    pos_in_kv = jnp.where(seg >= 0, past_lens[segc] + local, -1)

    if impl == "ref":
        return paged_prefill_attention_ref(
            q, cache.k, cache.v, cache.block_table, row_of_tok, pos_in_kv,
            scale)

    interpret = _interpret_default() if interpret is None else interpret
    N = cu.shape[0] - 1
    C = total
    # unfold the packed stream to one right-padded row per segment
    tok_idx = cu[:-1, None] + jnp.arange(C)[None, :]            # (N, C)
    in_seg = jnp.arange(C)[None, :] < (cu[1:] - cu[:-1])[:, None]
    tok_c = jnp.clip(tok_idx, 0, total - 1)
    q_seg = jnp.where(in_seg[..., None, None], q[tok_c], 0)
    pos_seg = jnp.where(in_seg & (row_of_seg >= 0)[:, None],
                        past_lens[:, None] + jnp.arange(C)[None, :], -1)
    out_seg = paged_prefill_attention_kernel(
        _pad_lane(q_seg), pos_seg.astype(jnp.int32), _pad_lane(cache.k),
        _pad_lane(cache.v), cache.block_table,
        row_of_seg.astype(jnp.int32), scale=scale, interpret=interpret)
    out = out_seg[segc, local][..., :d]                         # (total,Hq,d)
    return jnp.where((seg >= 0)[:, None, None], out, 0).astype(q.dtype)
