"""Fault-tolerant checkpointing (no orbax dependency).

Design (what matters at 1000+ nodes):
  * **atomic**: write to ``<dir>/tmp.<step>`` then ``os.rename`` — a killed
    writer never corrupts the latest checkpoint;
  * **self-describing**: a JSON manifest stores the pytree structure, shapes,
    dtypes and a checksum per array; arrays live in one ``.npz``;
  * **async**: ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and writes on a background thread — training continues;
  * **elastic restore**: ``restore(..., shardings=...)`` re-shards onto
    whatever mesh the restarted job has (different device count is fine) via
    ``jax.device_put`` with the new NamedShardings;
  * **retention**: ``keep_last`` old steps garbage-collected after a
    successful write.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree: Any, *, keep_last: int = 3,
         extra_meta: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    flat = _flatten_with_paths(host_tree)
    manifest = {"step": step, "time": time.time(),
                "extra": extra_meta or {},
                "arrays": {}}
    arrays = {}
    for i, (key, arr) in enumerate(sorted(flat.items())):
        name = f"a{i}"
        arrays[name] = arr
        manifest["arrays"][key] = {
            "file": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha1": hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest(),
        }
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target: Any, *, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True):
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching pytree of NamedShardings for
    elastic re-sharding; None = host arrays put on default device.
    Returns (tree, manifest_extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))

    flat_target = _flatten_with_paths(target)
    loaded = {}
    for key, meta in manifest["arrays"].items():
        arr = npz[meta["file"]]
        if verify:
            sha = hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest()
            if sha != meta["sha1"]:
                raise IOError(f"checksum mismatch for {key} in {path}")
        loaded[key] = arr
    missing = set(flat_target) - set(loaded)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")

    flat_shard = _flatten_with_paths(shardings) if shardings is not None else {}

    def rebuild(path_key, leaf):
        arr = loaded[path_key]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if path_key in flat_shard:
            return jax.device_put(arr, flat_shard[path_key])
        return jax.device_put(arr)

    # Rebuild in the target's structure.
    flat_paths = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for p, leaf in flat_paths[0]:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        leaves.append(rebuild(key, leaf))
    tree = jax.tree_util.tree_unflatten(flat_paths[1], leaves)
    return tree, manifest.get("extra", {})


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, persist on a background thread."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra_meta: Optional[dict] = None):
        self.wait()  # one in flight at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, keep_last=self.keep_last,
                     extra_meta=extra_meta)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
