"""Gradient compression for cross-pod data parallelism.

At 2+ pods the inter-pod links are the scarcest resource (DCN or long ICI
hops), so the pod-axis all-reduce is the one worth compressing.  Two schemes,
both with error feedback (the residual is re-added next step so the
compression is unbiased over time):

  * ``topk_compress``  — keep the largest-|g| fraction per tensor, all-reduce
    the dense-ified sparse tensor (simple, deterministic, shape-static).
  * ``int8_compress``  — per-tensor symmetric int8 quantization; all-reduce
    in int32 to avoid overflow, rescale after.

Use ``compressed_psum(tree, axis, scheme)`` inside a shard_map over the pod
axis; ``error_feedback_*`` wrap it with the residual state.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def topk_mask(x: jnp.ndarray, frac: float) -> jnp.ndarray:
    """Boolean mask keeping the ceil(frac * n) largest-|x| entries."""
    n = x.size
    kth = max(1, int(n * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, kth)[0][-1]
    return (jnp.abs(x) >= thresh)


def topk_compress(g: jnp.ndarray, frac: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (compressed_dense, residual).  compressed + residual == g."""
    mask = topk_mask(g, frac)
    kept = jnp.where(mask, g, 0)
    return kept, g - kept


def int8_quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    q, scale = int8_quantize(g.astype(jnp.float32))
    deq = int8_dequantize(q, scale).astype(g.dtype)
    return deq, g - deq


def compressed_psum(tree, axis_name: str, scheme: str = "none",
                    topk_frac: float = 0.01, residual=None):
    """psum over ``axis_name`` with optional compression + error feedback.

    Call inside shard_map/pmap.  Returns (reduced_tree, new_residual).
    """
    if scheme == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), tree), residual

    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, tree)

    def one(g, res):
        g = g + res.astype(g.dtype)
        if scheme == "topk":
            kept, new_res = topk_compress(g, topk_frac)
        elif scheme == "int8":
            kept, new_res = int8_compress(g)
        else:
            raise ValueError(scheme)
        reduced = jax.lax.psum(kept, axis_name)
        return reduced, new_res

    flat, tdef = jax.tree.flatten(tree)
    flat_res = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat, flat_res)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
