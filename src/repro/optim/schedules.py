"""Learning-rate schedules (pure functions of the fp32 step)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    """The paper's schedule: linear warmup then constant (App. C: 4k warmup)."""

    def fn(step):
        warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        return jnp.asarray(lr * warm, jnp.float32)

    return fn


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        prog = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr * warm * cos, jnp.float32)

    return fn


def warmup_rsqrt(lr: float, warmup_steps: int):
    def fn(step):
        s = jnp.maximum(step, 1.0)
        return jnp.asarray(
            lr * jnp.minimum(s / max(warmup_steps, 1),
                             (warmup_steps / s) ** 0.5 if warmup_steps else 1.0),
            jnp.float32)

    return fn
