"""Optimizers as pure pytree transformations (no optax dependency).

``adamw(...)`` returns an ``Optimizer`` namedtuple of pure functions:
  init(params) -> state;  update(grads, state, params, step) -> (updates, state)
so the train step is just ``params = apply_updates(params, updates)``.

Includes: Adam/AdamW (decoupled weight decay), global-norm clipping, any
schedule from ``repro.optim.schedules``, and fp32 master copies of the first
and second moments regardless of param dtype (bf16-safe training).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        stepf = step.astype(jnp.float32) + 1.0
        lr_t = jnp.asarray(lr_fn(stepf), jnp.float32)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf

        def one(g, mu, nu, p):
            g32 = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * jnp.square(g32)
            upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (-lr_t * upd).astype(p.dtype), mu, nu

        flat_g, tdef = jax.tree.flatten(grads)
        flat_mu = tdef.flatten_up_to(state["mu"])
        flat_nu = tdef.flatten_up_to(state["nu"])
        flat_p = tdef.flatten_up_to(params)
        out = [one(g, m, n, p) for g, m, n, p in
               zip(flat_g, flat_mu, flat_nu, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        new_state = {"mu": tdef.unflatten([o[1] for o in out]),
                     "nu": tdef.unflatten([o[2] for o in out])}
        return updates, new_state, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)


def sgd(lr: Callable | float, *, momentum: float = 0.0,
        clip_norm: Optional[float] = None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        if momentum:
            return {"mom": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)}
        return {}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        lr_t = jnp.asarray(lr_fn(step.astype(jnp.float32) + 1.0), jnp.float32)
        if momentum:
            new_mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads)
            updates = jax.tree.map(lambda m, p: (-lr_t * m).astype(p.dtype),
                                   new_mom, params)
            return updates, {"mom": new_mom}, {"grad_norm": gnorm, "lr": lr_t}
        updates = jax.tree.map(
            lambda g, p: (-lr_t * g.astype(jnp.float32)).astype(p.dtype),
            grads, params)
        return updates, state, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
