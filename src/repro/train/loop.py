"""The resumable training driver (DESIGN §8).

Builds the full stack for one (arch, shape, mesh) choice:
  data pipeline -> sharded init -> jit'd donated train step (microbatch
  accumulation + mixed precision + remat, ``repro.train.step``) ->
  checkpoint/restart -> heartbeats + straggler monitor -> preemption
  (SIGTERM -> checkpoint at the next step boundary) -> router health
  telemetry (selection entropy / token-drop rate / head utilization).

Resumability contract (tests/test_train_subsystem.py): a run killed at any
step boundary and restarted from its checkpoint replays the SAME loss curve
bit-for-bit as an uninterrupted run — the data pipeline is step-indexed
(``Prefetcher(start_step=...)``), the optimizer state travels with the
checkpoint, and the step counter rides in the manifest.

``repro.launch.train`` is the CLI face of this module.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs.base import ModelConfig, get_config
from repro.data.pipeline import PackedLMDataset, Prefetcher, SyntheticCorpus
from repro.dist import hints
from repro.dist import sharding as shd
from repro.dist.fault_tolerance import (Heartbeat, PreemptionHandler,
                                        StragglerMonitor, elastic_plan)
from repro.launch import mesh as mesh_lib
from repro.nn.module import init_shapes
from repro.nn.transformer import TransformerLM
from repro.optim import schedules
from repro.optim.optimizer import adamw
from repro.train.step import make_train_step, mixed_precision


@dataclasses.dataclass
class TrainConfig:
    arch: str = "mosa-paper"
    preset: str = "full"
    seq_len: int = 1024
    global_batch: int = 64
    steps: int = 100
    lr: float = 2.5e-4
    warmup: int = 400
    clip_norm: float = 0.25
    weight_decay: float = 0.0
    seed: int = 0
    rule_set: str = "tp"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    keep_last: int = 3
    log_every: int = 10
    mesh_shape: Optional[tuple] = None   # None = all local devices
    arch_kwargs: dict = dataclasses.field(default_factory=dict)
    # --- repro.train knobs (DESIGN §8) ---
    microbatch: int = 1                  # grad-accumulation splits per step
    compute: Optional[str] = None        # "bfloat16" -> bf16/fp32-master
    remat: Optional[str] = None          # none | full | dots_saveable | mosa
    mosa_impl: Optional[str] = None      # einsum | pallas (fused VJP kernels)
    router_health: bool = True           # log router telemetry at log_every
    # --- observability (DESIGN §11) ---
    health_in_step: bool = True          # health as train-step aux outputs
    metrics_path: Optional[str] = None   # obs snapshot on run() exit
    trace_path: Optional[str] = None     # Chrome-trace JSON on run() exit


def _apply_overrides(model_cfg: ModelConfig, cfg: TrainConfig) -> ModelConfig:
    if cfg.compute:
        model_cfg = mixed_precision(model_cfg, cfg.compute)
    if cfg.remat:
        model_cfg = dataclasses.replace(model_cfg, remat=cfg.remat)
    if cfg.mosa_impl and model_cfg.mosa is not None:
        model_cfg = dataclasses.replace(
            model_cfg,
            mosa=dataclasses.replace(model_cfg.mosa, impl=cfg.mosa_impl))
    return model_cfg


class Trainer:
    def __init__(self, cfg: TrainConfig,
                 model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = _apply_overrides(
            model_cfg or get_config(cfg.arch, preset=cfg.preset,
                                    **cfg.arch_kwargs), cfg)
        self.model = TransformerLM(self.model_cfg)
        if cfg.mesh_shape:
            axes = ("pod", "data", "model")[-len(cfg.mesh_shape):]
            self.mesh = mesh_lib.make_mesh(cfg.mesh_shape, axes)
        else:
            plan = elastic_plan(len(jax.devices()), tp=1)
            self.mesh = mesh_lib.make_mesh(plan["shape"], plan["axes"])
        self.optimizer = adamw(
            schedules.linear_warmup(cfg.lr, cfg.warmup),
            weight_decay=cfg.weight_decay, clip_norm=cfg.clip_norm)

        # shardings for the whole (params, opt, step) train state
        shapes = init_shapes(self.model)
        self.param_sh, self.opt_sh, self.scalar_sh = \
            shd.train_state_shardings(self.model, self.mesh, cfg.rule_set,
                                      self.optimizer, shapes)
        self.batch_sh = shd.batch_sharding(self.mesh, cfg.rule_set)

        # In-step router health (DESIGN §11): the stats ride the jitted
        # step's metrics instead of costing a second forward per log
        # interval; ``health_in_step=False`` falls back to the standalone
        # ``router_health`` forward at log time.
        self._health_in_step = bool(cfg.router_health and
                                    cfg.health_in_step and self._has_router)
        step_fn = make_train_step(self.model, self.optimizer,
                                  microbatches=cfg.microbatch,
                                  health=self._health_in_step)
        self.train_step = jax.jit(
            step_fn,
            in_shardings=(self.param_sh, self.opt_sh, self.scalar_sh,
                          jax.tree.map(lambda _: self.batch_sh,
                                       {"tokens": 0, "labels": 0})),
            out_shardings=(self.param_sh, self.opt_sh, self.scalar_sh, None),
            donate_argnums=(0, 1),
        )
        self._health_fn = None

        # data
        n_data = 1
        for a in ("pod", "data"):
            n_data *= self.mesh.shape.get(a, 1)
        self.dataset = PackedLMDataset(
            SyntheticCorpus(vocab=self.model_cfg.vocab, seed=cfg.seed),
            seq_len=cfg.seq_len, global_batch=cfg.global_batch,
            shard_index=0, shard_count=1)  # single-host: full batch here

        self.monitor = StragglerMonitor()
        self.preempt: Optional[PreemptionHandler] = None

    # ------------------------------------------------------------------ state
    def init_state(self):
        key = jax.random.PRNGKey(self.cfg.seed)
        with self.mesh, hints.sharding_hints(mesh=self.mesh):
            params = jax.jit(self.model.init,
                             out_shardings=self.param_sh)(key)
            opt_state = jax.jit(self.optimizer.init,
                                out_shardings=self.opt_sh)(params)
        step = jnp.zeros((), jnp.int32)
        return params, opt_state, step

    def restore_or_init(self):
        cfg = self.cfg
        if cfg.ckpt_dir and ckpt_lib.latest_step(cfg.ckpt_dir) is not None:
            shapes = init_shapes(self.model)
            opt_shapes = jax.eval_shape(self.optimizer.init, shapes)
            tree = {"params": shapes, "opt": opt_shapes}
            sh = {"params": self.param_sh, "opt": self.opt_sh}
            restored, extra = ckpt_lib.restore(cfg.ckpt_dir, tree,
                                               shardings=sh)
            step = jnp.asarray(extra.get("step", 0), jnp.int32)
            return (restored["params"], restored["opt"], step,
                    int(extra.get("step", 0)))
        params, opt, step = self.init_state()
        return params, opt, step, 0

    # ----------------------------------------------------------- telemetry
    @property
    def _has_router(self) -> bool:
        mc = self.model_cfg
        return (mc.mosa is not None and mc.sparse_variant == "mosa" and
                any(b.mixer == "mosa" for b in mc.resolved_pattern()))

    def router_health(self, params, batch):
        """Jitted expert-choice telemetry on the current batch; {} when the
        model has no learned sparse router."""
        if not self._has_router:
            return {}
        if self._health_fn is None:
            self._health_fn = jax.jit(
                lambda p, t: self.model.router_health(p, t),
                in_shardings=(self.param_sh, None))
        return {k: float(v)
                for k, v in self._health_fn(params,
                                            batch["tokens"]).items()}

    def _publish(self, i: int, dt: float, metrics: dict) -> None:
        """Route step telemetry through the obs registry (DESIGN §11) —
        the registry twin of the history/print logging, fed from the SAME
        already-host-synced floats (device-metrics pattern: no extra
        transfer)."""
        reg = obs.registry()
        if not reg.enabled:
            return
        reg.set("train.step", i)
        # train.step_time_s is observed by the step's registry.timer scope;
        # recording it here too would double-count.
        reg.set("train.tokens_per_s",
                metrics.get("tokens", 0.0) / max(dt, 1e-9))
        for k in ("loss", "ce", "ppl", "aux", "grad_norm"):
            if k in metrics:
                reg.set(f"train.{k}", metrics[k])
        for k in ("sel_entropy", "drop_rate", "head_util"):
            if k in metrics:
                reg.observe(f"train.router.{k}", metrics[k],
                            bounds=obs.UNIT_BOUNDS)

    # ------------------------------------------------------------------ train
    def run(self, steps: Optional[int] = None, install_signals: bool = True):
        cfg = self.cfg
        steps = steps if steps is not None else cfg.steps
        params, opt_state, step, start = self.restore_or_init()
        self.preempt = PreemptionHandler() if install_signals else None
        checkpointer = (ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir,
                                                   cfg.keep_last)
                        if cfg.ckpt_dir else None)
        hb = Heartbeat(cfg.ckpt_dir, rank=0) if cfg.ckpt_dir else None
        prefetch = Prefetcher(self.dataset, start_step=start)
        history = []
        try:
            with self.mesh, hints.sharding_hints(mesh=self.mesh):
                for i in range(start, steps):
                    data_step, batch = prefetch.next()
                    batch = {k: jnp.asarray(v) for k, v in batch.items()}
                    # timer.dt keeps feeding the straggler monitor and the
                    # log line even with obs off (only the histogram write
                    # is gated — the registry.timer contract).
                    with obs.registry().timer("train.step_time_s") as tm, \
                            obs.tracer().span("train_step", track="train",
                                              step=i):
                        params, opt_state, step, metrics = self.train_step(
                            params, opt_state, step, batch)
                        # the ONE host sync of the step — in-step health
                        # stats ride it as extra metric keys (DESIGN §11)
                        metrics = {k: float(v) for k, v in metrics.items()}
                    dt = tm.dt
                    straggler = self.monitor.record(i, dt)
                    if hb:
                        hb.beat(i)
                    if i % cfg.log_every == 0 or i == steps - 1:
                        if cfg.router_health and not self._health_in_step:
                            metrics.update(self.router_health(params, batch))
                        history.append({"step": i, "dt": dt, **metrics})
                    self._publish(i, dt, metrics)
                    if i % cfg.log_every == 0 or i == steps - 1:
                        health = (f" ent {metrics['sel_entropy']:.2f} "
                                  f"drop {metrics['drop_rate']:.2f}"
                                  if "sel_entropy" in metrics else "")
                        print(f"step {i:6d} loss {metrics['loss']:.4f} "
                              f"ppl {metrics['ppl']:.2f} "
                              f"gnorm {metrics['grad_norm']:.3f}"
                              f"{health} {dt*1e3:.0f}ms"
                              + (" [straggler]" if straggler else ""))
                    want_ckpt = checkpointer and (
                        (i + 1) % cfg.ckpt_every == 0 or i == steps - 1 or
                        (self.preempt and self.preempt.requested))
                    if want_ckpt:
                        checkpointer.save(
                            i + 1, {"params": params, "opt": opt_state},
                            extra_meta={"step": i + 1,
                                        "model": self.model_cfg.name})
                    if self.preempt and self.preempt.requested:
                        print(f"preemption requested; checkpointed at {i+1}")
                        break
        finally:
            prefetch.close()
            if checkpointer:
                checkpointer.wait()
            if self.preempt:
                self.preempt.restore()
            obs.dump(cfg.metrics_path, cfg.trace_path, tag="trainer")
        return params, opt_state, history
