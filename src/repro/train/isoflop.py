"""IsoFLOP sweep protocol (paper §4 / App. A) over the resumable loop.

The paper's headline claim is FLOP-matched: at a fixed forward-pass budget
(the dense baseline's), a hybrid that trades its dense heads for
``hybrid_mosa_heads(sparsity)`` MoSA heads reaches up to 27% lower
perplexity.  ``repro.core.flops`` already reproduces the published budget
tables (Table 4) and head counts (Table 5) exactly; this module turns those
numbers into RUNNABLE configs and drives ``repro.train.loop.Trainer`` over
them:

  * ``isoflop_sweep``  — the (variant, sparsity) grid at one model size /
    budget, every point carrying its analytic per-token forward FLOPs so the
    match is auditable (dense vs MoSA within the one-head rounding of the
    solver);
  * ``run_isoflop``    — trains each point through the resumable loop (own
    checkpoint dir per point: a preempted sweep resumes mid-point) and
    reports final loss/ppl + the FLOP accounting (per-token forward, 3x for
    the train step, totals for the run).

Smoke-scale protocol note: at ``preset="smoke"`` the configs shrink (2
layers, tiny vocab) but the head counts still come from the Table-5 solver
at the sweep's sequence length, so dense-vs-MoSA stays attention-budget-
matched — what the parity test asserts.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Sequence

from repro.configs.base import ModelConfig, get_config
from repro.core.flops import (PAPER_MODELS, flops_dense_head, flops_ffn,
                              flops_fixed_head, flops_mosa_head,
                              flops_routing_head)


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    name: str
    variant: str                 # dense | mosa | fixed | routing | pure
    sparsity: int                # 1 for dense
    cfg: ModelConfig
    flops_fwd_per_token: int     # analytic forward FLOPs / token (App. A)


def analytic_flops_per_token(cfg: ModelConfig, T: int) -> int:
    """Per-token forward FLOPs of one config under the paper's App. A
    accounting (attention + FFN; embeddings excluded like the paper).

    ``k`` is the selection width the model ACTUALLY runs —
    ``MoSAAttention.k_for`` with its ``min_k`` floor and T-clamps — not the
    bare ``T // sparsity`` of the solver: at small T / high sparsity the
    floor dominates (k_for(48) = min_k = 2 while T//32 = 1) and an audit
    counting the solver's k would certify unmatched budgets as matched.
    """
    h, hp = cfg.d_model, cfg.attention.d_head
    per_layer = flops_ffn(T, h, cfg.d_ff)
    if cfg.mosa is None:
        per_layer += cfg.attention.n_heads * flops_dense_head(T, h, hp)
    else:
        m = cfg.mosa
        if m.k_fixed > 0:                          # MoSAAttention.k_for
            k = min(m.k_fixed, T)
        else:
            k = max(min(T // m.sparsity, T), min(m.min_k, T))
        per_layer += m.n_dense_heads * flops_dense_head(T, h, m.d_head)
        head_fn = {"mosa": flops_mosa_head, "fixed": flops_fixed_head,
                   "routing": flops_routing_head}[cfg.sparse_variant]
        per_layer += m.n_mosa_heads * head_fn(T, k, h, m.d_head)
    return cfg.n_layers * per_layer // T


def isoflop_sweep(size: str = "tiny", sparsities: Sequence[int] = (8, 32),
                  T: int = 1024, preset: str = "full",
                  variants: Sequence[str] = ("dense", "mosa"),
                  **arch_kw) -> list[SweepPoint]:
    """The FLOP-matched grid at one budget: the dense baseline plus one
    point per (variant, sparsity), head counts from the Table-5 solver."""
    points = []
    for variant in variants:
        for sp in ((1,) if variant == "dense" else tuple(sparsities)):
            kw = dict(size=size, variant=variant, seq_len=T, **arch_kw)
            if variant != "dense":
                kw["sparsity"] = sp
            cfg = get_config("mosa-paper", preset=preset, **kw)
            points.append(SweepPoint(
                name=cfg.name, variant=variant, sparsity=sp, cfg=cfg,
                flops_fwd_per_token=analytic_flops_per_token(cfg, T)))
            if variant == "dense":
                break
    return points


def budget_match_error(points: Sequence[SweepPoint]) -> float:
    """Max relative deviation of any point's budget from the dense
    baseline's (the solver floors head counts, so MoSA points sit AT or just
    UNDER the budget)."""
    dense = [p for p in points if p.variant == "dense"]
    assert dense, "sweep has no dense baseline"
    ref = dense[0].flops_fwd_per_token
    return max(abs(p.flops_fwd_per_token - ref) / ref for p in points)


def run_isoflop(points: Sequence[SweepPoint], steps: int, seq_len: int,
                global_batch: int, ckpt_root: Optional[str] = None,
                train_kw: Optional[dict] = None) -> dict:
    """Train every sweep point through the resumable loop.

    Each point checkpoints under ``<ckpt_root>/<point.name>`` — rerunning
    the same sweep after a kill resumes each point from its last boundary
    (``Trainer.restore_or_init``).  Returns {point name: {final metrics,
    FLOP accounting, loss curve}}.
    """
    from repro.train.loop import TrainConfig, Trainer

    results = {}
    for pt in points:
        cfg = TrainConfig(
            seq_len=seq_len, global_batch=global_batch, steps=steps,
            ckpt_dir=(os.path.join(ckpt_root, pt.name)
                      if ckpt_root else None),
            **(train_kw or {}))
        trainer = Trainer(cfg, model_cfg=pt.cfg)
        _, _, history = trainer.run(install_signals=False)
        final = history[-1] if history else {}
        tokens = steps * global_batch * seq_len
        results[pt.name] = {
            "variant": pt.variant,
            "sparsity": pt.sparsity,
            "flops_fwd_per_token": pt.flops_fwd_per_token,
            # fwd + bwd ~ 3x fwd (the standard train-step accounting)
            "flops_train_per_token": 3 * pt.flops_fwd_per_token,
            "flops_total": 3 * pt.flops_fwd_per_token * tokens,
            "tokens": tokens,
            "final": {k: final.get(k) for k in
                      ("step", "loss", "ppl", "ce") if k in final},
            "loss_curve": [{"step": h["step"], "loss": h["loss"]}
                           for h in history],
        }
    return results
