"""The train step: grad accumulation, mixed precision, remat knobs.

``make_train_step(model, optimizer, microbatches=m)`` builds the function the
loop jits with donated state — signature ``(params, opt_state, step, batch)
-> (params, opt_state, step+1, metrics)`` so the caller can donate the first
two arguments and keep one copy of the state resident.

Microbatch gradient accumulation
    The global batch is split on dim 0 into ``m`` equal microbatches and
    ``value_and_grad`` runs under ``lax.scan`` — ONE compiled loss/backward
    body regardless of ``m``, with fp32 gradient accumulators.  Because every
    microbatch carries the same token count (the packed LM pipeline pads
    nothing), mean-of-means equals the full-batch mean and the accumulated
    step is numerically the large-batch step (asserted in
    tests/test_train_subsystem.py).

Mixed precision (bf16 compute / fp32 master)
    ``mixed_precision(cfg)`` keeps ``param_dtype`` fp32 — the parameters ARE
    the master weights — and sets ``compute_dtype`` bf16: every layer already
    casts parameters at use (``params["wq"].astype(cd)``), so activations,
    attention, and the MoSA kernels run bf16 while gradients and the AdamW
    moments (fp32 by construction, see ``repro.optim.optimizer``) stay fp32.
    bf16 shares fp32's exponent range, so no loss scaling is needed.

Remat
    The policy lives on ``ModelConfig.remat`` (``repro.nn.transformer``
    applies it per block / super-block): ``none`` | ``full`` |
    ``dots_saveable`` | ``mosa``.  The ``mosa`` policy is this subsystem's
    contribution: checkpoint AROUND the sparse gather — the gathered (B,H,k,h)
    activations and selected router scores are saved (they are the
    memory-traffic-bound part of the layer), while projections, the kxk
    attention, and the FFN recompute in the backward pass.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def mixed_precision(model_cfg, compute: str = "bfloat16"):
    """bf16-compute / fp32-master variant of ``model_cfg`` (see module
    docstring)."""
    return dataclasses.replace(model_cfg, compute_dtype=compute,
                               param_dtype="float32")


def with_remat(model_cfg, policy: str):
    """Set the remat policy knob (none | full | dots_saveable | mosa)."""
    return dataclasses.replace(model_cfg, remat=policy)


def microbatch_split(batch, microbatches: int):
    """(B, ...) leaves -> (m, B/m, ...); validates divisibility."""
    def one(x):
        B = x.shape[0]
        assert B % microbatches == 0, (
            f"global batch {B} not divisible by microbatches {microbatches}")
        return x.reshape(microbatches, B // microbatches, *x.shape[1:])
    return jax.tree.map(one, batch)


def make_train_step(model, optimizer, *, microbatches: int = 1,
                    health: bool = False):
    """Build ``(params, opt_state, step, batch) -> (params, opt_state,
    step+1, metrics)``.  ``microbatches > 1`` accumulates gradients over
    equal splits of the batch inside one compiled step.

    ``health=True``: the loss runs ``with_health`` and the router-health
    stats ride the step's metrics as extra aux outputs — fetched by the
    caller's existing post-step host sync, never a second forward or an
    extra device round-trip (DESIGN §11 device-metrics pattern)."""
    from repro.optim.optimizer import apply_updates

    def loss_fn(params, batch):
        return model.loss(params, batch, with_health=health)

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, loss, metrics

        mb = microbatch_split(batch, microbatches)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        # Accumulator structure follows whatever metrics the loss returns
        # (ce/aux/ppl/tokens, plus router health under ``health``) — shapes
        # come from eval_shape so new metric keys never touch this code.
        mb1 = jax.tree.map(lambda v: v[0], mb)
        m_shapes = jax.eval_shape(lambda p, b: loss_fn(p, b)[1], params, mb1)
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shapes)

        def body(carry, mbatch):
            g_acc, l_acc, m_acc = carry
            (l, met), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            m_acc = jax.tree.map(jnp.add, m_acc, met)
            return (g_acc, l_acc + l, m_acc), None

        (g_acc, l_acc, m_acc), _ = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32), m0), mb)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g, p: (g * inv).astype(p.dtype),
                             g_acc, params)
        # Means over microbatches — except tokens (a count, summed) and ppl
        # (recomputed from the mean ce: exp of mean, not mean of exp).
        metrics = {k: (v if k == "tokens" else v * inv)
                   for k, v in m_acc.items()}
        metrics["ppl"] = jnp.exp(metrics["ce"])
        return grads, l_acc * inv, metrics

    def train_step(params, opt_state, step, batch):
        grads, loss, metrics = grads_of(params, batch)
        updates, opt_state, opt_m = optimizer.update(grads, opt_state,
                                                     params, step)
        params = apply_updates(params, updates)
        metrics = {**metrics, **opt_m, "loss": loss}
        return params, opt_state, step + 1, metrics

    return train_step
