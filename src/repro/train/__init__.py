"""Training subsystem (DESIGN §8) — the training analogue of ``repro.serve``.

  * ``step``    — donated-state train step: microbatch gradient accumulation,
                  bf16-compute / fp32-master mixed precision, remat knobs
                  (including the MoSA-specific checkpoint-around-the-gather
                  policy);
  * ``loop``    — the resumable driver: checkpoint/restore, preemption
                  (SIGTERM -> checkpoint at the step boundary), heartbeats,
                  straggler detection, per-step router health telemetry;
  * ``isoflop`` — FLOP-matched config generation from ``repro.core.flops``
                  (the paper's IsoFLOP protocol) and a sweep runner over the
                  resumable loop.

Layering: ``repro.launch.train`` is a thin CLI over this package; the only
launch-side import here is the layering-neutral mesh helper
(``repro.launch.mesh``), never the serving stack.  Exports resolve lazily
(PEP 562, the ``repro.serve`` pattern) so importing one leaf never drags in
the rest.
"""

_EXPORTS = {
    "make_train_step": "step",
    "mixed_precision": "step",
    "microbatch_split": "step",
    "TrainConfig": "loop",
    "Trainer": "loop",
    "SweepPoint": "isoflop",
    "analytic_flops_per_token": "isoflop",
    "isoflop_sweep": "isoflop",
    "run_isoflop": "isoflop",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(f"repro.train.{_EXPORTS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.train' has no attribute {name!r}")
