"""Mixture of Sparse Attention — the paper's core layer.

Per head: router scores r = sigmoid(X W^r); expert-choice top-k token
selection; Q/K/V/O computed *only* for the selected tokens; attention over the
k x k submatrix with the index-derived causal mask (I_i >= I_j) and RoPE at
the original positions; outputs scaled by the router score (this carries the
router's gradient) and scatter-added back to the full sequence.

Complexity per head: O(k^2 + T) versus O(T^2) dense.

Implementation notes (TPU adaptation — see DESIGN.md §3):
  * all shapes static (expert-choice: exactly k per head);
  * indices sorted ascending → the mask is effectively lower-triangular and
    the scatter-add back to the sequence touches memory in order;
  * heads are batched into single einsums over an explicit head axis, which
    shards over the `model` mesh axis (head-parallel TP);
  * the inner attention can run through the Pallas kernel (`impl="pallas"`)
    or the fused-XLA reference path (`impl="einsum"`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import ad_checkpoint

from repro.configs.base import MoSAConfig
from repro.core import rope as rope_lib
from repro.dist import hints
from repro.core.kv_cache import MoSABlockKVCache, MoSAKVCache
from repro.core.router import (ExpertChoiceRouter, block_pool_scores,
                               expand_block_index, select_topk, selection_mask,
                               streaming_topk_update)
from repro.nn.layers import _trunc_normal
from repro.nn.module import logical

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MoSAAttention:
    d_model: int
    cfg: MoSAConfig
    rope_theta: float = 10000.0
    rotary_frac: float = 0.5        # paper rotates half the dims
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    impl: str = "einsum"            # einsum | pallas

    @property
    def router(self):
        return ExpertChoiceRouter(self.d_model, self.cfg.n_mosa_heads,
                                  self.param_dtype)

    def init(self, key):
        c = self.cfg
        kr, kq, kk, kv, ko = jax.random.split(key, 5)
        H, h, d = c.n_mosa_heads, self.d_model, c.d_head
        std = h ** -0.5
        return {
            "router": self.router.init(kr),
            "wq": _trunc_normal(kq, (H, h, d), std, self.param_dtype),
            "wk": _trunc_normal(kk, (H, h, d), std, self.param_dtype),
            "wv": _trunc_normal(kv, (H, h, d), std, self.param_dtype),
            "wo": _trunc_normal(ko, (H, d, h), d ** -0.5, self.param_dtype),
        }

    def specs(self):
        return {
            "router": self.router.specs(),
            "wq": logical("mosa_heads", "embed", None),
            "wk": logical("mosa_heads", "embed", None),
            "wv": logical("mosa_heads", "embed", None),
            "wo": logical("mosa_heads", None, "embed"),
        }

    def k_for(self, T: int) -> int:
        """Paper §3.5: k = max(floor(T / rho), min_k), capped at T.
        With k_fixed > 0 (paper §3.4 long-sequence mode): constant k."""
        if self.cfg.k_fixed > 0:
            return min(self.cfg.k_fixed, T)
        return max(min(T // self.cfg.sparsity, T), min(self.cfg.min_k, T))

    def kb_for(self, T: int) -> int:
        """Block-choice selection width: ``ceil(k_for(T) / sel_block_size)``
        blocks, capped at the number of blocks in the sequence.  At
        ``sel_block_size=1`` this is exactly ``k_for(T)`` — the token-choice
        equivalence (DESIGN §10)."""
        bs = self.cfg.sel_block_size
        return min(-(-self.k_for(T) // bs), -(-T // bs))

    # ------------------------------------------------------------------ train
    def __call__(self, params, x, positions=None, valid=None, segments=None):
        """x: (B, T, h) -> (B, T, h).  Full MoSA layer (all heads).

        ``valid``: optional (B, T) bool marking right-pad tokens False
        (bucketed serving prefill, DESIGN §7).  Unlike causal dense
        attention, expert-choice selection is NOT causal — an attended pad
        would steal top-k slots from real tokens — so invalid tokens' router
        scores are masked below the sigmoid range (to -1.0, finite so no
        NaN can leak through the 0 * -inf corner), which keeps them out of
        every head's selection whenever k real candidates exist; selected
        overflow slots (k > real tokens) are scaled to zero contribution.

        ``segments``: optional (B, T) int32 document ids for PACKED training
        rows (data/pipeline.py packs multiple docs back to back).  The k x k
        attention additionally requires seg_q == seg_k, so no probability
        mass ever crosses a document boundary; expert-choice selection stays
        row-global (static k per head — the expert-choice budget is a row
        property, exactly like the non-causality of selection itself, see
        DESIGN §9).  Pass per-doc ``positions`` alongside so RoPE restarts
        at every boundary.  ``segments=None`` is bit-for-bit the old path.
        """
        if self.cfg.selection_granularity == "block":
            return self._call_block(params, x, positions, valid, segments)
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        k = self.k_for(T)

        scores = self.router.scores(params["router"], x)          # (B,H,T) fp32
        if valid is not None:
            scores = jnp.where(valid[:, None, :], scores, -1.0)
        r, idx = select_topk(scores, k, c.force_first_token)      # (B,H,k)
        if valid is not None:
            r = jnp.where(r > 0.0, r, 0.0)  # overflow pads: zero output

        if positions is None:
            pos_sel = idx
        else:
            base = positions if positions.ndim == 2 else positions[0]
            pos_sel = jnp.take_along_axis(base[:, None], idx, axis=-1)

        # Gather selected tokens: (B, H, k, h).  vmap over the batch keeps B
        # a scatter/gather *batching* dim for GSPMD — explicit batch indices
        # made it replicate B and all-reduce 16 GiB buffers per layer
        # (§Perf cell-2 it.8).
        xs = jax.vmap(lambda xb, ib: xb[ib])(x.astype(cd), idx)
        # checkpoint_name: under remat="mosa" (train/step.py) the gathered
        # activations and the selection are SAVED while projections and the
        # kxk attention recompute — the gather/scatter pair is the one part
        # of this layer whose recompute is memory-bound, not FLOP-bound.
        xs = ad_checkpoint.checkpoint_name(xs, "mosa_gather")
        r = ad_checkpoint.checkpoint_name(r, "mosa_router")

        q = jnp.einsum("bnkh,nhd->bnkd", xs, params["wq"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        kk = jnp.einsum("bnkh,nhd->bnkd", xs, params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bnkh,nhd->bnkd", xs, params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        q = rope_lib.apply_rope(q, pos_sel, self.rope_theta, self.rotary_frac)
        kk = rope_lib.apply_rope(kk, pos_sel, self.rope_theta, self.rotary_frac)

        seg_sel = None
        if segments is not None:
            seg_sel = jax.vmap(lambda sb, ib: sb[ib])(
                segments.astype(jnp.int32), idx)                  # (B,H,k)

        if self.impl == "pallas":
            from repro.kernels import ops as kops
            att = kops.mosa_attention(q, kk, v, idx, r.astype(jnp.float32),
                                      seg=seg_sel)
        else:
            att = self._einsum_attention(q, kk, v, idx, r, seg=seg_sel)

        # Per-head output projection, then scatter-add to original positions
        # (vmap'd over batch — see gather note above).
        y_heads = jnp.einsum("bnkd,ndh->bnkh", att.astype(cd),
                             params["wo"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)

        def scatter_one(yh, ib):
            return jnp.zeros((T, h), cd).at[ib.reshape(-1)].add(
                yh.reshape(-1, h))

        y = jax.vmap(scatter_one)(y_heads, idx)
        # partial head-contributions combine into the seq-sharded residual:
        # constraining here lets GSPMD emit a reduce-scatter, not all-reduce
        y = hints.constrain(y, ("dp", "tp", None))
        return y

    def _einsum_attention(self, q, k, v, idx, r, seg=None):
        """Reference attention over selected tokens.  All inputs (B,H,k,*).
        ``seg``: optional (B,H,k) segment ids of the selected tokens — packed
        rows additionally mask cross-segment pairs."""
        scale = self.cfg.d_head ** -0.5
        s = jnp.einsum("bnqd,bnkd->bnqk", q, k,
                       preferred_element_type=jnp.float32) * scale
        mask = selection_mask(idx, idx)                            # (B,H,k,k)
        if seg is not None:
            mask &= seg[..., :, None] == seg[..., None, :]
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnqk,bnkd->bnqd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        # Router scaling — the router's gradient path.
        return att * r[..., None]

    # ----------------------------------------------------- block-choice train
    def _call_block(self, params, x, positions=None, valid=None,
                    segments=None):
        """Block-choice forward (DESIGN §10): expert-choice top-k over KV
        BLOCKS of ``sel_block_size`` tokens.  A block's router score is the
        mean of its token scores (``block_pool_scores``); the selected
        blocks' tokens are gathered as contiguous runs (the paged-allocator
        memory motion) and attend under the position-causal mask; outputs
        are scaled by the BLOCK score (the router's gradient path, summed
        over the block by the VJP).

        At ``sel_block_size=1`` every step below is the bitwise identity
        with ``__call__``'s token path — the maintained invariant
        ``tests/test_block_choice.py`` locks down.  ``force_first_token``
        generalizes to forcing block 0 (which contains token 0)."""
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        bs = c.sel_block_size
        kb = self.kb_for(T)

        scores = self.router.scores(params["router"], x)          # (B,H,T)
        if valid is not None:
            scores = jnp.where(valid[:, None, :], scores, -1.0)
        bsc = block_pool_scores(scores, bs)                       # (B,H,NBt)
        rblk, bidx = select_topk(bsc, kb, c.force_first_token)    # (B,H,kb)
        if valid is not None:
            rblk = jnp.where(rblk > 0.0, rblk, 0.0)  # all-pad blocks: zero

        pos = expand_block_index(bidx, bs, T)         # (B,H,kb*bs); -1 = pad
        posc = jnp.clip(pos, 0, T - 1)
        if positions is None:
            pos_rope = posc
        else:
            base = positions if positions.ndim == 2 else positions[0]
            pos_rope = jnp.take_along_axis(base[:, None], posc, axis=-1)

        xs = jax.vmap(lambda xb, ib: xb[ib])(x.astype(cd), posc)
        xs = ad_checkpoint.checkpoint_name(xs, "mosa_gather")
        rblk = ad_checkpoint.checkpoint_name(rblk, "mosa_router")

        q = jnp.einsum("bnkh,nhd->bnkd", xs, params["wq"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        kk = jnp.einsum("bnkh,nhd->bnkd", xs, params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bnkh,nhd->bnkd", xs, params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        q = rope_lib.apply_rope(q, pos_rope, self.rope_theta, self.rotary_frac)
        kk = rope_lib.apply_rope(kk, pos_rope, self.rope_theta,
                                 self.rotary_frac)

        seg_sel = None
        if segments is not None:
            seg_sel = jax.vmap(lambda sb, ib: sb[ib])(
                segments.astype(jnp.int32), posc)                 # (B,H,kb*bs)

        if self.impl == "pallas":
            from repro.kernels import ops as kops
            att = kops.mosa_block_attention(q, kk, v, bidx,
                                            rblk.astype(jnp.float32),
                                            sel_block_size=bs, T=T,
                                            seg=seg_sel)
        else:
            r_tok = jnp.broadcast_to(rblk[..., None],
                                     (B, H, kb, bs)).reshape(B, H, kb * bs)
            att = self._einsum_block_attention(q, kk, v, pos, r_tok,
                                               seg=seg_sel)

        y_heads = jnp.einsum("bnkd,ndh->bnkh", att.astype(cd),
                             params["wo"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)

        tgt = jnp.where(pos >= 0, pos, T)             # T -> dropped

        def scatter_one(yh, tb):
            return jnp.zeros((T, h), cd).at[tb.reshape(-1)].add(
                yh.reshape(-1, h), mode="drop")

        y = jax.vmap(scatter_one)(y_heads, tgt)
        y = hints.constrain(y, ("dp", "tp", None))
        return y

    def _einsum_block_attention(self, q, k, v, pos, r_tok, seg=None):
        """Reference attention over block-expanded tokens.  ``pos``: (B,H,S)
        expanded token positions (-1 = empty/ragged-tail row); ``r_tok``:
        (B,H,S) per-token copy of the BLOCK score.  Mirrors
        ``_einsum_attention`` exactly (same softmax form), plus the
        invalid-key mask and invalid-row zeroing the -1 sentinel needs —
        both bitwise no-ops at ``sel_block_size=1``."""
        scale = self.cfg.d_head ** -0.5
        ok = pos >= 0
        s = jnp.einsum("bnqd,bnkd->bnqk", q, k,
                       preferred_element_type=jnp.float32) * scale
        mask = selection_mask(pos, pos) & ok[..., None, :]
        if seg is not None:
            mask &= seg[..., :, None] == seg[..., None, :]
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnqk,bnkd->bnqd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return att * r_tok[..., None] * ok[..., None]

    def routing_stats(self, params, x):
        """Diagnostics: score stats + head-overlap (for logging)."""
        B, T, _ = x.shape
        k = self.k_for(T)
        scores = self.router.scores(params["router"], x)
        r, idx = select_topk(scores, k, self.cfg.force_first_token)
        sel = jax.nn.one_hot(idx, T, dtype=jnp.float32).sum(2)      # (B,H,T)
        coverage = (sel.sum(1) > 0).mean()       # fraction of tokens any head picks
        load = sel.sum(1).mean() / k             # avg #heads per token / k
        return {"score_mean": scores.mean(), "score_std": scores.std(),
                "coverage": coverage, "load": load}

    def router_health(self, params, x):
        """Per-step router health for the train loop (see
        ``repro.core.router.router_health_stats``): selection entropy,
        token-drop rate, head utilization.

        Granularity-aware: block-choice layers (DESIGN §10) are scored in
        BLOCK space — the units the router actually ranks — so drop_rate is
        the fraction of pooled blocks no head selects and entropy is
        normalized by ``log NB``; token-space stats would report a spurious
        ``1 - 1/bs`` floor of "dropped" tokens inside selected blocks."""
        from repro.core.router import (block_pool_scores,
                                       router_health_stats)
        B, T, _ = x.shape
        scores = self.router.scores(params["router"], x)
        if self.cfg.selection_granularity == "block":
            bs = self.cfg.sel_block_size
            bsc = block_pool_scores(scores, bs)
            r, bidx = select_topk(bsc, self.kb_for(T),
                                  self.cfg.force_first_token)
            return router_health_stats(r, bidx, bsc.shape[-1])
        r, idx = select_topk(scores, self.k_for(T),
                             self.cfg.force_first_token)
        return router_health_stats(r, idx, T)

    # ---------------------------------------------------------------- serving
    def prefill(self, params, x, cache: MoSAKVCache, positions=None,
                valid=None):
        """Run the prompt through training-style selection and fill the cache
        with each head's top candidates (the prompt is fully known, so
        non-autoregressive selection is exact here).

        The cache is filled WIDE — ``min(capacity, T)`` candidates, not just
        the ``k_for(T)`` the output uses.  Width costs nothing (the slots
        exist either way) and is what makes chunked / continued prefill
        (``prefill_past``) EXACT under the growing ``k = T/rho`` schedule: a
        token in the final top-``k_for(T_total)`` has prefix rank at most
        ``k_for(T_total) <= capacity``, so a capacity-wide boundary never
        drops it (DESIGN §9).  Under a constant-k schedule capacity equals
        ``k_fixed`` and nothing changes.  The layer OUTPUT ``y`` still uses
        exactly the training-time ``k_for(T)`` selection.

        ``valid`` (B, T) bool masks right-pad tokens out of the selection
        (scores to -1.0, see ``__call__``); slots that still land on a pad
        (k exceeds the real token count) are stored as the empty-slot
        sentinels (``scores=-inf``, ``idx=-1``) — right-pads have the
        LARGEST indices, so after the ascending-idx sort they fall exactly
        where the empty-slots-last invariant wants them."""
        if self.cfg.selection_granularity == "block":
            return self._prefill_block(params, x, cache, positions, valid)
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        k_cache = cache.k.shape[2]
        k = min(k_cache, T)

        y = self(params, x, positions, valid)

        scores = self.router.scores(params["router"], x)
        if valid is not None:
            scores = jnp.where(valid[:, None, :], scores, -1.0)
        r, idx = select_topk(scores, k, c.force_first_token)
        xs = jax.vmap(lambda xb, ib: xb[ib])(x.astype(cd), idx)
        kk = jnp.einsum("bnkh,nhd->bnkd", xs, params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        kk = rope_lib.apply_rope(kk, idx, self.rope_theta, self.rotary_frac)
        v = jnp.einsum("bnkh,nhd->bnkd", xs, params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        if valid is not None:
            sel_ok = r > 0.0
            r = jnp.where(sel_ok, r, -jnp.inf)
            idx = jnp.where(sel_ok, idx, -1)
        pad = k_cache - k
        if pad:
            kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            r = jnp.pad(r, ((0, 0), (0, 0), (0, pad)), constant_values=-jnp.inf)
            idx = jnp.pad(idx, ((0, 0), (0, 0), (0, pad)), constant_values=-1)
        nv = (jnp.full((B,), T, jnp.int32) if valid is None
              else valid.sum(-1).astype(jnp.int32))
        cache = MoSAKVCache(kk, v, r.astype(jnp.float32), idx,
                            cache.length + nv)
        return y, cache

    def _prefill_block(self, params, x, cache: MoSABlockKVCache,
                       positions=None, valid=None):
        """Block-choice prefill: training-style block selection fills the
        candidate set with the top ``CB`` COMPLETED blocks (their mean
        scores are final, so the stored state is exactly what streaming
        decode over the same prompt would converge to); the trailing
        partial block rides in the dedicated current slot with its running
        score sum (DESIGN §10).  Storage is capacity-wide for the same
        exactness-at-boundaries argument as the token path (``prefill``).
        """
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        bs = cache.block_size
        CB = cache.n_cand
        nbt = -(-T // bs)
        kcb = min(CB, nbt)
        INT_MAX = jnp.iinfo(jnp.int32).max

        y = self(params, x, positions, valid)

        scores = self.router.scores(params["router"], x)
        if valid is not None:
            scores = jnp.where(valid[:, None, :], scores, -1.0)
        nv = (jnp.full((B,), T, jnp.int32) if valid is None
              else valid.sum(-1).astype(jnp.int32))
        cbf = nv // bs                                    # completed blocks

        bsc = block_pool_scores(scores, bs)               # (B,H,NBt)
        done = jnp.arange(nbt)[None, None, :] < cbf[:, None, None]
        r, bidx = select_topk(jnp.where(done, bsc, -jnp.inf), kcb,
                              c.force_first_token)
        sel_ok = r > 0.0          # non-completed / forced-but-absent drop out
        r_st = jnp.where(sel_ok, r, -jnp.inf)
        b_st = jnp.where(sel_ok, bidx, -1)
        order = jnp.argsort(jnp.where(b_st < 0, INT_MAX, b_st), -1)
        b_st = jnp.take_along_axis(b_st, order, -1)
        r_st = jnp.take_along_axis(r_st, order, -1)
        if CB > kcb:
            pad = CB - kcb
            r_st = jnp.pad(r_st, ((0, 0), (0, 0), (0, pad)),
                           constant_values=-jnp.inf)
            b_st = jnp.pad(b_st, ((0, 0), (0, 0), (0, pad)),
                           constant_values=-1)

        # Whole-prompt K/V, roped at original positions (cf. ``prefill``).
        idx_all = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32),
                                   (B, H, T))
        k_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wk"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        v_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wv"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        k_all = rope_lib.apply_rope(k_all, idx_all, self.rope_theta,
                                    self.rotary_frac)

        off = jnp.arange(bs, dtype=jnp.int32)
        # candidate rows: contiguous runs of the selected completed blocks
        rows_pos = (b_st[..., None] * bs + off).reshape(B, H, CB * bs)
        row_ok = jnp.broadcast_to((b_st >= 0)[..., None],
                                  (B, H, CB, bs)).reshape(B, H, CB * bs)
        rowsc = jnp.clip(rows_pos, 0, T - 1)
        k_rows = jnp.take_along_axis(k_all, rowsc[..., None], axis=2)
        v_rows = jnp.take_along_axis(v_all, rowsc[..., None], axis=2)
        pos_rows = jnp.where(row_ok, rows_pos, -1)

        # current (partial) block: tokens [cbf*bs, nv)
        cur_pos = cbf[:, None] * bs + off                 # (B, bs)
        cur_ok = cur_pos < nv[:, None]
        cur_posb = jnp.broadcast_to(cur_pos[:, None], (B, H, bs))
        cur_posc = jnp.clip(cur_posb, 0, T - 1)
        cur_k = jnp.take_along_axis(k_all, cur_posc[..., None], axis=2)
        cur_v = jnp.take_along_axis(v_all, cur_posc[..., None], axis=2)
        cur_pos_st = jnp.where(cur_ok[:, None], cur_posb, -1)
        has_cur = (nv % bs) > 0                           # (B,)
        bidx_cur = jnp.broadcast_to(
            jnp.where(has_cur, cbf, -1)[:, None, None], (B, H, 1))
        t_ar = jnp.arange(T, dtype=jnp.int32)
        in_cur = ((t_ar[None] >= cbf[:, None] * bs) &
                  (t_ar[None] < nv[:, None]))             # (B, T)
        bsum = jnp.sum(jnp.where(in_cur[:, None], scores, 0.0), axis=-1)

        new = MoSABlockKVCache(
            jnp.concatenate([k_rows, cur_k], 2).astype(cache.k.dtype),
            jnp.concatenate([v_rows, cur_v], 2).astype(cache.v.dtype),
            jnp.concatenate([pos_rows, cur_pos_st], 2),
            jnp.concatenate([r_st.astype(jnp.float32),
                             jnp.full((B, H, 1), -jnp.inf, jnp.float32)], -1),
            jnp.concatenate([b_st, bidx_cur], -1),
            bsum.astype(jnp.float32),
            cache.length + nv)
        return y, new

    def prefill_past(self, params, x, cache: MoSAKVCache, positions=None,
                     valid=None):
        """Continued prefill: extend a restored prefix cache with a prompt
        suffix, reproducing training-style selection over the full prompt
        (DESIGN §7).

        Why this is EXACT — for every chunk split, every schedule: a token
        in the one-shot top-``k_for(T_total)`` has, within any prefix, rank
        at most ``k_for(T_total) <= capacity``; since ``prefill`` and this
        method both store the CAPACITY-wide top of their candidate union at
        every boundary, such a token is never dropped at a boundary, so the
        union of {cached entries} and {suffix tokens} is always a superset
        of the true selection.  (Scores, original-position RoPE, and K/V of
        cached entries are identical to what one-shot prefill computes, and
        the ascending-idx slot order makes top-k tie-breaking match too.)
        This covers the constant-k schedule (``k_fixed``, paper §3.4) AND
        the growing ``k = T / rho`` schedule — the former stored-width
        clamp to the chunk-local ``k_eff`` was the growing-k
        under-selection bug (DESIGN §9).  The output selection width
        matches one-shot prefill: ``min(k_for(L0 + T_valid), capacity)``,
        computed on traced lengths by rank-masking the union top-k (which
        ``lax.top_k`` already orders by score) in the suffix-output
        attention only.  Suffix-token outputs attend the final selection
        under the usual index-causal mask — identical math to ``__call__``
        restricted to suffix queries.  (The forced first token rides
        along: its cache entry gets a selection boost, its stored score
        stays real.)
        """
        if self.cfg.selection_granularity == "block":
            return self._prefill_past_block(params, x, cache, positions,
                                            valid)
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        kc = cache.k.shape[2]
        L0 = cache.length                                       # (B,)
        nv = (jnp.full((B,), T, jnp.int32) if valid is None
              else valid.sum(-1).astype(jnp.int32))

        if positions is None:
            base_pos = L0[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        else:
            base_pos = positions if positions.ndim == 2 else positions[0]
        idx_new = jnp.broadcast_to(base_pos[:, None], (B, H, T))

        scores_new = self.router.scores(params["router"], x)    # (B,H,T)
        if valid is not None:
            scores_new = jnp.where(valid[:, None, :], scores_new, -1.0)

        q_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wq"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        k_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wk"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        v_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wv"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        q_all = rope_lib.apply_rope(q_all, idx_new, self.rope_theta,
                                    self.rotary_frac)
        k_all = rope_lib.apply_rope(k_all, idx_new, self.rope_theta,
                                    self.rotary_frac)

        # Union candidates: cached prefix top-k (already roped at original
        # positions) + every suffix token.  Disjoint by construction
        # (cached idx < L0 <= suffix idx).
        scores_cat = jnp.concatenate([cache.scores, scores_new], axis=-1)
        idx_cat = jnp.concatenate([cache.idx, idx_new], axis=-1)
        k_cat = jnp.concatenate([cache.k.astype(cd), k_all], axis=2)
        v_cat = jnp.concatenate([cache.v.astype(cd), v_all], axis=2)

        sel_scores = scores_cat
        if c.force_first_token:
            sel_scores = jnp.where(idx_cat == 0, 2.0, sel_scores)  # boost
        _, j = jax.lax.top_k(sel_scores, kc)
        r_sel = jnp.take_along_axis(scores_cat, j, axis=-1)
        idx_sel = jnp.take_along_axis(idx_cat, j, axis=-1)
        k_sel = jnp.take_along_axis(k_cat, j[..., None], axis=2)
        v_sel = jnp.take_along_axis(v_cat, j[..., None], axis=2)

        sel_ok = r_sel > 0.0          # -inf empties / -1.0 pads drop out
        # One-shot selection width on traced lengths: top_k ordered the
        # union by (boosted) score, so rank == position.  The rank mask
        # gates ONLY the suffix-output attention (y must reproduce the
        # one-shot k_for(total) selection); STORAGE keeps the full
        # capacity-wide union — clobbering stored entries down to k_eff is
        # exactly the growing-k under-selection bug: a later chunk's larger
        # k_for(total') could legally re-admit a prefix token this chunk's
        # k_eff would have discarded.  Capacity-wide storage at every
        # boundary makes chunked == one-shot EXACT (see ``prefill``).
        total = L0 + nv
        if c.k_fixed > 0:
            k_eff = jnp.minimum(c.k_fixed, total)
        else:
            k_eff = jnp.maximum(jnp.minimum(total // c.sparsity, total),
                                jnp.minimum(c.min_k, total))
        k_eff = jnp.minimum(k_eff, kc)
        rank_ok = sel_ok & (jnp.arange(kc) < k_eff[:, None, None])
        r_st = jnp.where(sel_ok, r_sel, -jnp.inf)
        idx_st = jnp.where(sel_ok, idx_sel, -1)
        order = jnp.argsort(jnp.where(idx_st < 0,
                                      jnp.iinfo(jnp.int32).max, idx_st), -1)
        idx_st = jnp.take_along_axis(idx_st, order, -1)
        r_st = jnp.take_along_axis(r_st, order, -1)
        rank_ok = jnp.take_along_axis(rank_ok, order, -1)
        k_sel = jnp.take_along_axis(k_sel, order[..., None], 2)
        v_sel = jnp.take_along_axis(v_sel, order[..., None], 2)

        # Suffix-query outputs over the final selection (index-causal mask,
        # router-score scaling) — __call__ restricted to suffix queries.
        # Queries AND keys are rank-masked to the one-shot width.
        is_suffix = rank_ok & (idx_st >= L0[:, None, None]) & (idx_st >= 0)
        t_j = jnp.clip(idx_st - L0[:, None, None], 0, T - 1)
        q_sel = jnp.take_along_axis(q_all, t_j[..., None], axis=2)
        s = jnp.einsum("bnqd,bnkd->bnqk", q_sel, k_sel,
                       preferred_element_type=jnp.float32) * (d ** -0.5)
        mask = (selection_mask(idx_st, idx_st)
                & (idx_st >= 0)[:, :, None, :] & rank_ok[:, :, None, :])
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnqk,bnkd->bnqd", p.astype(cd), v_sel,
                         preferred_element_type=jnp.float32)
        r_q = jnp.where(is_suffix, jnp.maximum(r_st, 0.0), 0.0)
        att = att * r_q[..., None]
        y_heads = jnp.einsum("bnkd,ndh->bnkh", att.astype(cd),
                             params["wo"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)

        tgt = jnp.where(is_suffix, t_j, T)          # T -> dropped

        def scatter_one(yh, tb):
            return jnp.zeros((T, h), cd).at[tb.reshape(-1)].add(
                yh.reshape(-1, h), mode="drop")

        y = jax.vmap(scatter_one)(y_heads, tgt)
        y = hints.constrain(y, ("dp", "tp", None))

        cache = MoSAKVCache(k_sel.astype(cache.k.dtype),
                            v_sel.astype(cache.v.dtype),
                            r_st.astype(jnp.float32), idx_st, L0 + nv)
        return y, cache

    def _prefill_past_block(self, params, x, cache: MoSABlockKVCache,
                            positions=None, valid=None):
        """Block-choice continued prefill (DESIGN §10).

        Selection state is block-granular, so at any BLOCK-ALIGNED boundary
        the cache state is exactly what a longer one-shot prefill would
        hold for the same prefix: candidate blocks are completed (their
        mean scores final and immutable — a suffix can never change them)
        and the current slot is empty.  This is what makes paged MoSA
        prefix hits exact — the prefix-cache trie snapshots at block
        multiples (``sel_block_size`` defaults to the paged block size),
        closing the token path's chunk-causal gap.

        Union exactness mirrors the token path: a block in the final
        top-``kb_for(total)`` has prefix rank <= ``kb_for(total) <= CB``,
        so capacity-wide candidate storage at every boundary never drops
        it.  The suffix may straddle the cache's partial current block:
        its running ``bsum`` carries the head of the straddled block, and
        the old current rows are stitched in front of the suffix K/V when
        the block finally completes.

        The suffix-token OUTPUTS reproduce one-shot ``__call__`` over the
        whole prompt restricted to suffix queries: block scores of the
        union pool (old candidates + every suffix-touched block, with the
        trailing partial block at its one-shot partial mean), force boost
        on block 0, rank-masked to the traced one-shot width
        ``kb_for(L0 + nv)``.
        """
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        bs = cache.block_size
        CB = cache.n_cand
        NSB = (T + bs - 1) // bs + 1  # suffix can straddle this many blocks
        INT_MAX = jnp.iinfo(jnp.int32).max
        off = jnp.arange(bs, dtype=jnp.int32)
        L0 = cache.length                                       # (B,)
        nv = (jnp.full((B,), T, jnp.int32) if valid is None
              else valid.sum(-1).astype(jnp.int32))
        total = L0 + nv
        base0 = L0 // bs                                        # (B,)

        if positions is None:
            base_pos = L0[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        else:
            base_pos = positions if positions.ndim == 2 else positions[0]
        idx_new = jnp.broadcast_to(base_pos[:, None], (B, H, T))

        scores_new = self.router.scores(params["router"], x)    # (B,H,T)
        vmask = (jnp.ones((B, T), bool) if valid is None else valid)

        q_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wq"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        k_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wk"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        v_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wv"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        q_all = rope_lib.apply_rope(q_all, idx_new, self.rope_theta,
                                    self.rotary_frac)
        k_all = rope_lib.apply_rope(k_all, idx_new, self.rope_theta,
                                    self.rotary_frac)

        # --- per-relative-block score sums/counts over the suffix tokens
        rel = base_pos // bs - base0[:, None]                   # (B,T)
        oh = (jax.nn.one_hot(rel, NSB, dtype=jnp.float32)
              * vmask[..., None])                               # (B,T,NSB)
        sums = jnp.einsum("bnt,btj->bnj", scores_new, oh)       # (B,H,NSB)
        cnts = oh.sum(1)                                        # (B,NSB)
        carry = (L0 % bs).astype(jnp.float32)                   # (B,)
        is0 = (jnp.arange(NSB) == 0).astype(jnp.float32)        # (NSB,)
        tot_cnt = cnts + is0[None] * carry[:, None]             # (B,NSB)
        tot_sum = sums + is0[None, None] * cache.bsum[..., None]
        blk_end = (base0[:, None] + jnp.arange(NSB) + 1) * bs   # (B,NSB)
        done_new = blk_end <= total[:, None]                    # (B,NSB)
        # one-shot mean: final for completed, partial for the current block
        out_new = jnp.where(tot_cnt[:, None] > 0,
                            tot_sum / jnp.maximum(tot_cnt[:, None], 1.0),
                            -jnp.inf)                           # (B,H,NSB)
        cand_new = jnp.where(done_new[:, None], out_new, -jnp.inf)

        # --- K/V rows of the suffix-touched blocks.  Row (j, o) holds
        # absolute position p = (base0+j)*bs + o: before L0 it comes from
        # the cache's old current slot (the straddled block head), else
        # from the suffix projections.
        p_new = ((base0[:, None, None] + jnp.arange(NSB)[None, :, None]) * bs
                 + off[None, None]).reshape(B, NSB * bs)        # (B,NSB*bs)
        filled = p_new < total[:, None]
        from_old = p_new < L0[:, None]
        t_suf = jnp.clip(p_new - L0[:, None], 0, T - 1)         # (B,NSB*bs)
        old_cur_k = cache.k[:, :, CB * bs:].astype(cd)          # (B,H,bs,d)
        old_cur_v = cache.v[:, :, CB * bs:].astype(cd)
        o_pat = jnp.tile(off, NSB)                              # (NSB*bs,)
        t_sufb = jnp.broadcast_to(t_suf[:, None], (B, H, NSB * bs))
        k_new_rows = jnp.where(
            from_old[:, None, :, None],
            old_cur_k[:, :, o_pat], jnp.take_along_axis(
                k_all, t_sufb[..., None], axis=2))
        v_new_rows = jnp.where(
            from_old[:, None, :, None],
            old_cur_v[:, :, o_pat], jnp.take_along_axis(
                v_all, t_sufb[..., None], axis=2))
        pos_new_rows = jnp.broadcast_to(
            jnp.where(filled, p_new, -1)[:, None], (B, H, NSB * bs))

        # --- union pool: old candidates + suffix-touched blocks (disjoint
        # and ascending in block index by construction)
        P = CB + NSB
        pool_sc = jnp.concatenate([cache.bscore[..., :CB], out_new], -1)
        pool_bi = jnp.concatenate(
            [cache.bidx[..., :CB],
             jnp.broadcast_to((base0[:, None] + jnp.arange(NSB))[:, None],
                              (B, H, NSB)).astype(jnp.int32)], -1)
        pool_k = jnp.concatenate([cache.k[:, :, :CB * bs].astype(cd),
                                  k_new_rows], 2)
        pool_v = jnp.concatenate([cache.v[:, :, :CB * bs].astype(cd),
                                  v_new_rows], 2)
        pool_pos = jnp.concatenate([cache.pos[:, :, :CB * bs],
                                    pos_new_rows], 2)

        # --- candidate STORAGE: capacity-wide top-CB over completed blocks
        stor_sc = jnp.concatenate([cache.bscore[..., :CB], cand_new], -1)
        stor_sel = stor_sc
        if c.force_first_token:
            stor_sel = jnp.where(pool_bi == 0, 2.0, stor_sel)
        _, jst = jax.lax.top_k(stor_sel, CB)
        r_stor = jnp.take_along_axis(stor_sc, jst, -1)
        b_stor = jnp.take_along_axis(pool_bi, jst, -1)
        sel_ok = r_stor > 0.0
        r_stor = jnp.where(sel_ok, r_stor, -jnp.inf)
        b_stor = jnp.where(sel_ok, b_stor, -1)
        order = jnp.argsort(jnp.where(b_stor < 0, INT_MAX, b_stor), -1)
        b_stor = jnp.take_along_axis(b_stor, order, -1)
        r_stor = jnp.take_along_axis(r_stor, order, -1)
        jso = jnp.take_along_axis(jst, order, -1)               # (B,H,CB)
        rows_st = (jso[..., None] * bs + off).reshape(B, H, CB * bs)
        ck = jnp.take_along_axis(pool_k, rows_st[..., None], axis=2)
        cv = jnp.take_along_axis(pool_v, rows_st[..., None], axis=2)
        cp = jnp.take_along_axis(pool_pos, rows_st, -1)
        cp = jnp.where(jnp.broadcast_to((b_stor >= 0)[..., None],
                                        (B, H, CB, bs)).reshape(B, H, CB * bs),
                       cp, -1)

        # --- new current slot: the (possibly still partial) block at total
        cbn = total // bs                                       # (B,)
        jcur = (cbn - base0)[:, None]                           # (B,1)
        rows_cur = jnp.broadcast_to(
            (jcur * bs + off[None])[:, None], (B, H, bs))       # (B,H,bs)
        cur_k = jnp.take_along_axis(k_new_rows, rows_cur[..., None], axis=2)
        cur_v = jnp.take_along_axis(v_new_rows, rows_cur[..., None], axis=2)
        cur_pos = jnp.take_along_axis(pos_new_rows, rows_cur, -1)
        has_cur = (total % bs) > 0                              # (B,)
        bsum_new = jnp.where(
            has_cur[:, None],
            jnp.take_along_axis(
                tot_sum, jnp.broadcast_to(jcur[..., None], (B, H, 1)),
                -1)[..., 0],
            0.0)
        bidx_cur = jnp.broadcast_to(
            jnp.where(has_cur, cbn, -1)[:, None, None], (B, H, 1))

        # --- suffix-query outputs over the rank-masked one-shot selection
        out_sel = pool_sc
        if c.force_first_token:
            out_sel = jnp.where(pool_bi == 0, 2.0, out_sel)
        _, jo = jax.lax.top_k(out_sel, P)                       # full order
        r_o = jnp.take_along_axis(pool_sc, jo, -1)
        b_o = jnp.take_along_axis(pool_bi, jo, -1)
        if c.k_fixed > 0:
            k_eff = jnp.minimum(c.k_fixed, total)
        else:
            k_eff = jnp.maximum(jnp.minimum(total // c.sparsity, total),
                                jnp.minimum(c.min_k, total))
        kb_eff = jnp.minimum((k_eff + bs - 1) // bs, (total + bs - 1) // bs)
        rank_ok = (r_o > 0.0) & (jnp.arange(P) < kb_eff[:, None, None])
        rows_o = (jo[..., None] * bs + off).reshape(B, H, P * bs)
        kk_o = jnp.take_along_axis(pool_k, rows_o[..., None], axis=2)
        vv_o = jnp.take_along_axis(pool_v, rows_o[..., None], axis=2)
        pos_o = jnp.take_along_axis(pool_pos, rows_o, -1)
        ok_row = (jnp.broadcast_to(rank_ok[..., None],
                                   (B, H, P, bs)).reshape(B, H, P * bs)
                  & (pos_o >= 0))
        is_suffix = ok_row & (pos_o >= L0[:, None, None])
        t_j = jnp.clip(pos_o - L0[:, None, None], 0, T - 1)
        q_sel = jnp.take_along_axis(q_all, t_j[..., None], axis=2)
        s = jnp.einsum("bnqd,bnkd->bnqk", q_sel, kk_o,
                       preferred_element_type=jnp.float32) * (d ** -0.5)
        mask = (selection_mask(pos_o, pos_o) & ok_row[:, :, None, :])
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnqk,bnkd->bnqd", p.astype(cd), vv_o,
                         preferred_element_type=jnp.float32)
        r_tok = jnp.broadcast_to(r_o[..., None],
                                 (B, H, P, bs)).reshape(B, H, P * bs)
        r_q = jnp.where(is_suffix, jnp.maximum(r_tok, 0.0), 0.0)
        att = att * r_q[..., None]
        y_heads = jnp.einsum("bnkd,ndh->bnkh", att.astype(cd),
                             params["wo"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)
        tgt = jnp.where(is_suffix, t_j, T)                      # T -> dropped

        def scatter_one(yh, tb):
            return jnp.zeros((T, h), cd).at[tb.reshape(-1)].add(
                yh.reshape(-1, h), mode="drop")

        y = jax.vmap(scatter_one)(y_heads, tgt)
        y = hints.constrain(y, ("dp", "tp", None))

        new = MoSABlockKVCache(
            jnp.concatenate([ck, cur_k], 2).astype(cache.k.dtype),
            jnp.concatenate([cv, cur_v], 2).astype(cache.v.dtype),
            jnp.concatenate([cp, cur_pos], 2),
            jnp.concatenate([r_stor.astype(jnp.float32),
                             jnp.full((B, H, 1), -jnp.inf, jnp.float32)], -1),
            jnp.concatenate([b_stor, bidx_cur], -1),
            bsum_new.astype(jnp.float32),
            total)
        return y, new

    def prefill_packed(self, params, x, cache: MoSAKVCache, meta):
        """Packed multi-segment chunked prefill (DESIGN §9).

        ``x``: (1, C, h) — a flattened chunk of N prompt segments, each
        continuing a different batch row's cache.  ``meta`` is the packed
        layout built by ``TransformerLM.prefill_packed``: ``rows`` (N,)
        batch row per segment (-1 = inactive), ``tok_idx``/``in_seg``
        (N, C) the unpack gather, ``seg_of_tok``/``local_of_tok``/
        ``row_of_tok`` (C,) the scatter-back.

        Expert-choice selection is PER SEGMENT — the chunk is unpacked to a
        (N, C) right-padded batch and run through ``prefill_past`` (whose
        per-row traced ``L0 = cache.length`` and ``valid`` masking already
        express exactly the continued-chunk semantics), then the updated
        rows scatter back into the full B-row cache.  A row may appear at
        most ONCE per chunk (the scheduler guarantees it; duplicate rows
        would race in the write-back).  The MoSA projections run on the
        (N, C) unpacked view — an O(N·C) overhead on an O(k²) side, paid
        for keeping the exact-union selection math in one place.

        Cache-type agnostic: every leaf of ``MoSAKVCache`` AND the
        block-choice ``MoSABlockKVCache`` is batch-major, so the row
        gather / write-back is a ``tree.map``; ``prefill_past`` dispatches
        on the selection granularity internally.
        """
        B = cache.k.shape[0]
        rows = meta["rows"]
        rowc = jnp.clip(rows, 0, B - 1)
        rowd = jnp.where(rows < 0, B, rows)               # drop index
        gc = jax.tree.map(lambda a: a[rowc], cache)
        xs = x[0][meta["tok_idx"]] * meta["in_seg"][..., None].astype(x.dtype)
        y_seg, gc2 = self.prefill_past(params, xs, gc, None, meta["in_seg"])

        cache = jax.tree.map(
            lambda old, new: old.at[rowd].set(new.astype(old.dtype),
                                              mode="drop"), cache, gc2)
        segc = jnp.maximum(meta["seg_of_tok"], 0)
        y = y_seg[segc, meta["local_of_tok"]]             # (C, h)
        y = jnp.where((meta["row_of_tok"] >= 0)[:, None], y, 0.0)
        return y[None].astype(y_seg.dtype), cache

    def _decode_block(self, params, x, cache: MoSABlockKVCache,
                      positions=None):
        """Streaming BLOCK-choice decode (DESIGN §10).

        Sequencing per step (before attention, so the new token can attend
        itself — the ``decode_step`` convention):

          1. write the token's K/V into current-slot row ``t % bs`` and add
             its router score to the running ``bsum``;
          2. if that COMPLETES the block (``(t+1) % bs == 0``): its mean
             score is now final — run ``streaming_topk_update`` over the
             candidate blocks, copy the current rows into the evicted
             slot where selected, re-sort candidates by block index
             (empties last), and reset the current slot;
          3. attend over every valid row (``pos >= 0``) — candidates plus
             the in-progress block;
          4. scale the output by the query block's mean score — final mean
             x selected-flag on completion, the running partial mean
             otherwise (the current block always participates while it is
             being built; at ``sel_block_size=1`` every step completes, so
             this reduces exactly to token-choice's score x selected).
        """
        c, cd = self.cfg, self.compute_dtype
        B, _, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        bs = cache.block_size
        CB = cache.n_cand
        R = (CB + 1) * bs
        INT_MAX = jnp.iinfo(jnp.int32).max
        t = cache.length if positions is None else positions[:, 0]   # (B,)

        x0 = x[:, 0]
        score = self.router.scores(params["router"], x)[..., 0]      # (B,H)

        q = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wq"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        kk = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        pos_t = jnp.broadcast_to(t[:, None, None], (B, H, 1)).astype(jnp.int32)
        q = rope_lib.apply_rope(q[:, :, None], pos_t, self.rope_theta,
                                self.rotary_frac)[:, :, 0]
        kk = rope_lib.apply_rope(kk[:, :, None], pos_t, self.rope_theta,
                                 self.rotary_frac)[:, :, 0]

        # 1. write into current-slot row t % bs (masked elementwise update —
        #    see DenseKVCache.append for why not dynamic-update-slice)
        row = (CB * bs + t % bs)[:, None]                            # (B,1)
        hit = jax.lax.broadcasted_iota(jnp.int32, (B, R), 1) == row  # (B,R)
        m = hit[:, None, :, None]
        k2 = jnp.where(m, kk[:, :, None].astype(cache.k.dtype), cache.k)
        v2 = jnp.where(m, v[:, :, None].astype(cache.v.dtype), cache.v)
        pos2 = jnp.where(hit[:, None], t[:, None, None].astype(jnp.int32),
                         cache.pos)
        bsum2 = cache.bsum + score                                   # (B,H)
        cur_blk = (t // bs).astype(jnp.int32)                        # (B,)

        # 2. completion: the mean is final — run the block through the
        #    evict-min streaming policy shared with token-choice.
        completed = (t + 1) % bs == 0                                # (B,)
        final = bsum2 / bs                                           # (B,H)
        is_forced = (jnp.asarray(c.force_first_token)
                     & (cur_blk == 0) & completed)[:, None]          # (B,1)
        selected, slot, nbs_, nbi_ = streaming_topk_update(
            cache.bscore[..., :CB], cache.bidx[..., :CB], final,
            jnp.broadcast_to(cur_blk[:, None], (B, H)), is_forced)
        sel_flag = selected & completed[:, None]                     # (B,H)
        cand_sc = jnp.where(completed[:, None, None], nbs_,
                            cache.bscore[..., :CB])
        cand_bi = jnp.where(completed[:, None, None], nbi_,
                            cache.bidx[..., :CB])

        # copy current rows into the evicted slot where the block made it
        cur_k = k2[:, :, CB * bs:]                                   # (B,H,bs,d)
        cur_v = v2[:, :, CB * bs:]
        cur_pos = pos2[:, :, CB * bs:]
        hit_slot = ((jax.lax.broadcasted_iota(jnp.int32, (B, H, CB), 2)
                     == slot[..., None]) & sel_flag[..., None])      # (B,H,CB)
        ck = jnp.where(hit_slot[..., None, None],
                       cur_k[:, :, None], k2[:, :, :CB * bs].reshape(
                           B, H, CB, bs, d))
        cv = jnp.where(hit_slot[..., None, None],
                       cur_v[:, :, None], v2[:, :, :CB * bs].reshape(
                           B, H, CB, bs, d))
        cp = jnp.where(hit_slot[..., None],
                       cur_pos[:, :, None], pos2[:, :, :CB * bs].reshape(
                           B, H, CB, bs))

        # re-sort candidates by block index (empties last)
        order = jnp.argsort(jnp.where(cand_bi < 0, INT_MAX, cand_bi), -1)
        cand_bi = jnp.take_along_axis(cand_bi, order, -1)
        cand_sc = jnp.take_along_axis(cand_sc, order, -1)
        row_perm = (order[..., None] * bs +
                    jnp.arange(bs, dtype=jnp.int32)).reshape(B, H, CB * bs)
        ck = jnp.take_along_axis(ck.reshape(B, H, CB * bs, d),
                                 row_perm[..., None], axis=2)
        cv = jnp.take_along_axis(cv.reshape(B, H, CB * bs, d),
                                 row_perm[..., None], axis=2)
        cp = jnp.take_along_axis(cp.reshape(B, H, CB * bs), row_perm, -1)

        # 4'. query-block scale BEFORE the current slot resets
        cnt = (t % bs).astype(jnp.float32) + 1.0                     # (B,)
        r_q = jnp.where(completed[:, None],
                        final * sel_flag.astype(jnp.float32),
                        bsum2 / cnt[:, None])                        # (B,H)

        # reset the current slot where the block completed
        cur_pos = jnp.where(completed[:, None, None], -1, cur_pos)
        bsum3 = jnp.where(completed[:, None], 0.0, bsum2)
        bidx_cur = jnp.where(completed, -1, cur_blk)[:, None, None]  # (B,1,1)
        bidx_cur = jnp.broadcast_to(bidx_cur, (B, H, 1))

        # 3. attention over all valid rows
        k_full = jnp.concatenate([ck, cur_k], 2)
        v_full = jnp.concatenate([cv, cur_v], 2)
        pos_full = jnp.concatenate([cp, cur_pos], 2)
        ok = pos_full >= 0                                           # (B,H,R)
        s = jnp.einsum("bnd,bnkd->bnk", q, k_full.astype(cd),
                       preferred_element_type=jnp.float32) * (d ** -0.5)
        s = jnp.where(ok, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnk,bnkd->bnd", p.astype(cd), v_full.astype(cd),
                         preferred_element_type=jnp.float32)
        att = att * r_q[..., None]
        y = jnp.einsum("bnd,ndh->bh", att.astype(cd), params["wo"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)

        new = MoSABlockKVCache(
            k_full.astype(cache.k.dtype), v_full.astype(cache.v.dtype),
            pos_full,
            jnp.concatenate([cand_sc,
                             jnp.full((B, H, 1), -jnp.inf, jnp.float32)], -1),
            jnp.concatenate([cand_bi, bidx_cur], -1),
            bsum3, cache.length + 1)
        return y[:, None], new

    def decode_step(self, params, x, cache: MoSAKVCache, positions=None):
        """Streaming expert-choice decode (MoD-style adaptation, DESIGN §5).

        x: (B, 1, h).  The new token enters a head's top-k set iff its router
        score beats the current minimum (or it is the forced first token);
        only then does that head compute its output for this position.
        KV memory stays at k entries per head forever.

        Positions are per-row (``cache.length``): under continuous batching
        rows sit at different sequence offsets.  After every insertion the
        cache slots are re-sorted by original position (empty slots last), so
        ``cache.idx`` keeps the ascending-index invariant that training-time
        ``select_topk`` establishes — the layout stays deterministic and any
        index-derived causal mask stays lower-triangular (DESIGN §5).
        """
        if self.cfg.selection_granularity == "block":
            return self._decode_block(params, x, cache, positions)
        c, cd = self.cfg, self.compute_dtype
        B, _, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        t = cache.length if positions is None else positions[:, 0]   # (B,)

        x0 = x[:, 0]                                              # (B, h)
        score = self.router.scores(params["router"], x)[..., 0]   # (B, H)
        is_forced = jnp.logical_and(jnp.asarray(c.force_first_token),
                                    t == 0)[:, None]              # (B, 1)

        q = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wq"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        kk = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        pos_t = jnp.broadcast_to(t[:, None, None], (B, H, 1)).astype(jnp.int32)
        q = rope_lib.apply_rope(q[:, :, None], pos_t, self.rope_theta,
                                self.rotary_frac)[:, :, 0]
        kk = rope_lib.apply_rope(kk[:, :, None], pos_t, self.rope_theta,
                                 self.rotary_frac)[:, :, 0]

        selected, slot, new_scores, new_idx = streaming_topk_update(
            cache.scores, cache.idx, score,
            jnp.broadcast_to(t[:, None], (B, H)), is_forced)

        onehot = jax.nn.one_hot(slot, cache.k.shape[2], dtype=cd)  # (B,H,k)
        upd = (onehot * selected[..., None].astype(cd))[..., None]
        new_k = cache.k * (1 - upd) + upd * kk[:, :, None]
        new_v = cache.v * (1 - upd) + upd * v[:, :, None]

        # Restore the sorted-ascending slot order (empty slots sort last).
        order = jnp.argsort(jnp.where(new_idx < 0,
                                      jnp.iinfo(jnp.int32).max, new_idx), -1)
        new_idx = jnp.take_along_axis(new_idx, order, -1)
        new_scores = jnp.take_along_axis(new_scores, order, -1)
        new_k = jnp.take_along_axis(new_k, order[..., None], 2)
        new_v = jnp.take_along_axis(new_v, order[..., None], 2)

        # Attention of the (possibly inserted) query over the cached set.
        valid = new_idx >= 0                                       # (B,H,k)
        s = jnp.einsum("bnd,bnkd->bnk", q, new_k,
                       preferred_element_type=jnp.float32) * (d ** -0.5)
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnk,bnkd->bnd", p.astype(cd), new_v,
                         preferred_element_type=jnp.float32)
        att = att * (score * selected.astype(jnp.float32))[..., None]
        y = jnp.einsum("bnd,ndh->bh", att.astype(cd), params["wo"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)

        cache = MoSAKVCache(new_k, new_v, new_scores, new_idx, cache.length + 1)
        return y[:, None], cache
