"""Mixture of Sparse Attention — the paper's core layer.

Per head: router scores r = sigmoid(X W^r); expert-choice top-k token
selection; Q/K/V/O computed *only* for the selected tokens; attention over the
k x k submatrix with the index-derived causal mask (I_i >= I_j) and RoPE at
the original positions; outputs scaled by the router score (this carries the
router's gradient) and scatter-added back to the full sequence.

Complexity per head: O(k^2 + T) versus O(T^2) dense.

Implementation notes (TPU adaptation — see DESIGN.md §3):
  * all shapes static (expert-choice: exactly k per head);
  * indices sorted ascending → the mask is effectively lower-triangular and
    the scatter-add back to the sequence touches memory in order;
  * heads are batched into single einsums over an explicit head axis, which
    shards over the `model` mesh axis (head-parallel TP);
  * the inner attention can run through the Pallas kernel (`impl="pallas"`)
    or the fused-XLA reference path (`impl="einsum"`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import ad_checkpoint

from repro.configs.base import MoSAConfig
from repro.core import rope as rope_lib
from repro.dist import hints
from repro.core.kv_cache import MoSAKVCache
from repro.core.router import (ExpertChoiceRouter, select_topk, selection_mask,
                               streaming_topk_update)
from repro.nn.layers import _trunc_normal
from repro.nn.module import logical

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MoSAAttention:
    d_model: int
    cfg: MoSAConfig
    rope_theta: float = 10000.0
    rotary_frac: float = 0.5        # paper rotates half the dims
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    impl: str = "einsum"            # einsum | pallas

    @property
    def router(self):
        return ExpertChoiceRouter(self.d_model, self.cfg.n_mosa_heads,
                                  self.param_dtype)

    def init(self, key):
        c = self.cfg
        kr, kq, kk, kv, ko = jax.random.split(key, 5)
        H, h, d = c.n_mosa_heads, self.d_model, c.d_head
        std = h ** -0.5
        return {
            "router": self.router.init(kr),
            "wq": _trunc_normal(kq, (H, h, d), std, self.param_dtype),
            "wk": _trunc_normal(kk, (H, h, d), std, self.param_dtype),
            "wv": _trunc_normal(kv, (H, h, d), std, self.param_dtype),
            "wo": _trunc_normal(ko, (H, d, h), d ** -0.5, self.param_dtype),
        }

    def specs(self):
        return {
            "router": self.router.specs(),
            "wq": logical("mosa_heads", "embed", None),
            "wk": logical("mosa_heads", "embed", None),
            "wv": logical("mosa_heads", "embed", None),
            "wo": logical("mosa_heads", None, "embed"),
        }

    def k_for(self, T: int) -> int:
        """Paper §3.5: k = max(floor(T / rho), min_k), capped at T.
        With k_fixed > 0 (paper §3.4 long-sequence mode): constant k."""
        if self.cfg.k_fixed > 0:
            return min(self.cfg.k_fixed, T)
        return max(min(T // self.cfg.sparsity, T), min(self.cfg.min_k, T))

    # ------------------------------------------------------------------ train
    def __call__(self, params, x, positions=None, valid=None, segments=None):
        """x: (B, T, h) -> (B, T, h).  Full MoSA layer (all heads).

        ``valid``: optional (B, T) bool marking right-pad tokens False
        (bucketed serving prefill, DESIGN §7).  Unlike causal dense
        attention, expert-choice selection is NOT causal — an attended pad
        would steal top-k slots from real tokens — so invalid tokens' router
        scores are masked below the sigmoid range (to -1.0, finite so no
        NaN can leak through the 0 * -inf corner), which keeps them out of
        every head's selection whenever k real candidates exist; selected
        overflow slots (k > real tokens) are scaled to zero contribution.

        ``segments``: optional (B, T) int32 document ids for PACKED training
        rows (data/pipeline.py packs multiple docs back to back).  The k x k
        attention additionally requires seg_q == seg_k, so no probability
        mass ever crosses a document boundary; expert-choice selection stays
        row-global (static k per head — the expert-choice budget is a row
        property, exactly like the non-causality of selection itself, see
        DESIGN §9).  Pass per-doc ``positions`` alongside so RoPE restarts
        at every boundary.  ``segments=None`` is bit-for-bit the old path.
        """
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        k = self.k_for(T)

        scores = self.router.scores(params["router"], x)          # (B,H,T) fp32
        if valid is not None:
            scores = jnp.where(valid[:, None, :], scores, -1.0)
        r, idx = select_topk(scores, k, c.force_first_token)      # (B,H,k)
        if valid is not None:
            r = jnp.where(r > 0.0, r, 0.0)  # overflow pads: zero output

        if positions is None:
            pos_sel = idx
        else:
            base = positions if positions.ndim == 2 else positions[0]
            pos_sel = jnp.take_along_axis(base[:, None], idx, axis=-1)

        # Gather selected tokens: (B, H, k, h).  vmap over the batch keeps B
        # a scatter/gather *batching* dim for GSPMD — explicit batch indices
        # made it replicate B and all-reduce 16 GiB buffers per layer
        # (§Perf cell-2 it.8).
        xs = jax.vmap(lambda xb, ib: xb[ib])(x.astype(cd), idx)
        # checkpoint_name: under remat="mosa" (train/step.py) the gathered
        # activations and the selection are SAVED while projections and the
        # kxk attention recompute — the gather/scatter pair is the one part
        # of this layer whose recompute is memory-bound, not FLOP-bound.
        xs = ad_checkpoint.checkpoint_name(xs, "mosa_gather")
        r = ad_checkpoint.checkpoint_name(r, "mosa_router")

        q = jnp.einsum("bnkh,nhd->bnkd", xs, params["wq"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        kk = jnp.einsum("bnkh,nhd->bnkd", xs, params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bnkh,nhd->bnkd", xs, params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        q = rope_lib.apply_rope(q, pos_sel, self.rope_theta, self.rotary_frac)
        kk = rope_lib.apply_rope(kk, pos_sel, self.rope_theta, self.rotary_frac)

        seg_sel = None
        if segments is not None:
            seg_sel = jax.vmap(lambda sb, ib: sb[ib])(
                segments.astype(jnp.int32), idx)                  # (B,H,k)

        if self.impl == "pallas":
            from repro.kernels import ops as kops
            att = kops.mosa_attention(q, kk, v, idx, r.astype(jnp.float32),
                                      seg=seg_sel)
        else:
            att = self._einsum_attention(q, kk, v, idx, r, seg=seg_sel)

        # Per-head output projection, then scatter-add to original positions
        # (vmap'd over batch — see gather note above).
        y_heads = jnp.einsum("bnkd,ndh->bnkh", att.astype(cd),
                             params["wo"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)

        def scatter_one(yh, ib):
            return jnp.zeros((T, h), cd).at[ib.reshape(-1)].add(
                yh.reshape(-1, h))

        y = jax.vmap(scatter_one)(y_heads, idx)
        # partial head-contributions combine into the seq-sharded residual:
        # constraining here lets GSPMD emit a reduce-scatter, not all-reduce
        y = hints.constrain(y, ("dp", "tp", None))
        return y

    def _einsum_attention(self, q, k, v, idx, r, seg=None):
        """Reference attention over selected tokens.  All inputs (B,H,k,*).
        ``seg``: optional (B,H,k) segment ids of the selected tokens — packed
        rows additionally mask cross-segment pairs."""
        scale = self.cfg.d_head ** -0.5
        s = jnp.einsum("bnqd,bnkd->bnqk", q, k,
                       preferred_element_type=jnp.float32) * scale
        mask = selection_mask(idx, idx)                            # (B,H,k,k)
        if seg is not None:
            mask &= seg[..., :, None] == seg[..., None, :]
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnqk,bnkd->bnqd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        # Router scaling — the router's gradient path.
        return att * r[..., None]

    def routing_stats(self, params, x):
        """Diagnostics: score stats + head-overlap (for logging)."""
        B, T, _ = x.shape
        k = self.k_for(T)
        scores = self.router.scores(params["router"], x)
        r, idx = select_topk(scores, k, self.cfg.force_first_token)
        sel = jax.nn.one_hot(idx, T, dtype=jnp.float32).sum(2)      # (B,H,T)
        coverage = (sel.sum(1) > 0).mean()       # fraction of tokens any head picks
        load = sel.sum(1).mean() / k             # avg #heads per token / k
        return {"score_mean": scores.mean(), "score_std": scores.std(),
                "coverage": coverage, "load": load}

    def router_health(self, params, x):
        """Per-step router health for the train loop (see
        ``repro.core.router.router_health_stats``): selection entropy,
        token-drop rate, head utilization."""
        from repro.core.router import router_health_stats
        B, T, _ = x.shape
        k = self.k_for(T)
        scores = self.router.scores(params["router"], x)
        r, idx = select_topk(scores, k, self.cfg.force_first_token)
        return router_health_stats(r, idx, T)

    # ---------------------------------------------------------------- serving
    def prefill(self, params, x, cache: MoSAKVCache, positions=None,
                valid=None):
        """Run the prompt through training-style selection and fill the cache
        with each head's top candidates (the prompt is fully known, so
        non-autoregressive selection is exact here).

        The cache is filled WIDE — ``min(capacity, T)`` candidates, not just
        the ``k_for(T)`` the output uses.  Width costs nothing (the slots
        exist either way) and is what makes chunked / continued prefill
        (``prefill_past``) EXACT under the growing ``k = T/rho`` schedule: a
        token in the final top-``k_for(T_total)`` has prefix rank at most
        ``k_for(T_total) <= capacity``, so a capacity-wide boundary never
        drops it (DESIGN §9).  Under a constant-k schedule capacity equals
        ``k_fixed`` and nothing changes.  The layer OUTPUT ``y`` still uses
        exactly the training-time ``k_for(T)`` selection.

        ``valid`` (B, T) bool masks right-pad tokens out of the selection
        (scores to -1.0, see ``__call__``); slots that still land on a pad
        (k exceeds the real token count) are stored as the empty-slot
        sentinels (``scores=-inf``, ``idx=-1``) — right-pads have the
        LARGEST indices, so after the ascending-idx sort they fall exactly
        where the empty-slots-last invariant wants them."""
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        k_cache = cache.k.shape[2]
        k = min(k_cache, T)

        y = self(params, x, positions, valid)

        scores = self.router.scores(params["router"], x)
        if valid is not None:
            scores = jnp.where(valid[:, None, :], scores, -1.0)
        r, idx = select_topk(scores, k, c.force_first_token)
        xs = jax.vmap(lambda xb, ib: xb[ib])(x.astype(cd), idx)
        kk = jnp.einsum("bnkh,nhd->bnkd", xs, params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        kk = rope_lib.apply_rope(kk, idx, self.rope_theta, self.rotary_frac)
        v = jnp.einsum("bnkh,nhd->bnkd", xs, params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        if valid is not None:
            sel_ok = r > 0.0
            r = jnp.where(sel_ok, r, -jnp.inf)
            idx = jnp.where(sel_ok, idx, -1)
        pad = k_cache - k
        if pad:
            kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            r = jnp.pad(r, ((0, 0), (0, 0), (0, pad)), constant_values=-jnp.inf)
            idx = jnp.pad(idx, ((0, 0), (0, 0), (0, pad)), constant_values=-1)
        nv = (jnp.full((B,), T, jnp.int32) if valid is None
              else valid.sum(-1).astype(jnp.int32))
        cache = MoSAKVCache(kk, v, r.astype(jnp.float32), idx,
                            cache.length + nv)
        return y, cache

    def prefill_past(self, params, x, cache: MoSAKVCache, positions=None,
                     valid=None):
        """Continued prefill: extend a restored prefix cache with a prompt
        suffix, reproducing training-style selection over the full prompt
        (DESIGN §7).

        Why this is EXACT — for every chunk split, every schedule: a token
        in the one-shot top-``k_for(T_total)`` has, within any prefix, rank
        at most ``k_for(T_total) <= capacity``; since ``prefill`` and this
        method both store the CAPACITY-wide top of their candidate union at
        every boundary, such a token is never dropped at a boundary, so the
        union of {cached entries} and {suffix tokens} is always a superset
        of the true selection.  (Scores, original-position RoPE, and K/V of
        cached entries are identical to what one-shot prefill computes, and
        the ascending-idx slot order makes top-k tie-breaking match too.)
        This covers the constant-k schedule (``k_fixed``, paper §3.4) AND
        the growing ``k = T / rho`` schedule — the former stored-width
        clamp to the chunk-local ``k_eff`` was the growing-k
        under-selection bug (DESIGN §9).  The output selection width
        matches one-shot prefill: ``min(k_for(L0 + T_valid), capacity)``,
        computed on traced lengths by rank-masking the union top-k (which
        ``lax.top_k`` already orders by score) in the suffix-output
        attention only.  Suffix-token outputs attend the final selection
        under the usual index-causal mask — identical math to ``__call__``
        restricted to suffix queries.  (The forced first token rides
        along: its cache entry gets a selection boost, its stored score
        stays real.)
        """
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        kc = cache.k.shape[2]
        L0 = cache.length                                       # (B,)
        nv = (jnp.full((B,), T, jnp.int32) if valid is None
              else valid.sum(-1).astype(jnp.int32))

        if positions is None:
            base_pos = L0[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        else:
            base_pos = positions if positions.ndim == 2 else positions[0]
        idx_new = jnp.broadcast_to(base_pos[:, None], (B, H, T))

        scores_new = self.router.scores(params["router"], x)    # (B,H,T)
        if valid is not None:
            scores_new = jnp.where(valid[:, None, :], scores_new, -1.0)

        q_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wq"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        k_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wk"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        v_all = jnp.einsum("bth,nhd->bntd", x.astype(cd),
                           params["wv"].astype(cd),
                           preferred_element_type=jnp.float32).astype(cd)
        q_all = rope_lib.apply_rope(q_all, idx_new, self.rope_theta,
                                    self.rotary_frac)
        k_all = rope_lib.apply_rope(k_all, idx_new, self.rope_theta,
                                    self.rotary_frac)

        # Union candidates: cached prefix top-k (already roped at original
        # positions) + every suffix token.  Disjoint by construction
        # (cached idx < L0 <= suffix idx).
        scores_cat = jnp.concatenate([cache.scores, scores_new], axis=-1)
        idx_cat = jnp.concatenate([cache.idx, idx_new], axis=-1)
        k_cat = jnp.concatenate([cache.k.astype(cd), k_all], axis=2)
        v_cat = jnp.concatenate([cache.v.astype(cd), v_all], axis=2)

        sel_scores = scores_cat
        if c.force_first_token:
            sel_scores = jnp.where(idx_cat == 0, 2.0, sel_scores)  # boost
        _, j = jax.lax.top_k(sel_scores, kc)
        r_sel = jnp.take_along_axis(scores_cat, j, axis=-1)
        idx_sel = jnp.take_along_axis(idx_cat, j, axis=-1)
        k_sel = jnp.take_along_axis(k_cat, j[..., None], axis=2)
        v_sel = jnp.take_along_axis(v_cat, j[..., None], axis=2)

        sel_ok = r_sel > 0.0          # -inf empties / -1.0 pads drop out
        # One-shot selection width on traced lengths: top_k ordered the
        # union by (boosted) score, so rank == position.  The rank mask
        # gates ONLY the suffix-output attention (y must reproduce the
        # one-shot k_for(total) selection); STORAGE keeps the full
        # capacity-wide union — clobbering stored entries down to k_eff is
        # exactly the growing-k under-selection bug: a later chunk's larger
        # k_for(total') could legally re-admit a prefix token this chunk's
        # k_eff would have discarded.  Capacity-wide storage at every
        # boundary makes chunked == one-shot EXACT (see ``prefill``).
        total = L0 + nv
        if c.k_fixed > 0:
            k_eff = jnp.minimum(c.k_fixed, total)
        else:
            k_eff = jnp.maximum(jnp.minimum(total // c.sparsity, total),
                                jnp.minimum(c.min_k, total))
        k_eff = jnp.minimum(k_eff, kc)
        rank_ok = sel_ok & (jnp.arange(kc) < k_eff[:, None, None])
        r_st = jnp.where(sel_ok, r_sel, -jnp.inf)
        idx_st = jnp.where(sel_ok, idx_sel, -1)
        order = jnp.argsort(jnp.where(idx_st < 0,
                                      jnp.iinfo(jnp.int32).max, idx_st), -1)
        idx_st = jnp.take_along_axis(idx_st, order, -1)
        r_st = jnp.take_along_axis(r_st, order, -1)
        rank_ok = jnp.take_along_axis(rank_ok, order, -1)
        k_sel = jnp.take_along_axis(k_sel, order[..., None], 2)
        v_sel = jnp.take_along_axis(v_sel, order[..., None], 2)

        # Suffix-query outputs over the final selection (index-causal mask,
        # router-score scaling) — __call__ restricted to suffix queries.
        # Queries AND keys are rank-masked to the one-shot width.
        is_suffix = rank_ok & (idx_st >= L0[:, None, None]) & (idx_st >= 0)
        t_j = jnp.clip(idx_st - L0[:, None, None], 0, T - 1)
        q_sel = jnp.take_along_axis(q_all, t_j[..., None], axis=2)
        s = jnp.einsum("bnqd,bnkd->bnqk", q_sel, k_sel,
                       preferred_element_type=jnp.float32) * (d ** -0.5)
        mask = (selection_mask(idx_st, idx_st)
                & (idx_st >= 0)[:, :, None, :] & rank_ok[:, :, None, :])
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnqk,bnkd->bnqd", p.astype(cd), v_sel,
                         preferred_element_type=jnp.float32)
        r_q = jnp.where(is_suffix, jnp.maximum(r_st, 0.0), 0.0)
        att = att * r_q[..., None]
        y_heads = jnp.einsum("bnkd,ndh->bnkh", att.astype(cd),
                             params["wo"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)

        tgt = jnp.where(is_suffix, t_j, T)          # T -> dropped

        def scatter_one(yh, tb):
            return jnp.zeros((T, h), cd).at[tb.reshape(-1)].add(
                yh.reshape(-1, h), mode="drop")

        y = jax.vmap(scatter_one)(y_heads, tgt)
        y = hints.constrain(y, ("dp", "tp", None))

        cache = MoSAKVCache(k_sel.astype(cache.k.dtype),
                            v_sel.astype(cache.v.dtype),
                            r_st.astype(jnp.float32), idx_st, L0 + nv)
        return y, cache

    def prefill_packed(self, params, x, cache: MoSAKVCache, meta):
        """Packed multi-segment chunked prefill (DESIGN §9).

        ``x``: (1, C, h) — a flattened chunk of N prompt segments, each
        continuing a different batch row's cache.  ``meta`` is the packed
        layout built by ``TransformerLM.prefill_packed``: ``rows`` (N,)
        batch row per segment (-1 = inactive), ``tok_idx``/``in_seg``
        (N, C) the unpack gather, ``seg_of_tok``/``local_of_tok``/
        ``row_of_tok`` (C,) the scatter-back.

        Expert-choice selection is PER SEGMENT — the chunk is unpacked to a
        (N, C) right-padded batch and run through ``prefill_past`` (whose
        per-row traced ``L0 = cache.length`` and ``valid`` masking already
        express exactly the continued-chunk semantics), then the updated
        rows scatter back into the full B-row cache.  A row may appear at
        most ONCE per chunk (the scheduler guarantees it; duplicate rows
        would race in the write-back).  The MoSA projections run on the
        (N, C) unpacked view — an O(N·C) overhead on an O(k²) side, paid
        for keeping the exact-union selection math in one place.
        """
        B = cache.k.shape[0]
        rows = meta["rows"]
        rowc = jnp.clip(rows, 0, B - 1)
        rowd = jnp.where(rows < 0, B, rows)               # drop index
        gc = MoSAKVCache(cache.k[rowc], cache.v[rowc], cache.scores[rowc],
                         cache.idx[rowc], cache.length[rowc])
        xs = x[0][meta["tok_idx"]] * meta["in_seg"][..., None].astype(x.dtype)
        y_seg, gc2 = self.prefill_past(params, xs, gc, None, meta["in_seg"])

        def wb(old, new):
            return old.at[rowd].set(new.astype(old.dtype), mode="drop")

        cache = MoSAKVCache(wb(cache.k, gc2.k), wb(cache.v, gc2.v),
                            wb(cache.scores, gc2.scores),
                            wb(cache.idx, gc2.idx),
                            wb(cache.length, gc2.length))
        segc = jnp.maximum(meta["seg_of_tok"], 0)
        y = y_seg[segc, meta["local_of_tok"]]             # (C, h)
        y = jnp.where((meta["row_of_tok"] >= 0)[:, None], y, 0.0)
        return y[None].astype(y_seg.dtype), cache

    def decode_step(self, params, x, cache: MoSAKVCache, positions=None):
        """Streaming expert-choice decode (MoD-style adaptation, DESIGN §5).

        x: (B, 1, h).  The new token enters a head's top-k set iff its router
        score beats the current minimum (or it is the forced first token);
        only then does that head compute its output for this position.
        KV memory stays at k entries per head forever.

        Positions are per-row (``cache.length``): under continuous batching
        rows sit at different sequence offsets.  After every insertion the
        cache slots are re-sorted by original position (empty slots last), so
        ``cache.idx`` keeps the ascending-index invariant that training-time
        ``select_topk`` establishes — the layout stays deterministic and any
        index-derived causal mask stays lower-triangular (DESIGN §5).
        """
        c, cd = self.cfg, self.compute_dtype
        B, _, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        t = cache.length if positions is None else positions[:, 0]   # (B,)

        x0 = x[:, 0]                                              # (B, h)
        score = self.router.scores(params["router"], x)[..., 0]   # (B, H)
        is_forced = jnp.logical_and(jnp.asarray(c.force_first_token),
                                    t == 0)[:, None]              # (B, 1)

        q = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wq"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        kk = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        pos_t = jnp.broadcast_to(t[:, None, None], (B, H, 1)).astype(jnp.int32)
        q = rope_lib.apply_rope(q[:, :, None], pos_t, self.rope_theta,
                                self.rotary_frac)[:, :, 0]
        kk = rope_lib.apply_rope(kk[:, :, None], pos_t, self.rope_theta,
                                 self.rotary_frac)[:, :, 0]

        selected, slot, new_scores, new_idx = streaming_topk_update(
            cache.scores, cache.idx, score,
            jnp.broadcast_to(t[:, None], (B, H)), is_forced)

        onehot = jax.nn.one_hot(slot, cache.k.shape[2], dtype=cd)  # (B,H,k)
        upd = (onehot * selected[..., None].astype(cd))[..., None]
        new_k = cache.k * (1 - upd) + upd * kk[:, :, None]
        new_v = cache.v * (1 - upd) + upd * v[:, :, None]

        # Restore the sorted-ascending slot order (empty slots sort last).
        order = jnp.argsort(jnp.where(new_idx < 0,
                                      jnp.iinfo(jnp.int32).max, new_idx), -1)
        new_idx = jnp.take_along_axis(new_idx, order, -1)
        new_scores = jnp.take_along_axis(new_scores, order, -1)
        new_k = jnp.take_along_axis(new_k, order[..., None], 2)
        new_v = jnp.take_along_axis(new_v, order[..., None], 2)

        # Attention of the (possibly inserted) query over the cached set.
        valid = new_idx >= 0                                       # (B,H,k)
        s = jnp.einsum("bnd,bnkd->bnk", q, new_k,
                       preferred_element_type=jnp.float32) * (d ** -0.5)
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnk,bnkd->bnd", p.astype(cd), new_v,
                         preferred_element_type=jnp.float32)
        att = att * (score * selected.astype(jnp.float32))[..., None]
        y = jnp.einsum("bnd,ndh->bh", att.astype(cd), params["wo"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)

        cache = MoSAKVCache(new_k, new_v, new_scores, new_idx, cache.length + 1)
        return y[:, None], cache
