"""Mixture of Sparse Attention — the paper's core layer.

Per head: router scores r = sigmoid(X W^r); expert-choice top-k token
selection; Q/K/V/O computed *only* for the selected tokens; attention over the
k x k submatrix with the index-derived causal mask (I_i >= I_j) and RoPE at
the original positions; outputs scaled by the router score (this carries the
router's gradient) and scatter-added back to the full sequence.

Complexity per head: O(k^2 + T) versus O(T^2) dense.

Implementation notes (TPU adaptation — see DESIGN.md §3):
  * all shapes static (expert-choice: exactly k per head);
  * indices sorted ascending → the mask is effectively lower-triangular and
    the scatter-add back to the sequence touches memory in order;
  * heads are batched into single einsums over an explicit head axis, which
    shards over the `model` mesh axis (head-parallel TP);
  * the inner attention can run through the Pallas kernel (`impl="pallas"`)
    or the fused-XLA reference path (`impl="einsum"`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import MoSAConfig
from repro.core import rope as rope_lib
from repro.dist import hints
from repro.core.kv_cache import MoSAKVCache
from repro.core.router import (ExpertChoiceRouter, select_topk, selection_mask,
                               streaming_topk_update)
from repro.nn.layers import _trunc_normal
from repro.nn.module import logical

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MoSAAttention:
    d_model: int
    cfg: MoSAConfig
    rope_theta: float = 10000.0
    rotary_frac: float = 0.5        # paper rotates half the dims
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    impl: str = "einsum"            # einsum | pallas

    @property
    def router(self):
        return ExpertChoiceRouter(self.d_model, self.cfg.n_mosa_heads,
                                  self.param_dtype)

    def init(self, key):
        c = self.cfg
        kr, kq, kk, kv, ko = jax.random.split(key, 5)
        H, h, d = c.n_mosa_heads, self.d_model, c.d_head
        std = h ** -0.5
        return {
            "router": self.router.init(kr),
            "wq": _trunc_normal(kq, (H, h, d), std, self.param_dtype),
            "wk": _trunc_normal(kk, (H, h, d), std, self.param_dtype),
            "wv": _trunc_normal(kv, (H, h, d), std, self.param_dtype),
            "wo": _trunc_normal(ko, (H, d, h), d ** -0.5, self.param_dtype),
        }

    def specs(self):
        return {
            "router": self.router.specs(),
            "wq": logical("mosa_heads", "embed", None),
            "wk": logical("mosa_heads", "embed", None),
            "wv": logical("mosa_heads", "embed", None),
            "wo": logical("mosa_heads", None, "embed"),
        }

    def k_for(self, T: int) -> int:
        """Paper §3.5: k = max(floor(T / rho), min_k), capped at T.
        With k_fixed > 0 (paper §3.4 long-sequence mode): constant k."""
        if self.cfg.k_fixed > 0:
            return min(self.cfg.k_fixed, T)
        return max(min(T // self.cfg.sparsity, T), min(self.cfg.min_k, T))

    # ------------------------------------------------------------------ train
    def __call__(self, params, x, positions=None):
        """x: (B, T, h) -> (B, T, h).  Full MoSA layer (all heads)."""
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        k = self.k_for(T)

        scores = self.router.scores(params["router"], x)          # (B,H,T) fp32
        r, idx = select_topk(scores, k, c.force_first_token)      # (B,H,k)

        if positions is None:
            pos_sel = idx
        else:
            base = positions if positions.ndim == 2 else positions[0]
            pos_sel = jnp.take_along_axis(base[:, None], idx, axis=-1)

        # Gather selected tokens: (B, H, k, h).  vmap over the batch keeps B
        # a scatter/gather *batching* dim for GSPMD — explicit batch indices
        # made it replicate B and all-reduce 16 GiB buffers per layer
        # (§Perf cell-2 it.8).
        xs = jax.vmap(lambda xb, ib: xb[ib])(x.astype(cd), idx)

        q = jnp.einsum("bnkh,nhd->bnkd", xs, params["wq"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        kk = jnp.einsum("bnkh,nhd->bnkd", xs, params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bnkh,nhd->bnkd", xs, params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        q = rope_lib.apply_rope(q, pos_sel, self.rope_theta, self.rotary_frac)
        kk = rope_lib.apply_rope(kk, pos_sel, self.rope_theta, self.rotary_frac)

        if self.impl == "pallas":
            from repro.kernels import ops as kops
            att = kops.mosa_attention(q, kk, v, idx, r.astype(jnp.float32))
        else:
            att = self._einsum_attention(q, kk, v, idx, r)

        # Per-head output projection, then scatter-add to original positions
        # (vmap'd over batch — see gather note above).
        y_heads = jnp.einsum("bnkd,ndh->bnkh", att.astype(cd),
                             params["wo"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)

        def scatter_one(yh, ib):
            return jnp.zeros((T, h), cd).at[ib.reshape(-1)].add(
                yh.reshape(-1, h))

        y = jax.vmap(scatter_one)(y_heads, idx)
        # partial head-contributions combine into the seq-sharded residual:
        # constraining here lets GSPMD emit a reduce-scatter, not all-reduce
        y = hints.constrain(y, ("dp", "tp", None))
        return y

    def _einsum_attention(self, q, k, v, idx, r):
        """Reference attention over selected tokens.  All inputs (B,H,k,*)."""
        scale = self.cfg.d_head ** -0.5
        s = jnp.einsum("bnqd,bnkd->bnqk", q, k,
                       preferred_element_type=jnp.float32) * scale
        mask = selection_mask(idx, idx)                            # (B,H,k,k)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnqk,bnkd->bnqd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        # Router scaling — the router's gradient path.
        return att * r[..., None]

    def routing_stats(self, params, x):
        """Diagnostics: score stats + head-overlap (for logging)."""
        B, T, _ = x.shape
        k = self.k_for(T)
        scores = self.router.scores(params["router"], x)
        r, idx = select_topk(scores, k, self.cfg.force_first_token)
        sel = jax.nn.one_hot(idx, T, dtype=jnp.float32).sum(2)      # (B,H,T)
        coverage = (sel.sum(1) > 0).mean()       # fraction of tokens any head picks
        load = sel.sum(1).mean() / k             # avg #heads per token / k
        return {"score_mean": scores.mean(), "score_std": scores.std(),
                "coverage": coverage, "load": load}

    # ---------------------------------------------------------------- serving
    def prefill(self, params, x, cache: MoSAKVCache, positions=None):
        """Run the prompt through training-style selection and fill the cache
        with each head's top-k K/V (the prompt is fully known, so
        non-autoregressive selection is exact here)."""
        c, cd = self.cfg, self.compute_dtype
        B, T, h = x.shape
        k_cache = cache.k.shape[2]
        k = min(self.k_for(T), k_cache)

        y = self(params, x, positions)

        scores = self.router.scores(params["router"], x)
        r, idx = select_topk(scores, k, c.force_first_token)
        xs = jax.vmap(lambda xb, ib: xb[ib])(x.astype(cd), idx)
        kk = jnp.einsum("bnkh,nhd->bnkd", xs, params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        kk = rope_lib.apply_rope(kk, idx, self.rope_theta, self.rotary_frac)
        v = jnp.einsum("bnkh,nhd->bnkd", xs, params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        pad = k_cache - k
        if pad:
            kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            r = jnp.pad(r, ((0, 0), (0, 0), (0, pad)), constant_values=-jnp.inf)
            idx = jnp.pad(idx, ((0, 0), (0, 0), (0, pad)), constant_values=-1)
        cache = MoSAKVCache(kk, v, r.astype(jnp.float32), idx,
                            cache.length + T)
        return y, cache

    def decode_step(self, params, x, cache: MoSAKVCache, positions=None):
        """Streaming expert-choice decode (MoD-style adaptation, DESIGN §5).

        x: (B, 1, h).  The new token enters a head's top-k set iff its router
        score beats the current minimum (or it is the forced first token);
        only then does that head compute its output for this position.
        KV memory stays at k entries per head forever.

        Positions are per-row (``cache.length``): under continuous batching
        rows sit at different sequence offsets.  After every insertion the
        cache slots are re-sorted by original position (empty slots last), so
        ``cache.idx`` keeps the ascending-index invariant that training-time
        ``select_topk`` establishes — the layout stays deterministic and any
        index-derived causal mask stays lower-triangular (DESIGN §5).
        """
        c, cd = self.cfg, self.compute_dtype
        B, _, h = x.shape
        H, d = c.n_mosa_heads, c.d_head
        t = cache.length if positions is None else positions[:, 0]   # (B,)

        x0 = x[:, 0]                                              # (B, h)
        score = self.router.scores(params["router"], x)[..., 0]   # (B, H)
        is_forced = jnp.logical_and(jnp.asarray(c.force_first_token),
                                    t == 0)[:, None]              # (B, 1)

        q = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wq"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        kk = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bh,nhd->bnd", x0.astype(cd), params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        pos_t = jnp.broadcast_to(t[:, None, None], (B, H, 1)).astype(jnp.int32)
        q = rope_lib.apply_rope(q[:, :, None], pos_t, self.rope_theta,
                                self.rotary_frac)[:, :, 0]
        kk = rope_lib.apply_rope(kk[:, :, None], pos_t, self.rope_theta,
                                 self.rotary_frac)[:, :, 0]

        selected, slot, new_scores, new_idx = streaming_topk_update(
            cache.scores, cache.idx, score,
            jnp.broadcast_to(t[:, None], (B, H)), is_forced)

        onehot = jax.nn.one_hot(slot, cache.k.shape[2], dtype=cd)  # (B,H,k)
        upd = (onehot * selected[..., None].astype(cd))[..., None]
        new_k = cache.k * (1 - upd) + upd * kk[:, :, None]
        new_v = cache.v * (1 - upd) + upd * v[:, :, None]

        # Restore the sorted-ascending slot order (empty slots sort last).
        order = jnp.argsort(jnp.where(new_idx < 0,
                                      jnp.iinfo(jnp.int32).max, new_idx), -1)
        new_idx = jnp.take_along_axis(new_idx, order, -1)
        new_scores = jnp.take_along_axis(new_scores, order, -1)
        new_k = jnp.take_along_axis(new_k, order[..., None], 2)
        new_v = jnp.take_along_axis(new_v, order[..., None], 2)

        # Attention of the (possibly inserted) query over the cached set.
        valid = new_idx >= 0                                       # (B,H,k)
        s = jnp.einsum("bnd,bnkd->bnk", q, new_k,
                       preferred_element_type=jnp.float32) * (d ** -0.5)
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnk,bnkd->bnd", p.astype(cd), new_v,
                         preferred_element_type=jnp.float32)
        att = att * (score * selected.astype(jnp.float32))[..., None]
        y = jnp.einsum("bnd,ndh->bh", att.astype(cd), params["wo"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)

        cache = MoSAKVCache(new_k, new_v, new_scores, new_idx, cache.length + 1)
        return y[:, None], cache
