"""Expert-choice token router — the heart of MoSA.

Each MoSA head owns one router vector ``W^r in R^h``.  Scores are the
*non-competitive* sigmoid ``r = sigmoid(X W^r)`` (sigma-MoE observation cited
by the paper), and each head independently selects its top-k tokens
(expert-choice: the head is the expert, so load balance is perfect by
construction — exactly k tokens per head, no auxiliary loss).

Selection is non-autoregressive (paper §5); the *scores* however are strictly
causal (token t's score depends only on token t).  ``streaming_topk_update``
implements the MoD-style autoregressive adaptation used by the serving path:
a running top-k set with evict-min updates.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.module import logical
from repro.nn.layers import _trunc_normal


@dataclasses.dataclass(frozen=True)
class ExpertChoiceRouter:
    d_model: int
    n_heads: int
    param_dtype: jnp.dtype = jnp.float32

    def init(self, key):
        # Router kept in fp32: top-k boundary decisions are precision-sensitive.
        return {"w": _trunc_normal(key, (self.n_heads, self.d_model),
                                   self.d_model ** -0.5, jnp.float32)}

    def specs(self):
        return {"w": logical("mosa_heads", "embed")}

    def scores(self, params, x):
        """x: (B, T, h) -> sigmoid scores (B, H, T) in fp32."""
        logits = jnp.einsum("bth,nh->bnt", x.astype(jnp.float32), params["w"],
                            preferred_element_type=jnp.float32)
        return jax.nn.sigmoid(logits)


def select_topk(scores, k: int, force_first: bool = True):
    """Expert-choice selection.

    scores: (B, H, T) fp32.  Returns (r, idx), both (B, H, k), with ``idx``
    sorted ascending (so the index-derived causal mask is lower-triangular and
    the scatter back to the sequence is ordered) and ``r`` the corresponding
    router scores.

    ``force_first`` always includes token 0 (StreamingLLM attention-sink
    observation, used by the paper's IsoFLOP experiments): the head selects
    k-1 tokens from positions 1..T-1 plus token 0.  Token 0's output is still
    scaled by its *actual* router score.
    """
    B, H, T = scores.shape
    assert 0 < k <= T, f"k={k} out of range for T={T}"
    if force_first and k >= 2:
        _, idx_rest = jax.lax.top_k(scores[..., 1:], k - 1)      # (B, H, k-1)
        idx = jnp.concatenate(
            [jnp.zeros((B, H, 1), idx_rest.dtype), idx_rest + 1], axis=-1)
    else:
        _, idx = jax.lax.top_k(scores, k)
    idx = jnp.sort(idx, axis=-1)
    r = jnp.take_along_axis(scores, idx, axis=-1)
    return r, idx


def router_health_stats(r, idx, T: int):
    """Health metrics of one expert-choice selection (train-loop telemetry).

    r, idx: (B, H, k) — ``select_topk`` output for a (B, H, T) score tensor.

      * ``sel_entropy``   — entropy of the aggregate selection distribution
        over token positions, normalized by log T.  Low = the heads
        concentrate their k-budgets on few positions (router collapse —
        every head picking the same tokens); ~uniform coverage scores near
        the ceiling (the ceiling itself is (log B*H*k)/log T when
        B*H*k < T).
      * ``drop_rate``     — fraction of tokens selected by NO head; these
        positions get zero sparse-attention output AND zero router gradient
        this step (the paper's hybrid keeps dense heads partly for this).
      * ``head_util``     — mean router score over selected tokens: how
        strongly heads use their budget (scores sliding toward 0 = heads
        going dead; the sigmoid scale makes 0.5 the indifference point).
    """
    B, H, k = idx.shape
    sel = jax.nn.one_hot(idx, T, dtype=jnp.float32).sum(2)         # (B,H,T)
    counts = sel.sum(1)                                            # (B,T)
    drop_rate = (counts == 0).astype(jnp.float32).mean()
    p = sel.sum((0, 1)) / (B * H * k)                              # (T,)
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-20)), 0.0))
    return {"sel_entropy": ent / jnp.log(float(max(T, 2))),
            "drop_rate": drop_rate,
            "head_util": r.mean()}


def selection_mask(idx_q, idx_k):
    """Causal mask from original indices: allow iff I_q >= I_k.

    idx_q: (..., kq), idx_k: (..., kk) -> bool (..., kq, kk).
    """
    return idx_q[..., :, None] >= idx_k[..., None, :]


def block_pool_scores(scores, block_size: int):
    """Pool per-token router scores into per-block scores (block-choice MoSA).

    scores: (B, H, T) fp32 -> (B, H, NB) with NB = ceil(T / block_size).

    A block's score is the MEAN of its in-range token scores (the last block
    may cover fewer than ``block_size`` positions when ``block_size`` does
    not divide T; out-of-range slots are excluded from the mean).  At
    ``block_size=1`` this is the bitwise identity — the maintained
    token-choice equivalence (DESIGN §10) rests on it: sum over a size-1
    window then division by 1.0 reproduces every score exactly.
    """
    B, H, T = scores.shape
    bs = block_size
    nb = -(-T // bs)
    pad = nb * bs - T
    s = jnp.pad(scores, ((0, 0), (0, 0), (0, pad)))
    in_range = (jnp.arange(nb * bs) < T).reshape(nb, bs)            # (NB, bs)
    ssum = jnp.sum(jnp.where(in_range, s.reshape(B, H, nb, bs), 0.0), axis=-1)
    cnt = in_range.sum(-1).astype(scores.dtype)                     # (NB,) >= 1
    return ssum / cnt


def expand_block_index(bidx, block_size: int, T: int):
    """Per-block indices -> per-token positions (block-choice expansion).

    bidx: (..., NBsel) int32, -1 = empty slot.  Returns ``pos`` of shape
    (..., NBsel*block_size): ``bidx*bs + offset`` for real slots, and -1 for
    every token of an empty block or beyond ``T`` (the ragged tail of the
    last block).  The -1 sentinel keeps the downstream masks (``pos >= 0``)
    and scatters (positive sentinel + mode="drop") identical in shape to the
    token-choice path.
    """
    bs = block_size
    off = jnp.arange(bs, dtype=bidx.dtype)
    pos = bidx[..., None] * bs + off                                # (...,NB,bs)
    ok = (bidx[..., None] >= 0) & (pos < T)
    pos = jnp.where(ok, pos, -1)
    return pos.reshape(*bidx.shape[:-1], bidx.shape[-1] * bs)


def streaming_topk_update(cache_scores, cache_idx, new_score, new_pos, is_forced):
    """One step of the autoregressive (serving-time) top-k approximation.

    This is the evict-min policy behind ``repro.core.kv_cache.MoSAKVCache``
    (whose module docstring documents the ``-inf`` / ``-1`` empty-slot
    sentinels): the incoming token replaces the minimum-score slot iff its
    router score beats that minimum.  Empty slots score ``-inf``, so they
    always fill first.  The *storage* lives in the cache; the *policy* lives
    here — ``MoSAAttention.decode_step`` wires the two together.

    cache_scores: (..., k) current per-slot scores (-inf = empty slot)
    cache_idx:    (..., k) original positions of cached tokens (-1 = empty)
    new_score:    (...,)   router score of the incoming token
    new_pos:      scalar or broadcastable to new_score's shape — its position
    is_forced:    bool (broadcastable) — force insertion (token 0 /
                  attention sink)

    Returns (selected, slot, new_scores, new_idx):
      selected: (...,) bool — whether the token entered the set
      slot:     (...,) int  — which slot it replaced (valid where selected)
    """
    min_slot = jnp.argmin(cache_scores, axis=-1)                   # (...,)
    min_score = jnp.take_along_axis(cache_scores, min_slot[..., None], -1)[..., 0]
    selected = jnp.logical_or(new_score > min_score, is_forced)
    slot = min_slot
    new_scores = jnp.where(
        jax.nn.one_hot(slot, cache_scores.shape[-1], dtype=bool) & selected[..., None],
        new_score[..., None], cache_scores)
    new_idx = jnp.where(
        jax.nn.one_hot(slot, cache_idx.shape[-1], dtype=bool) & selected[..., None],
        jnp.asarray(new_pos)[..., None].astype(cache_idx.dtype), cache_idx)
    return selected, slot, new_scores, new_idx
