"""The paper's sparse-attention baselines: Fixed and Routing Attention.

Fixed sparse attention (Child et al.): the special case of MoSA with
``I = [0, rho, 2*rho, ...]`` and ``r = 1`` — same strided indices for every
head, no router.

Routing Attention (Routing Transformer): tokens clustered per head into
``rho`` clusters of size k by online k-means in a *tied* Q=K space; attention
runs within each cluster (causal on original indices); cluster centroids are
updated by an EMA toward their members (not by gradients).  We implement the
clusters as "virtual heads" so the gather/attend/scatter machinery is shared
with MoSA.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import rope as rope_lib
from repro.core.router import selection_mask
from repro.nn.layers import _trunc_normal
from repro.nn.module import logical

NEG_INF = -1e30


def fixed_indices(T: int, k: int, batch_shape=()):
    """Strided selection I = [0, rho, 2rho, ...] of length k."""
    rho = max(T // k, 1)
    idx = jnp.minimum(jnp.arange(k) * rho, T - 1).astype(jnp.int32)
    return jnp.broadcast_to(idx, batch_shape + (k,))


@dataclasses.dataclass(frozen=True)
class FixedSparseAttention:
    d_model: int
    n_heads: int
    d_head: int = 64
    sparsity: int = 32
    rope_theta: float = 10000.0
    rotary_frac: float = 0.5
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32

    def init(self, key):
        H, h, d = self.n_heads, self.d_model, self.d_head
        kq, kk, kv, ko = jax.random.split(key, 4)
        std = h ** -0.5
        return {"wq": _trunc_normal(kq, (H, h, d), std, self.param_dtype),
                "wk": _trunc_normal(kk, (H, h, d), std, self.param_dtype),
                "wv": _trunc_normal(kv, (H, h, d), std, self.param_dtype),
                "wo": _trunc_normal(ko, (H, d, h), d ** -0.5, self.param_dtype)}

    def specs(self):
        return {"wq": logical("mosa_heads", "embed", None),
                "wk": logical("mosa_heads", "embed", None),
                "wv": logical("mosa_heads", "embed", None),
                "wo": logical("mosa_heads", None, "embed")}

    def __call__(self, params, x, positions=None):
        cd = self.compute_dtype
        B, T, h = x.shape
        H, d = self.n_heads, self.d_head
        k = max(T // self.sparsity, 2)
        idx = fixed_indices(T, k, (B, H))                       # (B,H,k)

        xs = jax.vmap(lambda xb, ib: xb[ib])(x.astype(cd), idx)
        q = jnp.einsum("bnkh,nhd->bnkd", xs, params["wq"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        kk = jnp.einsum("bnkh,nhd->bnkd", xs, params["wk"].astype(cd),
                        preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bnkh,nhd->bnkd", xs, params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        q = rope_lib.apply_rope(q, idx, self.rope_theta, self.rotary_frac)
        kk = rope_lib.apply_rope(kk, idx, self.rope_theta, self.rotary_frac)

        s = jnp.einsum("bnqd,bnkd->bnqk", q, kk,
                       preferred_element_type=jnp.float32) * (d ** -0.5)
        s = jnp.where(selection_mask(idx, idx), s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bnqk,bnkd->bnqd", p.astype(cd), v,
                         preferred_element_type=jnp.float32).astype(cd)
        y_heads = jnp.einsum("bnkd,ndh->bnkh", att, params["wo"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)
        return jax.vmap(lambda yh, ib: jnp.zeros((T, h), cd).at[
            ib.reshape(-1)].add(yh.reshape(-1, h)))(y_heads, idx)


@dataclasses.dataclass(frozen=True)
class RoutingAttention:
    """Routing Transformer attention head(s) with online k-means clusters."""

    d_model: int
    n_heads: int
    d_head: int = 64
    sparsity: int = 32              # rho = number of clusters; cluster size k=T/rho
    rope_theta: float = 10000.0
    rotary_frac: float = 0.5
    ema_decay: float = 0.999
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32

    def init(self, key):
        H, h, d = self.n_heads, self.d_model, self.d_head
        kqk, kv, ko, kc = jax.random.split(key, 4)
        std = h ** -0.5
        return {"wqk": _trunc_normal(kqk, (H, h, d), std, self.param_dtype),
                "wv": _trunc_normal(kv, (H, h, d), std, self.param_dtype),
                "wo": _trunc_normal(ko, (H, d, h), d ** -0.5, self.param_dtype),
                # k-means state (EMA-updated, not gradient-trained)
                "centroids": _trunc_normal(kc, (H, self.sparsity, d), 1.0, jnp.float32)}

    def specs(self):
        return {"wqk": logical("mosa_heads", "embed", None),
                "wv": logical("mosa_heads", "embed", None),
                "wo": logical("mosa_heads", None, "embed"),
                "centroids": logical("mosa_heads", None, None)}

    def _cluster_select(self, qk, centroids, k):
        """qk: (B,H,T,d) normalized; -> idx (B,H,rho,k) member indices/cluster."""
        sim = jnp.einsum("bntd,ncd->bnct", qk, centroids.astype(qk.dtype),
                         preferred_element_type=jnp.float32)     # (B,H,rho,T)
        _, idx = jax.lax.top_k(sim, k)                           # (B,H,rho,k)
        return jnp.sort(idx, axis=-1)

    def __call__(self, params, x, positions=None, update_state: bool = False):
        cd = self.compute_dtype
        B, T, h = x.shape
        H, d, rho = self.n_heads, self.d_head, self.sparsity
        k = max(T // rho, 2)

        qk = jnp.einsum("bth,nhd->bntd", x.astype(cd), params["wqk"].astype(cd),
                        preferred_element_type=jnp.float32)
        qk = qk / (jnp.linalg.norm(qk, axis=-1, keepdims=True) + 1e-6)
        qk = qk.astype(cd)
        cent = params["centroids"]
        idx = self._cluster_select(qk.astype(jnp.float32), cent, k)  # (B,H,rho,k)

        # Flatten clusters into virtual heads: (B, H*rho, k)
        idxf = idx.reshape(B, H * rho, k)
        xs = jax.vmap(lambda xb, ib: xb[ib])(x.astype(cd), idxf)
        xs = xs.reshape(B, H, rho, k, h)
        qkv_sel = jnp.einsum("bnckh,nhd->bnckd", xs, params["wqk"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("bnckh,nhd->bnckd", xs, params["wv"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
        qr = rope_lib.apply_rope(qkv_sel, idx, self.rope_theta, self.rotary_frac)

        s = jnp.einsum("bncqd,bnckd->bncqk", qr, qr,
                       preferred_element_type=jnp.float32) * (d ** -0.5)
        s = jnp.where(selection_mask(idx, idx), s, NEG_INF)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        att = jnp.einsum("bncqk,bnckd->bncqd", p.astype(cd), v,
                         preferred_element_type=jnp.float32).astype(cd)
        y_heads = jnp.einsum("bnckd,ndh->bnckh", att, params["wo"].astype(cd),
                             preferred_element_type=jnp.float32).astype(cd)
        y = jax.vmap(lambda yh, ib: jnp.zeros((T, h), cd).at[
            ib.reshape(-1)].add(yh.reshape(-1, h)))(
                y_heads.reshape(B, H * rho * k, h).reshape(B, -1, h),
                idx.reshape(B, -1))
        if not update_state:
            return y
        return y, self.ema_centroids(params, qk, idx)

    def ema_centroids(self, params, qk, idx):
        """Online k-means EMA toward assigned members (stop-gradient)."""
        B, H, T, d = qk.shape
        rho, k = idx.shape[2], idx.shape[3]
        qk = jax.lax.stop_gradient(qk.astype(jnp.float32))
        members = jnp.take_along_axis(
            qk[:, :, None].reshape(B, H, 1, T, d).repeat(rho, 2),
            idx[..., None], axis=3)                              # (B,H,rho,k,d)
        mean = members.mean(axis=(0, 3))                         # (H,rho,d)
        cent = params["centroids"]
        new = self.ema_decay * cent + (1 - self.ema_decay) * mean
        return new / (jnp.linalg.norm(new, axis=-1, keepdims=True) + 1e-6)
