"""KV cache structures for serving.

Four cache families, all fixed-shape pytrees (jit/pjit friendly):

  * ``DenseKVCache``     — classic (B, S, Hkv, d) append cache.
  * ``WindowKVCache``    — ring buffer of the last ``window`` tokens.
  * ``MLAKVCache``       — DeepSeek latent cache: (B, S, kv_lora + rope_dim);
                           the per-head K/V are re-expanded from the latent.
  * ``MoSAKVCache``      — the paper's payoff: each MoSA head keeps only its
                           running top-k selected tokens (streaming
                           expert-choice).  KV memory per head is O(k),
                           independent of context length.

``MoSAKVCache`` is a passive container: the evict-min streaming policy that
decides which token a new arrival replaces lives in
``repro.core.router.streaming_topk_update`` (called from
``repro.core.mosa.MoSAAttention.decode_step``), not here.  Empty-slot
sentinels, used consistently by both sides:

  * ``scores == -inf`` — slot holds no token yet; any real router score
    (sigmoid output, in (0, 1)) beats it, so empty slots fill first;
  * ``idx == -1``      — same slot, position view; decode masks attention to
    ``idx >= 0`` and tests/kernels treat ``-1`` as "ignore".

Every cache keeps a per-row ``length`` so a continuous-batching server can
hold rows at different sequence positions in one batched cache.  Sharding:
``repro.dist.sharding.CACHE_AXES`` declares the logical axes of every cache
type (head-sharded MoSA decode, DESIGN §6).

These are the CONTIGUOUS layouts: one ``(B, max_len, ...)`` slab per slot.
The serving path can swap the dense and window families for the block-paged
equivalents in ``repro.serve.paged_kv`` (``PagedDenseKVCache`` /
``PagedWindowKVCache``): same append/gather semantics, but KV lives in
fixed-size pool blocks addressed through per-row block tables, so memory
scales with tokens actually held and shared prompt prefixes can share
physical blocks (DESIGN §7).  ``MoSAKVCache`` intentionally has no paged
counterpart — it is already O(k) per head, independent of context length.
``MoSABlockKVCache`` is the block-choice variant (DESIGN §10): the head
selects whole KV blocks of ``sel_block_size`` tokens, so its selection state
is naturally block-granular and snapshots taken at block-aligned boundaries
(the prefix-cache trie) capture it EXACTLY — paged MoSA prefix hits
reproduce the cold path bit-for-bit, unlike token-choice's chunk-causal
approximation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DenseKVCache(NamedTuple):
    k: jnp.ndarray        # (B, S, Hkv, d)
    v: jnp.ndarray        # (B, S, Hkv, d)
    length: jnp.ndarray   # (B,) int32 — tokens filled

    @classmethod
    def create(cls, batch, max_len, n_kv_heads, d_head, dtype=jnp.bfloat16):
        z = jnp.zeros((batch, max_len, n_kv_heads, d_head), dtype)
        return cls(z, z, jnp.zeros((batch,), jnp.int32))

    def append(self, k_new, v_new, n_valid=None):
        """k_new/v_new: (B, Tnew, Hkv, d).  Returns updated cache.

        Tnew == 1 (decode) uses a masked elementwise update — a
        dynamic-update-slice at a traced offset on the (sequence-sharded)
        cache dim would force GSPMD to all-gather the cache (measured
        ~17 GB/dev on musicgen decode_32k; §Perf it.3).  Prefill (length==0)
        writes with a static offset, which partitions cleanly.

        ``n_valid`` (B,) — real (non-right-pad) token count of a bucketed
        prefill: all Tnew rows are written, but ``length`` advances by
        ``n_valid``, so decode masks the pad tail (``k_pos < length``) and
        overwrites it in place, token by token.  The masked-prefill fix —
        see DESIGN §7 and the paged counterpart in
        ``repro.serve.paged_kv.PagedDenseKVCache.append`` (which drops pad
        writes outright).
        """
        B, Tnew = k_new.shape[:2]
        if Tnew == 1:
            S = self.k.shape[1]
            slot = jax.lax.broadcasted_iota(jnp.int32, (B, S), 1) == \
                self.length[:, None]                       # (B, S)
            m = slot[..., None, None]
            k = jnp.where(m, k_new.astype(self.k.dtype), self.k)
            v = jnp.where(m, v_new.astype(self.v.dtype), self.v)
            return DenseKVCache(k, v, self.length + 1)
        # All batch rows share the same length in our serving batches.
        k = jax.lax.dynamic_update_slice(self.k, k_new.astype(self.k.dtype),
                                         (0, self.length[0], 0, 0))
        v = jax.lax.dynamic_update_slice(self.v, v_new.astype(self.v.dtype),
                                         (0, self.length[0], 0, 0))
        adv = Tnew if n_valid is None else jnp.asarray(n_valid, jnp.int32)
        return DenseKVCache(k, v, self.length + adv)


class WindowKVCache(NamedTuple):
    k: jnp.ndarray        # (B, W, Hkv, d) ring buffer
    v: jnp.ndarray
    positions: jnp.ndarray  # (B, W) int32 original positions (-1 = empty)
    length: jnp.ndarray   # (B,) total tokens seen

    @classmethod
    def create(cls, batch, window, n_kv_heads, d_head, dtype=jnp.bfloat16):
        z = jnp.zeros((batch, window, n_kv_heads, d_head), dtype)
        pos = jnp.full((batch, window), -1, jnp.int32)
        return cls(z, z, pos, jnp.zeros((batch,), jnp.int32))

    def append_one(self, k_new, v_new):
        """k_new/v_new: (B, Hkv, d) — single decode step.

        Per-row ring slots (``length % W`` row by row): continuous batching
        refills slots mid-stream, so rows sit at different positions.  The
        masked elementwise update partitions cleanly for the same reason as
        ``DenseKVCache.append``.
        """
        B, W = self.positions.shape
        slot = (self.length % W)[:, None]                   # (B, 1)
        hit = jax.lax.broadcasted_iota(jnp.int32, (B, W), 1) == slot
        m = hit[..., None, None]
        k = jnp.where(m, k_new[:, None].astype(self.k.dtype), self.k)
        v = jnp.where(m, v_new[:, None].astype(self.v.dtype), self.v)
        pos = jnp.where(hit, self.length[:, None].astype(jnp.int32),
                        self.positions)
        return WindowKVCache(k, v, pos, self.length + 1)


class MLAKVCache(NamedTuple):
    latent: jnp.ndarray   # (B, S, kv_lora) compressed KV
    k_rope: jnp.ndarray   # (B, S, rope_dim) shared rotary key
    length: jnp.ndarray

    @classmethod
    def create(cls, batch, max_len, kv_lora, rope_dim, dtype=jnp.bfloat16):
        return cls(jnp.zeros((batch, max_len, kv_lora), dtype),
                   jnp.zeros((batch, max_len, rope_dim), dtype),
                   jnp.zeros((batch,), jnp.int32))

    def append(self, latent_new, k_rope_new, n_valid=None):
        B, Tnew = latent_new.shape[:2]
        if Tnew == 1:  # masked update — see DenseKVCache.append
            S = self.latent.shape[1]
            slot = jax.lax.broadcasted_iota(jnp.int32, (B, S), 1) == \
                self.length[:, None]
            lat = jnp.where(slot[..., None],
                            latent_new.astype(self.latent.dtype), self.latent)
            kr = jnp.where(slot[..., None],
                           k_rope_new.astype(self.k_rope.dtype), self.k_rope)
            return MLAKVCache(lat, kr, self.length + 1)
        start = self.length[0]
        lat = jax.lax.dynamic_update_slice(
            self.latent, latent_new.astype(self.latent.dtype), (0, start, 0))
        kr = jax.lax.dynamic_update_slice(
            self.k_rope, k_rope_new.astype(self.k_rope.dtype), (0, start, 0))
        adv = Tnew if n_valid is None else jnp.asarray(n_valid, jnp.int32)
        return MLAKVCache(lat, kr, self.length + adv)


def cache_nbytes(tree) -> int:
    """Total bytes of a cache pytree (the serving-side KV-memory metric)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


class MoSAKVCache(NamedTuple):
    """Streaming expert-choice cache: one top-k set per (batch, head).

    Eviction policy (evict-min on router scores) is implemented by
    ``repro.core.router.streaming_topk_update``; this type only defines the
    storage layout and the empty-slot sentinels (``scores = -inf``,
    ``idx = -1`` — see the module docstring).  ``idx`` is kept sorted
    ascending with empty slots last, matching the prefill/training-time
    ``select_topk`` convention.
    """

    k: jnp.ndarray        # (B, H, k, d) selected keys
    v: jnp.ndarray        # (B, H, k, d) selected values
    scores: jnp.ndarray   # (B, H, k) fp32 router scores; -inf = empty slot
    idx: jnp.ndarray      # (B, H, k) original positions; -1 = empty
    length: jnp.ndarray   # (B,) tokens seen

    @classmethod
    def create(cls, batch, n_heads, k, d_head, dtype=jnp.bfloat16):
        return cls(
            jnp.zeros((batch, n_heads, k, d_head), dtype),
            jnp.zeros((batch, n_heads, k, d_head), dtype),
            jnp.full((batch, n_heads, k), -jnp.inf, jnp.float32),
            jnp.full((batch, n_heads, k), -1, jnp.int32),
            jnp.zeros((batch,), jnp.int32))

    @property
    def kv_entries(self):
        return self.k.shape[1] * self.k.shape[2]  # H * k — the paper's KV metric


class MoSABlockKVCache(NamedTuple):
    """Streaming BLOCK-choice cache: one top-k set of KV *blocks* per
    (batch, head), plus one dedicated slot for the current (partial) block.

    Layout (``bs = sel_block_size``, ``CB`` candidate block slots):

      * ``k``/``v``   — (B, H, (CB+1)*bs, d) FLAT token rows, block-major;
        rows ``[s*bs, (s+1)*bs)`` belong to block slot ``s``.  Slot ``CB``
        (the last) is the CURRENT block being streamed.
      * ``pos``       — (B, H, (CB+1)*bs) int32 original token position per
        row; ``-1`` = empty/pad row.  Attention masks to ``pos >= 0``, so a
        ragged tail inside an otherwise-held block is never attended.  At
        ``bs = 1`` this is exactly ``MoSAKVCache.idx``.
      * ``bscore``    — (B, H, CB+1) fp32 per-block MEAN router score;
        ``-inf`` = empty slot (fills first under evict-min, exactly the
        token-cache sentinel).  Slot ``CB``'s entry is unused (-inf).
      * ``bidx``      — (B, H, CB+1) int32 block index; ``-1`` = empty.
        Candidate slots are kept sorted ascending with empties last (the
        ``select_topk`` convention); slot ``CB`` holds the in-progress
        block's index (or -1 before its first token).
      * ``bsum``      — (B, H) fp32 running sum of the current block's token
        scores — the only extra state streaming needs to finalize the mean.
      * ``length``    — (B,) tokens seen.

    Exactness invariant: only COMPLETED blocks (whose mean score is final
    and immutable) ever enter the candidate set; the partial current block
    rides in its dedicated slot verbatim.  Snapshots at block-aligned
    boundaries therefore see an empty current slot and fully-determined
    candidates — the basis of the paged prefix-hit bit-exactness (DESIGN
    §10).  Eviction policy is ``streaming_topk_update`` over ``bscore``,
    shared with the token cache.
    """

    k: jnp.ndarray        # (B, H, (CB+1)*bs, d)
    v: jnp.ndarray        # (B, H, (CB+1)*bs, d)
    pos: jnp.ndarray      # (B, H, (CB+1)*bs) int32; -1 = empty row
    bscore: jnp.ndarray   # (B, H, CB+1) fp32; -inf = empty slot
    bidx: jnp.ndarray     # (B, H, CB+1) int32; -1 = empty slot
    bsum: jnp.ndarray     # (B, H) fp32 current-block running score sum
    length: jnp.ndarray   # (B,) tokens seen

    @classmethod
    def create(cls, batch, n_heads, cb, block_size, d_head,
               dtype=jnp.bfloat16):
        rows = (cb + 1) * block_size
        return cls(
            jnp.zeros((batch, n_heads, rows, d_head), dtype),
            jnp.zeros((batch, n_heads, rows, d_head), dtype),
            jnp.full((batch, n_heads, rows), -1, jnp.int32),
            jnp.full((batch, n_heads, cb + 1), -jnp.inf, jnp.float32),
            jnp.full((batch, n_heads, cb + 1), -1, jnp.int32),
            jnp.zeros((batch, n_heads), jnp.float32),
            jnp.zeros((batch,), jnp.int32))

    @property
    def block_size(self):
        return self.k.shape[2] // self.bidx.shape[2]

    @property
    def n_cand(self):
        return self.bidx.shape[2] - 1  # CB — candidate slots, sans current

    @property
    def kv_entries(self):
        return self.k.shape[1] * self.k.shape[2]  # H * (CB+1) * bs
