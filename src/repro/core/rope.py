"""Rotary position embeddings, position-index aware.

MoSA gathers an arbitrary subset of tokens per head, so RoPE must be applied
at the *original* sequence positions (the gathered index vector ``I``), not at
``arange(k)``.  Everything here therefore takes an explicit ``positions``
array broadcastable to the leading dims of the input.

Also implements:
  * partial rotary (``rotary_frac`` — the paper rotates half the dims),
  * M-RoPE (qwen2-vl): the frequency dimension is split into (t, h, w)
    sections, each section driven by its own position component.
"""

from __future__ import annotations

import jax.numpy as jnp


def inv_freqs(d_rot: int, theta: float) -> jnp.ndarray:
    """(d_rot // 2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rope(x, positions, theta: float = 10000.0, rotary_frac: float = 1.0,
               mrope_sections: tuple = ()):
    """Apply RoPE at explicit positions.

    x:         (..., L, d) queries or keys.
    positions: (..., L) integer positions, broadcastable to x's leading dims;
               for M-RoPE: (3, ..., L) with (t, h, w) components.
    """
    d = x.shape[-1]
    d_rot = int(d * rotary_frac)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    x_rot, x_pass = x[..., :d_rot], x[..., d_rot:]
    freqs = inv_freqs(d_rot, theta)                       # (d_rot/2,)

    if mrope_sections:
        assert positions.ndim >= 1 and positions.shape[0] == 3, \
            "M-RoPE positions must have a leading (t,h,w) axis of size 3"
        assert sum(mrope_sections) == d_rot // 2, \
            f"mrope sections {mrope_sections} must sum to {d_rot // 2}"
        pos = positions.astype(jnp.float32)               # (3, ..., L)
        ang_all = pos[..., None] * freqs                  # (3, ..., L, d_rot/2)
        chunks = []
        off = 0
        for comp, sec in enumerate(mrope_sections):
            chunks.append(ang_all[comp, ..., off:off + sec])
            off += sec
        angles = jnp.concatenate(chunks, axis=-1)         # (..., L, d_rot/2)
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs

    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    cos = jnp.concatenate([cos, cos], axis=-1).astype(x.dtype)
    sin = jnp.concatenate([sin, sin], axis=-1).astype(x.dtype)
    x_rot = x_rot * cos + _rotate_half(x_rot) * sin
    if x_pass.shape[-1] == 0:
        return x_rot
    return jnp.concatenate([x_rot, x_pass], axis=-1)


def text_mrope_positions(positions):
    """Lift 1-D text positions to (3, ...) M-RoPE positions (t=h=w)."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
