"""The paper's FLOP accounting (App. A) — used for IsoFLOP matching.

These formulas reproduce the paper's published numbers exactly, and the tests
gate on that:
  * Table 4 forward-pass budgets (Tiny 54.76G … Large 1130.65G @ T=1024)
  * Table 5 FLOP-matched MoSA head counts (hybrid and pure)
"""

from __future__ import annotations

import dataclasses


def flops_dense_head(T: int, h: int, hp: int) -> int:
    """8hh'T (QKVO) + 4h'T^2 (attention)."""
    return 8 * h * hp * T + 4 * hp * T * T


def flops_mosa_head(T: int, k: int, h: int, hp: int) -> int:
    """8hh'k + 4h'k^2 + routing overhead (2hT + h'k)."""
    return 8 * h * hp * k + 4 * hp * k * k + 2 * h * T + hp * k


def flops_fixed_head(T: int, k: int, h: int, hp: int) -> int:
    return 8 * h * hp * k + 4 * hp * k * k


def flops_routing_head(T: int, k: int, h: int, hp: int) -> int:
    """rho (6hh'k + 4h'k^2) + 2h'T, rho = T/k (Q=K tying -> 3 projections)."""
    rho = T // k
    return rho * (6 * h * hp * k + 4 * hp * k * k) + 2 * hp * T


def flops_ffn(T: int, h: int, d_ff: int) -> int:
    """Two matmuls h<->d_ff: 4*h*d_ff*T  (paper uses d_ff=4h -> 16h^2T)."""
    return 4 * h * d_ff * T


@dataclasses.dataclass(frozen=True)
class PaperModel:
    """A dense baseline in the paper's hyperparameter space (App. C)."""

    name: str
    n_layers: int
    h: int
    d_ff: int
    hp: int
    n_heads: int

    def dense_flops(self, T: int = 1024) -> int:
        per_layer = self.n_heads * flops_dense_head(T, self.h, self.hp) \
            + flops_ffn(T, self.h, self.d_ff)
        return self.n_layers * per_layer

    def hybrid_mosa_heads(self, sparsity: int, T: int = 1024,
                          n_dense: int = 4) -> int:
        """Max MoSA heads s.t. hybrid FLOPs <= dense baseline (4 dense kept)."""
        k = T // sparsity
        budget = self.n_heads * flops_dense_head(T, self.h, self.hp)
        budget -= n_dense * flops_dense_head(T, self.h, self.hp)
        per = flops_mosa_head(T, k, self.h, self.hp)
        return max(0, budget // per)

    def pure_mosa_heads(self, sparsity: int, T: int = 1024) -> int:
        k = T // sparsity
        budget = self.n_heads * flops_dense_head(T, self.h, self.hp)
        return max(0, budget // flops_mosa_head(T, k, self.h, self.hp))

    def kv_total(self, T: int, n_dense: int, n_mosa: int, sparsity: int) -> int:
        """Paper's KV metric: KV = T*H_dense + k*H_mosa (Table 2)."""
        return T * n_dense + (T // sparsity) * n_mosa


# App. C, Table 4.
PAPER_MODELS = {
    "tiny": PaperModel("tiny", 6, 512, 2048, 64, 9),
    "small": PaperModel("small", 9, 1024, 4096, 64, 9),
    "medium": PaperModel("medium", 18, 1024, 4096, 64, 9),
    "large": PaperModel("large", 27, 1280, 5120, 64, 16),
}

# Published values for validation (Table 4, T=1024).
TABLE4_GFLOPS = {"tiny": 54.76, "small": 219.85, "medium": 430.70,
                 "large": 1130.65}

# Published hybrid-MoSA head counts (Table 5, bottom block).
TABLE5_HYBRID_HEADS = {
    "tiny": {2: 13, 4: 31, 8: 69, 16: 142, 32: 276, 64: 505, 128: 848, 256: 1277},
    "small": {2: 11, 4: 26, 8: 54, 16: 109, 32: 210, 64: 381},
}

# Table 5, pure-MoSA rows we can cross-check.
TABLE5_PURE_HEADS = {"tiny": {2: 23}}
