"""Dense attention family: GQA (with optional sliding window) and MLA.

Three implementations, selected by ``impl``:
  * ``naive``   — materializes the full (Tq, Tk) logits; test/tiny use.
  * ``chunked`` — pure-JAX flash: lax.scan over KV chunks carrying running
                  (max, denom, acc).  O(Tq·chunk) memory; this is what the
                  dry-run compiles (the Pallas kernel cannot lower on the CPU
                  backend) and it exhibits the same HLO roofline structure.
  * ``pallas``  — the TPU kernel from ``repro.kernels`` (validated in
                  interpret mode on CPU).

All softmax statistics are fp32 regardless of compute dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.core import rope as rope_lib
from repro.dist import hints
from repro.core.kv_cache import DenseKVCache, MLAKVCache, WindowKVCache
from repro.nn.layers import _trunc_normal
from repro.nn.module import logical
from repro.serve.paged_attention import (paged_attention_decode,
                                         paged_prefill_attention)
from repro.serve.paged_kv import PagedDenseKVCache, PagedWindowKVCache

NEG_INF = -1e30


def _mask_bias(q_pos, k_pos, window: int = 0, k_valid=None, q_seg=None,
               k_seg=None):
    """fp32 additive mask: causal (+ sliding window) from explicit positions.

    q_pos: (..., Tq), k_pos: (..., Tk) -> (..., Tq, Tk).
    ``q_seg``/``k_seg``: optional segment ids (packed rows, data/pipeline.py)
    — attention additionally requires seg_q == seg_k, so packed documents
    never leak into each other.
    """
    ok = q_pos[..., :, None] >= k_pos[..., None, :]
    if window > 0:
        ok &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    if q_seg is not None:
        ok &= q_seg[..., :, None] == k_seg[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def naive_attention(q, k, v, bias, scale):
    """q: (B,H,Tq,d), k/v: (B,H,Tk,d), bias: broadcastable (B,H,Tq,Tk)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(v.dtype)


def chunked_attention(q, k, v, q_pos, k_pos, scale, window: int = 0,
                      k_valid=None, chunk: int = 512, q_seg=None, k_seg=None):
    """Flash-style GQA attention via lax.scan over KV chunks.

    q: (B, Hq, Tq, d); k, v: (B, Hkv, Tk, d) with Hq % Hkv == 0 — the KV
    repeat is expressed inside the einsum (q reshaped to a (Hkv, n_rep)
    grouped head axis), never materialized.  q_pos: (B?, Tq) or (Tq,);
    k_pos: same for Tk.  ``q_seg``/``k_seg``: optional (B?, T) segment ids —
    packed rows additionally mask cross-segment pairs (see ``_mask_bias``).
    Returns (B, Hq, Tq, dv) in v.dtype.
    """
    B, Hq, Tq, d = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    R = Hq // Hkv
    dv = v.shape[-1]
    chunk = min(chunk, Tk)
    n_chunks = -(-Tk // chunk)
    pad = n_chunks * chunk - Tk
    ks = (None if k_seg is None
          else jnp.broadcast_to(k_seg, (B, Tk)).astype(jnp.int32))
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(jnp.broadcast_to(k_pos, (B, Tk)), ((0, 0), (0, pad)),
                     constant_values=jnp.iinfo(jnp.int32).max)
        kv_valid = jnp.pad(
            jnp.broadcast_to(k_valid if k_valid is not None
                             else jnp.ones((B, Tk), bool), (B, Tk)),
            ((0, 0), (0, pad)), constant_values=False)
        if ks is not None:
            ks = jnp.pad(ks, ((0, 0), (0, pad)), constant_values=-1)
    else:
        kp = jnp.broadcast_to(k_pos, (B, Tk))
        kv_valid = jnp.broadcast_to(
            k_valid if k_valid is not None else jnp.ones((B, Tk), bool), (B, Tk))

    qp = jnp.broadcast_to(q_pos, (B, Tq))
    qs = (None if q_seg is None
          else jnp.broadcast_to(q_seg, (B, Tq)).astype(jnp.int32))
    kc = k.reshape(B, Hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    kpc = kp.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    kvc = kv_valid.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    ksc = (jnp.zeros((n_chunks, B, chunk), jnp.int32) if ks is None
           else ks.reshape(B, n_chunks, chunk).transpose(1, 0, 2))

    qf = q.reshape(B, Hkv, R, Tq, d).astype(jnp.float32)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, kpb, kvb, ksb = inp
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qf, kb.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
        bias = _mask_bias(qp[:, None, None], kpb[:, None, None], window,
                          kvb[:, None, None],
                          None if qs is None else qs[:, None, None],
                          None if qs is None else ksb[:, None, None])
        s = s + bias
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bgkd->bgrqd", p, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, R, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, R, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, R, Tq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, kpc, kvc, ksc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Hq, Tq, dv).astype(v.dtype)


def gqa_attention(q, k, v, q_pos, k_pos, scale, window: int = 0,
                  k_valid=None, q_seg=None, k_seg=None):
    """Direct (unchunked) GQA attention — decode-friendly: the (Tq, Tk)
    logits materialize once, so a sequence-sharded KV cache shards them too.
    q: (B, Hq, Tq, d); k, v: (B, Hkv, Tk, d).
    """
    B, Hq, Tq, d = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    R = Hq // Hkv
    dv = v.shape[-1]
    qf = q.reshape(B, Hkv, R, Tq, d).astype(jnp.float32)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qf, k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    qp = jnp.broadcast_to(q_pos, (B, Tq))
    kp = jnp.broadcast_to(k_pos, (B, Tk))
    s = s + _mask_bias(qp[:, None, None], kp[:, None, None], window,
                       None if k_valid is None
                       else jnp.broadcast_to(k_valid, (B, Tk))[:, None, None],
                       None if q_seg is None else jnp.broadcast_to(
                           q_seg, (B, Tq))[:, None, None],
                       None if k_seg is None else jnp.broadcast_to(
                           k_seg, (B, Tk))[:, None, None])
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bgrqk,bgkd->bgrqd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(p.sum(-1), 1e-30)[..., None]
    return out.reshape(B, Hq, Tq, dv).astype(v.dtype)


@dataclasses.dataclass(frozen=True)
class MultiHeadAttention:
    """GQA attention with RoPE/M-RoPE, optional sliding window."""

    d_model: int
    cfg: AttentionConfig
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    impl: str = "chunked"         # naive | chunked | pallas
    rotary_frac: float = 1.0
    chunk: int = 512

    @property
    def _scale(self):
        return self.cfg.softmax_scale or self.cfg.d_head ** -0.5

    def init(self, key):
        c = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        std = self.d_model ** -0.5
        p = {
            "wq": _trunc_normal(k1, (self.d_model, c.n_heads * c.d_head), std, self.param_dtype),
            "wk": _trunc_normal(k2, (self.d_model, c.n_kv_heads * c.d_head), std, self.param_dtype),
            "wv": _trunc_normal(k3, (self.d_model, c.n_kv_heads * c.d_head), std, self.param_dtype),
            "wo": _trunc_normal(k4, (c.n_heads * c.d_head, self.d_model),
                                (c.n_heads * c.d_head) ** -0.5, self.param_dtype),
        }
        if c.qkv_bias:
            p["bq"] = jnp.zeros((c.n_heads * c.d_head,), self.param_dtype)
            p["bk"] = jnp.zeros((c.n_kv_heads * c.d_head,), self.param_dtype)
            p["bv"] = jnp.zeros((c.n_kv_heads * c.d_head,), self.param_dtype)
        return p

    def specs(self):
        s = {"wq": logical("embed", "heads"), "wk": logical("embed", "kv_heads"),
             "wv": logical("embed", "kv_heads"), "wo": logical("heads", "embed")}
        if self.cfg.qkv_bias:
            s.update(bq=logical("heads"), bk=logical("kv_heads"), bv=logical("kv_heads"))
        return s

    def _qkv(self, params, x):
        c, cd = self.cfg, self.compute_dtype
        B, T, _ = x.shape
        x = x.astype(cd)
        q = jnp.dot(x, params["wq"].astype(cd), preferred_element_type=jnp.float32)
        k = jnp.dot(x, params["wk"].astype(cd), preferred_element_type=jnp.float32)
        v = jnp.dot(x, params["wv"].astype(cd), preferred_element_type=jnp.float32)
        if c.qkv_bias:
            q = q + params["bq"].astype(jnp.float32)
            k = k + params["bk"].astype(jnp.float32)
            v = v + params["bv"].astype(jnp.float32)
        q = q.astype(cd).reshape(B, T, c.n_heads, c.d_head).transpose(0, 2, 1, 3)
        k = k.astype(cd).reshape(B, T, c.n_kv_heads, c.d_head).transpose(0, 2, 1, 3)
        v = v.astype(cd).reshape(B, T, c.n_kv_heads, c.d_head).transpose(0, 2, 1, 3)
        # Megatron-SP layout inside attention: heads sharded (tp), sequence
        # WHOLE — one gather here instead of one per KV chunk in the scan
        # (EXPERIMENTS.md §Perf it.5).  Skipped for decode (T == 1): a
        # heads-sharded single-token q conflicts with the seq-sharded cache
        # and forces a per-layer cache re-layout (§Perf cell-3 it.17).
        if T > 1:
            q = hints.constrain(q, ("dp", "tp", None, None))
            k = hints.constrain(k, ("dp", "tp", None, None))
            v = hints.constrain(v, ("dp", "tp", None, None))
        return q, k, v

    def _rope(self, t, positions):
        c = self.cfg
        if c.mrope_sections:
            if positions.shape[0] != 3:
                positions = rope_lib.text_mrope_positions(positions)
            pos = positions[:, :, None]  # (3, B, 1, T) broadcast over heads
            return rope_lib.apply_rope(t, pos, c.rope_theta, self.rotary_frac,
                                       c.mrope_sections)
        return rope_lib.apply_rope(t, positions[:, None], c.rope_theta,
                                   self.rotary_frac)

    def __call__(self, params, x, positions=None, segments=None):
        """Training / prefill-style full forward.  x: (B, T, h).

        ``segments``: optional (B, T) int32 document ids for packed rows
        (data/pipeline.py) — attention is causal WITHIN a document and never
        crosses a boundary.  Packed rows use per-doc ``positions`` so RoPE
        restarts at every boundary.  The ``pallas`` impl handles packed rows
        through the masked fused-XLA flash path (per-row doc counts are
        dynamic; the Pallas varlen kernel serves the single-stream
        ``kernels.ops.flash_attention_varlen`` entry used by serving).
        """
        c = self.cfg
        B, T, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T), (B, T))
        q, k, v = self._qkv(params, x)
        base_pos = positions if positions.ndim == 2 else positions[0]
        q = self._rope(q, positions)
        k = self._rope(k, positions)
        if segments is not None:
            # packed rows: per-doc positions are not globally monotone, so
            # causality needs the PACKED order; the seg-equality term then
            # confines attention to the document.
            packed_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
            out = chunked_attention(q, k, v, packed_pos, packed_pos,
                                    self._scale, window=c.window,
                                    chunk=self.chunk, q_seg=segments,
                                    k_seg=segments)
        elif self.impl == "naive":
            out = gqa_attention(q, k, v, base_pos, base_pos, self._scale,
                                window=c.window)
        elif self.impl == "pallas":
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, window=c.window)
        else:
            out = chunked_attention(q, k, v, base_pos, base_pos, self._scale,
                                    window=c.window, chunk=self.chunk)
        out = out.transpose(0, 2, 1, 3).reshape(B, T, c.n_heads * c.d_head)
        cd = self.compute_dtype
        return jnp.dot(out.astype(cd), params["wo"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)

    # ---- serving ----
    def prefill(self, params, x, cache, positions=None, valid=None):
        """``valid``: optional (B, T) bool — False marks right-pad tokens
        (the bucketed-prefill mask, DESIGN §7).  Causality already keeps
        right-pads out of every real token's attention; ``valid`` only
        drives how many tokens advance the cache ``length`` (pads are then
        progressively overwritten by decode, exactly like the contiguous
        cache's unwritten tail).  When ``cache.length > 0`` (paged caches
        restored from the prefix cache) the prompt suffix attends the
        cached past through ``gather`` — continued prefill."""
        if isinstance(cache, PagedWindowKVCache):
            return self._prefill_window_paged(params, x, cache, positions,
                                              valid)
        if isinstance(cache, PagedDenseKVCache):
            return self._prefill_dense_paged(params, x, cache, positions,
                                             valid)
        if isinstance(cache, WindowKVCache):
            return self._prefill_window(params, x, cache, positions, valid)
        c = self.cfg
        B, T, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T), (B, T))
        q, k, v = self._qkv(params, x)
        q = self._rope(q, positions)
        k = self._rope(k, positions)
        nv = None if valid is None else valid.sum(-1).astype(jnp.int32)
        cache = cache.append(k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                             n_valid=nv)
        base_pos = positions if positions.ndim == 2 else positions[0]
        out = chunked_attention(q, k, v, base_pos, base_pos,
                                self._scale, window=c.window, chunk=self.chunk)
        B_, H, T_, d = out.shape
        out = out.transpose(0, 2, 1, 3).reshape(B, T, H * d)
        cd = self.compute_dtype
        y = jnp.dot(out.astype(cd), params["wo"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        return y, cache

    def _prefill_dense_paged(self, params, x, cache: "PagedDenseKVCache",
                             positions=None, valid=None):
        """Paged dense prefill, past-aware: new K/V scatter into the row's
        pool blocks, then attention runs over the row's WHOLE gathered range
        (cached prefix + in-flight suffix) with a validity mask — one code
        path for fresh prefill (length == 0) and prefix-cache continuation
        (length == shared-prefix length)."""
        c = self.cfg
        B, T, _ = x.shape
        if positions is None:
            positions = cache.length[:, None] + \
                jnp.arange(T, dtype=jnp.int32)[None]
        q, k, v = self._qkv(params, x)
        q = self._rope(q, positions)
        k = self._rope(k, positions)
        nv = None if valid is None else valid.sum(-1).astype(jnp.int32)
        cache = cache.append(k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                             n_valid=nv)
        kk, vv = cache.gather()                        # (B, S, Hkv, d)
        S = kk.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        k_valid = k_pos < cache.length[:, None]
        base_pos = positions if positions.ndim == 2 else positions[0]
        out = chunked_attention(q, kk.transpose(0, 2, 1, 3),
                                vv.transpose(0, 2, 1, 3), base_pos, k_pos,
                                self._scale, window=c.window, k_valid=k_valid,
                                chunk=self.chunk)
        out = out.transpose(0, 2, 1, 3).reshape(B, T, -1)
        cd = self.compute_dtype
        y = jnp.dot(out.astype(cd), params["wo"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        return y, cache

    def _prefill_window_paged(self, params, x, cache: "PagedWindowKVCache",
                              positions=None, valid=None):
        """Paged window prefill, past-aware: the pre-append ring (gathered
        once) supplies the past keys — it holds the last W past tokens,
        which covers every key a suffix query's window can reach (W is
        min(cfg.window, max_len), so either the window bound or the whole
        past fits)."""
        c = self.cfg
        B, T, _ = x.shape
        if positions is None:
            positions = cache.length[:, None] + \
                jnp.arange(T, dtype=jnp.int32)[None]
        base_pos = positions if positions.ndim == 2 else positions[0]
        pk, pv = cache.gather()                        # past ring, pre-append
        ppos = cache.positions                         # (B, W)
        q, k, v = self._qkv(params, x)
        q = self._rope(q, positions)
        k = self._rope(k, positions)
        nv = None if valid is None else valid.sum(-1).astype(jnp.int32)
        cache = cache.append(k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                             n_valid=nv)
        k_all = jnp.concatenate([pk.transpose(0, 2, 1, 3), k], axis=2)
        v_all = jnp.concatenate([pv.transpose(0, 2, 1, 3), v], axis=2)
        kpos_all = jnp.concatenate(
            [ppos, jnp.broadcast_to(base_pos, (B, T))], axis=1)
        new_valid = (jnp.ones((B, T), bool) if valid is None
                     else jnp.broadcast_to(valid, (B, T)))
        k_valid = jnp.concatenate([ppos >= 0, new_valid], axis=1)
        out = chunked_attention(q, k_all, v_all, base_pos, kpos_all,
                                self._scale, window=c.window, k_valid=k_valid,
                                chunk=self.chunk)
        out = out.transpose(0, 2, 1, 3).reshape(B, T, -1)
        cd = self.compute_dtype
        y = jnp.dot(out.astype(cd), params["wo"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        return y, cache

    def prefill_packed(self, params, x, cache, meta):
        """Packed multi-segment chunked prefill (DESIGN §9).

        ``x``: (1, C, h) — a flattened chunk of N prompt segments, each
        continuing a different batch row's paged cache; ``meta`` is the
        packed layout from ``TransformerLM.prefill_packed``.  Requires a
        paged cache: the packed write primitive is ``append_packed`` and
        per-token KV indirection goes through block tables.
        """
        if isinstance(cache, PagedWindowKVCache):
            return self._prefill_packed_window(params, x, cache, meta)
        if isinstance(cache, PagedDenseKVCache):
            return self._prefill_packed_dense(params, x, cache, meta)
        raise ValueError(
            f"packed prefill requires a paged cache, got {type(cache).__name__}")

    def _prefill_packed_dense(self, params, x, cache: "PagedDenseKVCache",
                              meta):
        """Dense side of packed prefill: ONE pass over the packed stream.

        K/V scatter straight into each token's row blocks
        (``append_packed``); attention is the ragged-varlen paged kernel
        (``paged_prefill_attention``) — per-token causal over
        past + same-segment chunk prefix, never crossing segments.  This is
        the O(T²) side, so it is the one that genuinely computes on packed
        tokens (the window/MoSA sides are O(W)/O(k²) and unpack, see their
        docstrings)."""
        c = self.cfg
        assert c.window == 0, "dense paged cache implies window == 0"
        _, C, _ = x.shape
        pos = meta["pos_of_tok"][None]                     # (1, C)
        q, k, v = self._qkv(params, x)                     # (1, H, C, d)
        q = self._rope(q, pos)
        k = self._rope(k, pos)
        cache = cache.append_packed(k[0].transpose(1, 0, 2),
                                    v[0].transpose(1, 0, 2),
                                    meta["row_of_tok"], meta["pos_of_tok"])
        out = paged_prefill_attention(q[0].transpose(1, 0, 2), cache,
                                      meta["cu"], meta["rows"],
                                      meta["past_lens"], scale=self._scale)
        out = out.reshape(1, C, -1)
        cd = self.compute_dtype
        y = jnp.dot(out.astype(cd), params["wo"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        return y, cache

    def _prefill_packed_window(self, params, x, cache: "PagedWindowKVCache",
                               meta):
        """Window side of packed prefill: unpack to a (N, C) right-padded
        batch over a gathered N-row VIEW of the paged cache (row fields
        gathered, pools shared) and reuse ``_prefill_window_paged`` —
        per-segment rings, per-row lengths and valid masks already express
        the continued-chunk semantics.  Updated row fields scatter back
        (inactive segments clamp to row 0 for the gather; their appends are
        dropped by the zero valid mask and their write-back by ``rowd``).
        The O(N·C) re-projection is the price of sharing the ring math; the
        window side is O(W)-bounded, not the quadratic term."""
        B = cache.block_table.shape[0]
        rows = meta["rows"]
        rowc = jnp.clip(rows, 0, B - 1)
        rowd = jnp.where(rows < 0, B, rows)
        gc = PagedWindowKVCache(cache.k, cache.v, cache.block_table[rowc],
                                cache.positions[rowc], cache.length[rowc])
        xs = x[0][meta["tok_idx"]] * meta["in_seg"][..., None].astype(x.dtype)
        y_seg, gc2 = self._prefill_window_paged(params, xs, gc, None,
                                                meta["in_seg"])
        cache = PagedWindowKVCache(
            gc2.k, gc2.v, cache.block_table,
            cache.positions.at[rowd].set(gc2.positions, mode="drop"),
            cache.length.at[rowd].set(gc2.length, mode="drop"))
        segc = jnp.maximum(meta["seg_of_tok"], 0)
        y = y_seg[segc, meta["local_of_tok"]]              # (C, h)
        y = jnp.where((meta["row_of_tok"] >= 0)[:, None], y, 0.0)
        return y[None].astype(y_seg.dtype), cache

    def _prefill_window(self, params, x, cache: "WindowKVCache",
                        positions=None, valid=None):
        """Window prefill: run the full forward, keep the last W VALID
        tokens' KV.

        Kept tokens land at slot ``position % W`` — the SAME ring arithmetic
        ``WindowKVCache.append_one`` uses (slot ``length % W``) — so the
        first decode step after a prompt longer than the window overwrites
        the oldest kept token, not an arbitrary one.  With a ``valid`` mask
        (right-padded bucket prefill) the pads are dropped rather than
        cached, and ``length`` advances by the real token count only.
        """
        c = self.cfg
        B, T, _ = x.shape
        pos = positions if positions is not None else \
            jnp.broadcast_to(jnp.arange(T), (B, T))
        y = self(params, x, pos)
        q, k, v = self._qkv(params, x)
        k = self._rope(k, pos).transpose(0, 2, 1, 3)          # (B,T,Hkv,d)
        v = v.transpose(0, 2, 1, 3)
        W = cache.k.shape[1]
        nv = (jnp.full((B,), T, jnp.int32) if valid is None
              else valid.sum(-1).astype(jnp.int32))
        base_pos = pos if pos.ndim == 2 else pos[0]
        base_pos = jnp.broadcast_to(base_pos, (B, T)).astype(jnp.int32)
        t = jnp.arange(T, dtype=jnp.int32)[None, :]
        keep = (t < nv[:, None]) & (t >= nv[:, None] - W)
        slots = jnp.where(keep, base_pos % W, W)              # W -> dropped
        rows = jnp.arange(B)[:, None]
        kw = jnp.zeros_like(cache.k).at[rows, slots].set(
            k.astype(cache.k.dtype), mode="drop")
        vw = jnp.zeros_like(cache.v).at[rows, slots].set(
            v.astype(cache.v.dtype), mode="drop")
        posw = jnp.full_like(cache.positions, -1).at[rows, slots].set(
            base_pos, mode="drop")
        return y, WindowKVCache(kw, vw, posw, cache.length + nv)

    def _window_attend(self, params, q, kk, vv, kpos, pos):
        """Shared ring-decode attention: q (B,H,1,d); kk/vv (B,W,Hkv,d) in
        the RING layout (contiguous cache arrays or a paged ``gather()`` —
        bit-identical inputs give bit-identical outputs); kpos (B, W)."""
        c = self.cfg
        B = q.shape[0]
        kk = kk.transpose(0, 2, 1, 3).astype(q.dtype)         # (B,Hkv,W,d)
        vv = vv.transpose(0, 2, 1, 3).astype(q.dtype)
        Hkv, R = c.n_kv_heads, c.n_heads // c.n_kv_heads
        qg = q.reshape(B, Hkv, R, 1, c.d_head).astype(jnp.float32)
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qg,
                       kk.astype(jnp.float32)) * self._scale
        ok = (kpos >= 0)[:, None, None, None, :] & \
            (kpos[:, None, None, None, :] <= pos[:, None, None, :, None])
        s = jnp.where(ok, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(vv.dtype), vv)
        out = out.reshape(B, c.n_heads, 1, c.d_head)
        out = out.transpose(0, 2, 1, 3).reshape(B, 1, c.n_heads * c.d_head)
        cd = self.compute_dtype
        return jnp.dot(out.astype(cd), params["wo"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)

    def _decode_window(self, params, x, cache: "WindowKVCache", positions=None):
        B = x.shape[0]
        pos = cache.length[:, None] if positions is None else positions
        q, k, v = self._qkv(params, x)                        # (B,H,1,d)
        q = self._rope(q, pos)
        k = self._rope(k, pos)
        cache = cache.append_one(k[:, :, 0], v[:, :, 0])
        y = self._window_attend(params, q, cache.k, cache.v, cache.positions,
                                pos)
        return y, cache

    def _decode_window_paged(self, params, x, cache: "PagedWindowKVCache",
                             positions=None):
        B = x.shape[0]
        pos = cache.length[:, None] if positions is None else positions
        q, k, v = self._qkv(params, x)
        q = self._rope(q, pos)
        k = self._rope(k, pos)
        cache = cache.append_one(k[:, :, 0], v[:, :, 0])
        kk, vv = cache.gather()        # ring layout == WindowKVCache.k
        y = self._window_attend(params, q, kk, vv, cache.positions, pos)
        return y, cache

    def _decode_dense_paged(self, params, x, cache: "PagedDenseKVCache",
                            positions=None):
        """Paged dense decode: append into the row's pool blocks, then the
        paged-attention kernel (block-table indirect loads on TPU; the
        gather reference — the contiguous decode einsum bit-for-bit —
        elsewhere).  See ``repro.serve.paged_attention``."""
        c = self.cfg
        B = x.shape[0]
        pos = cache.length[:, None] if positions is None else positions
        q, k, v = self._qkv(params, x)                     # (B, H, 1, d)
        q = self._rope(q, pos)
        k = self._rope(k, pos)
        cache = cache.append(k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
        out = paged_attention_decode(q[:, :, 0], cache, scale=self._scale)
        out = out.reshape(B, 1, c.n_heads * c.d_head)
        cd = self.compute_dtype
        y = jnp.dot(out.astype(cd), params["wo"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        return y, cache

    def decode_step(self, params, x, cache, positions=None):
        """x: (B, 1, h); attends over the cache + itself."""
        if isinstance(cache, PagedWindowKVCache):
            return self._decode_window_paged(params, x, cache, positions)
        if isinstance(cache, PagedDenseKVCache):
            return self._decode_dense_paged(params, x, cache, positions)
        if isinstance(cache, WindowKVCache):
            return self._decode_window(params, x, cache, positions)
        c = self.cfg
        B = x.shape[0]
        pos = cache.length[:, None] if positions is None else positions
        q, k, v = self._qkv(params, x)                     # (B, H, 1, d)
        q = self._rope(q, pos)
        k = self._rope(k, pos)
        cache = cache.append(k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
        S = cache.k.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        k_valid = k_pos < cache.length[:, None]
        # attention in the CACHE's native (B, S, Hkv, d) layout: transposing a
        # sequence-sharded cache forces a per-layer all-gather (§Perf cell-3
        # it.16), while einsum contracts any layout for free.  Same story for
        # the head-sharded layout CACHE_AXES assigns under the tp rule sets
        # (DESIGN §6): g stays a batching dim of the einsum, so a
        # model-sharded cache never relayouts during fused decode.
        Hkv = c.n_kv_heads
        R = c.n_heads // Hkv
        qg = q.reshape(B, Hkv, R, 1, c.d_head).astype(jnp.float32)
        s = jnp.einsum("bgrqd,bsgd->bgrqs", qg,
                       cache.k.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * self._scale
        ok = (pos[:, None, None, :, None] >= k_pos[:, None, None, None, :]) \
            & k_valid[:, None, None, None, :]
        if c.window:
            ok &= (pos[:, None, None, :, None] -
                   k_pos[:, None, None, None, :]) < c.window
        s = jnp.where(ok, s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("bgrqs,bsgd->bgrqd", p,
                         cache.v.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        out = out / jnp.maximum(p.sum(-1), 1e-30)[..., None]
        out = out.astype(self.compute_dtype)
        out = out.reshape(B, c.n_heads, 1, c.d_head)
        out = out.transpose(0, 2, 1, 3).reshape(B, 1, c.n_heads * c.d_head)
        cd = self.compute_dtype
        y = jnp.dot(out.astype(cd), params["wo"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        return y, cache


@dataclasses.dataclass(frozen=True)
class MLAAttention:
    """DeepSeek-V2 Multi-head Latent Attention (v2-lite flavor: dense q)."""

    d_model: int
    cfg: AttentionConfig
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    impl: str = "chunked"
    chunk: int = 512

    def init(self, key):
        c, m = self.cfg, self.cfg.mla
        ks = jax.random.split(key, 6)
        std = self.d_model ** -0.5
        H = c.n_heads
        qd = m.nope_head_dim + m.rope_head_dim
        return {
            "wq": _trunc_normal(ks[0], (self.d_model, H * qd), std, self.param_dtype),
            "w_dkv": _trunc_normal(ks[1], (self.d_model, m.kv_lora_rank + m.rope_head_dim),
                                   std, self.param_dtype),
            "kv_norm": jnp.ones((m.kv_lora_rank,), self.param_dtype),
            "w_uk": _trunc_normal(ks[2], (m.kv_lora_rank, H * m.nope_head_dim),
                                  m.kv_lora_rank ** -0.5, self.param_dtype),
            "w_uv": _trunc_normal(ks[3], (m.kv_lora_rank, H * m.v_head_dim),
                                  m.kv_lora_rank ** -0.5, self.param_dtype),
            "wo": _trunc_normal(ks[4], (H * m.v_head_dim, self.d_model),
                                (H * m.v_head_dim) ** -0.5, self.param_dtype),
        }

    def specs(self):
        return {"wq": logical("embed", "heads"),
                "w_dkv": logical("embed", None),
                "kv_norm": logical(None),
                "w_uk": logical(None, "heads"),
                "w_uv": logical(None, "heads"),
                "wo": logical("heads", "embed")}

    def _latent(self, params, x):
        """x -> (latent (B,T,r) rms-normed, k_rope (B,T,rope_dim) unrotated)."""
        m = self.cfg.mla
        cd = self.compute_dtype
        dkv = jnp.dot(x.astype(cd), params["w_dkv"].astype(cd),
                      preferred_element_type=jnp.float32)
        lat, k_rope = dkv[..., :m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
        latf = lat.astype(jnp.float32)
        lat = latf * jax.lax.rsqrt(jnp.mean(latf ** 2, -1, keepdims=True) + 1e-6)
        lat = (lat * params["kv_norm"].astype(jnp.float32)).astype(cd)
        return lat, k_rope.astype(cd)

    def __call__(self, params, x, positions=None, segments=None):
        c, m = self.cfg, self.cfg.mla
        cd = self.compute_dtype
        B, T, _ = x.shape
        H = c.n_heads
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T), (B, T))
        qd = m.nope_head_dim + m.rope_head_dim
        q = jnp.dot(x.astype(cd), params["wq"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        q = q.reshape(B, T, H, qd).transpose(0, 2, 1, 3)
        q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
        q_rope = rope_lib.apply_rope(q_rope, positions[:, None], c.rope_theta)

        lat, k_rope = self._latent(params, x)
        k_rope = rope_lib.apply_rope(k_rope[:, None], positions[:, None],
                                     c.rope_theta)                   # (B,1,T,rd)
        k_nope = jnp.dot(lat, params["w_uk"].astype(cd),
                         preferred_element_type=jnp.float32).astype(cd)
        k_nope = k_nope.reshape(B, T, H, m.nope_head_dim).transpose(0, 2, 1, 3)
        v = jnp.dot(lat, params["w_uv"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        v = v.reshape(B, T, H, m.v_head_dim).transpose(0, 2, 1, 3)

        # Assemble full q/k with the shared rotary part broadcast to all heads.
        qk_scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, H, T, m.rope_head_dim))], axis=-1)
        if segments is not None:
            packed_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
            out = chunked_attention(q_full, k_full, v, packed_pos, packed_pos,
                                    qk_scale, chunk=self.chunk,
                                    q_seg=segments, k_seg=segments)
        else:
            out = chunked_attention(q_full, k_full, v, positions, positions,
                                    qk_scale, chunk=self.chunk)
        out = out.transpose(0, 2, 1, 3).reshape(B, T, H * m.v_head_dim)
        return jnp.dot(out.astype(cd), params["wo"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)

    def prefill(self, params, x, cache: MLAKVCache, positions=None,
                valid=None):
        m = self.cfg.mla
        B, T, _ = x.shape
        lat, k_rope_raw = self._latent(params, x)
        nv = None if valid is None else valid.sum(-1).astype(jnp.int32)
        cache = cache.append(lat, k_rope_raw, n_valid=nv)  # unrotated k_rope
        y = self(params, x, positions)
        return y, cache

    def decode_step(self, params, x, cache: MLAKVCache, positions=None):
        c, m = self.cfg, self.cfg.mla
        cd = self.compute_dtype
        B = x.shape[0]
        H = c.n_heads
        pos = cache.length[:, None] if positions is None else positions
        lat_new, k_rope_new = self._latent(params, x)
        cache = cache.append(lat_new, k_rope_new)
        S = cache.latent.shape[1]

        qd = m.nope_head_dim + m.rope_head_dim
        q = jnp.dot(x.astype(cd), params["wq"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        q = q.reshape(B, 1, H, qd).transpose(0, 2, 1, 3)
        q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
        q_rope = rope_lib.apply_rope(q_rope, pos[:, None], c.rope_theta)

        k_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        k_valid = k_pos < cache.length[:, None]
        k_rope = rope_lib.apply_rope(cache.k_rope[:, None].astype(cd),
                                     k_pos[:, None], c.rope_theta)
        lat = cache.latent.astype(cd)
        k_nope = jnp.dot(lat, params["w_uk"].astype(cd),
                         preferred_element_type=jnp.float32).astype(cd)
        k_nope = k_nope.reshape(B, S, H, m.nope_head_dim).transpose(0, 2, 1, 3)
        v = jnp.dot(lat, params["w_uv"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        v = v.reshape(B, S, H, m.v_head_dim).transpose(0, 2, 1, 3)

        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, H, S, m.rope_head_dim))], axis=-1)
        out = gqa_attention(q_full, k_full, v, pos, k_pos,
                            (m.nope_head_dim + m.rope_head_dim) ** -0.5,
                            k_valid=k_valid)
        out = out.transpose(0, 2, 1, 3).reshape(B, 1, H * m.v_head_dim)
        y = jnp.dot(out.astype(cd), params["wo"].astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
        return y, cache
