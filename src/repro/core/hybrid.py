"""Hybrid attention layer: a few dense (or local) heads + many sparse heads.

The paper's main configuration (App. B: 4 dense heads is the sparsity-
agnostic optimum; §3.4 swaps dense for sliding-window local heads on long
sequences).  ``variant`` selects the sparse side: the paper's MoSA, or its
two baselines (fixed / routing) for the IsoFLOP comparisons.

Head contributions are summed (each side carries its own output projection,
eq. 2/3 of the paper).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig, MoSAConfig
from repro.core.attention import MultiHeadAttention
from repro.core.baselines import FixedSparseAttention, RoutingAttention
from repro.core.kv_cache import (DenseKVCache, MoSABlockKVCache, MoSAKVCache,
                                 WindowKVCache)
from repro.core.mosa import MoSAAttention
from repro.nn.module import logical


@dataclasses.dataclass(frozen=True)
class HybridAttention:
    d_model: int
    cfg: MoSAConfig
    rope_theta: float = 10000.0
    rotary_frac: float = 0.5
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    variant: str = "mosa"            # mosa | fixed | routing
    impl: str = "einsum"             # inner attention impl for the sparse side
    dense_impl: str = "chunked"

    def _dense(self):
        c = self.cfg
        acfg = AttentionConfig(
            kind="gqa", n_heads=c.n_dense_heads, n_kv_heads=c.n_dense_heads,
            d_head=c.d_head, rope_theta=self.rope_theta,
            window=c.local_window)
        return MultiHeadAttention(self.d_model, acfg, self.param_dtype,
                                  self.compute_dtype, impl=self.dense_impl,
                                  rotary_frac=self.rotary_frac)

    def _sparse(self):
        c = self.cfg
        if self.variant == "mosa":
            return MoSAAttention(self.d_model, c, self.rope_theta,
                                 self.rotary_frac, self.param_dtype,
                                 self.compute_dtype, impl=self.impl)
        if self.variant == "fixed":
            return FixedSparseAttention(self.d_model, c.n_mosa_heads, c.d_head,
                                        c.sparsity, self.rope_theta,
                                        self.rotary_frac, self.param_dtype,
                                        self.compute_dtype)
        if self.variant == "routing":
            # FLOP-wise one routing head ~ rho MoSA heads (paper §3.2).
            n = max(1, c.n_mosa_heads // c.sparsity)
            return RoutingAttention(self.d_model, n, c.d_head, c.sparsity,
                                    self.rope_theta, self.rotary_frac,
                                    param_dtype=self.param_dtype,
                                    compute_dtype=self.compute_dtype)
        raise ValueError(self.variant)

    def _gated(self) -> bool:
        """Gate-combined selected+window form (DESIGN §10): in BLOCK-choice
        mode with a sliding-window dense side, the two branches are blended
        with learned per-token sigmoid gates (the NSA g_slc/g_swa idiom)
        instead of summed.  Token-choice and windowless configs keep the
        paper's plain head-sum — the bit-exactness invariants depend on it.
        """
        c = self.cfg
        return (self.variant == "mosa"
                and c.selection_granularity == "block"
                and c.local_window > 0 and c.n_dense_heads > 0)

    def init(self, key):
        kd, ks = jax.random.split(key)
        p = {"sparse": self._sparse().init(ks)}
        if self.cfg.n_dense_heads > 0:
            p["dense"] = self._dense().init(kd)
        if self._gated():
            # zero init: gates open at 0.5/0.5 — the summed form halved,
            # so training starts from an equivalent loss surface.
            p["gate"] = jnp.zeros((self.d_model, 2), self.param_dtype)
        return p

    def specs(self):
        s = {"sparse": self._sparse().specs()}
        if self.cfg.n_dense_heads > 0:
            s["dense"] = self._dense().specs()
        if self._gated():
            s["gate"] = logical("embed", None)
        return s

    def _combine(self, params, x, ys, yd):
        """Merge sparse and dense branch outputs: plain sum, or the learned
        per-token gates when ``_gated()`` (block-choice + window)."""
        if yd is None:
            return ys
        if self._gated():
            g = jax.nn.sigmoid(jnp.einsum(
                "bth,hg->btg", x.astype(jnp.float32),
                params["gate"].astype(jnp.float32),
                preferred_element_type=jnp.float32))
            out = (ys.astype(jnp.float32) * g[..., 0:1]
                   + yd.astype(jnp.float32) * g[..., 1:2])
            return out.astype(ys.dtype)
        return ys + yd

    def __call__(self, params, x, positions=None, segments=None):
        if segments is None:
            y = self._sparse()(params["sparse"], x, positions)
            yd = (self._dense()(params["dense"], x, positions)
                  if self.cfg.n_dense_heads > 0 else None)
            return self._combine(params, x, y, yd)
        # packed rows (data/pipeline.py): both sides mask cross-document
        # attention; the baselines don't take segments (train-only variants).
        y = self._sparse()(params["sparse"], x, positions, segments=segments)
        yd = (self._dense()(params["dense"], x, positions, segments=segments)
              if self.cfg.n_dense_heads > 0 else None)
        return self._combine(params, x, y, yd)

    def router_health(self, params, x):
        """Expert-choice health of the sparse side (train-loop telemetry);
        None for the fixed/routing baselines, which have no learned router."""
        sparse = self._sparse()
        if not hasattr(sparse, "router_health"):
            return None
        return sparse.router_health(params["sparse"], x)

    # ---------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   paged=None):
        """``paged``: optional ``repro.serve.paged_kv.PagedConfig`` — the
        dense/window side then uses block-paged pools (DESIGN §7).  The MoSA
        cache stays unpaged either way: it is already O(k) per head."""
        c = self.cfg
        k = self._sparse_k(max_len)
        if c.selection_granularity == "block":
            bs = c.sel_block_size
            cb = -(-min(k, max_len) // bs)     # capacity in BLOCKS
            caches = {"sparse": MoSABlockKVCache.create(
                batch, c.n_mosa_heads, cb, bs, c.d_head, dtype)}
        else:
            caches = {"sparse": MoSAKVCache.create(
                batch, c.n_mosa_heads, min(k, max_len), c.d_head, dtype)}
        if c.n_dense_heads > 0:
            if c.local_window > 0:
                if paged is not None:
                    from repro.serve.paged_kv import PagedWindowKVCache
                    caches["dense"] = PagedWindowKVCache.create(
                        batch, min(c.local_window, max_len), c.n_dense_heads,
                        c.d_head, dtype, block_size=paged.block_size,
                        num_blocks=paged.num_window_blocks,
                        identity_tables=paged.num_window_blocks == 0)
                else:
                    caches["dense"] = WindowKVCache.create(
                        batch, c.local_window, c.n_dense_heads, c.d_head,
                        dtype)
            elif paged is not None:
                from repro.serve.paged_kv import PagedDenseKVCache
                caches["dense"] = PagedDenseKVCache.create(
                    batch, max_len, c.n_dense_heads, c.d_head, dtype,
                    block_size=paged.block_size, num_blocks=paged.num_blocks,
                    identity_tables=paged.num_blocks == 0)
            else:
                caches["dense"] = DenseKVCache.create(
                    batch, max_len, c.n_dense_heads, c.d_head, dtype)
        return caches

    def prefill(self, params, x, caches, positions=None, valid=None,
                continued=False):
        """``continued`` (static): the caches hold a restored prompt prefix
        (prefix-cache hit) — the sparse side extends it through the exact
        union selection of ``MoSAAttention.prefill_past``; the dense side's
        paged prefill is past-aware through its cache ``length`` alone."""
        assert self.variant == "mosa", "serving path implemented for MoSA"
        sparse = self._sparse()
        if continued:
            y, sc = sparse.prefill_past(params["sparse"], x, caches["sparse"],
                                        positions, valid)
        else:
            y, sc = sparse.prefill(params["sparse"], x, caches["sparse"],
                                   positions, valid)
        out = dict(caches, sparse=sc)
        yd = None
        if self.cfg.n_dense_heads > 0:
            yd, dc = self._dense().prefill(params["dense"], x, caches["dense"],
                                           positions, valid)
            out["dense"] = dc
        return self._combine(params, x, y, yd), out

    def prefill_packed(self, params, x, caches, meta):
        """Packed multi-segment chunked prefill (DESIGN §9): the sparse side
        runs per-segment union selection (``MoSAAttention.prefill_packed``),
        the dense side its paged packed path."""
        assert self.variant == "mosa", "serving path implemented for MoSA"
        y, sc = self._sparse().prefill_packed(params["sparse"], x,
                                              caches["sparse"], meta)
        out = dict(caches, sparse=sc)
        yd = None
        if self.cfg.n_dense_heads > 0:
            yd, dc = self._dense().prefill_packed(params["dense"], x,
                                                  caches["dense"], meta)
            out["dense"] = dc
        return self._combine(params, x, y, yd), out

    def decode_step(self, params, x, caches, positions=None):
        assert self.variant == "mosa"
        y, sc = self._sparse().decode_step(params["sparse"], x,
                                           caches["sparse"], positions)
        out = dict(caches, sparse=sc)
        yd = None
        if self.cfg.n_dense_heads > 0:
            yd, dc = self._dense().decode_step(params["dense"], x,
                                               caches["dense"], positions)
            out["dense"] = dc
        return self._combine(params, x, y, yd), out

    def kv_total(self, T: int) -> int:
        """Paper Table 2 metric: KV = T*H_dense + k*H_mosa (window caps dense)."""
        c = self.cfg
        dense_T = min(T, c.local_window) if c.local_window > 0 else T
        return dense_T * c.n_dense_heads + self._sparse_k(T) * c.n_mosa_heads

    def _sparse_k(self, T: int) -> int:
        # Mirrors MoSAAttention.k_for, including the cap at T: without it
        # kv_total / init_cache would overstate KV for T < min_k.
        if self.cfg.k_fixed > 0:
            return min(self.cfg.k_fixed, T)
        return min(max(T // self.cfg.sparsity, self.cfg.min_k), T)
