# Tier-1 verification targets.  `make ci` is the gate: collection must exit 0
# (no module may break imports again) before the full suite runs.

PY ?= python
export PYTHONPATH := src

.PHONY: test collect lint smoke test-paged test-train test-property \
    test-blockchoice test-obs test-slo bench-smoke bench-train bench-check ci

# Tier-1 command from ROADMAP.md
test:
	$(PY) -m pytest -x -q

# Collection as a checked step: 9 of 13 seed test files once failed to even
# import; this target keeps that class of regression impossible to miss.
collect:
	$(PY) -m pytest -q --collect-only > /dev/null
	@echo "collection OK"

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	@echo "lint OK (compileall)"

# Fast signal: the dist substrate, kernels, and core MoSA math
smoke:
	$(PY) -m pytest -q tests/test_sharding_rules.py tests/test_substrates.py \
	    tests/test_dist_unit.py tests/test_mosa_core.py

# Paged-KV parity suite (PR 3): allocator invariants, paged==contiguous
# decode, prefix cache, preemption.  Pinned to CPU — with libtpu in the
# image an unset JAX_PLATFORMS probes for absent TPUs and hangs.
test-paged:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q tests/test_paged_kv.py \
	    tests/test_paged_serving.py

# Training subsystem suite (PR 4, DESIGN §8): fused-kernel VJP parity vs
# jax.grad of the reference (interpret mode), microbatch/mixed-precision/
# remat invariance, SIGTERM resume parity, IsoFLOP smoke sweep.  CPU-pinned
# like test-paged (libtpu probe hangs).
test-train:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q tests/test_train_grad.py \
	    tests/test_train_subsystem.py

# Property tests must EXECUTE: a missing hypothesis falls back to the
# vendored tests/_property_harness.py shim (collection fails loudly if
# even that breaks), and ANY skip in these files fails this target — the
# pre-ISSUE-6 importorskip silently shelved them for four PRs.
test-property:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q -rs tests/test_property.py \
	    tests/test_paged_kv.py > .prop_report.txt 2>&1 \
	    || { cat .prop_report.txt; rm -f .prop_report.txt; exit 1; }
	@cat .prop_report.txt
	@if grep -qE "[0-9]+ skipped" .prop_report.txt; then \
	    rm -f .prop_report.txt; \
	    echo "FAIL: property tests were SKIPPED (harness missing?)"; \
	    exit 1; \
	fi
	@rm -f .prop_report.txt

# Block-choice MoSA suite (DESIGN §10): the sel_block_size=1 == token-choice
# bitwise invariant (kernel/layer/LM fwd+bwd), block kernels vs oracle,
# chunked-prefill/decode cache parity, the property layer, and EXACT paged
# prefix hits through the Scheduler.  CPU-pinned (libtpu probe hangs).
test-blockchoice:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q -rs tests/test_block_choice.py \
	    > .blk_report.txt 2>&1 \
	    || { cat .blk_report.txt; rm -f .blk_report.txt; exit 1; }
	@cat .blk_report.txt
	@if grep -qE "[0-9]+ skipped" .blk_report.txt; then \
	    rm -f .blk_report.txt; \
	    echo "FAIL: block-choice tests were SKIPPED"; \
	    exit 1; \
	fi
	@rm -f .blk_report.txt

# Observability suite (DESIGN §11): registry/histogram quantile units,
# tracer + Chrome-trace validity, the scheduler counter-consistency drain
# property, device-metrics parity under jit + donated buffers, the
# obs-off zero-write guarantee, and the Scheduler/Trainer artifact dump
# paths.  0-skip gated like test-property.  CPU-pinned (libtpu probe
# hangs).
test-obs:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q -rs tests/test_obs.py \
	    > .obs_report.txt 2>&1 \
	    || { cat .obs_report.txt; rm -f .obs_report.txt; exit 1; }
	@cat .obs_report.txt
	@if grep -qE "[0-9]+ skipped" .obs_report.txt; then \
	    rm -f .obs_report.txt; \
	    echo "FAIL: observability tests were SKIPPED"; \
	    exit 1; \
	fi
	@rm -f .obs_report.txt

# SLO/load-harness suite (DESIGN §12): labeled series + snapshot merge
# (incl. the K-process order-independence property), prometheus label
# round-trip, tracer drop accounting, seeded load generators, the timed
# Scheduler under open/closed-loop traffic, shedding, and the
# Scheduler.records == records_from_spans parity.  0-skip gated like
# test-obs.  CPU-pinned (libtpu probe hangs).
test-slo:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q -rs tests/test_slo.py \
	    > .slo_report.txt 2>&1 \
	    || { cat .slo_report.txt; rm -f .slo_report.txt; exit 1; }
	@cat .slo_report.txt
	@if grep -qE "[0-9]+ skipped" .slo_report.txt; then \
	    rm -f .slo_report.txt; \
	    echo "FAIL: SLO/load-harness tests were SKIPPED"; \
	    exit 1; \
	fi
	@rm -f .slo_report.txt

# Decode-path perf trajectory: refreshes the TRACKED BENCH_serve.json
# (fused vs per-token decode tok/s, MoSA vs dense KV bytes, and the paged
# family: paged vs contiguous tok/s + capacity at fixed budget; CPU, tiny
# scale).  Each refresh appends a trajectory entry.
bench-smoke:
	$(PY) -m benchmarks.serve_bench --out BENCH_serve.json

# Train-step perf trajectory: refreshes the TRACKED BENCH_train.json
# (dense vs MoSA-reference vs MoSA-fused-VJP step time + tokens/s, grad-
# accumulation overhead; CPU, tiny scale — DESIGN §8 honesty note).
bench-train:
	JAX_PLATFORMS=cpu $(PY) -m benchmarks.train_bench --out BENCH_train.json

# Fails if the newest trajectory entry regresses throughput by >10%
# against the previous entry (serve: fused decode variants; train: the
# compiled dense / mosa_ref step paths), if packed prefill efficiency
# drops under its floor, if obs_overhead exceeds the 2% ceiling
# (DESIGN §11), or if the SLO overload sweep loses its graceful-
# degradation shape (DESIGN §12).
bench-check:
	$(PY) -m benchmarks.serve_bench --check --out BENCH_serve.json
	$(PY) -m benchmarks.train_bench --check --out BENCH_train.json

# bench-smoke/bench-train run BEFORE test: the suite validates the
# regenerated artifacts, so what this ci run leaves behind is what passed;
# bench-check then gates the refreshed trajectories.
ci: lint collect test-paged test-train test-property test-blockchoice \
    test-obs test-slo bench-smoke bench-train bench-check test
