# Tier-1 verification targets.  `make ci` is the gate: collection must exit 0
# (no module may break imports again) before the full suite runs.

PY ?= python
export PYTHONPATH := src

.PHONY: test collect lint smoke bench-smoke ci

# Tier-1 command from ROADMAP.md
test:
	$(PY) -m pytest -x -q

# Collection as a checked step: 9 of 13 seed test files once failed to even
# import; this target keeps that class of regression impossible to miss.
collect:
	$(PY) -m pytest -q --collect-only > /dev/null
	@echo "collection OK"

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	@echo "lint OK (compileall)"

# Fast signal: the dist substrate, kernels, and core MoSA math
smoke:
	$(PY) -m pytest -q tests/test_sharding_rules.py tests/test_substrates.py \
	    tests/test_dist_unit.py tests/test_mosa_core.py

# Decode-path perf trajectory: refreshes the TRACKED BENCH_serve.json
# (fused vs per-token decode tok/s + MoSA vs dense KV bytes; CPU, tiny scale).
bench-smoke:
	$(PY) -m benchmarks.serve_bench --out BENCH_serve.json

# bench-smoke runs BEFORE test: the suite validates the regenerated
# BENCH_serve.json, so the artifact this ci run leaves behind is the one
# that passed.
ci: lint collect bench-smoke test
