# Tier-1 verification targets.  `make ci` is the gate: collection must exit 0
# (no module may break imports again) before the full suite runs.

PY ?= python
export PYTHONPATH := src

.PHONY: test collect lint smoke test-paged bench-smoke bench-check ci

# Tier-1 command from ROADMAP.md
test:
	$(PY) -m pytest -x -q

# Collection as a checked step: 9 of 13 seed test files once failed to even
# import; this target keeps that class of regression impossible to miss.
collect:
	$(PY) -m pytest -q --collect-only > /dev/null
	@echo "collection OK"

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	@echo "lint OK (compileall)"

# Fast signal: the dist substrate, kernels, and core MoSA math
smoke:
	$(PY) -m pytest -q tests/test_sharding_rules.py tests/test_substrates.py \
	    tests/test_dist_unit.py tests/test_mosa_core.py

# Paged-KV parity suite (PR 3): allocator invariants, paged==contiguous
# decode, prefix cache, preemption.  Pinned to CPU — with libtpu in the
# image an unset JAX_PLATFORMS probes for absent TPUs and hangs.
test-paged:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q tests/test_paged_kv.py \
	    tests/test_paged_serving.py

# Decode-path perf trajectory: refreshes the TRACKED BENCH_serve.json
# (fused vs per-token decode tok/s, MoSA vs dense KV bytes, and the paged
# family: paged vs contiguous tok/s + capacity at fixed budget; CPU, tiny
# scale).  Each refresh appends a trajectory entry.
bench-smoke:
	$(PY) -m benchmarks.serve_bench --out BENCH_serve.json

# Fails if the newest trajectory entry regresses fused decode throughput
# by >10% against the previous entry.
bench-check:
	$(PY) -m benchmarks.serve_bench --check --out BENCH_serve.json

# bench-smoke runs BEFORE test: the suite validates the regenerated
# BENCH_serve.json, so the artifact this ci run leaves behind is the one
# that passed; bench-check then gates the refreshed trajectory.
ci: lint collect test-paged bench-smoke bench-check test
