"""End-to-end training driver example.

Default (CPU-friendly): the paper's Tiny MoSA hybrid, reduced to 2 layers,
a few hundred steps on the synthetic corpus, with checkpointing enabled —
kill it mid-run and start it again to watch it resume.

At scale (TPU pod), the same entry point trains the real thing:

    python examples/train_lm.py --full --size small --sparsity 32 \\
        --steps 100000 --batch 64 --seq 1024       # the paper's Table 1 run

Usage (CPU demo):
    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses

from repro.configs.mosa_paper import paper_config
from repro.launch.train import TrainConfig, Trainer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--size", default="tiny")
    p.add_argument("--variant", default="mosa",
                   choices=["dense", "mosa", "fixed", "routing", "pure"])
    p.add_argument("--sparsity", type=int, default=8)
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--full", action="store_true",
                   help="train the full-size paper model (TPU scale)")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = p.parse_args()

    model_cfg = paper_config(args.size, args.variant, args.sparsity,
                             seq_len=args.seq)
    if not args.full:  # shrink for CPU
        pat = model_cfg.pattern[:2] if model_cfg.pattern else ()
        model_cfg = dataclasses.replace(model_cfg, n_layers=2, vocab=2048,
                                        pattern=pat)
    n_heads = (model_cfg.mosa.n_mosa_heads if model_cfg.mosa else
               model_cfg.attention.n_heads)
    print(f"model: {model_cfg.name} ({model_cfg.n_layers}L, "
          f"{n_heads} {'MoSA' if model_cfg.mosa else 'dense'} heads)")

    cfg = TrainConfig(
        arch="-", seq_len=args.seq, global_batch=args.batch,
        steps=args.steps, lr=1e-3 if not args.full else 2.5e-4,
        warmup=max(args.steps // 10, 10), clip_norm=0.25,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 50),
        log_every=10)
    trainer = Trainer(cfg, model_cfg=model_cfg)
    params, _, history = trainer.run()
    print(f"done: loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}; "
          f"straggler stats: {trainer.monitor.summary()}")


if __name__ == "__main__":
    main()
