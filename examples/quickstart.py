"""Quickstart: build a MoSA hybrid layer, run it, inspect the routing.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import MoSAConfig
from repro.core.hybrid import HybridAttention
from repro.core.mosa import MoSAAttention

key = jax.random.PRNGKey(0)
B, T, h = 2, 256, 128

# --- a MoSA layer: 8 sparse heads, each selecting T/8 = 32 tokens ----------
cfg = MoSAConfig(n_mosa_heads=8, sparsity=8, n_dense_heads=0, d_head=32)
mosa = MoSAAttention(h, cfg)
params = mosa.init(key)
x = jax.random.normal(key, (B, T, h))

y = jax.jit(mosa.__call__)(params, x)
print(f"MoSA: {x.shape} -> {y.shape}, k per head = {mosa.k_for(T)}")

stats = mosa.routing_stats(params, x)
print("routing:", {k: float(v) for k, v in stats.items()})

# --- the paper's hybrid: 4 dense heads + many sparse heads ----------------
hy_cfg = MoSAConfig(n_mosa_heads=16, sparsity=8, n_dense_heads=4, d_head=32)
hybrid = HybridAttention(h, hy_cfg)
hp = hybrid.init(key)
yh = jax.jit(hybrid.__call__)(hp, x)
print(f"Hybrid: {yh.shape}; KV cache at T={T}: {hybrid.kv_total(T)} entries "
      f"vs dense {T * (16 + 4)} ("
      f"{100 * (1 - hybrid.kv_total(T) / (T * 20)):.0f}% smaller)")

# --- gradient flows through the router (that's what makes it learnable) ---
g = jax.grad(lambda p: jnp.sum(mosa(p, x) ** 2))(params)
print("router grad norm:", float(jnp.linalg.norm(g['router']['w'])))
