"""Batched serving with MoSA streaming KV caches.

Shows the paper's KV-cache claim live: the MoSA heads keep only their top-k
tokens, so the cache footprint is a fraction of dense attention's at the same
context length.  Requests flow through the continuous-batching pool: decode
runs in scan-fused chunks, finished slots (EOS or length limit) refill
between chunks (DESIGN §6).

    PYTHONPATH=src python examples/serve_batched.py --gen 24
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.kv_cache import cache_nbytes
from repro.launch.serve import RequestPool, Server


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mosa-paper")
    p.add_argument("--variant", default="mosa")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--eos", type=int, default=-1,
                   help="EOS token id (< 0 disables early stop)")
    args = p.parse_args()

    akw = {"variant": args.variant} if args.arch == "mosa-paper" else {}
    cfg = get_config(args.arch, preset="smoke", **akw)
    server = Server(cfg, batch=args.batch, max_len=args.max_len)

    # continuous batching: submit more requests than slots; finished slots
    # are refilled between fused decode chunks
    pool = RequestPool(server, eos=args.eos)
    key = jax.random.PRNGKey(0)
    for i in range(args.batch * 2):
        plen = 8 + 4 * (i % 3)
        prompt = jax.random.randint(jax.random.fold_in(key, i), (plen,), 2,
                                    cfg.vocab)
        pool.submit(prompt, max_new=args.gen)
    results = pool.run()
    print(f"served {len(results)} requests x {args.gen} tokens")

    # KV accounting
    caches = server.new_cache()
    total = cache_nbytes(caches)
    print(f"cache footprint @T={args.max_len}: {total/2**20:.2f} MiB")
    if cfg.mosa is not None:
        from repro.core.hybrid import HybridAttention
        hy = HybridAttention(cfg.d_model, cfg.mosa)
        kv = hy.kv_total(args.max_len)
        dense_kv = args.max_len * (cfg.mosa.n_dense_heads +
                                   cfg.mosa.n_mosa_heads)
        print(f"KV entries/layer: {kv} vs dense {dense_kv} "
              f"({100 * (1 - kv / dense_kv):.0f}% smaller — paper Table 2)")


if __name__ == "__main__":
    main()
