"""Paged serving: block-granular admission, prefix sharing, preemption.

The contiguous pool (``examples/serve_batched.py``) reserves a worst-case
``max_len`` slab per slot; here the same hybrid model serves through the
paged subsystem (DESIGN §7): KV lives in fixed-size blocks, requests are
admitted while free blocks suffice, identical prompt prefixes share
physical blocks through the hash-trie prefix cache, and exhausting the
pool preempts the newest request to recompute later instead of failing.

    PYTHONPATH=src python examples/serve_paged.py --gen 16
    PYTHONPATH=src python examples/serve_paged.py --num-blocks 10  # preempt
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.kv_cache import cache_nbytes
from repro.launch.serve import Scheduler, Server
from repro.serve.paged_kv import PagedConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--variant", default="mosa")
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--num-blocks", type=int, default=24,
                   help="dense-pool budget; shrink to watch "
                        "preempt-to-recompute kick in")
    args = p.parse_args()

    cfg = get_config("mosa-paper", preset="smoke", variant=args.variant)
    paged = PagedConfig(block_size=args.block_size,
                        num_blocks=args.num_blocks)
    server = Server(cfg, batch=args.batch, max_len=args.max_len, paged=paged)
    sched = Scheduler(server, chunk=8)

    # a shared "system prompt" + per-request suffixes: the trie maps the
    # shared full blocks to shared physical blocks (prefilled ONCE)
    key = jax.random.PRNGKey(0)
    shared = jax.random.randint(key, (2 * args.block_size + 3,), 2,
                                cfg.vocab)
    for i in range(args.batch * 2):
        suffix = jax.random.randint(jax.random.fold_in(key, i), (4,), 2,
                                    cfg.vocab)
        sched.submit(jnp.concatenate([shared, suffix]), max_new=args.gen)
    results = sched.run()
    print(f"served {len(results)} requests x {args.gen} tokens")
    print(f"stats: {sched.stats}")
    print(f"dense pool: {sched.dense_pool.live_blocks} blocks live "
          f"(prefix cache retains {sched.prefix.n_nodes}) of "
          f"{sched.dense_pool.num_blocks}")
    print(f"worst-case paged cache: "
          f"{cache_nbytes(server.new_cache()) / 2**20:.2f} MiB")


if __name__ == "__main__":
    main()
