"""Long-sequence MoSA (paper §3.4): constant k + local attention.

Trains the MoSA+local hybrid at growing sequence lengths with k fixed, and
prints the per-head FLOP cost — flat in T for attention, versus quadratic for
dense.  This is the configuration the long_500k dry-run cells use.

    PYTHONPATH=src python examples/long_context.py
"""

import argparse
import dataclasses
import time

import jax

from repro.configs.base import MoSAConfig
from repro.configs.mosa_paper import paper_config
from repro.core.flops import flops_dense_head, flops_mosa_head
from repro.launch.train import TrainConfig, Trainer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--seqs", type=int, nargs="+", default=[256, 512, 1024])
    p.add_argument("--k", type=int, default=64)
    p.add_argument("--steps", type=int, default=20)
    args = p.parse_args()

    h, hp = 512, 64
    print(f"{'T':>6} {'mosa head GF':>14} {'dense head GF':>14} {'ratio':>8}")
    for T in [args.seqs[-1], 4 * args.seqs[-1], 16 * args.seqs[-1], 524288]:
        fm = flops_mosa_head(T, args.k, h, hp)
        fd = flops_dense_head(T, h, hp)
        print(f"{T:>6} {fm/1e9:>14.3f} {fd/1e9:>14.3f} {fd/fm:>8.1f}x")

    for T in args.seqs:
        cfg = paper_config("tiny", "mosa", sparsity=max(T // args.k, 1),
                           seq_len=T, n_mosa_heads=8, local_window=64)
        cfg = dataclasses.replace(
            cfg, n_layers=2, vocab=1024, pattern=cfg.pattern[:2],
            mosa=dataclasses.replace(cfg.mosa, k_fixed=args.k))
        tcfg = TrainConfig(arch="-", seq_len=T, global_batch=2,
                           steps=args.steps, lr=1e-3, warmup=5, log_every=100)
        tr = Trainer(tcfg, model_cfg=cfg)
        t0 = time.perf_counter()
        _, _, hist = tr.run(install_signals=False)
        dt = (time.perf_counter() - t0) / args.steps
        print(f"T={T:5d} k={args.k}: loss {hist[-1]['loss']:.3f}  "
              f"{dt*1e3:.0f} ms/step (local window 64 + 8 MoSA heads)")


if __name__ == "__main__":
    main()
