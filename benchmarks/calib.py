"""Machine-speed calibration for the tracked perf trajectories.

The BENCH_*.json regression gates compare absolute tokens/s across bench
refreshes that may run days apart on a shared box whose effective speed
drifts (cgroup cpu-shares, noisy neighbors, thermal state) — measured
swings of +-20% on identical code, which is ABOVE the 10% gate tolerance.

Fix: every refresh records ``calib_ms``, the median time of a fixed
numpy matmul workload taken right before the measurements.  ``--check``
then scales the previous entry's throughput by (prev_calib / cur_calib)
before applying the tolerance: if the machine measures 20% slower today,
yesterday's baseline is discounted 20% and only a CODE regression trips
the gate.  An entry PREDATING calibration cannot be normalized at all — the
gate skips that single transition pair (printing why) rather than compare
numbers from unknown machine states; every later pair is normalized.
"""

from __future__ import annotations

import time

import numpy as np


def calibrate_ms(n: int = 384, reps: int = 30) -> float:
    """Median wall time (ms) of a fixed f32 matmul — the machine-speed
    yardstick stored with each trajectory entry."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n), dtype=np.float32)
    b = rng.standard_normal((n, n), dtype=np.float32)
    a @ b                                   # warm the BLAS path
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        a @ b
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e3


def comparable(prev_entry: dict, cur_entry: dict) -> bool:
    """Both entries carry a calibration — the pair can be normalized."""
    return bool(prev_entry.get("calib_ms")) and \
        bool(cur_entry.get("calib_ms"))


def scale_baseline(old_tok_s: float, prev_entry: dict, cur_entry: dict):
    """Discount a previous entry's throughput by the measured machine-speed
    ratio.  Callers guard with ``comparable`` first."""
    pc, cc = prev_entry.get("calib_ms"), cur_entry.get("calib_ms")
    if not pc or not cc:
        return old_tok_s, 1.0
    ratio = pc / cc                          # <1 = machine slower now
    return old_tok_s * ratio, ratio


def check_gate(traj, values_of, tol: float, label: str) -> int:
    """The shared ``--check`` gate both bench families run (serve + train).

    ``traj``: the artifact's trajectory list; ``values_of(entry)`` ->
    ``{variant: tok_s}`` extracts the gated throughputs of one entry.
    Compares the two newest entries with the calibration-normalized
    baseline; returns a process exit code (1 = regression) and prints the
    verdict."""
    if len(traj) < 2:
        print(f"bench-check({label}): <2 trajectory entries, nothing to "
              "compare")
        return 0
    prev, cur = traj[-2], traj[-1]
    if not comparable(prev, cur):
        print(f"bench-check({label}): previous entry predates machine-"
              "speed calibration (benchmarks.calib) — absolute tok/s from "
              "an unknown machine state is not comparable; skipping this "
              "one transition pair")
        return 0
    old_vals, new_vals = values_of(prev), values_of(cur)
    failures = []
    ratio = 1.0
    for v, old in old_vals.items():
        new = new_vals.get(v)
        if not (old and new):
            continue
        baseline, ratio = scale_baseline(old, prev, cur)
        if new < (1.0 - tol) * baseline:
            failures.append(f"{v}: {old} (machine-adjusted "
                            f"{baseline:.0f}) -> {new} tok/s")
    for line in failures:
        print(f"bench-check({label}) REGRESSION", line)
    if not failures:
        print(f"bench-check({label}) OK ({old_vals} -> {new_vals}, "
              f"machine-speed ratio {ratio:.2f}, tol {tol:.0%})")
    return 1 if failures else 0
