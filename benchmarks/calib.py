"""Machine-speed calibration for the tracked perf trajectories.

The BENCH_*.json regression gates compare absolute tokens/s across bench
refreshes that may run days apart on a shared box whose effective speed
drifts (cgroup cpu-shares, noisy neighbors, thermal state) — measured
swings of +-20% on identical code, which is ABOVE the 10% gate tolerance.

Fix: every refresh records ``calib_ms``, the median time of a fixed
workload taken right before the measurements.  ``--check`` then scales the
previous entries' throughput by (prev_calib / cur_calib) before applying
the tolerance: if the machine measures 20% slower today, yesterday's
baseline is discounted 20% and only a CODE regression trips the gate.

Two hardenings learned from flaky gates on identical code (ISSUE 6):

  * the yardstick is a JITted jax matmul, not a numpy BLAS call — the
    benches time XLA's thread pool, and the numpy workload responded to
    box load differently enough (measured -18% residual after
    normalization, back-to-back) to invert the correction.  The workload
    is versioned (``CALIB_VERSION``): entries calibrated with a different
    workload are in different units and are never cross-normalized — the
    gate skips those transition pairs (printing why) instead of comparing
    numbers from unknown machine states.
  * the baseline is the MIN of the normalized throughputs over the last
    ``window`` comparable entries, not just the previous one: a single
    entry whose calibration snapshot caught a load spike its own bench
    didn't (or vice versa) produces a bogus-high baseline, and pair-wise
    comparison turns that one entry into a guaranteed false regression.
    A real code regression sits below ALL recent history and still trips.
"""

from __future__ import annotations

import time

# Bump whenever the calibrate_ms workload changes: calib_ms values from
# different workloads are different units and must never form a ratio.
CALIB_VERSION = 2


def calibrate_ms(n: int = 384, reps: int = 30) -> float:
    """Median wall time (ms) of a fixed JITted f32 matmul — the
    machine-speed yardstick stored with each trajectory entry (same XLA
    runtime + thread pool the benches themselves exercise)."""
    import jax
    import jax.numpy as jnp
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    f = jax.jit(lambda x: x @ x)
    jax.block_until_ready(f(a))                # warm the compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(a))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e3


def comparable(prev_entry: dict, cur_entry: dict) -> bool:
    """Both entries carry a calibration in the SAME units — the pair can
    be normalized.  Entries predating calibration (no ``calib_ms``) or
    from an older workload version (``calib_v`` mismatch) cannot."""
    return bool(prev_entry.get("calib_ms")) and \
        bool(cur_entry.get("calib_ms")) and \
        prev_entry.get("calib_v") == cur_entry.get("calib_v")


def scale_baseline(old_tok_s: float, prev_entry: dict, cur_entry: dict):
    """Discount a previous entry's throughput by the measured machine-speed
    ratio.  Callers guard with ``comparable`` first."""
    pc, cc = prev_entry.get("calib_ms"), cur_entry.get("calib_ms")
    if not pc or not cc:
        return old_tok_s, 1.0
    ratio = pc / cc                          # <1 = machine slower now
    return old_tok_s * ratio, ratio


def check_gate(traj, values_of, tol: float, label: str,
               window: int = 3) -> int:
    """The shared ``--check`` gate both bench families run (serve + train).

    ``traj``: the artifact's trajectory list; ``values_of(entry)`` ->
    ``{variant: tok_s}`` extracts the gated throughputs of one entry.
    Compares the newest entry against the MIN calibration-normalized
    baseline over the last ``window`` comparable entries (module
    docstring); returns a process exit code (1 = regression) and prints
    the verdict."""
    if len(traj) < 2:
        print(f"bench-check({label}): <2 trajectory entries, nothing to "
              "compare")
        return 0
    cur = traj[-1]
    prevs = [e for e in traj[-1 - window:-1] if comparable(e, cur)]
    if not prevs:
        print(f"bench-check({label}): no recent entry shares the current "
              f"calibration workload (v{cur.get('calib_v')}) — absolute "
              "tok/s across different yardsticks or uncalibrated machine "
              "states is not comparable; skipping this transition")
        return 0
    new_vals = values_of(cur)
    failures, floors = [], {}
    for v, new in new_vals.items():
        if not new:
            continue
        baselines = []
        for p in prevs:
            old = values_of(p).get(v)
            if old:
                b, _ = scale_baseline(old, p, cur)
                baselines.append(b)
        if not baselines:
            continue
        floor = min(baselines)
        floors[v] = round(floor)
        if new < (1.0 - tol) * floor:
            failures.append(
                f"{v}: {new} tok/s under floor {floor:.0f} (min of "
                f"{len(baselines)} machine-adjusted entries)")
    for line in failures:
        print(f"bench-check({label}) REGRESSION", line)
    if not failures:
        print(f"bench-check({label}) OK ({new_vals} vs adjusted floors "
              f"{floors}, tol {tol:.0%}, window {len(prevs)})")
    return 1 if failures else 0
