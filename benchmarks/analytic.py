"""Analytic cost model for the roofline terms.

Why analytic: XLA's ``compiled.cost_analysis()`` counts while-loop (scan)
bodies ONCE — with scan-over-layers every per-layer FLOP/byte is undercounted
by the trip count (verified experimentally; see EXPERIMENTS.md §Dry-run
caveats).  The FLOP formulas here are the paper's own accounting (App. A),
which this repo reproduces against Table 4 to the cent, extended to the other
mixer families.  Bytes are a standard HBM-traffic model (params + optimizer
+ activations + caches).  Collective bytes stay HLO-derived (with trip-count
correction) in repro.launch.dryrun.

All numbers are GLOBAL (divide by chips for per-device).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeCfg


def _attn_flops(cfg, B, T, Tkv, window=0):
    a = cfg.attention
    h = cfg.d_model
    eff_kv = min(Tkv, window) if window else Tkv
    if a.kind == "mla":
        m = a.mla
        qd = m.nope_head_dim + m.rope_head_dim
        f = 2 * B * T * h * (a.n_heads * qd)                      # q proj
        f += 2 * B * T * h * (m.kv_lora_rank + m.rope_head_dim)   # down kv
        f += 2 * B * Tkv * m.kv_lora_rank * a.n_heads * (
            m.nope_head_dim + m.v_head_dim)                        # up k/v
        f += 2 * B * a.n_heads * T * eff_kv * (qd + m.v_head_dim)  # attn
        f += 2 * B * T * (a.n_heads * m.v_head_dim) * h            # out
        return f
    d = a.d_head
    f = 2 * B * T * h * (2 * a.n_heads * d + 2 * a.n_kv_heads * d)  # QKVO
    f += 4 * B * a.n_heads * T * eff_kv * d                         # attn
    return f


def _mosa_flops(cfg, B, T, Tkv):
    """Hybrid layer: paper's per-head formula + the dense/local side."""
    m = cfg.mosa
    h = cfg.d_model
    d = m.d_head
    k = min(m.k_fixed or max(T // m.sparsity, m.min_k), Tkv)
    f = m.n_mosa_heads * B * (8 * h * d * k + 4 * d * k * k +
                              2 * h * T + d * k)
    if m.n_dense_heads:
        eff = min(Tkv, m.local_window) if m.local_window else Tkv
        f += 2 * B * T * h * (4 * m.n_dense_heads * d)
        f += 4 * B * m.n_dense_heads * T * eff * d
    return f


def _ffn_flops(cfg, B, T, kind):
    h = cfg.d_model
    if kind == "dense":
        mult = 6 if cfg.ffn_act == "swiglu" else 4
        return mult * B * T * h * cfg.d_ff
    if kind == "moe":
        c = cfg.moe
        f = 2 * B * T * h * c.n_experts                     # router
        f += 6 * B * T * c.top_k * h * c.d_expert           # active experts
        if c.n_shared_experts:
            d_sh = (c.d_shared or c.d_expert) * c.n_shared_experts
            f += 6 * B * T * h * d_sh
        return f
    return 0


def _mamba_flops(cfg, B, T):
    c = cfg.mamba
    h = cfg.d_model
    di = c.expand * h
    dr = c.dt_rank or -(-h // 16)
    ds = c.d_state
    f = 2 * B * T * h * 2 * di                 # in_proj
    f += 2 * B * T * di * c.d_conv             # conv
    f += 2 * B * T * di * (dr + 2 * ds)        # x_proj
    f += 2 * B * T * dr * di                   # dt_proj
    f += 6 * B * T * di * ds                   # selective scan
    f += 2 * B * T * di * h                    # out_proj
    return f


def _xlstm_flops(cfg, B, T, kind):
    x = cfg.xlstm
    h = cfg.d_model
    H = cfg.attention.n_heads
    if kind == "mlstm":
        di = int(x.proj_factor_mlstm * h)
        dh = di // H
        f = 2 * B * T * h * 2 * di             # up
        f += 2 * B * T * di * x.conv1d_kernel
        f += 3 * 2 * B * T * di * di           # q k v
        f += 6 * B * T * H * dh * dh           # matrix memory update + read
        f += 2 * B * T * di * h                # down
        return f
    d_up = int(x.proj_factor_slstm * h)
    dh = h // H
    f = 2 * B * T * h * 4 * h                  # input gates
    f += 2 * B * T * 4 * H * dh * dh           # recurrent gates
    f += 2 * B * T * h * d_up + 2 * B * T * (d_up // 2) * h
    return f


def model_flops(cfg: ModelConfig, B: int, T: int, Tkv: Optional[int] = None,
                train: bool = False) -> float:
    """Forward FLOPs of one step (multiply externally for bwd/remat)."""
    Tkv = Tkv if Tkv is not None else T
    total = 0.0
    for spec in cfg.resolved_pattern():
        if spec.mixer == "attn":
            total += _attn_flops(cfg, B, T, Tkv)
        elif spec.mixer == "attn_local":
            total += _attn_flops(cfg, B, T, Tkv,
                                 window=cfg.attention.window or 1024)
        elif spec.mixer == "mosa":
            total += _mosa_flops(cfg, B, T, Tkv)
        elif spec.mixer == "mamba":
            total += _mamba_flops(cfg, B, T)
        elif spec.mixer in ("mlstm", "slstm"):
            total += _xlstm_flops(cfg, B, T, spec.mixer)
        total += _ffn_flops(cfg, B, T, spec.ffn)
    total += 2 * B * T * cfg.d_model * cfg.vocab       # unembed
    if train:
        mult = 3 + (1 if cfg.remat != "none" else 0)   # fwd + 2x bwd (+remat)
        total *= mult
    return total


def param_counts(cfg: ModelConfig):
    """(total_params, active_params) — active scales experts by top_k/E."""
    import jax
    from repro.nn.module import init_shapes
    from repro.nn.transformer import TransformerLM
    shapes = init_shapes(TransformerLM(cfg))
    total = active = 0.0
    scale = (cfg.moe.top_k / cfg.moe.n_experts) if cfg.moe else 1.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        is_expert = (cfg.moe is not None and leaf.ndim >= 3 and
                     any(k in ("w_gate", "w_up", "w_down") for k in keys))
        active += n * (scale if is_expert else 1.0)
    return total, active


def cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """Total serving-cache bytes at context length S (analytic)."""
    import jax
    import jax.numpy as jnp
    from repro.nn.transformer import TransformerLM
    model = TransformerLM(cfg)
    shapes = jax.eval_shape(lambda: model.init_cache(B, S, jnp.bfloat16))
    return float(sum(l.size * l.dtype.itemsize
                     for l in jax.tree.leaves(shapes)))


@dataclasses.dataclass
class CellCost:
    flops_global: float
    bytes_global: float
    model_flops: float       # 6·N_active·D (2·N_active·D inference)
    n_params: float
    n_active: float


def cell_cost(cfg: ModelConfig, shape: ShapeCfg) -> CellCost:
    B, T = shape.global_batch, shape.seq_len
    n_total, n_active = param_counts(cfg)
    pbytes = n_total * (2 if cfg.param_dtype == "bfloat16" else 4)
    abytes = 2 if cfg.compute_dtype == "bfloat16" else 4

    if shape.kind == "train":
        flops = model_flops(cfg, B, T, train=True)
        # params: read fwd + read bwd + read remat-fwd; grads w+r;
        # adam: m,v read+write fp32 + param write
        bytes_ = pbytes * 3 + n_total * (4 + 4) + n_total * (16 + 16 + 2)
        # activations: ~8 residual-sized r/w per layer (norms, mixer, ffn)
        bytes_ += cfg.n_layers * 8 * B * T * cfg.d_model * abytes
        mflops = 6 * n_active * B * T
    elif shape.kind == "prefill":
        flops = model_flops(cfg, B, T)
        bytes_ = pbytes + cache_bytes(cfg, B, T)
        bytes_ += cfg.n_layers * 6 * B * T * cfg.d_model * abytes
        mflops = 2 * n_active * B * T
    else:  # decode: one token against a T-long cache
        flops = model_flops(cfg, B, 1, Tkv=T)
        cb = cache_bytes(cfg, B, T)
        bytes_ = pbytes + cb          # read all params + touch the cache
        mflops = 2 * n_active * B
    return CellCost(flops, bytes_, mflops, n_total, n_active)
