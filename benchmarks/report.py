"""Assemble EXPERIMENTS.md §Dry-run and §Roofline from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.report \
        --single experiments/dryrun/16x16 --multi experiments/dryrun/2x16x16
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline import analyze_cell, load_dir

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_b(b):
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if b >= div:
            return f"{b / div:.1f} {unit}"
    return f"{b:.0f} B"


def dryrun_table(recs):
    lines = [
        "| arch | shape | kind | compile (s) | mem/dev | HLO coll ops "
        "| coll bytes/dev (corrected) | top collective | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         SHAPE_ORDER.index(r["shape"]))):
        coll = r["collective_bytes_per_device"]
        kinds = {k: v for k, v in coll.items()
                 if not k.startswith("_") and k != "total"}
        top = max(kinds, key=kinds.get) if kinds else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compile_s']:.1f} "
            f"| {_fmt_b(r['memory'].get('total_per_device', 0))} "
            f"| {coll.get('_ops', 0)} | {_fmt_b(coll['total'])} "
            f"| {top} | {r.get('note', '')[:40]} |")
    return "\n".join(lines)


def roofline_table(recs):
    rows = [analyze_cell(r) for r in recs]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
        "| MODEL_FLOPS | useful ratio | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} "
            f"| {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} "
            f"| **{r['dominant']}** "
            f"| {r.get('model_flops', 0):.2e} "
            f"| {r.get('useful_ratio', float('nan')):.2f} "
            f"| {r.get('roofline_frac', float('nan')):.3f} "
            f"| {r['advice'].split(':')[0]} |")
    return "\n".join(lines)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--single", default="experiments/dryrun/16x16")
    p.add_argument("--multi", default="experiments/dryrun/2x16x16")
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)

    parts = []
    single = load_dir(args.single) if os.path.isdir(args.single) else []
    multi = load_dir(args.multi) if os.path.isdir(args.multi) else []

    parts.append("### Dry-run — single pod 16x16 (256 chips)\n")
    parts.append(dryrun_table(single))
    if multi:
        parts.append("\n### Dry-run — multi-pod 2x16x16 (512 chips)\n")
        parts.append(dryrun_table(multi))
        ok = {(r["arch"], r["shape"]) for r in multi}
        parts.append(f"\nmulti-pod cells compiled: {len(ok)}/40\n")
    parts.append("\n### Roofline — single pod (the scored table)\n")
    parts.append(roofline_table(single))
    text = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
