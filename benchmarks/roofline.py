"""Roofline analysis over the dry-run artifacts.

Reads ``experiments/dryrun/<mesh>/<arch>__<shape>.json`` (written by
repro.launch.dryrun) and derives, per cell:

    t_compute = FLOPs_per_device / PEAK_FLOPS
    t_memory  = bytes_per_device / HBM_BW
    t_coll    = collective_bytes_per_device / LINK_BW

(the per-device values come from the partitioned HLO, so dividing the global
quantities by `chips` per the spec formula gives exactly these), plus the
dominant term, MODEL_FLOPS = 6·N_active·D (2·N_active·D for inference), the
usefulness ratio MODEL_FLOPS / HLO_FLOPs_global, and a one-line "what to do"
note per bottleneck.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun/16x16]
      [--markdown]
"""

from __future__ import annotations

import argparse
import json
import os

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # B/s
LINK_BW = 50e9            # B/s per ICI link

ADVICE = {
    "compute": "raise arithmetic intensity: fuse, bigger per-chip batch, "
               "bf16 everywhere — or accept (compute-bound is the goal)",
    "memory": "cut HLO bytes: remat policy, fused attention (no logits "
              "materialization), smaller fp32 surfaces, layout",
    "collective": "reshard: fewer all-gathers (check fsdp prefetch), "
                  "reduce-scatter grads, overlap collectives with compute, "
                  "compress cross-pod grads",
}


def active_params(arch: str, shape_kind: str, model_name: str = "") -> float:
    """6·N·D convention: N counts each MoE expert tensor at top_k/n_experts
    of its size (active experts only) and includes everything else."""
    import jax
    from repro.configs.base import get_config
    from repro.launch.dryrun import build_cfg
    from repro.nn.module import init_shapes
    from repro.nn.transformer import TransformerLM

    shape_name = {"train": "train_4k", "prefill": "prefill_32k",
                  "decode": "decode_32k"}[shape_kind]
    cfg, _, _ = build_cfg(arch, shape_name)
    model = TransformerLM(cfg)
    shapes = init_shapes(model)
    scale = (cfg.moe.top_k / cfg.moe.n_experts) if cfg.moe else 1.0
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = 1
        for s in leaf.shape:
            n *= s
        if any(k in ("w_gate", "w_up", "w_down") for k in keys) and \
                leaf.ndim >= 3 and cfg.moe is not None:
            total += n * scale
        else:
            total += n
    return total


def analyze_cell(rec: dict, with_model_flops: bool = True) -> dict:
    n = rec["n_devices"]
    an = rec.get("analytic")
    coll = rec["collective_bytes_per_device"]["total"]
    if an is not None:
        t_c = an["flops_global"] / n / PEAK_FLOPS
        t_m = an["bytes_global"] / n / HBM_BW
    else:  # legacy record: raw HLO numbers (scan bodies counted once)
        t_c = rec.get("per_device_flops_hlo_raw",
                      rec.get("per_device_flops", 0)) / PEAK_FLOPS
        t_m = rec.get("per_device_bytes_hlo_raw",
                      rec.get("per_device_bytes", 0)) / HBM_BW
    t_l = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    dom = max(terms, key=terms.get)
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_l,
        "dominant": dom,
        "bound_s": terms[dom],
        "mem_gib": rec["memory"].get("total_per_device", 0) / 2**30,
        "advice": ADVICE[dom],
    }
    if an is not None:
        out["model_flops"] = an["model_flops"]
        out["useful_ratio"] = an["model_flops"] / max(an["flops_global"], 1)
        # roofline fraction: time the chips MUST spend on useful math vs the
        # time the compiled program is bounded by (dominant term)
        t_useful = an["model_flops"] / n / PEAK_FLOPS
        out["roofline_frac"] = t_useful / max(terms[dom], 1e-12)
    elif with_model_flops:
        try:
            n_act = active_params(rec["arch"], rec["kind"])
            tokens = rec["global_batch"] * (
                rec["seq_len"] if rec["kind"] in ("train", "prefill") else 1)
            mult = 6 if rec["kind"] == "train" else 2
            model_flops = mult * n_act * tokens
            out["model_flops"] = model_flops
            out["useful_ratio"] = model_flops / max(
                rec.get("global_flops", 1), 1)
            t_useful = model_flops / n / PEAK_FLOPS
            out["roofline_frac"] = t_useful / max(terms[dom], 1e-12)
        except Exception as e:  # pragma: no cover
            out["model_flops_error"] = repr(e)
    return out


def load_dir(d: str):
    recs = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                recs.append(json.load(fh))
    return recs


def markdown_table(rows):
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
           "| mem/dev GiB | MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} "
            f"| {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} "
            f"| **{r['dominant']}** | {r['mem_gib']:.1f} "
            f"| {r.get('useful_ratio', float('nan')):.2f} "
            f"| {r.get('roofline_frac', float('nan')):.2f} |")
    return "\n".join(lines)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun/16x16")
    p.add_argument("--markdown", action="store_true")
    p.add_argument("--no-model-flops", action="store_true")
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)
    rows = [analyze_cell(r, not args.no_model_flops)
            for r in load_dir(args.dir)]
    if args.markdown:
        text = markdown_table(rows)
    else:
        text = json.dumps(rows, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
