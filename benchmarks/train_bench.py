"""Train-step benchmark family — the training-path perf trajectory
(DESIGN §8).

Measures, at CPU smoke scale, the full donated train step (fwd + bwd +
AdamW) built by ``repro.train.step.make_train_step``:

  * ``dense``      — the dense baseline;
  * ``mosa_ref``   — MoSA hybrid through the einsum reference path (the
    dense-gather fallback every training step paid before the fused VJP
    kernels existed);
  * ``mosa_fused`` — the same model through ``impl="pallas"``: fused fwd
    kernel + custom-VJP Pallas backward.

plus a ``microbatch`` entry (same global batch split 2x) measuring the
grad-accumulation overhead of the scan-based accumulator.

Honesty note (same convention as BENCH_serve.json's paged family): on CPU
the Pallas kernels run through the INTERPRETER, so ``fused_over_ref`` here
tracks correctness/trajectory, not the TPU speedup — the ratio is recorded
as measured, a value < 1 on CPU is expected, and the regression gate
(``--check``) gates the compiled paths (dense / mosa_ref) only.  On a TPU
host the same script lowers the kernels natively and the ratio becomes the
paper-relevant number (the "no optimized kernel" caveat, closed).

Writes ``BENCH_train.json`` (tracked; ``make bench-train`` refreshes it,
``trajectory`` grows one entry per refresh, ``make bench-check`` gates)
plus an untracked ``BENCH_train.trace.json`` Chrome trace — one span per
timed step on the "bench" track, labeled by variant (DESIGN §11).

    PYTHONPATH=src python -m benchmarks.train_bench --steps 3
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.calib import CALIB_VERSION, calibrate_ms, check_gate
from repro import obs
from repro.configs.base import get_config
from repro.nn.transformer import TransformerLM
from repro.optim import schedules
from repro.optim.optimizer import adamw
from repro.train.step import make_train_step

# Table-2 ppl-matched recipe at smoke scale (see serve_bench.py).
TABLE2_RECIPE = {"sparsity": 32, "n_mosa_heads": 17}


def _median(ts):
    ts = sorted(ts)
    return ts[len(ts) // 2]


def _shrink(cfg, d_model: int):
    if not d_model or d_model == cfg.d_model:
        return cfg
    d_head = max(d_model // 8, 8)
    kw = {"attention": dataclasses.replace(cfg.attention, d_head=d_head)}
    if cfg.mosa is not None:
        kw["mosa"] = dataclasses.replace(cfg.mosa, d_head=d_head)
    return dataclasses.replace(cfg, d_model=d_model, d_ff=2 * d_model, **kw)


def _build_cfg(variant: str, seq: int, d_model: int, impl: str = "einsum",
               granularity: str = "token", sel_block_size: int = 16,
               sparsity: int = 0):
    kw = dict(TABLE2_RECIPE) if variant == "mosa" else {}
    if sparsity:
        kw["sparsity"] = sparsity
    cfg = _shrink(get_config("mosa-paper", preset="smoke", variant=variant,
                             seq_len=seq, **kw), d_model)
    if cfg.mosa is not None:
        cfg = dataclasses.replace(
            cfg, mosa=dataclasses.replace(
                cfg.mosa, impl=impl, selection_granularity=granularity,
                sel_block_size=sel_block_size))
    return cfg


def time_step(cfg, batch: int, seq: int, steps: int = 5,
              microbatches: int = 1, calib0: float = 0.0,
              label: str = "step") -> dict:
    """Best-of-``steps`` full-train-step time (jit-warmed) and tokens/s.
    Min-time (transient box load only adds time) and, when ``calib0`` is
    given, rescaled by a calibration sampled at this timed region — both
    noise defenses documented in ``serve_bench.time_decode``.  Every timed
    step is recorded as a tracer span (track "bench") so a refresh leaves
    a Chrome-trace artifact of the whole variant sweep (DESIGN §11)."""
    model = TransformerLM(cfg)
    optimizer = adamw(schedules.linear_warmup(1e-3, 10), clip_norm=1.0)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = optimizer.init(params)
    step = jnp.zeros((), jnp.int32)
    fn = jax.jit(make_train_step(model, optimizer,
                                 microbatches=microbatches),
                 donate_argnums=(0, 1))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (batch, seq), 2, cfg.vocab)
    batch_d = {"tokens": tokens, "labels": tokens}
    ts = []
    local = 0.0
    for it in range(steps + 1):                 # iteration 0 warms compile
        t0 = time.perf_counter()
        with obs.tracer().span(label, track="bench", it=it,
                               warm=(it == 0)):
            params, opt_state, step, metrics = fn(params, opt_state, step,
                                                  batch_d)
            jax.block_until_ready(metrics["loss"])
        if it:
            ts.append(time.perf_counter() - t0)
        else:                                   # machine speed at timing
            local = calibrate_ms()
    dt = min(ts)
    if calib0 and local:
        dt *= calib0 / local                    # as-if at refresh-start speed
    return {"step_ms": round(dt * 1e3, 2),
            "tok_s": round(batch * seq / dt, 1),
            "loss": float(metrics["loss"])}


def run_bench(batch: int = 4, seq: int = 64, d_model: int = 64,
              steps: int = 5,
              trace_path: str = "BENCH_train.trace.json") -> dict:
    obs.tracer().reset()                 # trace holds exactly this sweep
    res = {
        "benchmark": "train_step",
        "config": {"arch": "mosa-paper", "preset": "smoke", "batch": batch,
                   "seq": seq, "d_model": d_model,
                   "mosa_recipe": TABLE2_RECIPE},
        "env": {"jax": jax.__version__, "backend": jax.default_backend(),
                "devices": len(jax.devices())},
        "note": ("fused runs through the Pallas interpreter on non-TPU "
                 "backends; fused_over_ref < 1 is expected on CPU (see "
                 "module docstring)"),
        "calib_ms": round(calibrate_ms(), 3),
        "calib_v": CALIB_VERSION,
        "variants": {},
    }
    calib0 = res["calib_ms"]
    res["variants"]["dense"] = time_step(
        _build_cfg("dense", seq, d_model), batch, seq, steps, calib0=calib0,
        label="dense")
    res["variants"]["mosa_ref"] = time_step(
        _build_cfg("mosa", seq, d_model, impl="einsum"), batch, seq, steps,
        calib0=calib0, label="mosa_ref")
    res["variants"]["mosa_fused"] = time_step(
        _build_cfg("mosa", seq, d_model, impl="pallas"), batch, seq, steps,
        calib0=calib0, label="mosa_fused")
    res["variants"]["microbatch2"] = time_step(
        _build_cfg("mosa", seq, d_model), batch, seq, steps, microbatches=2,
        calib0=calib0, label="microbatch2")
    # Block-choice family (DESIGN §10): an exactly FLOP-matched pair — at
    # sparsity 4 / seq 64, k_for = 16 tokens per head, and with
    # sel_block_size 8 the block path selects kb = 2 blocks = the same 16
    # rows — so tok/s is apples-to-apples and the post-step training loss
    # is a perplexity proxy for routing granularity alone.
    blk_bs, blk_rho = 8, 4
    res["variants"]["mosa_tok_match"] = time_step(
        _build_cfg("mosa", seq, d_model, sparsity=blk_rho), batch, seq,
        steps, calib0=calib0, label="mosa_tok_match")
    res["variants"]["mosa_block"] = time_step(
        _build_cfg("mosa", seq, d_model, granularity="block",
                   sel_block_size=blk_bs, sparsity=blk_rho), batch, seq,
        steps, calib0=calib0, label="mosa_block")
    ref = res["variants"]["mosa_ref"]
    res["fused_over_ref"] = round(
        res["variants"]["mosa_fused"]["tok_s"] / ref["tok_s"], 3)
    res["accum_overhead"] = round(
        ref["tok_s"] / res["variants"]["microbatch2"]["tok_s"], 3)
    import math
    tokm, blkm = res["variants"]["mosa_tok_match"], \
        res["variants"]["mosa_block"]
    res["block_family"] = {
        "sel_block_size": blk_bs, "sparsity": blk_rho,
        "rows_per_head": 16,
        "block_over_token_tok_s": round(blkm["tok_s"] / tokm["tok_s"], 3),
        "ppl_proxy_token": round(math.exp(min(tokm["loss"], 30.0)), 3),
        "ppl_proxy_block": round(math.exp(min(blkm["loss"], 30.0)), 3),
        "note": ("FLOP-matched: kb*sel_block_size == k_for(seq) rows per "
                 "head; ppl proxy = exp(loss) after the timed steps from "
                 "identical init/data")}
    if trace_path:
        obs.tracer().export_chrome(trace_path)
        res["trace_path"] = trace_path
    return res


def _append_trajectory(res: dict, prev: dict) -> None:
    traj = list(prev.get("trajectory", []))
    entry = {"entry": len(traj),
             "calib_ms": res.get("calib_ms"),
             "calib_v": res.get("calib_v"),
             "tok_s": {v: r["tok_s"] for v, r in res["variants"].items()},
             "fused_over_ref": res["fused_over_ref"]}
    traj.append(entry)
    res["trajectory"] = traj[-12:]


# Gated variants: compiled paths only — mosa_fused is interpreter-bound off
# TPU and its CPU timing noise would make the gate flap (module docstring).
# The block-choice pair is compiled einsum and rides the same gate.
GATED = ("dense", "mosa_ref", "mosa_tok_match", "mosa_block")


def check_regression(path: str, tol: float = 0.10) -> int:
    import os
    if not os.path.exists(path):
        print(f"bench-check: {path} missing — run `make bench-train`")
        return 1
    res = json.loads(open(path).read())
    return check_gate(
        res.get("trajectory", []),
        lambda e: {v: (e.get("tok_s") or {}).get(v) for v in GATED},
        tol, "train")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--d-model", type=int, default=64,
                   help="shrink the smoke model to this width")
    p.add_argument("--steps", type=int, default=5)
    p.add_argument("--out", default="BENCH_train.json")
    p.add_argument("--check", action="store_true")
    args = p.parse_args(argv)

    if args.check:
        raise SystemExit(check_regression(args.out))

    try:
        with open(args.out) as f:
            prev = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        prev = {}
    base = args.out[:-len(".json")] if args.out.endswith(".json") else \
        args.out
    res = run_bench(args.batch, args.seq, args.d_model, args.steps,
                    trace_path=f"{base}.trace.json")
    _append_trajectory(res, prev)
    print("name,us_per_call,derived")
    for v, r in res["variants"].items():
        print(f"train/{v},0.0,step={r['step_ms']}ms;tok_s={r['tok_s']}")
    print(f"train/fused_over_ref,0.0,ratio={res['fused_over_ref']}")
    print(f"train/accum_overhead,0.0,ratio={res['accum_overhead']}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
