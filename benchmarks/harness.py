"""Shared benchmark utilities: timed jit calls + short-training runs."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def time_jit(fn, *args, iters: int = 5, warmup: int = 2):
    """Median wall time (us) of a jitted call on this host."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def short_train(model_cfg, steps: int = 40, seq: int = 128, batch: int = 8,
                lr: float = 3e-3, seed: int = 0):
    """Run a short training; returns (final_loss, final_ppl, s_per_step)."""
    from repro.launch.train import TrainConfig, Trainer
    cfg = TrainConfig(arch="-", seq_len=seq, global_batch=batch, steps=steps,
                      lr=lr, warmup=max(steps // 8, 1), seed=seed,
                      log_every=max(steps - 1, 1))
    tr = Trainer(cfg, model_cfg=model_cfg)
    t0 = time.perf_counter()
    _, _, hist = tr.run(install_signals=False)
    wall = time.perf_counter() - t0
    last = hist[-1]
    return last["loss"], last["ppl"], wall / steps
