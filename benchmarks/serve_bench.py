"""Decode benchmark family — the serving-path perf trajectory.

Measures, for dense vs MoSA variants of the paper's model at smoke scale:

  * decode throughput (tok/s) of the scan-fused chunk decoder
    (``Server.decode_many``, one dispatch per chunk) against the legacy
    per-token loop (one jit dispatch + eager sampling dispatches per token;
    the contrast measures dispatch overhead — jax async dispatch means
    neither path syncs the host per token) — DESIGN §6;
  * KV-cache footprint in bytes at the same ``max_len`` — the paper's
    serving payoff (MoSA heads hold k entries each, independent of context);
  * the PAGED family (DESIGN §7): fused decode tok/s on block-paged caches
    vs the contiguous slabs, and — the paged payoff — max concurrent
    requests at a FIXED cache-memory budget.  Capacity is computed from the
    measured byte layout of both cache families (the contiguous path
    reserves a worst-case ``max_len`` slab per slot; the paged path pays
    ``ceil(tokens / block) * block`` plus the bounded per-row state), with
    the request profile = this benchmark's own prompt+gen length.

The mixed-length family doubles as the observability gate (DESIGN §11):
each refresh measures ``obs_overhead`` (scheduler wall time with the obs
registry+tracer on vs off, interleaved warm passes) and emits untracked
``BENCH_serve.trace.json`` (Chrome trace, one track per request) +
``BENCH_serve.metrics.jsonl`` (registry snapshot time series) artifacts,
self-checked for full request lifecycle coverage.

The SLO family (DESIGN §12) closes the loop on *service*, not capacity:
a seeded closed-loop calibration measures the sustainable request rate,
then an open-loop Poisson sweep offers 0.5x/1x/2x that rate (two-tenant
mix, admission-controlled via ``max_queue``) through the timed Scheduler
and records goodput + TTFT/TPOT tails per rate.  SLO thresholds are
self-relative (3x the p90 of the uncontended pass), so — like the calib
gate — machine drift cannot flip the verdict.  ``--check`` gates that
overload degrades goodput *gracefully*: requests shed/preempt rather
than every admitted request's TTFT collapsing together.

``BENCH_serve.json`` carries a ``trajectory`` list (one summary entry per
refresh); ``--check`` compares the two most recent entries and exits
nonzero on a >10% fused-throughput regression (``make bench-check``),
a packed-efficiency floor, the <=2% obs-overhead ceiling, and the
SLO-family overload gates.
Entries carry a machine-speed calibration (``benchmarks.calib``) and the
gate normalizes the baseline by it, so cross-refresh machine drift —
measured at +-20% on this shared box, above the gate tolerance — cannot
masquerade as a code regression.

Two deliberate choices at smoke scale:

  * the model is SHRUNK (``--d-model``) below the paper's tiny config: the
    fused/loop contrast is about per-token dispatch + host-sync overhead,
    and on a slow CPU the full smoke model is weight-streaming-bound
    (~10 ms/step of parameter reads), which hides exactly the overhead the
    fused path removes.  At real serving scale the accelerator streams
    weights fast enough that dispatch shows; shrinking reproduces that
    regime on CPU.  Both paths always run the SAME config.
  * the MoSA variant is the paper's Table-2 ppl-matched recipe (4 dense +
    17 MoSA heads @ rho=32), not the IsoFLOP hybrid: KV size is a
    resource-at-matched-quality claim, and the IsoFLOP hybrid trades its
    FLOP budget for ~5x more heads, which would inflate its cache.

Writes ``BENCH_serve.json`` (the tracked perf-trajectory artifact; `make
bench-smoke` refreshes it) and prints one CSV row per measurement.

    PYTHONPATH=src python -m benchmarks.serve_bench --gen 64 --max-len 256
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.calib import CALIB_VERSION, calibrate_ms, check_gate
from repro.configs.base import get_config
from repro.core.kv_cache import cache_nbytes
from repro.dist import hints
from repro.launch.serve import Server
from repro.nn.transformer import TransformerLM
from repro.serve.paged_kv import (PagedConfig, PagedDenseKVCache,
                                  PagedWindowKVCache)

# Paper Table 2 (tiny): ppl-matched hybrid — 4 dense + 17 MoSA heads, rho=32.
TABLE2_RECIPE = {"sparsity": 32, "n_mosa_heads": 17}


def _median(ts):
    ts = sorted(ts)
    return ts[len(ts) // 2]


def _trimmed_mean(ts, keep: float = 0.6):
    """Mean of the fastest ``keep`` fraction — transient neighbor load only
    ever ADDS time, so the slow tail is noise, not signal."""
    ts = sorted(ts)
    k = max(1, int(len(ts) * keep))
    return sum(ts[:k]) / k


def time_decode(server: Server, prompts, gen: int, fused: bool,
                iters: int = 5, calib0: float = 0.0) -> float:
    """Best-of-``iters`` decode throughput (tok/s), prefill excluded,
    compile warmed.  Two noise defenses learned from flaky gates on
    identical code (shared CI box): min-time, not median — transient
    neighbor load only ever ADDS time, and median-of-3 swung ±18%
    back-to-back; and when ``calib0`` (the refresh-start calibration) is
    given, the result is rescaled by a calibration sampled right AT this
    timed region — a sustained load window minutes after refresh start is
    invisible to the per-entry calibration and otherwise reads as a code
    regression."""
    B = prompts.shape[0]
    key = jax.random.PRNGKey(0)
    ts = []
    local = 0.0
    with server.mesh, hints.sharding_hints(mesh=server.mesh):
        for it in range(iters + 1):          # iteration 0 warms the compile
            caches = server.new_cache()
            logits, caches = server.prefill(server.params, prompts, caches)
            tok = server.sample(logits[:, -1], key)[:, None]
            jax.block_until_ready((tok, caches))
            t0 = time.perf_counter()
            if fused:
                toks, caches = server.decode_many(server.params, tok, caches,
                                                  key, gen)
                jax.block_until_ready(toks)
            else:
                for _ in range(gen):
                    logits, caches = server.decode_step(server.params, tok,
                                                        caches)
                    tok = jnp.argmax(logits[:, -1],
                                     axis=-1).astype(jnp.int32)[:, None]
                jax.block_until_ready(tok)
            if it:
                ts.append(time.perf_counter() - t0)
            else:                            # machine speed as timing starts
                local = calibrate_ms()
    tok_s = B * gen / min(ts)
    if calib0 and local:
        tok_s *= local / calib0              # as-if at refresh-start speed
    return tok_s


def _shrink(cfg, d_model: int):
    """Scale the smoke config down to a dispatch-bound size (see module
    docstring); ``d_model == 0`` keeps the config untouched."""
    if not d_model or d_model == cfg.d_model:
        return cfg
    d_head = max(d_model // 8, 8)
    kw = {"attention": dataclasses.replace(cfg.attention, d_head=d_head)}
    if cfg.mosa is not None:
        kw["mosa"] = dataclasses.replace(cfg.mosa, d_head=d_head)
    return dataclasses.replace(cfg, d_model=d_model, d_ff=2 * d_model, **kw)


def bench_variant(variant: str, batch: int, prompt_len: int, gen: int,
                  max_len: int, iters: int = 5, d_model: int = 128,
                  calib0: float = 0.0) -> dict:
    kw = dict(TABLE2_RECIPE) if variant == "mosa" else {}
    cfg = _shrink(get_config("mosa-paper", preset="smoke", variant=variant,
                             **kw), d_model)
    server = Server(cfg, batch=batch, max_len=max_len)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 2, cfg.vocab)
    fused = time_decode(server, prompts, gen, fused=True, iters=iters,
                        calib0=calib0)
    stepwise = time_decode(server, prompts, gen, fused=False, iters=iters,
                           calib0=calib0)
    out = {
        "fused_tok_s": round(fused, 2),
        "stepwise_tok_s": round(stepwise, 2),
        "fused_speedup": round(fused / stepwise, 2),
        "cache_bytes": cache_nbytes(server.new_cache()),
    }
    if cfg.mosa is not None:
        from repro.core.hybrid import HybridAttention
        hy = HybridAttention(cfg.d_model, cfg.mosa)
        out["kv_entries_per_layer"] = hy.kv_total(max_len)
        out["kv_entries_dense_equiv"] = max_len * (
            cfg.mosa.n_dense_heads + cfg.mosa.n_mosa_heads)
    return out


def _cache_layout(cfg, max_len: int, block_size: int) -> dict:
    """Measured byte layout of both cache families for ONE model config:
    per-slot contiguous bytes, per-row paged overhead (tables, MoSA rows),
    and per-block pool bytes (dense and window groups, stacked layers
    weighted by their unit count)."""
    model = TransformerLM(cfg)
    paged = PagedConfig(block_size=block_size)

    def nbytes(batch, pg=None):
        shapes = jax.eval_shape(
            lambda: model.init_cache(batch, max_len, jnp.bfloat16, paged=pg))
        return cache_nbytes(shapes)

    contig_row = nbytes(1)
    dense_block = window_block = 0
    wb = 0
    shapes = jax.eval_shape(
        lambda: model.init_cache(1, max_len, jnp.bfloat16, paged=paged))

    def walk(path, leaf):
        nonlocal dense_block, window_block, wb
        if isinstance(leaf, (PagedDenseKVCache, PagedWindowKVCache)):
            n_axis = 0 if leaf.k.ndim == 4 else 1     # stacked pools
            per_block = 2 * (cache_nbytes(leaf.k) // leaf.k.shape[n_axis])
            if isinstance(leaf, PagedDenseKVCache):
                dense_block += per_block
            else:
                window_block += per_block
                wb = leaf.block_table.shape[-1]
        return leaf

    jax.tree_util.tree_map_with_path(
        walk, shapes,
        is_leaf=lambda x: isinstance(x, (PagedDenseKVCache,
                                         PagedWindowKVCache)))
    pool_row = nbytes(1, paged)
    # per-row paged overhead = everything that is not pool: tables, MoSA
    # caches, window positions (pools here are the 1-row worst case).
    nb = -(-max_len // block_size)
    row_overhead = pool_row - nb * dense_block - wb * window_block
    return {"contig_row": contig_row, "dense_block": dense_block,
            "window_block": window_block, "wb": wb,
            "row_overhead": max(row_overhead, 0), "nb": nb}


def capacity_at_budget(cfg, max_len: int, req_tokens: int,
                       block_size: int = 16, budget_slots: int = 8) -> dict:
    """Max concurrent requests under a FIXED cache-memory budget (the bytes
    ``budget_slots`` contiguous slots would reserve): the contiguous path
    admits one request per worst-case slab; the paged path admits while
    blocks for the request's ACTUAL tokens fit (DESIGN §7)."""
    lay = _cache_layout(cfg, max_len, block_size)
    budget = budget_slots * lay["contig_row"]
    req_blocks = -(-req_tokens // block_size)
    per_req = (lay["row_overhead"] + req_blocks * lay["dense_block"] +
               lay["wb"] * lay["window_block"])
    paged_max = int(budget // per_req)
    return {"budget_bytes": int(budget), "req_tokens": req_tokens,
            "block_size": block_size,
            "contiguous_max_concurrent": budget_slots,
            "paged_max_concurrent": paged_max,
            "paged_bytes_per_request": int(per_req),
            "capacity_ratio": round(paged_max / budget_slots, 2)}


def bench_paged(batch: int, prompt_len: int, gen: int, max_len: int,
                iters: int, d_model: int, calib0: float = 0.0) -> dict:
    """Paged-vs-contiguous family on the Table-2 MoSA recipe: fused decode
    tok/s (same model, same sampler — the contrast isolates the paged
    append/gather path), worst-case KV bytes, capacity at fixed budget."""
    kw = dict(TABLE2_RECIPE)
    cfg = _shrink(get_config("mosa-paper", preset="smoke", variant="mosa",
                             **kw), d_model)
    contig = Server(cfg, batch=batch, max_len=max_len)
    paged = Server(cfg, batch=batch, max_len=max_len, params=contig.params,
                   paged=PagedConfig(block_size=16))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 2, cfg.vocab)
    fused_paged = time_decode(paged, prompts, gen, fused=True, iters=iters,
                              calib0=calib0)
    fused_contig = time_decode(contig, prompts, gen, fused=True, iters=iters,
                               calib0=calib0)
    out = {
        "fused_tok_s": round(fused_paged, 2),
        "fused_tok_s_contiguous": round(fused_contig, 2),
        "paged_over_contiguous": round(fused_paged / fused_contig, 3),
        "cache_bytes": cache_nbytes(paged.new_cache()),
        "cache_bytes_contiguous": cache_nbytes(contig.new_cache()),
        "capacity": capacity_at_budget(cfg, max_len,
                                       req_tokens=prompt_len + gen),
    }
    return out


# Length-skewed arrival mix (mixed-length family): mostly short prompts
# with a heavy tail of long ones — the regime where pow2 bucketing paid up
# to 2x padding and a monolithic prefill stalled TTFT for everyone.
MIXED_LENS = (12, 180, 24, 9, 96, 33, 17, 140, 28, 11, 64, 48, 21, 200,
              37, 15)


def _pow2_bucket(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


def _check_obs_artifacts(metrics_path: str, trace_path: str, rids) -> None:
    """Self-check of the emitted observability artifacts (ISSUE 8
    acceptance): the Chrome trace must carry the queued -> prefill ->
    decode lifecycle for EVERY request on its own track, and the metrics
    snapshot must hold the TTFT/TPOT histograms plus BlockPool and
    prefix-cache series.  Raises AssertionError on any gap."""
    tr = json.loads(open(trace_path).read())
    tid_name = {ev["tid"]: ev["args"]["name"]
                for ev in tr["traceEvents"]
                if ev.get("ph") == "M" and ev.get("name") == "thread_name"}
    by_track: dict = {}
    for ev in tr["traceEvents"]:
        if ev.get("ph") == "X":
            by_track.setdefault(tid_name.get(ev["tid"]), set()).add(
                ev["name"])
    for r in rids:
        missing = {"queued", "prefill", "decode"} - by_track.get(
            f"req{r}", set())
        assert not missing, f"trace missing spans {missing} for req{r}"
    snap = json.loads(open(metrics_path).read().splitlines()[-1])
    h = snap["histograms"]
    assert h.get("serve.ttft_s", {}).get("count", 0) >= len(rids), \
        f"serve.ttft_s histogram incomplete: {h.get('serve.ttft_s')}"
    assert "serve.tpot_s" in h, f"no TPOT histogram in {sorted(h)}"
    assert any(k.startswith("pool.dense.") for k in snap["gauges"]), \
        f"no BlockPool gauges in {sorted(snap['gauges'])}"
    assert any(k.startswith("prefix.") for k in snap["counters"]), \
        f"no prefix-cache counters in {sorted(snap['counters'])}"


def _paged_server(max_len: int, d_model: int, batch: int) -> Server:
    """The paged Table-2 MoSA server the mixed and SLO families share —
    ONE instance, so the second family rides the first's warm jit caches
    instead of recompiling identical programs."""
    cfg = _shrink(get_config("mosa-paper", preset="smoke", variant="mosa",
                             **TABLE2_RECIPE), d_model)
    nb = -(-max_len // 16)
    return Server(cfg, batch=batch, max_len=max_len,
                  paged=PagedConfig(block_size=16, num_blocks=batch * nb,
                                    num_window_blocks=4 * batch))


def bench_mixed(gen: int, max_len: int, d_model: int,
                chunk_tokens: int = 32, batch: int = 8,
                obs_iters: int = 6,
                metrics_path: str = "BENCH_serve.metrics.jsonl",
                trace_path: str = "BENCH_serve.trace.json",
                server: Server = None) -> dict:
    """Mixed-length family (ISSUE 6): the chunked packed-prefill scheduler
    over a length-skewed arrival mix.  Reports TTFT p50/p99 (seconds from
    run start to each request's first sampled token) and the packed-token
    efficiency — prefilled tokens / prefill chunk slots paid — against the
    analytic pow2-bucket efficiency the deleted ``_bucket`` path would have
    paid on the same mix.

    Also the observability family (ISSUE 8): ``obs_overhead`` = scheduler
    wall time with the obs registry+tracer ON over OFF, gated <= 1.02 by
    ``--check``.  The true overhead profiles at <1% (the hot path is dict
    lookups plus a bisect), an order of magnitude under per-pass box noise
    (±5-10% on a ~0.5 s warm pass), so the estimator is built for noise:
    warm interleaved passes with the on/off ORDER alternated each round
    (cancels slow drift), a 40%-trimmed mean per side (min-of-k proved
    unstable — a single lucky pass on either side swings the ratio), and
    one fresh confirmation round before a >1.02 ratio is recorded (a real
    hot-path regression fails both rounds; a neighbor-load spike does
    not).  A final instrumented pass emits the Chrome-trace JSON and
    metrics-snapshot JSONL artifacts and self-checks that the trace
    covers every request's queued -> prefill -> decode lifecycle."""
    from repro import obs
    from repro.serve.scheduler import Scheduler

    if server is None:
        server = _paged_server(max_len, d_model, batch)
    cfg = server.model_cfg
    batch = server.batch
    key = jax.random.PRNGKey(2)
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (P,), 2,
                                  cfg.vocab)
               for i, P in enumerate(MIXED_LENS)]

    def one_pass(prefix_cache=False, mpath=None, tpath=None):
        sched = Scheduler(server, chunk=8, chunk_tokens=chunk_tokens,
                          max_prefill_segs=batch, prefix_cache=prefix_cache,
                          metrics_path=mpath, trace_path=tpath)
        rids = [sched.submit(p, max_new=gen) for p in prompts]
        t0 = time.perf_counter()
        res = sched.run()
        dt = time.perf_counter() - t0
        assert all(len(res[r]) == gen for r in rids)
        return sched, rids, dt

    # Reported pass: cold (includes compile), obs on — identical regime to
    # every earlier refresh so the packed_efficiency trajectory compares.
    obs.set_enabled(True)
    sched, rids, _ = one_pass()
    ttft = sorted(sched.ttft[r] for r in rids)
    st = sched.stats
    eff = st["prefilled_tokens"] / max(st["prefill_chunk_slots"], 1)
    total = sum(MIXED_LENS)
    out = {
        "requests": len(MIXED_LENS),
        "prompt_tokens_total": total,
        "chunk_tokens": chunk_tokens,
        "gen": gen,
        "ttft_s_p50": round(ttft[len(ttft) // 2], 4),
        "ttft_s_p99": round(ttft[min(len(ttft) - 1,
                                     int(0.99 * len(ttft)))], 4),
        "packed_efficiency": round(eff, 4),
        "pow2_bucket_efficiency": round(
            total / sum(_pow2_bucket(n) for n in MIXED_LENS), 4),
        "prefill_chunks": st["prefill_chunks"],
        "preemptions": st["preemptions"],
    }

    # obs overhead (see docstring for the estimator rationale).
    def overhead_round():
        t_on, t_off = [], []
        for i in range(max(obs_iters, 2)):
            first = bool(i % 2)          # alternate order: drift cancels
            obs.set_enabled(first)
            (t_on if first else t_off).append(one_pass()[2])
            obs.set_enabled(not first)
            (t_on if not first else t_off).append(one_pass()[2])
        return _trimmed_mean(t_on) / _trimmed_mean(t_off)

    try:
        ratio = overhead_round()
        if ratio > 1.02:                 # confirm before recording a fail
            ratio = min(ratio, overhead_round())
    finally:
        obs.set_enabled(True)
    out["obs_overhead"] = round(ratio, 4)

    # Artifact pass: fresh registry/tracer so the exported trace holds
    # exactly one run's spans; prefix cache ON so its series appear.
    obs.registry().reset()
    obs.tracer().reset()
    _, arids, _ = one_pass(prefix_cache=True, mpath=metrics_path,
                           tpath=trace_path)
    _check_obs_artifacts(metrics_path, trace_path, arids)
    out["obs_artifacts"] = {"metrics": metrics_path, "trace": trace_path}
    return out


def bench_slo(server: Server, gen: int = 12, n_req: int = 24,
              seed: int = 7, rates=(0.5, 1.0, 2.0), max_queue: int = 4,
              chunk_tokens: int = 32) -> dict:
    """SLO/goodput family (DESIGN §12): sweep arrival rate through
    saturation and measure what fraction of OFFERED requests the
    scheduler serves within SLO.

    Three design choices make the numbers meaningful on a shared box:

      * the sustainable rate is measured, not assumed — a closed-loop
        pass at full concurrency (self-throttling, so it reads capacity,
        never overload) calibrates the req/s the open-loop sweep is
        scaled against, so "2x" is 2x THIS machine's saturation point;
      * SLO thresholds are self-relative — 3x the p90 TTFT/TPOT of the
        sweep's own uncontended (0.5x) pass — so machine drift between
        refreshes rescales the thresholds along with the latencies;
      * one workload seed across all rates — the rng draws interarrivals
        before request bodies, so every rate offers the IDENTICAL request
        population on a faster or slower clock.

    ``max_queue`` bounds admission: overload sheds excess arrivals
    (``outcome="shed"``, counted against goodput) instead of letting the
    queue destroy every admitted request's TTFT — the graceful-
    degradation shape ``check_regression`` gates."""
    from repro import obs
    from repro.obs.slo import SLOSpec, evaluate
    from repro.serve.loadgen import (Arrival, ClosedLoopSource,
                                     OpenLoopSource, TenantSpec,
                                     poisson_workload)
    from repro.serve.scheduler import Scheduler

    vocab = server.model_cfg.vocab
    batch = server.batch
    tenants = (TenantSpec("gold", weight=1.0, prompt_len=(8, 24),
                          max_new=(4, gen)),
               TenantSpec("free", weight=2.0, prompt_len=(16, 48),
                          max_new=(4, gen)))
    obs.set_enabled(True)

    def run_source(source, mq=None):
        sched = Scheduler(server, chunk=8, chunk_tokens=chunk_tokens,
                          max_prefill_segs=batch, prefix_cache=False,
                          max_queue=mq)
        t0 = time.perf_counter()
        sched.run(max_steps=100_000, source=source)
        return sched, time.perf_counter() - t0

    # Calibration: closed loop holding ``batch`` requests outstanding,
    # over the SWEEP'S OWN request population (arrival times ignored) —
    # run twice, first pass discarded.  The warm pass retires every
    # one-time prefill/decode-chunk compile this exact population
    # triggers; without it those compiles land in the timed passes,
    # inflating the calibration (so "2x" never saturates) or the 0.5x
    # pass (queue backup -> sheds at HALF the sustainable rate, poisoning
    # the SLO thresholds it defines).  Both failure shapes were observed.
    wl = poisson_workload(1.0, n_req, seed + 1, vocab, tenants)
    run_source(ClosedLoopSource(wl, batch))        # warm pass: discarded
    cal, cal_dt = run_source(ClosedLoopSource(wl, batch))
    n_fin = sum(1 for r in cal.records.values()
                if r["outcome"] == "finished")
    sustainable = n_fin / max(cal_dt, 1e-9)

    # Open-loop Poisson sweep through saturation (arrivals keep coming no
    # matter how far behind the server falls — the overload-honest mode).
    # ``wl`` was drawn at rate 1.0 req/s; rescaling its clock offers the
    # IDENTICAL request population at every rate.
    passes = {}
    for mult in rates:
        rate = max(sustainable * mult, 1e-3)
        arrivals = [Arrival(a.t / rate, a.tenant, a.prompt, a.max_new)
                    for a in wl]
        sched, dt = run_source(OpenLoopSource(arrivals), mq=max_queue)
        passes[mult] = (list(sched.records.values()), dt,
                        sched.stats["preemptions"])

    lo = min(passes)
    wide = evaluate(passes[lo][0], SLOSpec(ttft_s=float("inf")))
    ttft_slo = max(3.0 * wide["ttft"].get("p90", 0.0), 1e-3)
    tpot_slo = (3.0 * wide["tpot"]["p90"]
                if wide["tpot"]["count"] else None)
    spec = SLOSpec(ttft_s=ttft_slo, tpot_s=tpot_slo, name=f"3x-p90@{lo}x")

    out = {"sustainable_req_s": round(sustainable, 3),
           "n_requests": n_req, "seed": seed, "max_queue": max_queue,
           "tenants": [t.name for t in tenants],
           "spec": {"name": spec.name, "ttft_s": round(ttft_slo, 4),
                    "tpot_s": (round(tpot_slo, 5)
                               if tpot_slo is not None else None)},
           "rates": {}}
    for mult in sorted(passes):
        recs, dt, npre = passes[mult]
        ev = evaluate(recs, spec)
        out["rates"][f"{mult}x"] = {
            "offered_req_s": round(sustainable * mult, 3),
            "duration_s": round(dt, 3),
            "total": ev["total"], "finished": ev["finished"],
            "shed": ev["shed"], "preempted": npre,
            "goodput": round(ev["goodput"], 4),
            "served_goodput": round(ev["served_goodput"], 4),
            "ttft_p50": round(ev["ttft"].get("p50", 0.0), 4),
            "ttft_p99": round(ev["ttft"].get("p99", 0.0), 4),
            "tpot_p50": round(ev["tpot"].get("p50", 0.0), 5),
            "tpot_p99": round(ev["tpot"].get("p99", 0.0), 5),
            "per_tenant": {
                t: {"total": s["total"], "shed": s["shed"],
                    "goodput": round(s["goodput"], 4)}
                for t, s in ev.get("per_tenant", {}).items()},
        }
    return out


def run_bench(batch: int = 2, prompt_len: int = 16, gen: int = 64,
              max_len: int = 256, iters: int = 5,
              variants=("dense", "mosa"), d_model: int = 128,
              out_path: str = "BENCH_serve.json") -> dict:
    calib0 = round(calibrate_ms(), 3)
    res = {
        "benchmark": "serve_decode",
        "config": {"arch": "mosa-paper", "preset": "smoke", "batch": batch,
                   "prompt_len": prompt_len, "gen": gen, "max_len": max_len,
                   "d_model": d_model, "mosa_recipe": TABLE2_RECIPE},
        "env": {"jax": jax.__version__, "backend": jax.default_backend(),
                "devices": len(jax.devices())},
        "calib_ms": calib0,
        "calib_v": CALIB_VERSION,
        "variants": {},
    }
    for v in variants:
        res["variants"][v] = bench_variant(v, batch, prompt_len, gen,
                                           max_len, iters, d_model, calib0)
    if {"dense", "mosa"} <= set(res["variants"]):
        d, m = res["variants"]["dense"], res["variants"]["mosa"]
        res["kv_bytes_mosa_over_dense"] = round(
            m["cache_bytes"] / d["cache_bytes"], 4)
    res["paged"] = bench_paged(batch, prompt_len, gen, max_len, iters,
                               d_model, calib0)
    # Short gen: the mixed family measures PREFILL scheduling (TTFT +
    # packing), not decode throughput — the families above cover that.
    base = out_path[:-len(".json")] if out_path.endswith(".json") else \
        out_path
    server = _paged_server(max_len, d_model, batch=8)
    res["mixed"] = bench_mixed(gen=8, max_len=max_len, d_model=d_model,
                               metrics_path=f"{base}.metrics.jsonl",
                               trace_path=f"{base}.trace.json",
                               server=server)
    res["slo"] = bench_slo(server)
    return res


def _append_trajectory(res: dict, prev: dict) -> None:
    """Grow the tracked perf trajectory: one summary entry per refresh.
    A pre-trajectory artifact (PR 2) seeds entry 0 from its recorded
    numbers so the very first paged refresh already has a baseline."""
    traj = list(prev.get("trajectory", []))
    if not traj and prev.get("variants"):
        traj.append({"entry": 0,
                     "fused_tok_s": {v: r.get("fused_tok_s")
                                     for v, r in prev["variants"].items()}})
    entry = {"entry": len(traj),
             "calib_ms": res.get("calib_ms"),
             "calib_v": res.get("calib_v"),
             "fused_tok_s": {v: r["fused_tok_s"]
                             for v, r in res["variants"].items()}}
    if "paged" in res:
        entry["paged_fused_tok_s"] = res["paged"]["fused_tok_s"]
        entry["capacity_ratio"] = \
            res["paged"]["capacity"]["capacity_ratio"]
    if "mixed" in res:
        entry["packed_efficiency"] = res["mixed"]["packed_efficiency"]
        if "obs_overhead" in res["mixed"]:
            entry["obs_overhead"] = res["mixed"]["obs_overhead"]
    if "slo" in res:
        rt = res["slo"]["rates"]
        keys = sorted(rt, key=lambda k: float(k[:-1]))
        lo_k, hi_k = keys[0], keys[-1]
        entry["slo"] = {
            "rates": len(keys),
            "goodput_low": rt[lo_k]["goodput"],
            "goodput_high": rt[hi_k]["goodput"],
            "shed_preempt_high": rt[hi_k]["shed"] + rt[hi_k]["preempted"],
            "ttft_p99_high": rt[hi_k]["ttft_p99"],
            "ttft_slo": res["slo"]["spec"]["ttft_s"],
        }
    traj.append(entry)
    res["trajectory"] = traj[-12:]


def _gated_values(entry: dict) -> dict:
    vals = dict(entry.get("fused_tok_s") or {})
    if entry.get("paged_fused_tok_s"):
        vals["paged"] = entry["paged_fused_tok_s"]
    return vals


def check_regression(path: str, tol: float = 0.10) -> int:
    """``make bench-check``: fail (nonzero) when the newest trajectory
    entry regresses fused decode throughput by more than ``tol`` against
    the previous entry's machine-speed-adjusted baseline (the shared gate
    in ``benchmarks.calib``)."""
    import os
    if not os.path.exists(path):
        print(f"bench-check: {path} missing — run `make bench-smoke`")
        return 1
    res = json.loads(open(path).read())
    traj = res.get("trajectory", [])
    # Hard floor (not a relative gate): the chunked packed prefill must
    # keep >= 95% of its chunk slots doing real work on the mixed-length
    # family (ISSUE 6 acceptance) — pow2 bucketing managed ~65%.
    if traj and "packed_efficiency" in traj[-1]:
        eff = traj[-1]["packed_efficiency"]
        if eff < 0.95:
            print(f"bench-check FAIL(serve): packed_efficiency {eff} "
                  f"< 0.95 floor")
            return 1
        print(f"bench-check OK(serve): packed_efficiency {eff} >= 0.95")
    # Hard ceiling (ISSUE 8 acceptance): instrumentation must stay within
    # 2% of the obs-off scheduler wall time on the mixed-length family.
    if traj and "obs_overhead" in traj[-1]:
        ov = traj[-1]["obs_overhead"]
        if ov > 1.02:
            print(f"bench-check FAIL(serve): obs_overhead {ov} "
                  f"> 1.02 ceiling")
            return 1
        print(f"bench-check OK(serve): obs_overhead {ov} <= 1.02")
    # SLO family (DESIGN §12): overload must degrade goodput GRACEFULLY —
    # the sweep saturates (sheds/preempts appear), goodput at the
    # uncontended rate stays high, and admitted work's TTFT is protected
    # by admission control instead of collapsing with the queue.  All
    # thresholds are self-relative to the same refresh's measurements, so
    # machine drift cannot flip them.
    if traj and "slo" in traj[-1]:
        sl = traj[-1]["slo"]
        fails = []
        if sl["rates"] < 3:
            fails.append(f"only {sl['rates']} arrival rates swept (< 3)")
        if sl["goodput_low"] < 0.75:
            fails.append(f"goodput {sl['goodput_low']} < 0.75 at the "
                         f"uncontended (lowest) rate")
        # Margin = one request quantum (goodput moves in 1/n_req ~ 0.04
        # steps; a single TPOT outlier at the low rate shifts it that
        # much): overload may not look BETTER than uncontended.
        if sl["goodput_high"] > sl["goodput_low"] + 0.05:
            fails.append(f"goodput at overload ({sl['goodput_high']}) "
                         f"exceeds the uncontended rate "
                         f"({sl['goodput_low']}) — the SLO thresholds "
                         f"are not binding")
        if sl["shed_preempt_high"] <= 0:
            fails.append("overload produced no sheds or preemptions — "
                         "the sweep never saturated the server")
        if sl["ttft_p99_high"] > 10 * sl["ttft_slo"]:
            fails.append(f"ttft_p99 {sl['ttft_p99_high']}s at overload "
                         f"> 10x the SLO ({sl['ttft_slo']}s) — admission "
                         f"control is not protecting admitted work")
        if fails:
            for msg in fails:
                print(f"bench-check FAIL(serve/slo): {msg}")
            return 1
        print(f"bench-check OK(serve/slo): goodput {sl['goodput_low']} "
              f"-> {sl['goodput_high']} across {sl['rates']} rates; "
              f"overload shed+preempt={sl['shed_preempt_high']}; "
              f"ttft_p99 {sl['ttft_p99_high']}s <= 10x slo")
    return check_gate(traj, _gated_values, tol, "serve")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=64)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--iters", type=int, default=5)
    p.add_argument("--d-model", type=int, default=128,
                   help="shrink the smoke model to this width "
                        "(0 = keep the full smoke config)")
    p.add_argument("--out", default="BENCH_serve.json")
    p.add_argument("--check", action="store_true",
                   help="compare the two newest trajectory entries and "
                        "fail on a >10%% fused-throughput regression")
    args = p.parse_args(argv)

    if args.check:
        raise SystemExit(check_regression(args.out))

    try:
        with open(args.out) as f:
            prev = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        prev = {}
    res = run_bench(args.batch, args.prompt_len, args.gen, args.max_len,
                    args.iters, d_model=args.d_model, out_path=args.out)
    _append_trajectory(res, prev)
    print("name,us_per_call,derived")
    for v, r in res["variants"].items():
        print(f"decode/{v},0.0,fused={r['fused_tok_s']}tok/s;"
              f"stepwise={r['stepwise_tok_s']}tok/s;"
              f"speedup={r['fused_speedup']}x")
        print(f"decode/{v}_kv,0.0,cache_bytes={r['cache_bytes']}")
    if "kv_bytes_mosa_over_dense" in res:
        print(f"decode/kv_ratio,0.0,"
              f"mosa_over_dense={res['kv_bytes_mosa_over_dense']}")
    pg = res["paged"]
    print(f"decode/paged,0.0,fused={pg['fused_tok_s']}tok/s;"
          f"vs_contig={pg['paged_over_contiguous']}x")
    cap = pg["capacity"]
    print(f"decode/paged_capacity,0.0,"
          f"concurrent={cap['paged_max_concurrent']}"
          f"vs{cap['contiguous_max_concurrent']};"
          f"ratio={cap['capacity_ratio']}x@"
          f"{cap['budget_bytes']}B")
    mx = res["mixed"]
    print(f"prefill/mixed,0.0,ttft_p50={mx['ttft_s_p50']}s;"
          f"ttft_p99={mx['ttft_s_p99']}s;"
          f"packed_eff={mx['packed_efficiency']};"
          f"pow2_eff={mx['pow2_bucket_efficiency']};"
          f"chunks={mx['prefill_chunks']}")
    print(f"obs/overhead,0.0,on_over_off={mx['obs_overhead']};"
          f"trace={mx['obs_artifacts']['trace']};"
          f"metrics={mx['obs_artifacts']['metrics']}")
    sl = res["slo"]
    rate_keys = sorted(sl["rates"], key=lambda k: float(k[:-1]))
    print("slo/goodput,0.0," +
          ";".join(f"{k}={sl['rates'][k]['goodput']}"
                   for k in rate_keys) +
          f";sustainable={sl['sustainable_req_s']}req/s;"
          f"ttft_slo={sl['spec']['ttft_s']}s")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
