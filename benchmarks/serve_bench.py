"""Decode benchmark family — the serving-path perf trajectory.

Measures, for dense vs MoSA variants of the paper's model at smoke scale:

  * decode throughput (tok/s) of the scan-fused chunk decoder
    (``Server.decode_many``, one dispatch per chunk) against the legacy
    per-token loop (one jit dispatch + eager sampling dispatches per token;
    the contrast measures dispatch overhead — jax async dispatch means
    neither path syncs the host per token) — DESIGN §6;
  * KV-cache footprint in bytes at the same ``max_len`` — the paper's
    serving payoff (MoSA heads hold k entries each, independent of context).

Two deliberate choices at smoke scale:

  * the model is SHRUNK (``--d-model``) below the paper's tiny config: the
    fused/loop contrast is about per-token dispatch + host-sync overhead,
    and on a slow CPU the full smoke model is weight-streaming-bound
    (~10 ms/step of parameter reads), which hides exactly the overhead the
    fused path removes.  At real serving scale the accelerator streams
    weights fast enough that dispatch shows; shrinking reproduces that
    regime on CPU.  Both paths always run the SAME config.
  * the MoSA variant is the paper's Table-2 ppl-matched recipe (4 dense +
    17 MoSA heads @ rho=32), not the IsoFLOP hybrid: KV size is a
    resource-at-matched-quality claim, and the IsoFLOP hybrid trades its
    FLOP budget for ~5x more heads, which would inflate its cache.

Writes ``BENCH_serve.json`` (the tracked perf-trajectory artifact; `make
bench-smoke` refreshes it) and prints one CSV row per measurement.

    PYTHONPATH=src python -m benchmarks.serve_bench --gen 64 --max-len 256
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.kv_cache import cache_nbytes
from repro.dist import hints
from repro.launch.serve import Server

# Paper Table 2 (tiny): ppl-matched hybrid — 4 dense + 17 MoSA heads, rho=32.
TABLE2_RECIPE = {"sparsity": 32, "n_mosa_heads": 17}


def _median(ts):
    ts = sorted(ts)
    return ts[len(ts) // 2]


def time_decode(server: Server, prompts, gen: int, fused: bool,
                iters: int = 3) -> float:
    """Median decode throughput (tok/s), prefill excluded, compile warmed."""
    B = prompts.shape[0]
    key = jax.random.PRNGKey(0)
    ts = []
    with server.mesh, hints.sharding_hints(mesh=server.mesh):
        for it in range(iters + 1):          # iteration 0 warms the compile
            caches = server.new_cache()
            logits, caches = server.prefill(server.params, prompts, caches)
            tok = server.sample(logits[:, -1], key)[:, None]
            jax.block_until_ready((tok, caches))
            t0 = time.perf_counter()
            if fused:
                toks, caches = server.decode_many(server.params, tok, caches,
                                                  key, gen)
                jax.block_until_ready(toks)
            else:
                for _ in range(gen):
                    logits, caches = server.decode_step(server.params, tok,
                                                        caches)
                    tok = jnp.argmax(logits[:, -1],
                                     axis=-1).astype(jnp.int32)[:, None]
                jax.block_until_ready(tok)
            if it:
                ts.append(time.perf_counter() - t0)
    return B * gen / _median(ts)


def _shrink(cfg, d_model: int):
    """Scale the smoke config down to a dispatch-bound size (see module
    docstring); ``d_model == 0`` keeps the config untouched."""
    if not d_model or d_model == cfg.d_model:
        return cfg
    d_head = max(d_model // 8, 8)
    kw = {"attention": dataclasses.replace(cfg.attention, d_head=d_head)}
    if cfg.mosa is not None:
        kw["mosa"] = dataclasses.replace(cfg.mosa, d_head=d_head)
    return dataclasses.replace(cfg, d_model=d_model, d_ff=2 * d_model, **kw)


def bench_variant(variant: str, batch: int, prompt_len: int, gen: int,
                  max_len: int, iters: int = 3, d_model: int = 128) -> dict:
    kw = dict(TABLE2_RECIPE) if variant == "mosa" else {}
    cfg = _shrink(get_config("mosa-paper", preset="smoke", variant=variant,
                             **kw), d_model)
    server = Server(cfg, batch=batch, max_len=max_len)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 2, cfg.vocab)
    fused = time_decode(server, prompts, gen, fused=True, iters=iters)
    stepwise = time_decode(server, prompts, gen, fused=False, iters=iters)
    out = {
        "fused_tok_s": round(fused, 2),
        "stepwise_tok_s": round(stepwise, 2),
        "fused_speedup": round(fused / stepwise, 2),
        "cache_bytes": cache_nbytes(server.new_cache()),
    }
    if cfg.mosa is not None:
        from repro.core.hybrid import HybridAttention
        hy = HybridAttention(cfg.d_model, cfg.mosa)
        out["kv_entries_per_layer"] = hy.kv_total(max_len)
        out["kv_entries_dense_equiv"] = max_len * (
            cfg.mosa.n_dense_heads + cfg.mosa.n_mosa_heads)
    return out


def run_bench(batch: int = 2, prompt_len: int = 16, gen: int = 64,
              max_len: int = 256, iters: int = 3,
              variants=("dense", "mosa"), d_model: int = 128) -> dict:
    res = {
        "benchmark": "serve_decode",
        "config": {"arch": "mosa-paper", "preset": "smoke", "batch": batch,
                   "prompt_len": prompt_len, "gen": gen, "max_len": max_len,
                   "d_model": d_model, "mosa_recipe": TABLE2_RECIPE},
        "env": {"jax": jax.__version__, "backend": jax.default_backend(),
                "devices": len(jax.devices())},
        "variants": {},
    }
    for v in variants:
        res["variants"][v] = bench_variant(v, batch, prompt_len, gen,
                                           max_len, iters, d_model)
    if {"dense", "mosa"} <= set(res["variants"]):
        d, m = res["variants"]["dense"], res["variants"]["mosa"]
        res["kv_bytes_mosa_over_dense"] = round(
            m["cache_bytes"] / d["cache_bytes"], 4)
    return res


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=64)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--d-model", type=int, default=128,
                   help="shrink the smoke model to this width "
                        "(0 = keep the full smoke config)")
    p.add_argument("--out", default="BENCH_serve.json")
    args = p.parse_args(argv)

    res = run_bench(args.batch, args.prompt_len, args.gen, args.max_len,
                    args.iters, d_model=args.d_model)
    print("name,us_per_call,derived")
    for v, r in res["variants"].items():
        print(f"decode/{v},0.0,fused={r['fused_tok_s']}tok/s;"
              f"stepwise={r['stepwise_tok_s']}tok/s;"
              f"speedup={r['fused_speedup']}x")
        print(f"decode/{v}_kv,0.0,cache_bytes={r['cache_bytes']}")
    if "kv_bytes_mosa_over_dense" in res:
        print(f"decode/kv_ratio,0.0,"
              f"mosa_over_dense={res['kv_bytes_mosa_over_dense']}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
