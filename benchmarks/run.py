"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Scales are reduced so the
whole suite runs on a single CPU in minutes; every harness exposes knobs to
run at the paper's true scale on real hardware.

  table1_isoflop   — FLOP-matched dense vs MoSA vs Fixed vs Routing (Table 1)
  table2_resource  — wall/step + KV cache, ppl-matched setting (Table 2)
  fig3_sparsity    — MoSA ppl across sparsity levels (Fig. 3)
  fig4_longseq     — constant-k long-sequence scaling (Fig. 4)
  kernels          — mosa/flash attention micro-benchmarks (XLA path)
  flops_check      — paper Table 4/5 accounting (exact)
  decode           — serving decode path: fused vs per-token tok/s + KV bytes
                     (full knobs / JSON artifact: ``benchmarks.serve_bench``)
"""

from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp

from benchmarks.harness import short_train, time_jit
from repro.configs.mosa_paper import paper_config
from repro.core.flops import (PAPER_MODELS, TABLE4_GFLOPS,
                              TABLE5_HYBRID_HEADS, flops_dense_head,
                              flops_mosa_head, flops_routing_head)

ROWS = []


def emit(name: str, us: float, derived: str):
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _reduced(cfg, n_layers=2, vocab=512):
    pat = cfg.pattern[:n_layers] if cfg.pattern else ()
    return dataclasses.replace(cfg, n_layers=n_layers, vocab=vocab,
                               pattern=pat)


# --------------------------------------------------------------- Table 1
def table1_isoflop(steps=60, seq=256, batch=8):
    """FLOP-matched comparison at reduced scale.  derived = final ppl."""
    rho = 8
    variants = {
        "dense": _reduced(paper_config("tiny", "dense", seq_len=seq)),
        "mosa": _reduced(paper_config("tiny", "mosa", rho, seq_len=seq)),
        "fixed": _reduced(paper_config("tiny", "fixed", rho, seq_len=seq)),
        "routing": _reduced(paper_config("tiny", "routing", rho, seq_len=seq)),
    }
    results = {}
    for name, cfg in variants.items():
        loss, ppl, s_step = short_train(cfg, steps=steps, seq=seq, batch=batch)
        results[name] = ppl
        emit(f"table1_isoflop/{name}", s_step * 1e6, f"ppl={ppl:.2f}")
    emit("table1_isoflop/mosa_vs_dense", 0.0,
         f"ppl_ratio={results['mosa'] / results['dense']:.3f}")
    return results


# --------------------------------------------------------------- Table 2
def table2_resource(steps=40, seq=256, batch=8):
    """Perplexity-matched resource use: wall/step + the KV metric."""
    from repro.core.hybrid import HybridAttention
    dense = _reduced(paper_config("tiny", "dense", seq_len=seq))
    # paper's Table-2 tiny recipe: 4 dense + 17 MoSA @ rho=32 (ppl-matched)
    mosa = _reduced(paper_config("tiny", "mosa", 32, seq_len=seq,
                                 n_mosa_heads=17))
    _, ppl_d, s_d = short_train(dense, steps=steps, seq=seq, batch=batch)
    _, ppl_m, s_m = short_train(mosa, steps=steps, seq=seq, batch=batch)
    emit("table2_resource/dense", s_d * 1e6, f"ppl={ppl_d:.2f}")
    emit("table2_resource/mosa", s_m * 1e6,
         f"ppl={ppl_m:.2f};wall_gain={100 * (1 - s_m / s_d):.1f}%")
    hy = HybridAttention(mosa.d_model, mosa.mosa)
    T = 1024
    kv_m = hy.kv_total(T)
    kv_d = T * dense.attention.n_heads
    emit("table2_resource/kv_total", 0.0,
         f"dense={kv_d};mosa={kv_m};gain={100 * (1 - kv_m / kv_d):.1f}%")


# ---------------------------------------------------------------- Fig. 3
def fig3_sparsity(steps=40, seq=256, batch=8, sparsities=(2, 4, 8, 16)):
    """MoSA ppl across sparsity at fixed FLOPs (U-curve of Fig. 3)."""
    base = _reduced(paper_config("tiny", "dense", seq_len=seq))
    _, ppl0, s0 = short_train(base, steps=steps, seq=seq, batch=batch)
    emit("fig3_sparsity/rho=1(dense)", s0 * 1e6, f"ppl={ppl0:.2f}")
    for rho in sparsities:
        cfg = _reduced(paper_config("tiny", "mosa", rho, seq_len=seq))
        _, ppl, s = short_train(cfg, steps=steps, seq=seq, batch=batch)
        emit(f"fig3_sparsity/rho={rho}", s * 1e6,
             f"ppl={ppl:.2f};heads={cfg.mosa.n_mosa_heads}")


# ---------------------------------------------------------------- Fig. 4
def fig4_longseq(seqs=(256, 512, 1024), k=64, steps=25, batch=2):
    """Constant-k scaling: MoSA+local FLOPs & ppl as T grows (Fig. 4)."""
    h, hp = 512, 64
    for T in seqs:
        f_mosa = 60 * flops_mosa_head(T, k, h, hp)
        f_routing = 4 * flops_routing_head(T, k, h, hp)
        emit(f"fig4_longseq/flops_T={T}", 0.0,
             f"mosa60={f_mosa:.3e};routing4={f_routing:.3e};"
             f"ratio={f_mosa / f_routing:.3f}")
    for T in seqs:
        cfg = paper_config("tiny", "mosa", sparsity=max(T // k, 1), seq_len=T,
                           n_mosa_heads=8, local_window=64)
        cfg = _reduced(cfg)
        cfg = dataclasses.replace(
            cfg, mosa=dataclasses.replace(cfg.mosa, k_fixed=k))
        loss, ppl, s = short_train(cfg, steps=steps, seq=T, batch=batch)
        emit(f"fig4_longseq/mosa_T={T}", s * 1e6, f"ppl={ppl:.2f};k={k}")


# --------------------------------------------------------------- kernels
def kernels():
    """Micro-benchmarks of the attention layers (jitted XLA path on host)."""
    from repro.configs.base import AttentionConfig, MoSAConfig
    from repro.core.attention import MultiHeadAttention
    from repro.core.mosa import MoSAAttention
    key = jax.random.PRNGKey(0)
    B, T, h = 4, 1024, 512

    x = jax.random.normal(key, (B, T, h), jnp.float32)
    for rho in (8, 32):
        cfg = MoSAConfig(n_mosa_heads=8, sparsity=rho, n_dense_heads=0,
                         d_head=64)
        m = MoSAAttention(h, cfg)
        p = m.init(key)
        fn = jax.jit(m.__call__)
        us = time_jit(fn, p, x)
        flops = 8 * flops_mosa_head(T, T // rho, h, 64)
        emit(f"kernels/mosa_layer_rho{rho}", us,
             f"GFLOP={flops / 1e9:.2f};GFLOPs={flops / us / 1e3:.1f}")

    acfg = AttentionConfig(n_heads=8, n_kv_heads=8, d_head=64)
    mha = MultiHeadAttention(h, acfg, impl="chunked")
    p = mha.init(key)
    us = time_jit(jax.jit(mha.__call__), p, x)
    flops = 8 * flops_dense_head(T, h, 64)
    emit("kernels/dense_layer", us,
         f"GFLOP={flops / 1e9:.2f};GFLOPs={flops / us / 1e3:.1f}")


# ---------------------------------------------------------------- decode
def decode(batch=2, gen=32, max_len=256):
    """Serving decode path (tok/s + KV bytes); see benchmarks.serve_bench."""
    from benchmarks.serve_bench import run_bench
    res = run_bench(batch=batch, gen=gen, max_len=max_len)
    for v, r in res["variants"].items():
        emit(f"decode/{v}", 1e6 * batch / r["fused_tok_s"],
             f"fused={r['fused_tok_s']}tok/s;"
             f"stepwise={r['stepwise_tok_s']}tok/s;"
             f"speedup={r['fused_speedup']}x;kv_bytes={r['cache_bytes']}")
    if "kv_bytes_mosa_over_dense" in res:
        emit("decode/kv_ratio", 0.0,
             f"mosa_over_dense={res['kv_bytes_mosa_over_dense']}")


# ----------------------------------------------------------- accounting
def flops_check():
    for size, want in TABLE4_GFLOPS.items():
        got = PAPER_MODELS[size].dense_flops() / 1e9
        emit(f"flops_check/table4_{size}", 0.0,
             f"got={got:.2f}G;paper={want}G;match={abs(got - want) < 0.01}")
    for size, rows in TABLE5_HYBRID_HEADS.items():
        ok = all(PAPER_MODELS[size].hybrid_mosa_heads(s) == n
                 for s, n in rows.items())
        emit(f"flops_check/table5_{size}", 0.0, f"exact_match={ok}")


ALL = {
    "flops_check": flops_check,
    "kernels": kernels,
    "decode": decode,
    "table1_isoflop": table1_isoflop,
    "table2_resource": table2_resource,
    "fig3_sparsity": fig3_sparsity,
    "fig4_longseq": fig4_longseq,
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for n in names:
        ALL[n]()


if __name__ == '__main__':
    main()
