"""repro.train subsystem: microbatch accumulation, mixed precision, remat
policies, SIGTERM-driven checkpoint-resume loss-curve parity, router health
telemetry, and the IsoFLOP smoke sweep (DESIGN §8)."""

import dataclasses
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import get_config
from repro.nn.transformer import TransformerLM
from repro.optim import schedules
from repro.optim.optimizer import adamw
from repro.train.loop import TrainConfig, Trainer
from repro.train.step import make_train_step, microbatch_split


def _cfg(tmp_path=None, steps=8, **kw):
    kw.setdefault("arch_kwargs", {"variant": "mosa"})
    kw.setdefault("log_every", 100)
    return TrainConfig(
        arch="mosa-paper", preset="smoke",
        seq_len=64, global_batch=4, steps=steps, lr=1e-3, warmup=4,
        ckpt_dir=str(tmp_path) if tmp_path else None, ckpt_every=4, **kw)


def _batch(cfg, B=4, T=32, seed=0):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (B, T), 2,
                                cfg.vocab)
    return {"tokens": tokens, "labels": tokens}


# ------------------------------------------------------------- microbatch
def test_microbatch_accumulation_matches_full_batch():
    """m-way grad accumulation is numerically the large-batch step: equal
    token counts per microbatch make mean-of-means the full mean."""
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa")
    model = TransformerLM(cfg)
    opt = adamw(schedules.linear_warmup(1e-3, 10), clip_norm=1.0)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    outs = {}
    for m in (1, 4):
        step_fn = make_train_step(model, opt, microbatches=m)
        p, o, s, metrics = step_fn(params, opt.init(params),
                                   jnp.zeros((), jnp.int32), batch)
        outs[m] = (p, metrics)
    np.testing.assert_allclose(float(outs[4][1]["loss"]),
                               float(outs[1][1]["loss"]), rtol=1e-6)
    np.testing.assert_allclose(float(outs[4][1]["grad_norm"]),
                               float(outs[1][1]["grad_norm"]), rtol=1e-6)
    # fp accumulation-order noise only (AdamW's mu/sqrt(nu) amplifies tiny
    # grad deltas near nu ~ 0, so the bound is on the UPDATE scale ~ lr)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   rtol=1e-5)


def test_microbatch_split_validates_divisibility():
    with pytest.raises(AssertionError):
        microbatch_split({"x": jnp.zeros((5, 2))}, 2)


# -------------------------------------------------------- mixed precision
def test_mixed_precision_bf16_compute_f32_master(tmp_path):
    """compute="bfloat16": master params stay fp32 (they ARE the master
    weights), activations run bf16, training still reduces the loss, and a
    checkpoint round-trips the fp32 masters exactly."""
    tr = Trainer(_cfg(tmp_path, steps=8, compute="bfloat16", log_every=1))
    assert tr.model_cfg.cdtype == jnp.bfloat16
    assert tr.model_cfg.pdtype == jnp.float32
    params, _, hist = tr.run(install_signals=False)
    for leaf in jax.tree.leaves(params):
        assert leaf.dtype == jnp.float32
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert np.isfinite([h["loss"] for h in hist]).all()


# ------------------------------------------------------------------ remat
@pytest.mark.parametrize("remat", ["full", "dots_saveable", "mosa"])
def test_remat_policies_preserve_loss_and_grads(remat):
    """Every remat knob — including the MoSA checkpoint-around-the-gather
    policy — changes memory, never math."""
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa")
    cfg_r = dataclasses.replace(cfg, remat=remat)
    batch = _batch(cfg)
    params = TransformerLM(cfg).init(jax.random.PRNGKey(0))

    def val_grad(c):
        m = TransformerLM(c)
        return jax.value_and_grad(m.loss, has_aux=True)(params, batch)

    (l0, _), g0 = val_grad(cfg)
    (l1, _), g1 = val_grad(cfg_r)
    np.testing.assert_allclose(float(l1), float(l0), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-5,
                                   rtol=1e-5)


# ----------------------------------------------- preemption resume parity
def test_sigterm_resume_replays_loss_curve_bit_exact(tmp_path):
    """The satellite acceptance test: train N steps uninterrupted; train the
    same config, deliver a REAL SIGTERM mid-run (the PreemptionHandler path,
    not a poked flag), restart from the checkpoint, and the concatenated
    loss curve matches the uninterrupted one bit-for-bit."""
    N = 10
    tr_a = Trainer(_cfg(tmp_path / "solid", steps=N, log_every=1))
    _, _, hist_a = tr_a.run(install_signals=False)
    losses_a = [h["loss"] for h in hist_a]
    assert len(losses_a) == N

    tr_b = Trainer(_cfg(tmp_path / "killed", steps=N, log_every=1))
    orig = tr_b.train_step
    calls = {"n": 0}

    def wrapped(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 4:
            os.kill(os.getpid(), signal.SIGTERM)
        return orig(*a, **kw)

    tr_b.train_step = wrapped
    _, _, hist_b = tr_b.run()           # handler installed; catches SIGTERM
    assert ckpt.latest_step(str(tmp_path / "killed")) == 4
    assert [h["step"] for h in hist_b] == [0, 1, 2, 3]

    tr_c = Trainer(_cfg(tmp_path / "killed", steps=N, log_every=1))
    _, _, hist_c = tr_c.run(install_signals=False)
    assert [h["step"] for h in hist_c] == list(range(4, N))

    losses_bc = [h["loss"] for h in hist_b] + [h["loss"] for h in hist_c]
    assert losses_bc == losses_a        # bit-exact, not allclose


# ---------------------------------------------------------- router health
def test_router_health_metrics_in_history():
    tr = Trainer(_cfg(steps=2, log_every=1))
    _, _, hist = tr.run(install_signals=False)
    for h in hist:
        assert 0.0 <= h["drop_rate"] <= 1.0
        assert 0.0 <= h["head_util"] <= 1.0
        assert 0.0 <= h["sel_entropy"] <= 1.0 + 1e-6
    # smoke hybrid has 17+ heads x k over T=64: every token should be picked
    assert hist[0]["drop_rate"] < 0.5


def test_router_health_empty_for_dense_models():
    tr = Trainer(_cfg(steps=1, arch_kwargs={"variant": "dense"}))
    _, _, hist = tr.run(install_signals=False)
    assert "sel_entropy" not in hist[0]


def test_transformer_router_health_scanned_layers():
    """The scan-fused backbone accumulates per-layer stats through the
    carry: a uniform (periodic) MoSA stack reports the same KEYS as the
    unrolled walk and finite values."""
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa")
    assert TransformerLM(cfg)._layout()[2] >= 2      # scanned units
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 2, cfg.vocab)
    stats = model.router_health(params, tokens)
    assert set(stats) == {"sel_entropy", "drop_rate", "head_util"}
    for v in stats.values():
        assert np.isfinite(float(v))


# ---------------------------------------------------------------- isoflop
def test_isoflop_smoke_sweep_end_to_end(tmp_path):
    """Acceptance: dense vs MoSA at ONE matched budget runs end-to-end
    through the resumable loop; budgets audit within the solver's one-head
    rounding; a rerun with more steps RESUMES from the checkpoints instead
    of restarting."""
    from repro.train.isoflop import (budget_match_error, isoflop_sweep,
                                     run_isoflop)

    points = isoflop_sweep(preset="smoke", T=64, sparsities=(8,))
    assert [p.variant for p in points] == ["dense", "mosa"]
    assert budget_match_error(points) < 0.05
    kw = {"lr": 1e-3, "warmup": 2, "log_every": 1, "ckpt_every": 100}

    res = run_isoflop(points, steps=4, seq_len=64, global_batch=2,
                      ckpt_root=str(tmp_path), train_kw=kw)
    assert set(res) == {p.name for p in points}
    for name, r in res.items():
        assert len(r["loss_curve"]) == 4
        assert np.isfinite(r["final"]["loss"])
        assert r["flops_total"] == r["flops_train_per_token"] * r["tokens"]

    res2 = run_isoflop(points, steps=6, seq_len=64, global_batch=2,
                       ckpt_root=str(tmp_path), train_kw=kw)
    for name, r in res2.items():
        # resumed at the step-4 boundary, trained only the remainder
        assert [h["step"] for h in r["loss_curve"]] == [4, 5]


def test_analytic_flops_match_paper_table():
    """The sweep's budget audit rests on flops.py, which reproduces the
    paper's Table 4 — pin the bridge: analytic_flops_per_token(dense tiny)
    equals the published budget / T."""
    from repro.core.flops import PAPER_MODELS, TABLE4_GFLOPS
    from repro.train.isoflop import analytic_flops_per_token

    cfg = get_config("mosa-paper", preset="full", size="tiny",
                     variant="dense")
    per_tok = analytic_flops_per_token(cfg, 1024)
    want = TABLE4_GFLOPS["tiny"] * 1e9 / 1024
    assert abs(per_tok - want) / want < 1e-3
    assert per_tok == PAPER_MODELS["tiny"].dense_flops(1024) // 1024
