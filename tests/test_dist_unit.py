"""Direct unit tests for repro.dist edge cases the seed suite doesn't cover:
elastic_plan under ragged/underscale device counts, StragglerMonitor warmup
and baseline hygiene, resolve_spec on empty/scalar shapes, hints role
resolution, and pipeline input validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.dist.fault_tolerance import (Heartbeat, PreemptionHandler,
                                        StragglerMonitor, elastic_plan)
from repro.dist.hints import constrain, resolve, sharding_hints
from repro.dist.pipeline import pipeline_forward, stack_stage_params
from repro.nn.module import LogicalSpec, logical, resolve_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


# ------------------------------------------------------------- elastic_plan
def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


@pytest.mark.parametrize("n,tp", [(248, 16), (24, 16), (7, 16), (1, 16),
                                  (512, 16), (17, 4), (256, 1)])
def test_elastic_plan_accounts_for_every_device(n, tp):
    plan = elastic_plan(n, tp=tp)
    assert _prod(plan["shape"]) + plan["devices_idle"] == n
    assert plan["devices_idle"] >= 0
    assert len(plan["shape"]) == len(plan["axes"])


def test_elastic_plan_non_divisible_host_counts():
    # lose 1 host (8 chips) of 31 in a tp=16 pod slice: data shrinks, tp holds
    p = elastic_plan(248, tp=16)
    assert p["shape"] == (15, 16)
    assert p["devices_idle"] == 8
    # 24 devices can't fill even two tp=16 rows: one row, 8 idle
    p = elastic_plan(24, tp=16)
    assert p["shape"] == (1, 16)
    assert p["devices_idle"] == 8


def test_elastic_plan_tp_larger_than_device_count():
    # tp > surviving devices: tp shrinks to what exists, nothing idles
    p = elastic_plan(4, tp=16)
    assert p["shape"] == (1, 4)
    assert p["tp"] == 4
    assert p["devices_idle"] == 0
    p = elastic_plan(7, tp=16)
    assert p["shape"] == (1, 7)


def test_elastic_plan_pods_only_when_divisible():
    assert elastic_plan(512, tp=16, want_pods=True)["axes"] == \
        ("pod", "data", "model")
    # data = 17 doesn't split into pods of 16: stays 2-axis
    p = elastic_plan(17 * 16, tp=16, want_pods=True)
    assert p["shape"] == (17, 16)
    assert p["axes"] == ("data", "model")


def test_elastic_plan_rejects_zero_devices():
    with pytest.raises(ValueError):
        elastic_plan(0)


# -------------------------------------------------------- straggler monitor
def test_straggler_monitor_never_flags_during_warmup():
    mon = StragglerMonitor(z_threshold=3.0, warmup_steps=5)
    # wild variation inside warmup must not flag (baseline not trusted yet)
    for i, dt in enumerate([0.1, 5.0, 0.1, 9.0, 0.1]):
        assert not mon.record(i, dt)


def test_straggler_monitor_constant_baseline_flags_outlier():
    # identical step times -> variance 0; the std floor must keep z finite
    # for normal steps yet still flag a 15x stall
    mon = StragglerMonitor(z_threshold=3.0, warmup_steps=3)
    for i in range(10):
        assert not mon.record(i, 0.1)
    assert mon.record(10, 1.5)
    assert not mon.record(11, 0.1)     # back to normal


def test_straggler_monitor_excludes_events_from_baseline():
    mon = StragglerMonitor(z_threshold=3.0, warmup_steps=3)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 2.0)
    # the stall must not have raised the baseline: the next stall still flags
    assert mon.record(11, 2.0)
    s = mon.summary()
    assert s["straggler_events"] == 2
    assert s["healthy_steps"] == 10
    assert abs(s["mean_step_s"] - 0.1) < 1e-9


# -------------------------------------------------------------- resolve_spec
MESH = FakeMesh({"data": 4, "model": 8})


def test_resolve_spec_scalar_shape():
    assert resolve_spec((), LogicalSpec(()), {"mlp": "model"}, MESH) == P()


def test_resolve_spec_none_spec():
    assert resolve_spec((8, 8), None, {"mlp": "model"}, MESH) == P()


def test_resolve_spec_empty_rules():
    assert resolve_spec((8, 8), logical("embed", "mlp"), {}, MESH) == P()


def test_resolve_spec_zero_sized_dim_replicates():
    # a 0-length dim is never divisible-shardable; must not raise
    assert resolve_spec((0, 8), logical("mlp", None), {"mlp": "model"},
                        MESH) == P()


# --------------------------------------------------------------------- hints
def test_hints_resolve_outside_context_is_none():
    assert resolve((4, 8), ("dp", "tp")) is None


def test_hints_resolve_trims_dp_axes():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 4})
    with sharding_hints(mesh=mesh):
        # batch 8: (pod, data) product 32 doesn't divide -> pod alone does
        assert resolve((8, 64), ("dp", "tp")) == P("pod", "model")
        # batch 1: nothing divides; model divides 64
        assert resolve((1, 64), ("dp", "tp")) == P(None, "model")
        # nothing resolves at all -> None (constrain becomes identity)
        assert resolve((1, 3), ("dp", "tp")) is None


def test_hints_no_mesh_axis_reuse_across_dims():
    mesh = FakeMesh({"data": 2, "model": 4})
    with sharding_hints(mesh=mesh):
        # both dims ask for tp; the second must not reuse "model"
        assert resolve((8, 8), ("tp", "tp")) == P("model")


def test_hints_literal_axis_role_passthrough():
    mesh = FakeMesh({"data": 2, "model": 4})
    with sharding_hints(mesh=mesh):
        assert resolve((8, 8), (None, "model")) == P(None, "model")
        # unknown axis name -> replicated, not an error
        assert resolve((8, 8), ("pipe", None)) is None


def test_hints_constrain_roundtrip_values():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(12.0).reshape(3, 4)
    with sharding_hints(mesh=mesh):
        y = constrain(x, ("dp", "tp"))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ----------------------------------------------------------------- sharding
def test_fit_axes_prefers_outer_axes():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert shd.fit_axes(256, ("pod", "data"), mesh) == ("pod", "data")
    assert shd.fit_axes(16, ("pod", "data"), mesh) == ("pod",)
    assert shd.fit_axes(1, ("pod", "data"), mesh) == ()
    # axes absent from the mesh are ignored
    assert shd.fit_axes(8, ("pipe", "data"), FakeMesh({"data": 4})) == \
        ("data",)


def test_unknown_rule_set_raises():
    with pytest.raises(KeyError):
        shd.dp_axes(FakeMesh({"data": 2}), "nope")
    with pytest.raises(KeyError):
        shd.mesh_rules(FakeMesh({"data": 2}), "nope")


def test_mesh_rules_drops_absent_axes():
    rules = shd.mesh_rules(FakeMesh({"data": 2, "model": 4}), "fsdp_tp")
    assert rules["embed"] == ("data",)          # pod absent -> filtered
    assert rules["mlp"] == ("model",)
    assert rules["expert_mlp"] is None


# -------------------------------------------------------- heartbeat / signal
def test_heartbeat_multiple_ranks(tmp_path):
    for r in (0, 2, 5):
        Heartbeat(str(tmp_path), rank=r).beat(1)
    assert Heartbeat.stale_ranks(str(tmp_path), timeout_s=3600) == []
    assert Heartbeat.stale_ranks(str(tmp_path), timeout_s=0) == [0, 2, 5]
    # a directory with no heartbeats has no stale ranks
    assert Heartbeat.stale_ranks(str(tmp_path / "empty"), timeout_s=0) == []


def test_preemption_handler_restore_is_idempotent():
    import signal as signal_lib
    before = signal_lib.getsignal(signal_lib.SIGTERM)
    h = PreemptionHandler()
    assert not h.requested
    h.restore()
    h.restore()
    assert signal_lib.getsignal(signal_lib.SIGTERM) is before


# ------------------------------------------------------------------ pipeline
def test_stack_stage_params_shapes():
    stacked = stack_stage_params([{"w": jnp.ones((3, 3)) * i}
                                  for i in range(4)])
    assert stacked["w"].shape == (4, 3, 3)
    np.testing.assert_array_equal(np.asarray(stacked["w"][2]),
                                  np.full((3, 3), 2.0))


def test_pipeline_forward_validates_inputs():
    mesh = jax.make_mesh((1,), ("pipe",))
    params = stack_stage_params([{"w": jnp.eye(4)}])
    x = jnp.ones((6, 4))

    def stage(p, a):
        return a @ p["w"]

    with pytest.raises(ValueError):
        pipeline_forward(stage, params, x, mesh=mesh, n_microbatches=4)
    with pytest.raises(ValueError):
        pipeline_forward(stage, params, x, mesh=mesh, n_microbatches=2,
                         axis="pod")
    bad = stack_stage_params([{"w": jnp.eye(4)}, {"w": jnp.eye(4)}])
    with pytest.raises(ValueError):
        pipeline_forward(stage, bad, x, mesh=mesh, n_microbatches=2)


def test_pipeline_forward_single_stage_matches_direct():
    mesh = jax.make_mesh((1,), ("pipe",))
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 8)) * 0.3
    params = stack_stage_params([{"w": w}])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))

    def stage(p, a):
        return jnp.tanh(a @ p["w"]) + a

    y = pipeline_forward(stage, params, x, mesh=mesh, n_microbatches=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(stage({"w": w}, x)),
                               atol=1e-6)
