"""Full-model serving parity: prefill + decode == training forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.nn.transformer import TransformerLM

# smoke archs that exercise every cache type:
#   qwen2 (dense GQA), gemma3 (window+dense mix), deepseek (MLA),
#   jamba (mamba+attn+moe), xlstm (recurrent only)
PARITY_ARCHS = ["qwen2-1.5b", "gemma3-4b", "deepseek-v2-lite-16b",
                "jamba-v0.1-52b", "xlstm-125m"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_config(arch, preset="smoke")
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, P, G = 1, 12, 4
    T = P + G
    toks = jax.random.randint(key, (B, T), 2, cfg.vocab)

    logits_full, _ = model(params, toks)
    logits_full = np.asarray(logits_full, np.float32)

    caches = model.init_cache(B, T, jnp.float32)
    lp, caches = model.prefill(params, toks[:, :P], caches)
    # prefill returns last-position logits
    np.testing.assert_allclose(np.asarray(lp[:, -1], np.float32),
                               logits_full[:, P - 1], atol=2e-3, rtol=2e-3)
    for t in range(P, T):
        ld, caches = model.decode_step(params, toks[:, t:t + 1], caches)
        np.testing.assert_allclose(np.asarray(ld[:, 0], np.float32),
                                   logits_full[:, t], atol=2e-3, rtol=2e-3)


def test_mosa_model_decode_runs_and_shrinks_cache():
    """MoSA serving: cache is k entries/head; decode produces finite logits.
    (Exact parity does not hold by design — training-time selection is
    non-autoregressive; decode uses the streaming approximation.)"""
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa")
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, P, G, T = 1, 16, 4, 20
    toks = jax.random.randint(key, (B, T), 2, cfg.vocab)
    caches = model.init_cache(B, T, jnp.float32)
    lp, caches = model.prefill(params, toks[:, :P], caches)
    assert np.isfinite(np.asarray(lp)).all()
    for t in range(P, T):
        ld, caches = model.decode_step(params, toks[:, t:t + 1], caches)
        assert np.isfinite(np.asarray(ld)).all()
    # cache size: MoSA heads hold k << T entries
    mosa_cache = jax.tree.leaves(
        [c["sparse"].k for c in _iter_mosa_caches(caches)])
    assert all(x.shape[-2] <= cfg.mosa.n_mosa_heads * T for x in mosa_cache)


def _iter_mosa_caches(caches):
    out = []
    for part in ("scan", "tail"):
        for v in caches.get(part, {}).values():
            if isinstance(v, dict) and "sparse" in v:
                out.append(v)
    return out


def test_server_generate_deterministic():
    from repro.launch.serve import Server
    cfg = get_config("qwen2-1.5b", preset="smoke")
    server = Server(cfg, batch=2, max_len=32)
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (2, 8), 2, cfg.vocab)
    t1, _ = server.generate(prompts, 6)
    t2, _ = server.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 6)


def test_request_pool_drains_queue():
    from repro.launch.serve import RequestPool, Server
    cfg = get_config("qwen2-1.5b", preset="smoke")
    server = Server(cfg, batch=2, max_len=32)
    pool = RequestPool(server)
    key = jax.random.PRNGKey(2)
    for i in range(3):
        pool.submit(jax.random.randint(jax.random.fold_in(key, i), (6,), 2,
                                       cfg.vocab), max_new=4)
    results = pool.run()
    assert set(results) == {0, 1, 2}
    assert all(len(v) == 4 for v in results.values())
