"""Hypothesis property tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# real hypothesis when installed, else the vendored fallback — these
# property tests ALWAYS run (a missing harness fails collection, loudly)
from _property_harness import given, settings, st  # noqa: E402

from repro.configs.base import MoSAConfig
from repro.core.flops import PaperModel, flops_dense_head, flops_mosa_head
from repro.core.mosa import MoSAAttention
from repro.core.router import select_topk, streaming_topk_update
from repro.data.pipeline import PackedLMDataset, SyntheticCorpus
from repro.kernels import ops, ref
from repro.optim.grad_compression import int8_compress, topk_compress

SETTINGS = dict(max_examples=20, deadline=None)


@given(T=st.integers(4, 64), k_frac=st.floats(0.1, 1.0),
       seed=st.integers(0, 2**16), force=st.booleans())
@settings(**SETTINGS)
def test_select_topk_invariants(T, k_frac, seed, force):
    k = max(2, int(T * k_frac))
    scores = jax.random.uniform(jax.random.PRNGKey(seed), (2, 3, T))
    r, idx = select_topk(scores, k, force_first=force)
    idx_np = np.asarray(idx)
    # sorted ascending, unique, in range
    assert (np.diff(idx_np, axis=-1) > 0).all()
    assert idx_np.min() >= 0 and idx_np.max() < T
    if force:
        assert (idx_np[..., 0] == 0).all()
    # r values are the true scores at idx
    want = np.take_along_axis(np.asarray(scores), idx_np, axis=-1)
    np.testing.assert_allclose(np.asarray(r), want)
    # expert choice = perfect load balance: exactly k per head, every head
    assert idx_np.shape[-1] == k


@given(seed=st.integers(0, 2**16), B=st.integers(1, 3), H=st.integers(1, 4),
       S=st.integers(2, 32), d=st.integers(4, 32))
@settings(**SETTINGS)
def test_mosa_kernel_property_matches_oracle(seed, B, H, S, d):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    T = 4 * S
    q = jax.random.normal(ks[0], (B, H, S, d))
    k = jax.random.normal(ks[1], (B, H, S, d))
    v = jax.random.normal(ks[2], (B, H, S, d))
    idx = jnp.sort(jnp.stack([
        jnp.stack([jax.random.permutation(
            jax.random.fold_in(ks[3], b * H + h_), T)[:S]
            for h_ in range(H)]) for b in range(B)]), -1).astype(jnp.int32)
    r = jax.nn.sigmoid(jax.random.normal(ks[4], (B, H, S)))
    np.testing.assert_allclose(
        np.asarray(ops.mosa_attention(q, k, v, idx, r)),
        np.asarray(ref.mosa_attention_ref(q, k, v, idx, r)),
        atol=3e-5, rtol=3e-5)


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_streaming_topk_matches_exact_topk(seed):
    """Streaming evict-min over causal scores == exact top-k of the prefix."""
    rng = np.random.default_rng(seed)
    T, k = 24, 5
    scores_seq = rng.random(T).astype(np.float32)
    cs = jnp.full((1, k), -jnp.inf)
    ci = jnp.full((1, k), -1, jnp.int32)
    for t in range(T):
        _, _, cs, ci = streaming_topk_update(
            cs, ci, jnp.asarray([scores_seq[t]]), t, jnp.asarray(False))
    got = set(np.asarray(ci[0]).tolist())
    want = set(np.argsort(scores_seq)[-k:].tolist())
    assert got == want


@given(T=st.sampled_from([256, 512, 1024, 2048]),
       rho=st.sampled_from([2, 4, 8, 16, 32]),
       h=st.sampled_from([256, 512, 1024]))
@settings(**SETTINGS)
def test_mosa_head_always_cheaper_than_dense(T, rho, h):
    hp = 64
    k = T // rho
    assert flops_mosa_head(T, k, h, hp) < flops_dense_head(T, h, hp)


@given(n_heads=st.integers(5, 24), layers=st.integers(2, 12),
       h=st.sampled_from([256, 512, 1024]), rho=st.sampled_from([2, 8, 32]))
@settings(**SETTINGS)
def test_isoflop_solver_tight(n_heads, layers, h, rho):
    pm = PaperModel("x", layers, h, 4 * h, 64, n_heads)
    n = pm.hybrid_mosa_heads(rho)
    budget = n_heads * flops_dense_head(1024, h, 64)
    spend = 4 * flops_dense_head(1024, h, 64) + \
        n * flops_mosa_head(1024, 1024 // rho, h, 64)
    assert spend <= budget
    assert spend + flops_mosa_head(1024, 1024 // rho, h, 64) > budget


@given(seed=st.integers(0, 2**16), frac=st.floats(0.05, 1.0))
@settings(**SETTINGS)
def test_compression_identity(seed, frac):
    g = jax.random.normal(jax.random.PRNGKey(seed), (257,))
    kept, res = topk_compress(g, frac)
    np.testing.assert_allclose(np.asarray(kept + res), np.asarray(g),
                               atol=1e-6)
    deq, res2 = int8_compress(g)
    np.testing.assert_allclose(np.asarray(deq + res2), np.asarray(g),
                               atol=1e-6)


@given(step=st.integers(0, 10_000), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_data_pipeline_pure_function_of_step(step, seed):
    ds = PackedLMDataset(SyntheticCorpus(vocab=512, seed=seed), 32, 2)
    a = ds.batch_at(step)
    b = ds.batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 1


@given(seed=st.integers(0, 2**16), sparsity=st.sampled_from([2, 4, 8]))
@settings(max_examples=10, deadline=None)
def test_mosa_layer_output_finite_and_sparse(seed, sparsity):
    key = jax.random.PRNGKey(seed)
    B, T, h = 1, 32, 16
    cfg = MoSAConfig(n_mosa_heads=3, sparsity=sparsity, n_dense_heads=0,
                     d_head=8)
    m = MoSAAttention(h, cfg)
    p = m.init(key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, T, h))
    y = np.asarray(m(p, x))
    assert np.isfinite(y).all()
    # at most H*k rows can be nonzero
    nonzero_rows = (np.abs(y[0]).max(-1) > 0).sum()
    assert nonzero_rows <= cfg.n_mosa_heads * m.k_for(T)
