"""Distributed-correctness tests.

These run in SUBPROCESSES with ``--xla_force_host_platform_device_count=8``
so the main pytest process keeps a single device (smoke tests must see one
device; the 512-way override belongs to the dry-run only).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(script: str, n_devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # Pin the subprocess to the CPU platform: these are CPU-emulation tests,
    # and with libtpu installed an unset JAX_PLATFORMS makes backend init
    # probe for (absent) TPU hardware — which can hang past the timeout.
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """pjit train step on a 2x4 mesh == single-device step (same seed)."""
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.dist import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.nn.module import init_shapes
        from repro.nn.transformer import TransformerLM
        from repro.optim.optimizer import adamw, apply_updates

        cfg = get_config("qwen2-1.5b", preset="smoke")
        model = TransformerLM(cfg)
        key = jax.random.PRNGKey(0)
        toks = jax.random.randint(key, (8, 33), 2, cfg.vocab)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        opt = adamw(1e-3, clip_norm=1.0)

        def step(params, opt_state, batch):
            (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
            upd, opt_state, _ = opt.update(g, opt_state, params,
                                           jnp.zeros((), jnp.int32))
            return apply_updates(params, upd), l

        # single device reference
        params = model.init(key)
        ref_params, ref_loss = step(params, opt.init(params), batch)

        # 2x4 mesh, tp rules
        mesh = make_mesh((2, 4), ("data", "model"))
        shapes = init_shapes(model)
        psh = shd.param_shardings(model, mesh, "fsdp_tp", shapes)
        bsh = shd.batch_sharding(mesh, "fsdp_tp", batch=8)
        with mesh:
            params_s = jax.jit(model.init, out_shardings=psh)(key)
            os_ = jax.jit(opt.init)(params_s)
            batch_s = jax.device_put(batch, {"tokens": bsh, "labels": bsh})
            new_params, loss = jax.jit(step)(params_s, os_, batch_s)
        print("LOSS_DIFF", abs(float(loss) - float(ref_loss)))
        d = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(ref_params), jax.tree.leaves(new_params)))
        print("PARAM_DIFF", d)
    """)
    loss_diff = float(out.split("LOSS_DIFF")[1].split()[0])
    param_diff = float(out.split("PARAM_DIFF")[1].split()[0])
    assert loss_diff < 1e-4
    assert param_diff < 1e-4


def test_mosa_head_parallel_matches_replicated():
    """MoSA heads sharded over the model axis == replicated computation."""
    out = run_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import MoSAConfig
        from repro.core.mosa import MoSAAttention
        from repro.launch.mesh import make_mesh

        cfg = MoSAConfig(n_mosa_heads=8, sparsity=4, n_dense_heads=0, d_head=16)
        m = MoSAAttention(64, cfg)
        key = jax.random.PRNGKey(0)
        p = m.init(key)
        x = jax.random.normal(key, (4, 64, 64))
        y_ref = m(p, x)

        mesh = make_mesh((2, 4), ("data", "model"))
        heads = NamedSharding(mesh, P("model"))
        psh = {"router": {"w": heads},
               "wq": heads, "wk": heads, "wv": heads, "wo": heads}
        bsh = NamedSharding(mesh, P("data"))
        with mesh:
            y = jax.jit(m.__call__, in_shardings=(psh, bsh))(p, x)
        print("DIFF", float(jnp.abs(y - y_ref).max()))
    """)
    assert float(out.split("DIFF")[1].split()[0]) < 1e-4


def test_pipeline_parallel_matches_sequential():
    out = run_devices("""
        import jax, jax.numpy as jnp
        from repro.dist.pipeline import pipeline_forward, stack_stage_params
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4,), ("pipe",))
        key = jax.random.PRNGKey(0)
        ws = [jax.random.normal(jax.random.fold_in(key, i), (16, 16)) * 0.3
              for i in range(4)]
        stage_params = stack_stage_params([{"w": w} for w in ws])

        def stage(p, x):
            return jnp.tanh(x @ p["w"]) + x

        x = jax.random.normal(key, (8, 16))
        y_seq = x
        for w in ws:
            y_seq = stage({"w": w}, y_seq)
        y_pipe = pipeline_forward(stage, stage_params, x, mesh=mesh,
                                  n_microbatches=4)
        print("DIFF", float(jnp.abs(y_pipe - y_seq).max()))

        # gradients flow through the pipeline
        def loss(sp):
            return jnp.sum(pipeline_forward(stage, sp, x, mesh=mesh,
                                            n_microbatches=4) ** 2)
        g = jax.grad(loss)(stage_params)
        print("GNORM", float(jnp.linalg.norm(g["w"])))
    """)
    assert float(out.split("DIFF")[1].split()[0]) < 1e-5
    assert float(out.split("GNORM")[1].split()[0]) > 0


def test_compressed_psum_cross_pod():
    """top-k compressed all-reduce over a pod axis, with error feedback."""
    out = run_devices("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.grad_compression import compressed_psum

        mesh = make_mesh((2, 4), ("pod", "data"))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 256))

        @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                 check_rep=False)
        def reduce_exact(g):
            out, _ = compressed_psum({"g": g}, "pod", "none")
            return out["g"]

        @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                 check_rep=False)
        def reduce_topk(g):
            out, res = compressed_psum({"g": g}, "pod", "topk", topk_frac=0.5)
            return out["g"] + jax.lax.psum(res["g"], "pod")  # add back residual

        exact = reduce_exact(g)
        approx = reduce_topk(g)
        print("DIFF", float(jnp.abs(exact - approx).max()))
    """)
    # compressed + residual == exact (error feedback is lossless in sum)
    assert float(out.split("DIFF")[1].split()[0]) < 1e-5


def test_dryrun_single_cell_subprocess():
    """The dry-run entry point works end to end for one light cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"      # see run_devices: avoid TPU probing
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-1.5b",
         "--shape", "decode_32k", "--out-dir", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "all cells compiled OK" in out.stdout
    with open("/tmp/dryrun_test/16x16/qwen2-1.5b__decode_32k.json") as f:
        rec = json.load(f)
    assert rec["analytic"]["flops_global"] > 0
    assert rec["memory"]["total_per_device"] > 0


def test_moe_ep_shard_map_matches_vmap_path():
    """Expert-parallel shard_map MoE == per-row vmap dispatch (it.11)."""
    out = run_devices("""
        import jax, jax.numpy as jnp
        from repro.configs.base import MoEConfig
        from repro.nn.ffn import MoEFFN
        from repro.launch.mesh import make_mesh
        from repro.dist import hints

        cfg = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=4.0)
        m = MoEFFN(64, cfg)
        key = jax.random.PRNGKey(0)
        p = m.init(key)
        x = jax.random.normal(key, (4, 32, 64))
        y_ref, aux_ref = m(p, x)                    # vmap path (no hints)
        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh, hints.sharding_hints(mesh=mesh):
            y_ep, aux_ep = jax.jit(m.__call__)(p, x)  # EP path
            g = jax.jit(jax.grad(lambda p_: m(p_, x)[0].sum()))(p)
        print("DIFF", float(jnp.abs(y_ref - y_ep).max()))
        print("AUXDIFF", abs(float(aux_ref) - float(aux_ep)))
        print("GNORM", float(jnp.linalg.norm(g["w_gate"])))
    """)
    assert float(out.split("DIFF")[1].split()[0]) < 1e-4
    assert float(out.split("AUXDIFF")[1].split()[0]) < 1e-5
    assert float(out.split("GNORM")[1].split()[0]) > 0
