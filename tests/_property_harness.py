"""Property-test harness: real ``hypothesis`` when present, else a vendored
minimal fallback — so ``tests/test_property.py`` and the allocator sweep in
``tests/test_paged_kv.py`` ALWAYS execute (ISSUE 6 satellite: the CI image
lacks hypothesis, and ``pytest.importorskip`` silently skipped them for four
PRs).

The fallback implements exactly the API surface those suites use —
``given`` (positional + keyword strategies), ``settings(max_examples,
deadline)``, and ``st.integers/floats/booleans/sampled_from/lists/tuples``
— with a deterministic per-test PRNG (seeded from the test name), so a
falsifying example reproduces on re-run.  No shrinking, no database: this
is a fallback, not a hypothesis reimplementation.  If neither import path
works, the ImportError propagates and collection fails — a loud ``make
ci`` failure, never a silent skip.
"""

try:
    from hypothesis import given, settings, strategies as st
    USING_FALLBACK = False
except ImportError:
    import functools
    import inspect
    import random as _random
    import zlib

    USING_FALLBACK = True

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.draw(rng)
                for _ in range(rng.randint(min_size, max_size))])

        @staticmethod
        def tuples(*elems):
            return _Strategy(
                lambda rng: tuple(e.draw(rng) for e in elems))

    st = _St()

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return deco

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            names = list(inspect.signature(fn).parameters)
            by_name = dict(zip(names, pos_strategies))
            overlap = set(by_name) & set(kw_strategies)
            assert not overlap, f"strategy given twice: {overlap}"
            by_name.update(kw_strategies)

            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(run, "_max_examples",
                            getattr(fn, "_max_examples", 50))
                rng = _random.Random(
                    zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    drawn = {k: s.draw(rng) for k, s in by_name.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception:
                        print(f"Falsifying example ({fn.__name__}, "
                              f"try {i}): {drawn}")
                        raise

            # wraps() copies __wrapped__, which would make pytest resolve
            # the ORIGINAL signature and demand fixtures for the strategy
            # params — the wrapper's own (*args, **kwargs) is the truth
            del run.__wrapped__
            # mimic hypothesis's attribute shape: pytest plugins (anyio)
            # introspect ``obj.hypothesis.inner_test``
            run.hypothesis = type("Hypothesis", (),
                                  {"inner_test": staticmethod(fn)})()
            return run
        return deco
