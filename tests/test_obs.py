"""Observability suite (ISSUE 8, DESIGN §11): registry/histogram units,
exporter formats, tracer + Chrome-trace validity, the scheduler
counter-consistency property (admitted == finished + preempted after a
drain), device-metrics parity under jit + donated buffers, the obs-off
zero-write guarantee, and the Scheduler/Trainer artifact dump paths."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.base import BlockSpec, get_config
from repro.launch.serve import Scheduler, Server
from repro.obs.export import prometheus_text
from repro.obs.metrics import (DEFAULT_BOUNDS, UNIT_BOUNDS, Histogram,
                               Registry, publish)
from repro.obs.tracing import Tracer
from repro.serve.paged_kv import PagedConfig
from tests._property_harness import given, settings, st


@pytest.fixture(autouse=True)
def fresh_obs():
    """Every test starts (and leaves) the process-global registry/tracer
    enabled and empty — the obs state is deliberately global, so tests
    must not leak series into each other."""
    obs.set_enabled(True)
    obs.registry().reset()
    obs.tracer().reset()
    yield
    obs.set_enabled(True)
    obs.registry().reset()
    obs.tracer().reset()


# ---------------------------------------------------------------- registry
def test_counter_gauge_semantics():
    reg = Registry()
    reg.inc("c")
    reg.inc("c", 2.5)
    reg.set("g", 7.0)
    reg.set("g", 3.0)                    # last value wins
    reg.set_max("hw", 3.0)
    reg.set_max("hw", 1.0)               # high-water keeps the max
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3.5
    assert snap["gauges"]["g"] == 3.0
    assert snap["gauges"]["hw"] == 3.0


def test_registry_type_collision_asserts():
    reg = Registry()
    reg.inc("x")
    with pytest.raises(AssertionError):
        reg.observe("x", 1.0)


def test_registry_disabled_zero_writes():
    """The ISSUE 8 guarantee: a disabled registry records NOTHING — the
    convenience calls fast-exit and the factories hand back a shared no-op
    never stored in the map."""
    reg = Registry(enabled=False)
    reg.inc("a")
    reg.set("b", 1.0)
    reg.observe("c", 0.5)
    h = reg.histogram("d")
    h.observe(1.0)
    assert h.summary() == {}
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "gauges_meta": {}, "histograms": {}}
    assert reg._metrics == {}
    assert publish({"x": 1.0}, "p.", reg=reg) == {}
    # labeled calls are just as write-free
    reg.inc("a", tenant="x")
    reg.observe("c", 0.5, tenant="x")
    with reg.timer("t", tenant="x") as t:
        pass
    assert t.dt >= 0.0                      # the clock still ran
    assert reg._metrics == {}


# --------------------------------------------------------------- histogram
def test_histogram_single_observation_is_exact():
    h = Histogram("t", bounds=UNIT_BOUNDS)
    h.observe(0.37)
    for q in (0.0, 0.5, 0.9, 0.99, 1.0):
        assert h.quantile(q) == pytest.approx(0.37)
    s = h.summary()
    assert s["count"] == 1 and s["min"] == s["max"] == 0.37


def test_histogram_uniform_quantiles():
    """Unit-width buckets, one sample per bucket: interpolated quantiles
    are exact at every bucket edge."""
    h = Histogram("t", bounds=tuple(float(i) for i in range(101)))
    for v in range(1, 101):
        h.observe(float(v))
    assert h.quantile(0.50) == pytest.approx(50.0)
    assert h.quantile(0.90) == pytest.approx(90.0)
    assert h.quantile(0.99) == pytest.approx(99.0)
    p = h.percentiles()
    assert set(p) == {"p50", "p90", "p99"}


def test_histogram_interpolates_within_bucket():
    """100 samples of 0.42 land in one UNIT bucket (0.40, 0.45]; min/max
    clamping must report 0.42 for every quantile, not the bucket edges."""
    h = Histogram("t", bounds=UNIT_BOUNDS)
    for _ in range(100):
        h.observe(0.42)
    assert h.quantile(0.5) == pytest.approx(0.42)
    assert h.quantile(0.99) == pytest.approx(0.42)


def test_histogram_overflow_and_bounds():
    h = Histogram("t", bounds=(1.0, 2.0))
    for v in (0.5, 1.5, 100.0):
        h.observe(v)
    assert h.counts == [1, 1, 1]          # under, mid, overflow
    assert h.quantile(1.0) == pytest.approx(100.0)
    assert DEFAULT_BOUNDS[0] == pytest.approx(1e-6)
    assert DEFAULT_BOUNDS[-1] >= 1e3 * 0.99
    assert all(a < b for a, b in zip(DEFAULT_BOUNDS, DEFAULT_BOUNDS[1:]))
    assert UNIT_BOUNDS[0] == 0.0 and UNIT_BOUNDS[-1] == 1.0
    with pytest.raises(AssertionError):
        Histogram("bad", bounds=(1.0, 1.0))


def test_publish_kinds():
    reg = Registry()
    publish({"a": 1.0}, "g.", reg=reg)
    publish({"a": 0.5}, "h.", reg=reg, kind="histogram")
    snap = reg.snapshot()
    assert snap["gauges"]["g.a"] == 1.0
    assert snap["histograms"]["h.a"]["count"] == 1


# --------------------------------------------------------------- exporters
def test_prometheus_text_format():
    reg = Registry()
    reg.inc("serve.admitted", 3)
    reg.set("pool.dense.free_blocks", 7)
    reg.observe("serve.ttft-s", 1.5, bounds=(1.0, 2.0))
    txt = prometheus_text(reg)
    assert "# TYPE serve_admitted counter\nserve_admitted 3" in txt
    assert "# TYPE pool_dense_free_blocks gauge" in txt
    # cumulative buckets + +Inf == count
    assert 'serve_ttft_s_bucket{le="1"} 0' in txt
    assert 'serve_ttft_s_bucket{le="2"} 1' in txt
    assert 'serve_ttft_s_bucket{le="+Inf"} 1' in txt
    assert "serve_ttft_s_count 1" in txt


def test_dump_json_and_prom(tmp_path):
    reg = obs.registry()
    reg.inc("a")
    reg.observe("b", 0.5)
    mpath, ppath = tmp_path / "m.json", tmp_path / "m.prom"
    obs.dump(metrics_path=str(mpath), prom_path=str(ppath))
    snap = json.loads(mpath.read_text())
    assert snap["counters"]["a"] == 1.0
    assert "# TYPE b histogram" in ppath.read_text()
    # .jsonl suffix appends lines instead of overwriting
    jl = tmp_path / "m.jsonl"
    obs.dump(metrics_path=str(jl), tag="t1")
    obs.dump(metrics_path=str(jl), tag="t2")
    lines = [json.loads(x) for x in jl.read_text().splitlines()]
    assert [x["tag"] for x in lines] == ["t1", "t2"]
    assert all("time" in x for x in lines)


# ------------------------------------------------------------------ tracer
def test_tracer_chrome_trace_valid(tmp_path):
    tr = Tracer()
    with tr.span("outer", track="a", n=1):
        pass
    t0 = tr.now()
    tr.add("phase", t0, t0 + 0.5, track="b")
    tr.instant("marker", track="a")
    doc = tr.chrome_trace()
    evs = doc["traceEvents"]
    meta = {e["args"]["name"]: e["tid"] for e in evs if e["ph"] == "M"}
    assert set(meta) == {"a", "b"}
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"outer", "phase", "marker"}
    phase = next(e for e in xs if e["name"] == "phase")
    assert phase["tid"] == meta["b"]
    assert phase["dur"] == pytest.approx(5e5, rel=1e-3)   # 0.5 s in µs
    path = tmp_path / "t.json"
    tr.export_chrome(str(path))
    assert json.loads(path.read_text())["traceEvents"]
    jl = tmp_path / "t.jsonl"
    tr.export_jsonl(str(jl))
    assert len(jl.read_text().splitlines()) == 3


def test_tracer_disabled_and_ring():
    tr = Tracer(capacity=4, enabled=False)
    with tr.span("x"):
        pass
    tr.add("y", 0.0, 1.0)
    tr.instant("z")
    assert len(tr) == 0
    tr.enabled = True
    for i in range(10):
        tr.instant(f"s{i}")
    assert len(tr) == 4                       # ring keeps the newest
    assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]


def test_set_enabled_toggles_both():
    obs.set_enabled(False)
    assert not obs.registry().enabled and not obs.tracer().enabled
    assert not obs.enabled()
    obs.set_enabled(True)
    assert obs.enabled()


# ------------------------------------------------- scheduler integration
def _hybrid_cfg():
    """3-layer dense + window + MoSA stack (the paged-serving acceptance
    config) — exercises pool gauges, prefix counters, AND serve-time
    router health in one scheduler run."""
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa",
                     sparsity=4)
    return dataclasses.replace(
        cfg, n_layers=3,
        attention=dataclasses.replace(cfg.attention, window=16),
        pattern=(BlockSpec("attn", "dense"), BlockSpec("attn_local", "dense"),
                 BlockSpec("mosa", "dense")))


def _dense_window_cfg():
    cfg = get_config("mosa-paper", preset="smoke", variant="dense")
    return dataclasses.replace(
        cfg, n_layers=2,
        attention=dataclasses.replace(cfg.attention, window=16),
        pattern=(BlockSpec("attn", "dense"),
                 BlockSpec("attn_local", "dense")))


_SMALL_SERVER = None


def small_server():
    """One dense+window server shared by the drain tests (cached — compile
    once); the paged pool is small enough that long request mixes preempt.
    A plain helper, not a fixture: the vendored property harness binds
    ``given`` strategies by parameter position, so property tests cannot
    take fixture arguments."""
    global _SMALL_SERVER
    if _SMALL_SERVER is None:
        cfg = _dense_window_cfg()
        _SMALL_SERVER = Server(cfg, batch=2, max_len=64,
                               paged=PagedConfig(block_size=8,
                                                 num_blocks=14,
                                                 num_window_blocks=4))
    return _SMALL_SERVER


def _run_mix(server, lens, max_new, prefix_cache=False, **kw):
    sched = Scheduler(server, chunk=4, prefix_cache=prefix_cache, **kw)
    rids = [sched.submit(
        jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(7), i),
                           (n,), 2, 64), max_new=max_new)
        for i, n in enumerate(lens)]
    out = sched.run()
    assert all(len(out[r]) == max_new for r in rids)
    return sched, rids


@settings(max_examples=5, deadline=None)
@given(lens=st.lists(st.integers(1, 24), min_size=1, max_size=5),
       max_new=st.integers(1, 6))
def test_scheduler_counter_consistency_property(lens, max_new):
    """Drain invariant (ISSUE 8): after every request completes,
    admitted == finished + preempted (each preemption costs one re-admit),
    submitted == finished, and the in-flight gauge reads zero — across
    random length mixes including pool-exhausting ones."""
    server = small_server()
    reg = obs.registry()
    reg.reset()
    sched, _ = _run_mix(server, lens, max_new)
    snap = reg.snapshot()
    c = snap["counters"]
    assert c["serve.admitted"] == \
        c["serve.finished"] + c.get("serve.preempted", 0)
    assert c["serve.submitted"] == len(lens)
    assert c["serve.finished"] == len(lens)
    assert snap["gauges"]["serve.in_flight"] == 0
    assert snap["gauges"]["serve.queue_depth"] == 0
    assert c["serve.generated_tokens"] == len(lens) * max_new
    assert sched.stats["preemptions"] == c.get("serve.preempted", 0)


def test_scheduler_obs_off_noop():
    """obs disabled: the scheduler still serves correctly (including the
    bounded ttft compat property) and the registry/tracer record nothing."""
    server = small_server()
    obs.set_enabled(False)
    sched, rids = _run_mix(server, [5, 9], 3)
    assert obs.registry().snapshot() == \
        {"counters": {}, "gauges": {}, "gauges_meta": {}, "histograms": {}}
    assert len(obs.tracer()) == 0
    assert all(r in sched.ttft for r in rids)     # ttft survives obs-off


def test_scheduler_artifacts_and_lifecycle(tmp_path):
    """End-to-end artifact dump on the MoSA hybrid: the Chrome trace holds
    queued -> prefill -> decode for every request, the metrics snapshot
    carries TTFT/TPOT histograms, pool gauges, prefix counters, and the
    serve-time router-health series (same registry as training)."""
    cfg = _hybrid_cfg()
    server = Server(cfg, batch=2, max_len=64,
                    paged=PagedConfig(block_size=8, num_blocks=24,
                                      num_window_blocks=4))
    mpath = tmp_path / "metrics.jsonl"
    tpath = tmp_path / "trace.json"
    sched = Scheduler(server, chunk=4, prefix_cache=True,
                      metrics_path=str(mpath), trace_path=str(tpath),
                      router_health_every=1)
    rids = [sched.submit(
        jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(8), i),
                           (n,), 2, cfg.vocab), max_new=4)
        for i, n in enumerate((5, 11, 7))]
    out = sched.run()
    assert all(len(out[r]) == 4 for r in rids)

    doc = json.loads(tpath.read_text())
    tid_name = {e["tid"]: e["args"]["name"] for e in doc["traceEvents"]
                if e.get("ph") == "M"}
    by_track = {}
    for e in doc["traceEvents"]:
        if e.get("ph") == "X":
            by_track.setdefault(tid_name[e["tid"]], set()).add(e["name"])
    for r in rids:
        assert {"queued", "prefill", "decode"} <= by_track[f"req{r}"], \
            f"req{r}: {by_track.get(f'req{r}')}"
    assert "prefill_chunk" in by_track["sched"]
    assert "decode_chunk" in by_track["sched"]

    snap = json.loads(mpath.read_text().splitlines()[-1])
    assert snap["tag"] == "scheduler"
    h = snap["histograms"]
    assert h["serve.ttft_s"]["count"] == len(rids)
    assert h["serve.tpot_s"]["count"] == len(rids)
    assert 0 < h["serve.chunk_packed_efficiency"]["max"] <= 1.0
    assert "serve.router.sel_entropy" in h        # MoSA health, serve side
    assert 0.0 <= h["serve.router.drop_rate"]["max"] <= 1.0
    g = snap["gauges"]
    # drained up to the prefix trie's retained blocks (one per node)
    assert g["pool.dense.live_blocks"] == g.get("prefix.nodes", 0)
    assert g["pool.dense.live_high_water"] > 0
    assert any(k.startswith("prefix.") for k in snap["counters"])
    assert g["serve.tokens_per_s"] > 0


# ------------------------------------------- device-metrics / train side
def _tiny_mosa_cfg():
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa",
                     sparsity=4)
    return dataclasses.replace(cfg, n_layers=2, pattern=(
        BlockSpec("attn", "dense"), BlockSpec("mosa", "dense")))


def test_health_in_step_parity_jit_donated():
    """Device-metrics pattern (DESIGN §11): router-health stats computed
    in-step (riding the jitted, donated train step's metrics) match the
    standalone ``router_health`` forward on the same params/batch."""
    from repro.nn.transformer import TransformerLM
    from repro.optim import schedules
    from repro.optim.optimizer import adamw
    from repro.train.step import make_train_step

    cfg = _tiny_mosa_cfg()
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 2, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    want = jax.jit(model.router_health)(params, tokens)

    opt = adamw(schedules.linear_warmup(1e-3, 10), clip_norm=1.0)
    opt_state = opt.init(params)
    fn = jax.jit(make_train_step(model, opt, health=True),
                 donate_argnums=(0, 1))
    _, _, _, metrics = fn(params, opt_state, jnp.zeros((), jnp.int32), batch)
    for k in ("sel_entropy", "drop_rate", "head_util"):
        np.testing.assert_allclose(float(metrics[k]), float(want[k]),
                                   rtol=1e-6, err_msg=k)
        assert 0.0 <= float(metrics[k]) <= 1.0


def test_health_in_step_microbatch_accumulates():
    """Health keys survive the scan-based microbatch accumulator (shapes
    come from eval_shape, values are means over microbatches)."""
    from repro.nn.transformer import TransformerLM
    from repro.optim import schedules
    from repro.optim.optimizer import adamw
    from repro.train.step import make_train_step

    cfg = _tiny_mosa_cfg()
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 2, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    fn = jax.jit(make_train_step(model, opt := adamw(
        schedules.linear_warmup(1e-3, 10), clip_norm=1.0),
        microbatches=2, health=True))
    _, _, _, m = fn(params, opt.init(params), jnp.zeros((), jnp.int32),
                    batch)
    for k in ("sel_entropy", "drop_rate", "head_util"):
        assert 0.0 <= float(m[k]) <= 1.0, k


def test_trainer_registry_and_dump(tmp_path):
    """Trainer routes step telemetry through the registry and dumps the
    configured artifacts on exit; health_in_step=False falls back to the
    standalone forward at log intervals (flag parity satellite)."""
    from repro.launch.train import TrainConfig, Trainer

    mpath = tmp_path / "train.json"
    tpath = tmp_path / "train.trace.json"
    cfg = TrainConfig(arch="mosa-paper", preset="smoke",
                      arch_kwargs={"variant": "mosa"}, seq_len=32,
                      global_batch=2, steps=3, lr=1e-3, warmup=2,
                      log_every=1, metrics_path=str(mpath),
                      trace_path=str(tpath))
    tr = Trainer(cfg)
    assert tr._health_in_step
    _, _, hist = tr.run(install_signals=False)
    snap = json.loads(mpath.read_text())
    assert snap["gauges"]["train.step"] == 2
    assert snap["histograms"]["train.step_time_s"]["count"] == 3
    assert snap["gauges"]["train.tokens_per_s"] > 0
    assert snap["histograms"]["train.router.sel_entropy"]["count"] == 3
    assert snap["gauges"]["train.loss"] > 0.0
    doc = json.loads(tpath.read_text())
    steps = [e for e in doc["traceEvents"]
             if e.get("ph") == "X" and e["name"] == "train_step"]
    assert len(steps) == 3
    # in-step health rode the metrics into the history at every log step
    assert all("sel_entropy" in h for h in hist)

    # fallback path: same telemetry via the standalone forward
    obs.registry().reset()
    cfg2 = dataclasses.replace(cfg, health_in_step=False, metrics_path=None,
                               trace_path=None)
    tr2 = Trainer(cfg2)
    assert not tr2._health_in_step
    _, _, hist2 = tr2.run(install_signals=False)
    assert all("sel_entropy" in h for h in hist2)
    snap2 = obs.registry().snapshot()
    assert snap2["histograms"]["train.router.sel_entropy"]["count"] >= 1
