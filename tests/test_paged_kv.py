"""Paged KV subsystem units: BlockPool invariants, paged-vs-contiguous
cache parity, the paged-attention kernel, and the prefix trie (PR 3).

Property-style allocator tests run twice: a deterministic stdlib-random
sweep that always runs, and a hypothesis version gated exactly like
``tests/test_property.py`` (the CI image may lack hypothesis).
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kv_cache import DenseKVCache, WindowKVCache
from repro.serve.paged_attention import (paged_attention_kernel,
                                         paged_attention_ref)
from repro.serve.paged_kv import (BlockPool, PagedDenseKVCache,
                                  PagedWindowKVCache, copy_blocks)
from repro.serve.prefix_cache import PrefixCache

# real hypothesis when installed, else the vendored fallback (see
# tests/_property_harness.py) — the sweep below always executes
from _property_harness import given, settings, st


# -------------------------------------------------------------- allocator
def test_block_pool_alloc_free_refcount():
    pool = BlockPool(8, 4)
    a = pool.alloc(3)
    b = pool.alloc(5)
    assert sorted(a + b) == list(range(8)) and pool.alloc(1) is None
    pool.incref(a)                       # shared (trie + row)
    pool.decref(a)
    assert pool.free_blocks == 0         # still referenced once
    pool.decref(a)
    assert pool.free_blocks == 3
    pool.decref(b)
    assert pool.free_blocks == 8
    with pytest.raises(AssertionError):  # double free caught
        pool.decref(b[:1])


def test_block_pool_ensure_owned_cow():
    pool = BlockPool(4, 4)
    (bid,) = pool.alloc(1)
    owned, copied = pool.ensure_owned(bid)
    assert owned == bid and not copied   # exclusive: no copy
    pool.incref([bid])                   # now shared
    owned, copied = pool.ensure_owned(bid)
    assert copied and owned != bid and pool.refcount(bid) == 1
    pool2 = BlockPool(1, 4)
    (only,) = pool2.alloc(1)
    pool2.incref([only])
    assert pool2.ensure_owned(only) is None   # exhausted -> caller preempts


def _run_alloc_trace(ops, num_blocks):
    """Replay an alloc/free/share trace; check the allocator invariants:
    no double-free, live+free partition the pool, exclusive live blocks
    never alias across owners."""
    pool = BlockPool(num_blocks, 4)
    owners = {}          # owner id -> list of block ids
    shared = []          # blocks holding an extra (trie-like) ref
    next_owner = 0
    for kind, arg in ops:
        if kind == "alloc":
            ids = pool.alloc(arg)
            if ids is not None:
                owners[next_owner] = ids
                next_owner += 1
        elif kind == "free" and owners:
            key = sorted(owners)[arg % len(owners)]
            pool.decref(owners.pop(key))
        elif kind == "share" and owners:
            key = sorted(owners)[arg % len(owners)]
            if owners[key]:
                bid = owners[key][0]
                pool.incref([bid])
                shared.append(bid)
        elif kind == "unshare" and shared:
            pool.decref([shared.pop()])
        # invariants after every op
        live = [b for ids in owners.values() for b in ids]
        assert len(live) == len(set(live)), "block aliased across live owners"
        for b in live:
            assert pool.refcount(b) >= 1
        assert pool.free_blocks + len(set(live + shared)) == num_blocks
    for ids in owners.values():
        pool.decref(ids)
    for b in shared:
        pool.decref([b])
    assert pool.free_blocks == num_blocks    # everything returns


def test_block_pool_trace_property_deterministic():
    for seed in range(20):
        rng = random.Random(seed)
        ops = [(rng.choice(["alloc", "free", "share", "unshare"]),
                rng.randrange(4)) for _ in range(60)]
        _run_alloc_trace([(k, a + 1 if k == "alloc" else a) for k, a in ops],
                         num_blocks=12)


@given(st.lists(st.tuples(
    st.sampled_from(["alloc", "free", "share", "unshare"]),
    st.integers(1, 5)), max_size=80),
    st.integers(4, 24))
@settings(max_examples=25, deadline=None)
def test_block_pool_trace_property(ops, num_blocks):
    _run_alloc_trace(ops, num_blocks)


# ---------------------------------------------------- paged cache parity
def test_paged_dense_matches_contiguous_bitwise():
    key = jax.random.PRNGKey(0)
    B, H, d, bs, ML = 2, 4, 8, 4, 32
    kv = jax.random.normal(key, (B, 10, H, d), jnp.float32)
    c = DenseKVCache.create(B, ML, H, d, jnp.float32).append(kv, kv)
    p = PagedDenseKVCache.create(B, ML, H, d, jnp.float32, block_size=bs,
                                 identity_tables=True).append(kv, kv)
    for t in range(6):
        one = jax.random.normal(jax.random.fold_in(key, t), (B, 1, H, d))
        c, p = c.append(one, one), p.append(one, one)
    gk, gv = p.gather()
    L = int(c.length[0])
    np.testing.assert_array_equal(np.asarray(c.k[:, :L]),
                                  np.asarray(gk[:, :L]))
    np.testing.assert_array_equal(np.asarray(c.v[:, :L]),
                                  np.asarray(gv[:, :L]))
    np.testing.assert_array_equal(np.asarray(c.length), np.asarray(p.length))


def test_paged_dense_n_valid_drops_pads():
    key = jax.random.PRNGKey(1)
    B, H, d = 2, 2, 4
    kv = jax.random.normal(key, (B, 8, H, d), jnp.float32)
    p = PagedDenseKVCache.create(B, 16, H, d, jnp.float32, block_size=4,
                                 identity_tables=True)
    p = p.append(kv, kv, n_valid=jnp.asarray([5, 8]))
    np.testing.assert_array_equal(np.asarray(p.length), [5, 8])
    gk, _ = p.gather()
    assert np.asarray(gk[0, 5:]).sum() == 0          # pad KV never written
    np.testing.assert_array_equal(np.asarray(gk[0, :5]),
                                  np.asarray(kv[0, :5]))


def test_paged_window_ring_matches_contiguous():
    key = jax.random.PRNGKey(2)
    B, H, d, W = 2, 2, 8, 8
    wc = WindowKVCache.create(B, W, H, d, jnp.float32)
    wp = PagedWindowKVCache.create(B, W, H, d, jnp.float32, block_size=4,
                                   identity_tables=True)
    for t in range(13):                              # wraps the ring
        one = jax.random.normal(jax.random.fold_in(key, t), (B, H, d))
        wc, wp = wc.append_one(one, one), wp.append_one(one, one)
    gk, gv = wp.gather()
    np.testing.assert_array_equal(np.asarray(wc.k), np.asarray(gk))
    np.testing.assert_array_equal(np.asarray(wc.positions),
                                  np.asarray(wp.positions))

    # multi-token (prefill) append == token-by-token ring arithmetic
    kvw = jax.random.normal(jax.random.fold_in(key, 99), (B, 13, H, d))
    wp2 = PagedWindowKVCache.create(B, W, H, d, jnp.float32, block_size=4,
                                    identity_tables=True).append(kvw, kvw)
    wc2 = WindowKVCache.create(B, W, H, d, jnp.float32)
    for t in range(13):
        wc2 = wc2.append_one(kvw[:, t], kvw[:, t])
    np.testing.assert_array_equal(np.asarray(wc2.k),
                                  np.asarray(wp2.gather()[0]))
    np.testing.assert_array_equal(np.asarray(wc2.positions),
                                  np.asarray(wp2.positions))


def test_unallocated_rows_never_corrupt_other_blocks():
    """Writes through a -1 block table are dropped, not clobbered."""
    B, H, d = 2, 2, 4
    p = PagedDenseKVCache.create(B, 16, H, d, jnp.float32, block_size=4,
                                 identity_tables=True)
    # row 1 has no blocks
    p = p._replace(block_table=p.block_table.at[1].set(-1))
    kv = jnp.ones((B, 6, H, d), jnp.float32)
    p = p.append(kv, kv)
    assert np.asarray(p.k[4:]).sum() == 0    # row-1 region untouched
    gk, _ = p.gather()
    np.testing.assert_array_equal(np.asarray(gk[0, :6]), np.asarray(kv[0]))


def test_copy_blocks_device_cow():
    p = PagedDenseKVCache.create(1, 16, 2, 4, jnp.float32, block_size=4,
                                 identity_tables=True)
    kv = jnp.arange(1 * 6 * 2 * 4, dtype=jnp.float32).reshape(1, 6, 2, 4)
    p = p.append(kv, kv)
    p2 = copy_blocks(p, jnp.asarray([0]), jnp.asarray([3]))
    np.testing.assert_array_equal(np.asarray(p2.k[3]), np.asarray(p2.k[0]))
    np.testing.assert_array_equal(np.asarray(p2.k[1]), np.asarray(p.k[1]))


# ------------------------------------------------------- paged attention
def test_paged_attention_ref_matches_contiguous_decode_math():
    """The gather reference reproduces the contiguous decode einsum."""
    key = jax.random.PRNGKey(3)
    B, Hq, Hkv, d, bs, ML = 2, 4, 2, 8, 4, 16
    kv = jax.random.normal(key, (B, 9, Hkv, d), jnp.float32)
    p = PagedDenseKVCache.create(B, ML, Hkv, d, jnp.float32, block_size=bs,
                                 identity_tables=True).append(kv, kv)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, Hq, d))
    out = paged_attention_ref(q, p.k, p.v, p.block_table, p.length, d ** -0.5)

    # oracle: dense masked softmax over the first `length` positions
    kk = kv.transpose(0, 2, 1, 3)                      # (B, Hkv, T, d)
    qg = q.reshape(B, Hkv, Hq // Hkv, d)
    s = jnp.einsum("bgrd,bgkd->bgrk", qg, kk) * (d ** -0.5)
    pr = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bgrk,bgkd->bgrd", pr, kv.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out).reshape(B, Hq, d),
                               np.asarray(want).reshape(B, Hq, d),
                               atol=1e-5, rtol=1e-5)


def test_paged_attention_kernel_matches_ref():
    """The Pallas kernel (interpret mode on CPU) == the gather reference,
    including rows at different lengths and unallocated -1 table tails."""
    key = jax.random.PRNGKey(4)
    B, Hq, Hkv, bs, nb = 2, 4, 2, 4, 4
    d = 128                                           # lane-aligned
    N = B * nb
    k_pool = jax.random.normal(key, (N, bs, Hkv, d), jnp.float32)
    v_pool = jax.random.normal(jax.random.fold_in(key, 1),
                               (N, bs, Hkv, d), jnp.float32)
    bt = jnp.arange(N, dtype=jnp.int32).reshape(B, nb)
    bt = bt.at[0, 2:].set(-1)                         # row 0: 2 blocks only
    lengths = jnp.asarray([6, 15], jnp.int32)
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, Hq, d),
                          jnp.float32)
    ref = paged_attention_ref(q, k_pool, v_pool, bt, lengths, d ** -0.5)
    ker = paged_attention_kernel(q, k_pool, v_pool, bt, lengths,
                                 scale=d ** -0.5, interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------ prefix trie
def test_prefix_trie_insert_lookup_refcounts():
    pool = BlockPool(16, 4)
    trie = PrefixCache(4)
    toks = list(range(100, 112))                       # 3 full blocks
    ids = pool.alloc(3)
    chain, tip = trie.insert(toks, ids, pool)
    assert chain == ids and tip is not None
    assert all(pool.refcount(b) == 2 for b in ids)     # row + trie
    trie.attach_snapshot(tip, {"state": "s3"})

    # full-block prefix of a longer prompt matches; snapshot gating works
    node, depth = trie.lookup(toks + [7, 8], need_snapshot=True)
    assert node is tip and depth == 12
    node2, depth2 = trie.lookup(toks[:9] + [5], need_snapshot=False)
    assert depth2 == 8 and node2.snapshot is None
    assert trie.lookup(toks[:9] + [5], need_snapshot=True) == (None, 0)
    # the last token never matches (a hit must leave >= 1 token to prefill)
    assert trie.lookup(toks[:4], need_snapshot=False) == (None, 0)

    got = trie.acquire(node, pool)
    assert got == ids and all(pool.refcount(b) == 3 for b in ids)
    pool.decref(got)

    # shared insert: an identical prefix computed elsewhere keeps trie ids
    ids_b = pool.alloc(3)
    chain_b, _ = trie.insert(toks, ids_b, pool)
    assert chain_b == ids                              # trie authoritative
    pool.decref(ids_b)

    # release the row refs; LRU eviction drains leaf-first
    pool.decref(ids)
    free0 = pool.free_blocks
    assert trie.evict_lru(pool)                        # deepest leaf
    assert pool.free_blocks == free0 + 1
    while trie.evict_lru(pool):
        pass
    assert trie.n_nodes == 0 and pool.free_blocks == 16
