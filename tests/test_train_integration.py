"""Training-loop integration: loss goes down, checkpoint/restart resumes
bit-exactly, preemption triggers a save."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.launch.train import TrainConfig, Trainer


def _cfg(tmp_path=None, steps=8, **kw):
    return TrainConfig(
        arch="mosa-paper", preset="smoke", arch_kwargs={"variant": "mosa"},
        seq_len=64, global_batch=4, steps=steps, lr=1e-3, warmup=4,
        ckpt_dir=str(tmp_path) if tmp_path else None, ckpt_every=4,
        log_every=100, **kw)


def test_training_reduces_loss():
    tr = Trainer(_cfg(steps=20))
    _, _, hist = tr.run(install_signals=False)
    losses = [h["loss"] for h in hist]
    assert losses[-1] < losses[0]


def test_checkpoint_restart_resumes_bit_exact(tmp_path):
    # run 8 steps straight
    tr1 = Trainer(_cfg(tmp_path / "a", steps=8))
    p1, o1, _ = tr1.run(install_signals=False)

    # run 4 steps, "crash", restart, run to 8
    tr2 = Trainer(_cfg(tmp_path / "b", steps=4))
    tr2.run(install_signals=False)
    assert ckpt.latest_step(str(tmp_path / "b")) == 4
    tr3 = Trainer(_cfg(tmp_path / "b", steps=8))
    p3, o3, _ = tr3.run(install_signals=False)

    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_checkpoint(tmp_path):
    tr = Trainer(_cfg(tmp_path, steps=100))
    # simulate SIGTERM after the 2nd step by toggling the flag
    orig_step = tr.train_step

    calls = {"n": 0}

    def wrapped(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2 and tr.preempt is not None:
            tr.preempt.requested = True
        return orig_step(*a, **kw)

    tr.train_step = wrapped
    tr.run()
    assert ckpt.latest_step(str(tmp_path)) == 2   # saved at the boundary


def test_elastic_restore_across_mesh_change(tmp_path):
    """Checkpoint saved under one sharding restores under another."""
    from repro.configs.base import get_config
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.nn.module import init_shapes
    from repro.nn.transformer import TransformerLM

    cfg = get_config("qwen2-1.5b", preset="smoke")
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 1, params)

    mesh = make_host_mesh(tp=1)  # "new cluster": 1 device
    shapes = init_shapes(model)
    sh = shd.param_shardings(model, mesh, "tp", shapes)
    restored, _ = ckpt.restore(str(tmp_path), shapes, shardings=sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_state_travels_with_checkpoint(tmp_path):
    """Resume consumes exactly the batches the crashed run would have."""
    tr = Trainer(_cfg(tmp_path, steps=6))
    seen = []
    orig = tr.train_step

    def spy(params, opt, step, batch):
        seen.append(np.asarray(batch["tokens"])[0, :4].tolist())
        return orig(params, opt, step, batch)

    tr.train_step = spy
    tr.run(install_signals=False)

    tr2 = Trainer(_cfg(tmp_path, steps=8))
    seen2 = []
    orig2 = tr2.train_step

    def spy2(params, opt, step, batch):
        seen2.append(np.asarray(batch["tokens"])[0, :4].tolist())
        return orig2(params, opt, step, batch)

    tr2.train_step = spy2
    tr2.run(install_signals=False)
    # restart at step 6 (ckpt_every=4 -> last ckpt at step 4? no: saved at
    # i+1 == 4 and at the final step 6) -> resumes with batch 6 and 7
    assert seen2[0] == Trainer(_cfg(steps=1)).dataset.batch_at(6)["tokens"][0, :4].tolist()
