"""Unit tests for the sharding substrate: logical-axis resolution,
divisibility safety, cache specs, hints, and the analytic cost model."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.dist import sharding as shd
from repro.dist.hints import constrain, sharding_hints
from repro.launch.mesh import make_host_mesh
from repro.nn.module import LogicalSpec, logical, resolve_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 4, "model": 8})


def test_resolve_spec_basic():
    assert resolve_spec((32, 64), logical("embed", "mlp"),
                        {"embed": None, "mlp": "model"}, MESH) == P(None, "model")


def test_resolve_spec_divisibility_safe():
    # 6 % 8 != 0 -> replicate instead of fail (GQA kv heads case)
    assert resolve_spec((6, 64), logical("heads", None),
                        {"heads": "model"}, MESH) == P()


def test_resolve_spec_no_axis_reuse():
    # two dims mapped to the same mesh axis: second gets dropped
    spec = resolve_spec((8, 8), logical("a", "b"),
                        {"a": "model", "b": "model"}, MESH)
    assert spec == P("model")


def test_resolve_spec_multi_axis_batch():
    m = FakeMesh({"pod": 2, "data": 4, "model": 8})
    spec = resolve_spec((16, 128), logical("batch", None),
                        {"batch": ("pod", "data")}, m)
    assert spec == P(("pod", "data"))


def test_dp_axes_trims_to_divisibility():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    m.shape = {"pod": 2, "data": 16, "model": 16}
    assert shd.dp_axes(m, "fsdp_tp", batch=256) == ("pod", "data")
    assert shd.dp_axes(m, "fsdp_tp", batch=16) == ("pod",) or \
        shd.dp_axes(m, "fsdp_tp", batch=16) == ("pod", "data")[:1]
    assert shd.dp_axes(m, "fsdp_tp", batch=1) == ()


def test_cache_shardings_structures():
    from repro.nn.transformer import TransformerLM
    cfg = get_config("gemma3-4b", preset="smoke")
    model = TransformerLM(cfg)
    mesh = make_host_mesh(tp=1)
    shapes = jax.eval_shape(lambda: model.init_cache(2, 32, jnp.float32))
    sh = shd.cache_shardings(shapes, mesh, "tp")
    # same tree structure
    assert jax.tree.structure(shapes) == jax.tree.structure(
        jax.tree.map(lambda x: 0, sh))


def test_hints_noop_without_context():
    x = jnp.ones((4, 8))
    y = constrain(x, ("dp", "tp"))
    assert (y == x).all()


def test_hints_divisibility_safe():
    mesh = make_host_mesh(tp=1)  # 1x1 mesh
    with sharding_hints(mesh=mesh):
        x = jnp.ones((3, 5))
        y = constrain(x, ("dp", "tp"))   # nothing divides -> no-op semantics
        assert (y == x).all()


def test_param_shardings_cover_all_leaves():
    from repro.nn.module import init_shapes
    from repro.nn.transformer import TransformerLM
    for arch in ("qwen2-1.5b", "jamba-v0.1-52b", "xlstm-125m"):
        cfg = get_config(arch, preset="smoke")
        model = TransformerLM(cfg)
        shapes = init_shapes(model)
        mesh = make_host_mesh(tp=1)
        sh = shd.param_shardings(model, mesh, "fsdp_tp", shapes)
        n_shapes = len(jax.tree.leaves(shapes))
        n_sh = len(jax.tree.leaves(
            jax.tree.map(lambda s: 0, sh)))
        assert n_shapes == n_sh, arch


# ----------------------------------------------------------- analytic model
def test_analytic_matches_paper_flops():
    """The analytic estimator reduces to the paper's formula on its models."""
    from benchmarks.analytic import model_flops
    from repro.configs.mosa_paper import paper_config
    from repro.core.flops import PAPER_MODELS
    cfg = paper_config("tiny", "dense", seq_len=1024)
    got = model_flops(cfg, B=1, T=1024)
    want = PAPER_MODELS["tiny"].dense_flops(1024)
    # analytic adds the unembed term the paper omits; remove it to compare
    got -= 2 * 1024 * cfg.d_model * cfg.vocab
    assert abs(got - want) / want < 1e-6


def test_analytic_active_params_moe():
    from benchmarks.analytic import param_counts
    cfg = get_config("granite-moe-1b-a400m", preset="full")
    total, active = param_counts(cfg)
    assert 1.2e9 < total < 1.5e9
    assert active < total            # top-8 of 32 experts
    assert active > total * 0.25


def test_analytic_cache_bytes_scale_with_context():
    from benchmarks.analytic import cache_bytes
    cfg = get_config("qwen2-1.5b", preset="smoke")
    b1 = cache_bytes(cfg, 1, 64)
    b2 = cache_bytes(cfg, 1, 128)
    assert b2 > b1 * 1.8             # dense cache ~ linear in S


def test_analytic_mosa_cache_constant_in_context():
    """The paper's claim at the analytic level: MoSA-hybrid cache is O(k)."""
    from benchmarks.analytic import cache_bytes
    cfg = get_config("qwen2-1.5b", preset="smoke").with_mosa(
        sparsity=4, n_mosa_heads=4, local_window=16, k_fixed=8)
    b1 = cache_bytes(cfg, 1, 64)
    b2 = cache_bytes(cfg, 1, 128)
    assert b2 < b1 * 1.1             # window + k_fixed: ~flat in S
