"""Dense attention family: GQA / MLA / local window, train vs serve parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, MLAConfig
from repro.core.attention import (MLAAttention, MultiHeadAttention,
                                  chunked_attention, gqa_attention)
from repro.core.kv_cache import DenseKVCache, MLAKVCache, WindowKVCache
from repro.core.rope import apply_rope, text_mrope_positions


def test_chunked_matches_direct():
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, T, d = 2, 4, 2, 37, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, T, d))
    k = jax.random.normal(ks[1], (B, Hkv, T, d))
    v = jax.random.normal(ks[2], (B, Hkv, T, d))
    pos = jnp.arange(T)
    o1 = chunked_attention(q, k, v, pos, pos, d ** -0.5, chunk=8)
    o2 = gqa_attention(q, k, v, pos, pos, d ** -0.5)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_chunked_window_matches_direct():
    key = jax.random.PRNGKey(1)
    B, H, T, d = 1, 2, 64, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, H, T, d))
    v = jax.random.normal(ks[2], (B, H, T, d))
    pos = jnp.arange(T)
    o1 = chunked_attention(q, k, v, pos, pos, d ** -0.5, window=9, chunk=16)
    o2 = gqa_attention(q, k, v, pos, pos, d ** -0.5, window=9)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@pytest.mark.parametrize("qkv_bias", [False, True])
def test_gqa_train_vs_decode_parity(qkv_bias):
    """Decoding token-by-token must reproduce the training forward."""
    key = jax.random.PRNGKey(0)
    B, T, h = 1, 12, 32
    cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=8, qkv_bias=qkv_bias)
    m = MultiHeadAttention(h, cfg, impl="chunked", chunk=4)
    p = m.init(key)
    x = jax.random.normal(key, (B, T, h))
    y_train = m(p, x)
    cache = DenseKVCache.create(B, T, 2, 8, jnp.float32)
    ys = []
    for t in range(T):
        y, cache = m.decode_step(p, x[:, t:t + 1], cache)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               atol=2e-5)


def test_window_attention_train_vs_decode_parity():
    key = jax.random.PRNGKey(2)
    B, T, h, W = 1, 20, 32, 6
    cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=8, window=W)
    m = MultiHeadAttention(h, cfg, impl="chunked", chunk=4)
    p = m.init(key)
    x = jax.random.normal(key, (B, T, h))
    y_train = m(p, x)
    cache = WindowKVCache.create(B, W, 2, 8, jnp.float32)
    ys = []
    for t in range(T):
        y, cache = m.decode_step(p, x[:, t:t + 1], cache)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               atol=2e-5)


def test_mla_train_vs_decode_parity():
    key = jax.random.PRNGKey(3)
    B, T, h = 1, 10, 32
    mla = MLAConfig(kv_lora_rank=16, rope_head_dim=8, v_head_dim=8,
                    nope_head_dim=8)
    cfg = AttentionConfig(kind="mla", n_heads=4, d_head=16, mla=mla)
    m = MLAAttention(h, cfg)
    p = m.init(key)
    x = jax.random.normal(key, (B, T, h))
    y_train = m(p, x)
    cache = MLAKVCache.create(B, T, 16, 8, jnp.float32)
    ys = []
    for t in range(T):
        y, cache = m.decode_step(p, x[:, t:t + 1], cache)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_train),
                               np.asarray(jnp.concatenate(ys, 1)), atol=3e-5)


def test_mla_cache_is_latent_sized():
    """MLA's point: the cache holds the latent, not per-head K/V."""
    cache = MLAKVCache.create(2, 100, 16, 8, jnp.float32)
    per_token = cache.latent.shape[-1] + cache.k_rope.shape[-1]
    assert per_token == 24            # kv_lora + rope_dim, NOT H*(2*d_head)


def test_rope_position_awareness():
    """RoPE at gathered positions == RoPE applied then gathered."""
    key = jax.random.PRNGKey(0)
    T, d = 16, 8
    x = jax.random.normal(key, (1, T, d))
    idx = jnp.asarray([[1, 5, 11]])
    full = apply_rope(x, jnp.arange(T)[None])
    gathered = jnp.take_along_axis(x, idx[..., None], axis=1)
    direct = apply_rope(gathered, idx)
    via_full = jnp.take_along_axis(full, idx[..., None], axis=1)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(via_full),
                               atol=1e-6)


def test_rope_partial_rotation():
    x = jnp.ones((1, 4, 8))
    y = apply_rope(x, jnp.arange(4)[None], rotary_frac=0.5)
    # last half of dims untouched
    np.testing.assert_array_equal(np.asarray(y[..., 4:]), np.ones((1, 4, 4)))
    assert not np.allclose(np.asarray(y[..., :4]), 1.0)


def test_mrope_text_equals_rope():
    """For pure text (t=h=w), M-RoPE must reduce to standard RoPE."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, 8, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    std = apply_rope(x, pos)
    mr = apply_rope(x, text_mrope_positions(pos), mrope_sections=(2, 3, 3))
    np.testing.assert_allclose(np.asarray(std), np.asarray(mr), atol=1e-6)


def test_mrope_distinct_components_differ():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, 8, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    p3 = text_mrope_positions(pos)
    p3b = p3.at[1].add(3)  # shift the h component (vision patches)
    a = apply_rope(x, p3, mrope_sections=(2, 3, 3))
    b = apply_rope(x, p3b, mrope_sections=(2, 3, 3))
    assert float(jnp.abs(a - b).max()) > 1e-3
