"""Traffic/SLO layer suite (ISSUE 9, DESIGN §12): labeled metric series,
cross-process snapshot merging (unit + property parity vs one shared
registry, including JSONL round-trips), the ``registry.timer`` helper,
tracer ring-drop accounting, labeled Prometheus rendering, seeded load
generation, the Scheduler's timed source mode (open loop, closed loop,
shedding), and SLO/goodput evaluation with scheduler-records vs
span-derived-records parity."""

import dataclasses
import itertools
import json

import numpy as np
import pytest

from repro import obs
from repro.configs.base import BlockSpec, get_config
from repro.launch.serve import Scheduler, Server
from repro.obs.export import (merge_snapshot_files, prometheus_text,
                              write_metrics_jsonl)
from repro.obs.metrics import Registry, merge_snapshots, series_key
from repro.obs.slo import SLOSpec, evaluate, records_from_spans
from repro.obs.tracing import Tracer
from repro.serve.loadgen import (ClosedLoopSource, OpenLoopSource,
                                 TenantSpec, bursty_workload,
                                 closed_workload, poisson_workload)
from repro.serve.paged_kv import PagedConfig
from tests._property_harness import given, settings, st


@pytest.fixture(autouse=True)
def fresh_obs():
    obs.set_enabled(True)
    obs.registry().reset()
    obs.tracer().reset()
    yield
    obs.set_enabled(True)
    obs.registry().reset()
    obs.tracer().reset()


# ------------------------------------------------------------------ labels
def test_series_key_rendering():
    assert series_key("a.b", None) == "a.b"
    assert series_key("a.b", {}) == "a.b"
    assert series_key("a.b", {"t": "x"}) == 'a.b{t="x"}'
    # sorted keys -> process-independent snapshot keys
    assert series_key("m", {"b": "2", "a": "1"}) == 'm{a="1",b="2"}'
    # exposition-format escapes
    assert series_key("m", {"v": 'a"b\\c\nd'}) == \
        'm{v="a\\"b\\\\c\\nd"}'


def test_labeled_series_are_distinct():
    reg = Registry()
    reg.inc("serve.finished")
    reg.inc("serve.finished", tenant="a")
    reg.inc("serve.finished", tenant="a")
    reg.inc("serve.finished", tenant="b")
    snap = reg.snapshot()
    assert snap["counters"]["serve.finished"] == 1
    assert snap["counters"]['serve.finished{tenant="a"}'] == 2
    assert snap["counters"]['serve.finished{tenant="b"}'] == 1
    assert reg.get("serve.finished", tenant="a").value == 2
    assert reg.get("serve.finished", tenant="a").labels == {"tenant": "a"}
    # same name, different label sets, same family — and histograms too
    reg.observe("h", 0.5, tenant="a")
    reg.observe("h", 0.7)
    assert reg.get("h", tenant="a").count == 1
    assert reg.get("h").count == 1


# ------------------------------------------------------------------- timer
def test_timer_observes_and_measures():
    reg = Registry()
    with reg.timer("op.time_s") as t:
        pass
    assert t.dt >= 0.0
    h = reg.get("op.time_s")
    assert h.count == 1 and h.sum == t.dt
    with reg.timer("op.time_s", tenant="a") as t2:
        pass
    assert reg.get("op.time_s", tenant="a").count == 1
    assert t2.dt >= 0.0
    # disabled: clock runs, nothing recorded
    off = Registry(enabled=False)
    with off.timer("op.time_s") as t3:
        pass
    assert t3.dt >= 0.0 and off._metrics == {}


# ----------------------------------------------------- tracer ring drops
def test_tracer_ring_drop_accounting():
    tr = Tracer(capacity=4)
    for i in range(4):
        tr.instant(f"s{i}")
    assert tr.dropped_spans == 0
    for i in range(3):
        tr.instant(f"x{i}")
    assert tr.dropped_spans == 3              # 3 oldest overwritten
    assert len(tr) == 4
    assert tr.chrome_trace()["otherData"]["dropped_spans"] == 3
    tr.reset()
    assert tr.dropped_spans == 0
    assert tr.chrome_trace()["otherData"]["dropped_spans"] == 0
    # a disabled tracer never drops (it never records)
    tr.enabled = False
    for i in range(10):
        tr.instant(f"y{i}")
    assert tr.dropped_spans == 0


def test_dump_publishes_dropped_spans(tmp_path):
    tr = Tracer(capacity=2)
    for i in range(5):
        tr.instant(f"s{i}")
    mpath = tmp_path / "m.json"
    obs.dump(metrics_path=str(mpath), tr=tr)
    snap = json.loads(mpath.read_text())
    assert snap["gauges"]["tracer.dropped_spans"] == 3


# ----------------------------------------------------- prometheus format
def _parse_prom(txt):
    """Minimal exposition-format reader (samples attach to the family
    whose HELP/TYPE header precedes them): {family: {"type", "help",
    "samples": {sample_line_name_with_labels: value}}}."""
    fams, cur = {}, None
    for line in txt.splitlines():
        if line.startswith(("# HELP ", "# TYPE ")):
            _, field, name, rest = line.split(" ", 3)
            cur = fams.setdefault(name, {"samples": {}})
            cur[field.lower()] = rest
        elif line:
            key, val = line.rsplit(" ", 1)
            cur["samples"][key] = float(val)
    return fams


def test_prometheus_labeled_round_trip():
    reg = Registry()
    reg.inc("serve.finished", 3)
    reg.inc("serve.finished", tenant="a")
    reg.set("pool.free", 7)
    reg.observe("serve.ttft_s", 1.5, bounds=(1.0, 2.0), tenant='q"t')
    txt = prometheus_text(reg)
    fams = _parse_prom(txt)
    f = fams["serve_finished"]
    assert f["type"] == "counter" and f["help"] == "serve.finished"
    assert f["samples"]["serve_finished"] == 3
    assert f["samples"]['serve_finished{tenant="a"}'] == 1
    assert fams["pool_free"]["type"] == "gauge"
    h = fams["serve_ttft_s"]
    assert h["type"] == "histogram"
    # labeled buckets carry BOTH le and the series labels, escaped
    assert h["samples"]['serve_ttft_s_bucket{le="2",tenant="q\\"t"}'] == 1
    assert h["samples"]['serve_ttft_s_bucket{le="+Inf",tenant="q\\"t"}'] == 1
    assert h["samples"]['serve_ttft_s_count{tenant="q\\"t"}'] == 1
    # one HELP/TYPE header per family even with multiple series
    assert txt.count("# TYPE serve_finished counter") == 1


# ----------------------------------------------------------------- merge
def _strip_meta(snap):
    return {k: v for k, v in snap.items() if k != "gauges_meta"}


def test_merge_semantics_unit():
    a, b = Registry(), Registry()
    a.inc("c", 2)
    b.inc("c", 3)
    a.inc("only_a")
    b.set_max("hw", 5.0)
    a.set_max("hw", 7.0)
    a.set("last", 1.0)
    b.set("last", 2.0)                  # newer stamp wins
    a.observe("h", 0.5)
    b.observe("h", 1.5)
    b.observe("h", 2.5)
    m = merge_snapshots([a.snapshot(), b.snapshot()])
    assert m["counters"]["c"] == 5
    assert m["counters"]["only_a"] == 1
    assert m["gauges"]["hw"] == 7.0
    assert m["gauges"]["last"] == 2.0
    h = m["histograms"]["h"]
    assert h["count"] == 3 and h["sum"] == 4.5
    assert h["min"] == 0.5 and h["max"] == 2.5
    assert h["p50"] > 0


def test_merge_order_independent():
    regs = [Registry() for _ in range(3)]
    for i, r in enumerate(regs):
        r.inc("c", i + 1)
        r.set("g", float(i))
        r.set_max("m", float(10 - i))
        for v in range(i + 2):
            r.observe("h", v / 4.0)
    snaps = [r.snapshot() for r in regs]
    first = merge_snapshots(snaps)
    for perm in itertools.permutations(snaps):
        assert merge_snapshots(list(perm)) == first
    # associativity: merging a merged snapshot with the third matches
    two = merge_snapshots(snaps[:2])
    assert _strip_meta(merge_snapshots([two, snaps[2]])) == \
        _strip_meta(first)


_OPS = ("inc", "set", "set_max", "observe")


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(_OPS), st.integers(0, 2),
              st.sampled_from(("m.a", "m.b", "m.c")),
              st.sampled_from(("", "a", "b")),
              st.integers(1, 100)),
    min_size=1, max_size=40))
def test_merge_parity_property(ops):
    """The §12 aggregation contract: K per-process registries fed a random
    interleaving of ops merge to EXACTLY what one shared registry fed the
    same global sequence reports — counters, gauges (both kinds),
    histograms, labels included.  Values are quarter-integers so float
    addition is exact regardless of grouping."""
    procs = [Registry() for _ in range(3)]
    shared = Registry()
    for kind, p, base, tenant, v in ops:
        name = base + "." + kind            # one metric type per name
        labels = {"tenant": tenant} if tenant else {}
        val = v / 4.0
        for reg in (procs[p], shared):
            getattr(reg, kind)(name, val, **labels)
    merged = merge_snapshots([r.snapshot() for r in procs])
    assert _strip_meta(merged) == _strip_meta(shared.snapshot())
    # order-independence on the same draw
    rev = merge_snapshots([r.snapshot() for r in reversed(procs)])
    assert _strip_meta(rev) == _strip_meta(merged)


def test_merge_jsonl_files_parity(tmp_path):
    """Replica aggregation end-to-end: N processes dump JSONL snapshots,
    ``merge_snapshot_files`` reads the last line of each and reproduces
    the shared-registry view."""
    shared = Registry()
    paths = []
    for i in range(3):
        r = Registry()
        for reg in (r, shared):
            reg.inc("req.count", i + 1, tenant=f"t{i}")
            reg.inc("req.count", 2)
            reg.observe("lat.s", (i + 1) / 4.0)
            reg.set_max("hw", float(i))
        p = tmp_path / f"replica{i}.jsonl"
        write_metrics_jsonl(str(p), r, tag=f"r{i}")     # stale line...
        r.inc("req.count", 1)
        shared.inc("req.count", 1)
        write_metrics_jsonl(str(p), r, tag=f"r{i}")     # ...then final
        paths.append(str(p))
    merged = merge_snapshot_files(paths)
    want = shared.snapshot()
    assert merged["counters"] == want["counters"]
    assert merged["gauges"] == want["gauges"]
    for k, h in want["histograms"].items():
        got = merged["histograms"][k]
        for field in ("count", "sum", "counts", "bounds", "min", "max",
                      "p50", "p90", "p99"):
            assert got[field] == h[field], (k, field)


# --------------------------------------------------------------- loadgen
def test_workload_determinism_and_mix():
    tenants = (TenantSpec("a", weight=3.0, prompt_len=(4, 8),
                          max_new=(2, 4)),
               TenantSpec("b", weight=1.0, prompt_len=(16, 24),
                          max_new=(5, 6)))
    w1 = poisson_workload(rate=10.0, n=200, seed=7, vocab=64,
                          tenants=tenants)
    w2 = poisson_workload(rate=10.0, n=200, seed=7, vocab=64,
                          tenants=tenants)
    assert len(w1) == 200
    for x, y in zip(w1, w2):
        assert x.t == y.t and x.tenant == y.tenant and x.max_new == y.max_new
        assert np.array_equal(x.prompt, y.prompt)
    assert poisson_workload(10.0, 200, 8, 64, tenants)[0].t != w1[0].t
    # arrival times sorted, mean interarrival ~ 1/rate
    ts = [a.t for a in w1]
    assert ts == sorted(ts)
    assert ts[-1] / 200 == pytest.approx(0.1, rel=0.5)
    # tenant mix respects weights; lengths respect per-tenant ranges
    frac_a = sum(a.tenant == "a" for a in w1) / 200
    assert 0.55 <= frac_a <= 0.95
    for a in w1:
        lo, hi = (4, 8) if a.tenant == "a" else (16, 24)
        assert lo <= len(a.prompt) <= hi
        assert (a.prompt >= 0).all() and (a.prompt < 64).all()


def test_bursty_workload_is_burstier():
    po = poisson_workload(10.0, 500, 3, 64)
    bu = bursty_workload(10.0, 500, 3, 64, cv=3.0)

    def cv(arr):
        gaps = np.diff([0.0] + [a.t for a in arr])
        return gaps.std() / gaps.mean()

    assert cv(bu) > 1.5 * cv(po)
    assert cv(po) == pytest.approx(1.0, rel=0.3)        # Poisson CV = 1


def test_closed_workload_and_sources():
    w = closed_workload(5, 1, 64)
    assert all(a.t == 0.0 for a in w)

    class FakeSched:
        def __init__(self):
            self.results = {}
            self.subs = []

        def submit(self, prompt, max_new, tenant=""):
            rid = len(self.subs)
            self.subs.append((len(prompt), max_new, tenant))
            return rid

    # open loop: submits exactly the due arrivals
    arr = poisson_workload(5.0, 10, 2, 64)
    src = OpenLoopSource(arr)
    fake = FakeSched()
    src.pump(fake, arr[2].t)
    assert len(fake.subs) == 3
    assert src.next_arrival_in(arr[2].t) == \
        pytest.approx(arr[3].t - arr[2].t)
    src.pump(fake, arr[-1].t)
    assert src.exhausted() and src.next_arrival_in(1e9) is None
    # closed loop: holds `concurrency` outstanding
    csrc = ClosedLoopSource(w, concurrency=2)
    fake2 = FakeSched()
    csrc.pump(fake2, 0.0)
    assert len(fake2.subs) == 2
    csrc.pump(fake2, 1.0)
    assert len(fake2.subs) == 2               # nothing finished yet
    fake2.results[0] = "done"
    csrc.pump(fake2, 2.0)
    assert len(fake2.subs) == 3
    fake2.results.update({1: "d", 2: "d"})
    csrc.pump(fake2, 3.0)
    assert len(fake2.subs) == 5 and csrc.exhausted()


# ------------------------------------------------------------ slo.evaluate
def _rec(rid, tenant, outcome, ttft, tpot, toks=8, qd=0.0):
    return {"rid": rid, "tenant": tenant, "outcome": outcome,
            "t_arrival": 0.0, "queue_delay_s": qd, "ttft_s": ttft,
            "tpot_s": tpot, "new_tokens": toks}


def test_evaluate_goodput_and_tenants():
    spec = SLOSpec(ttft_s=0.1, tpot_s=0.02, name="interactive")
    recs = [
        _rec(0, "a", "finished", 0.05, 0.01),
        _rec(1, "a", "finished", 0.50, 0.01),      # TTFT miss
        _rec(2, "b", "finished", 0.05, 0.05),      # TPOT miss
        _rec(3, "b", "finished", 0.05, None, toks=1),  # no TPOT obligation
        _rec(4, "b", "shed", None, None, toks=0),
    ]
    ev = evaluate(recs, spec)
    assert ev["total"] == 5 and ev["finished"] == 4 and ev["shed"] == 1
    assert ev["slo_met"] == 2
    assert ev["goodput"] == pytest.approx(2 / 5)
    assert ev["served_goodput"] == pytest.approx(2 / 4)
    assert ev["spec"]["name"] == "interactive"
    assert ev["ttft"]["count"] == 4
    assert ev["ttft"]["p50"] == pytest.approx(0.05)
    per = ev["per_tenant"]
    assert set(per) == {"a", "b"}
    assert per["a"]["goodput"] == pytest.approx(1 / 2)
    assert per["b"]["goodput"] == pytest.approx(1 / 3)
    assert per["b"]["shed"] == 1
    # empty record set
    empty = evaluate([], spec)
    assert empty["goodput"] == 0.0 and empty["ttft"] == {"count": 0}


def test_records_from_spans_synthetic():
    tr = Tracer()
    t0 = 1.0
    tr.add("queued", t0, t0 + 0.2, track="req5")
    tr.add("prefill", t0 + 0.2, t0 + 0.5, track="req5", prompt=16)
    tr.add("decode", t0 + 0.5, t0 + 1.5, track="req5", tokens=11)
    tr.instant("finish", track="req5", tokens=11, tenant="a")
    tr.instant("shed", track="req7", tenant="b")
    tr.add("queued", t0, t0 + 9.9, track="req9")       # never finished
    recs = {r["rid"]: r for r in records_from_spans(tr.spans())}
    r5 = recs[5]
    assert r5["outcome"] == "finished" and r5["tenant"] == "a"
    assert r5["t_arrival"] == pytest.approx(t0)
    assert r5["queue_delay_s"] == pytest.approx(0.2)
    assert r5["ttft_s"] == pytest.approx(0.5)
    assert r5["tpot_s"] == pytest.approx(1.0 / 10)
    assert r5["new_tokens"] == 11
    assert recs[7]["outcome"] == "shed" and recs[7]["tenant"] == "b"
    assert recs[9]["outcome"] == "incomplete"


# ----------------------------------------------- scheduler timed mode
def _dense_window_cfg():
    cfg = get_config("mosa-paper", preset="smoke", variant="dense")
    return dataclasses.replace(
        cfg, n_layers=2,
        attention=dataclasses.replace(cfg.attention, window=16),
        pattern=(BlockSpec("attn", "dense"),
                 BlockSpec("attn_local", "dense")))


_SERVER = None


def small_server():
    """Cached dense+window server (compile once across this module); the
    small pool makes long mixes preempt — same pattern as test_obs."""
    global _SERVER
    if _SERVER is None:
        _SERVER = Server(_dense_window_cfg(), batch=2, max_len=64,
                         paged=PagedConfig(block_size=8, num_blocks=14,
                                           num_window_blocks=4))
    return _SERVER


_TENANTS = (TenantSpec("gold", weight=1.0, prompt_len=(4, 12),
                       max_new=(2, 4)),
            TenantSpec("free", weight=1.0, prompt_len=(4, 12),
                       max_new=(2, 4)))


def test_timed_open_loop_serves_all():
    server = small_server()
    sched = Scheduler(server, chunk=4, prefix_cache=False)
    arrivals = poisson_workload(rate=200.0, n=6, seed=11, vocab=64,
                                tenants=_TENANTS)
    src = OpenLoopSource(arrivals)
    out = sched.run(source=src)
    assert len(src.submitted_rids) == 6
    for a, rid in zip(src.arrivals, src.submitted_rids):
        assert len(out[rid]) == a.max_new
    recs = list(sched.records.values())
    assert len(recs) == 6
    assert all(r["outcome"] == "finished" for r in recs)
    assert {r["tenant"] for r in recs} <= {"gold", "free"}
    snap = obs.registry().snapshot()
    for r in recs:
        assert r["ttft_s"] is not None and r["ttft_s"] > 0
        assert r["queue_delay_s"] >= 0
        if not snap["counters"].get("serve.preempted", 0):
            # arrival-based TTFT includes the queue wait (a preempted
            # request's final queue delay restarts, so only assert on
            # preemption-free runs)
            assert r["ttft_s"] >= r["queue_delay_s"] * (1 - 1e-9)
    assert snap["counters"]['serve.finished{tenant="gold"}'] + \
        snap["counters"]['serve.finished{tenant="free"}'] == 6
    assert snap["histograms"]["serve.queue_delay_s"]["count"] >= 6
    assert snap["histograms"]["serve.run_s"]["count"] == 1


def test_timed_closed_loop_bounds_concurrency():
    server = small_server()
    sched = Scheduler(server, chunk=4, prefix_cache=False)
    reqs = closed_workload(5, 13, 64, tenants=_TENANTS[:1])
    src = ClosedLoopSource(reqs, concurrency=1)
    out = sched.run(source=src)
    assert len(out) == 5
    for a, rid in zip(reqs, src.submitted_rids):
        assert len(out[rid]) == a.max_new
    snap = obs.registry().snapshot()
    # concurrency cap binds BELOW the batch size (2): the closed loop is
    # doing the limiting, not the server
    assert snap["gauges"]["serve.max_concurrent"] == 1
    assert snap["counters"]["serve.finished"] == 5


def test_shedding_under_max_queue():
    server = small_server()
    sched = Scheduler(server, chunk=4, prefix_cache=False, max_queue=1)
    rids = [sched.submit(np.full((6,), 3, np.int32), max_new=2,
                         tenant="gold") for _ in range(4)]
    out = sched.run()
    # first fills the queue; the rest shed at submit time
    assert len(out[rids[0]]) == 2
    for r in rids[1:]:
        assert len(out[r]) == 0
    recs = sched.records
    assert recs[rids[0]]["outcome"] == "finished"
    assert all(recs[r]["outcome"] == "shed" for r in rids[1:])
    snap = obs.registry().snapshot()
    assert snap["counters"]["serve.shed"] == 3
    assert snap["counters"]['serve.shed{tenant="gold"}'] == 3
    assert snap["counters"]["serve.submitted"] == 4
    assert snap["counters"]["serve.finished"] == 1
    # goodput accounting sees the sheds
    ev = evaluate(list(recs.values()), SLOSpec(ttft_s=1e9))
    assert ev["total"] == 4 and ev["shed"] == 3
    assert ev["goodput"] == pytest.approx(1 / 4)
    assert ev["served_goodput"] == pytest.approx(1.0)


def test_scheduler_records_match_span_records():
    """Parity (§12): the offline span-derived records equal the live
    scheduler records — across a mix long enough to trigger preemption
    and re-prefill on the small pool."""
    server = small_server()
    sched = Scheduler(server, chunk=4, prefix_cache=False)
    rng = np.random.default_rng(5)
    # P=50 rows admit at 7 dense blocks each (pool: 14, so both fit
    # exactly), then decode growth to 51+ tokens wants an 8th each — the
    # newer row MUST be preempted: preemption + re-prefill (resumed span)
    # are exercised by construction.
    for i, n in enumerate((50, 50, 12)):
        sched.submit(rng.integers(2, 64, size=(n,)).astype(np.int32),
                     max_new=10, tenant="gold" if i % 2 else "free")
    out = sched.run()
    assert len(out) == 3
    assert obs.registry().snapshot()["counters"].get(
        "serve.preempted", 0) > 0, "mix was meant to preempt"
    live = sched.records
    derived = {r["rid"]: r for r in records_from_spans(obs.tracer().spans())}
    assert set(derived) == set(live)
    for rid, want in live.items():
        got = derived[rid]
        # ttft is float-reassembled from span endpoints (t0 + dur): approx;
        # every other field is computed from the same floats — exact.
        assert got["ttft_s"] == pytest.approx(want["ttft_s"], rel=1e-9)
        for k in ("tenant", "outcome", "t_arrival", "queue_delay_s",
                  "tpot_s", "new_tokens"):
            assert got[k] == want[k], (rid, k, got[k], want[k])
    ev = evaluate(list(live.values()), SLOSpec(ttft_s=1e9))
    assert ev["finished"] == 3 and "per_tenant" in ev
