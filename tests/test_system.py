"""End-to-end behaviour tests of the paper's system claims.

These are the paper-facing assertions: hybrid MoSA trains at matched FLOPs
with better loss trend than dense (directional IsoFLOP check at CPU scale),
and the KV/compute accounting matches the paper's formulas.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mosa_paper import paper_config
from repro.core.flops import PAPER_MODELS
from repro.launch.train import TrainConfig, Trainer
from repro.nn.transformer import TransformerLM


def _short_train(model_cfg, steps=30, seq=128, batch=8, seed=0, lr=3e-3):
    cfg = TrainConfig(arch="unused", seq_len=seq, global_batch=batch,
                      steps=steps, lr=lr, warmup=10,
                      log_every=max(steps // 3, 1), seed=seed)
    tr = Trainer(cfg, model_cfg=model_cfg)
    _, _, hist = tr.run(install_signals=False)
    return hist[-1]["loss"]


def test_isoflop_directional_mosa_beats_dense():
    """Short-training CPU check of the paper's headline ordering:
    hybrid MoSA <= dense at matched FLOPs (tiny scale, synthetic data).

    30 steps cannot reproduce 100k-step perplexities; we assert the
    *ordering* with slack, as a smoke-level directional signal.  The full
    IsoFLOP harness is benchmarks/table1_isoflop.py.
    """
    dense = paper_config("tiny", "dense", seq_len=128)
    dense = dataclasses.replace(dense, n_layers=2, vocab=512)
    mosa = paper_config("tiny", "mosa", sparsity=8, seq_len=128)
    mosa = dataclasses.replace(mosa, n_layers=2, vocab=512,
                               pattern=mosa.pattern[:2])
    l_dense = _short_train(dense)
    l_mosa = _short_train(mosa)
    # hybrid has many more (cheap) heads at matched FLOPs; at minimum it must
    # be in the same band as dense — 5% slack for short-run noise.
    assert l_mosa < l_dense * 1.05, (l_mosa, l_dense)


def test_model_flops_match_paper_accounting():
    """configs/mosa_paper wires the Table-5 solver into real configs."""
    cfg = paper_config("tiny", "mosa", sparsity=8)
    assert cfg.mosa.n_mosa_heads == PAPER_MODELS["tiny"].hybrid_mosa_heads(8)
    assert cfg.mosa.n_dense_heads == 4
    cfg_pure = paper_config("tiny", "pure", sparsity=2)
    assert cfg_pure.mosa.n_dense_heads == 0
    assert cfg_pure.mosa.n_mosa_heads == \
        PAPER_MODELS["tiny"].pure_mosa_heads(2)


def test_kv_cache_reduction_at_serve_time():
    """The serving stack realizes the paper's Table-2 KV claim."""
    from repro.core.hybrid import HybridAttention
    # paper Table 2 tiny recipe: ppl-matched = 4 dense + 17 MoSA @ rho=32
    cfg = paper_config("tiny", "mosa", sparsity=32, n_mosa_heads=17)
    hy = HybridAttention(cfg.d_model, cfg.mosa)
    T = 1024
    kv_mosa = hy.kv_total(T)
    kv_dense = T * PAPER_MODELS["tiny"].n_heads
    assert kv_mosa / kv_dense < 0.62       # Table 2 band (-51% w/ 17 heads)

    # and the actual cache arrays agree with the accounting
    caches = hy.init_cache(1, T, jnp.float32)
    entries = caches["sparse"].kv_entries + caches["dense"].k.shape[1] * \
        caches["dense"].k.shape[2]
    assert entries == kv_mosa


def test_sparse_baselines_run_at_matched_flops():
    """Fixed and Routing variants instantiate and train one step."""
    for variant in ("fixed", "routing"):
        cfg = paper_config("tiny", variant, sparsity=8, seq_len=64)
        cfg = dataclasses.replace(cfg, n_layers=2, vocab=256,
                                  pattern=cfg.pattern[:2])
        model = TransformerLM(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        toks = jax.random.randint(key, (2, 33), 2, 256)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        assert np.isfinite(float(loss))
        assert all(not bool(jnp.isnan(g).any())
                   for g in jax.tree.leaves(grads))


def test_long_context_mosa_constant_k():
    """Paper §3.4: constant k as T grows — cost per head stays flat."""
    from repro.configs.base import MoSAConfig
    from repro.core.mosa import MoSAAttention
    cfg = MoSAConfig(n_mosa_heads=2, sparsity=16, n_dense_heads=0, d_head=8,
                     k_fixed=16)
    m = MoSAAttention(32, cfg)
    key = jax.random.PRNGKey(0)
    p = m.init(key)
    for T in (64, 128, 256):
        x = jax.random.normal(key, (1, T, 32))
        y = m(p, x)
        assert y.shape == (1, T, 32)
        assert m.k_for(T) == 16
