"""Behavioural tests of the paper's core mechanism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoSAConfig
from repro.core.kv_cache import MoSAKVCache
from repro.core.mosa import MoSAAttention
from repro.core.router import (ExpertChoiceRouter, select_topk,
                               selection_mask, streaming_topk_update)


def test_select_topk_sorted_and_scored():
    scores = jnp.asarray([[[0.9, 0.1, 0.8, 0.5, 0.7]]])
    r, idx = select_topk(scores, 3, force_first=False)
    assert idx.tolist() == [[[0, 2, 4]]]
    np.testing.assert_allclose(np.asarray(r), [[[0.9, 0.8, 0.7]]])


def test_select_topk_force_first():
    scores = jnp.asarray([[[0.0, 0.9, 0.8, 0.7, 0.6]]])
    r, idx = select_topk(scores, 3, force_first=True)
    assert idx.tolist() == [[[0, 1, 2]]]          # 0 forced despite score 0.0
    np.testing.assert_allclose(np.asarray(r)[0, 0, 0], 0.0)  # true score kept


def test_expert_choice_perfect_load_balance():
    """Every head selects exactly k tokens — no balancing loss needed."""
    key = jax.random.PRNGKey(0)
    B, H, T, k = 3, 8, 64, 16
    router = ExpertChoiceRouter(32, H)
    p = router.init(key)
    x = jax.random.normal(key, (B, T, 32))
    scores = router.scores(p, x)
    r, idx = select_topk(scores, k)
    assert idx.shape == (B, H, k)
    # no duplicate tokens within a head's selection
    for b in range(B):
        for h in range(H):
            sel = np.asarray(idx[b, h])
            assert len(np.unique(sel)) == k


def test_selection_mask_is_causal_on_original_indices():
    idx = jnp.asarray([[[2, 5, 9]]])
    m = selection_mask(idx, idx)[0, 0]
    want = np.tril(np.ones((3, 3), bool))
    np.testing.assert_array_equal(np.asarray(m), want)


def test_mosa_output_zero_at_unselected_positions():
    key = jax.random.PRNGKey(0)
    B, T, h = 1, 32, 16
    cfg = MoSAConfig(n_mosa_heads=2, sparsity=8, n_dense_heads=0, d_head=8,
                     force_first_token=False)
    m = MoSAAttention(h, cfg)
    p = m.init(key)
    x = jax.random.normal(key, (B, T, h))
    y = m(p, x)
    scores = m.router.scores(p["router"], x)
    _, idx = select_topk(scores, m.k_for(T), False)
    selected = np.zeros(T, bool)
    selected[np.asarray(idx).reshape(-1)] = True
    y_np = np.asarray(y)[0]
    assert np.abs(y_np[~selected]).max() == 0.0
    assert np.abs(y_np[selected]).max() > 0.0


def test_mosa_router_gradient_flows():
    key = jax.random.PRNGKey(0)
    cfg = MoSAConfig(n_mosa_heads=4, sparsity=4, n_dense_heads=0, d_head=8)
    m = MoSAAttention(16, cfg)
    p = m.init(key)
    x = jax.random.normal(key, (2, 32, 16))
    g = jax.grad(lambda p_: jnp.sum(m(p_, x) ** 2))(p)
    assert float(jnp.abs(g["router"]["w"]).max()) > 0.0


def test_mosa_complexity_k_for():
    cfg = MoSAConfig(n_mosa_heads=1, sparsity=32, n_dense_heads=0, d_head=8)
    m = MoSAAttention(16, cfg)
    assert m.k_for(1024) == 32
    assert m.k_for(4096) == 128
    assert m.k_for(16) == 2          # min_k floor (paper §3.5)
    m2 = MoSAAttention(16, MoSAConfig(n_mosa_heads=1, sparsity=32,
                                      n_dense_heads=0, d_head=8, k_fixed=64))
    assert m2.k_for(524288) == 64    # paper §3.4: constant k on long seqs


def test_streaming_topk_update():
    scores = jnp.asarray([[-jnp.inf, -jnp.inf, -jnp.inf]])
    idx = jnp.asarray([[-1, -1, -1]])
    # fill three slots
    for t, s in enumerate([0.5, 0.2, 0.8]):
        sel, slot, scores, idx = streaming_topk_update(
            scores, idx, jnp.asarray([s]), t, jnp.asarray(False))
        assert bool(sel[0])
    # score below the min -> rejected
    sel, _, scores, idx = streaming_topk_update(
        scores, idx, jnp.asarray([0.1]), 3, jnp.asarray(False))
    assert not bool(sel[0])
    # score above the min -> evicts the min (0.2 at slot 1)
    sel, slot, scores, idx = streaming_topk_update(
        scores, idx, jnp.asarray([0.6]), 4, jnp.asarray(False))
    assert bool(sel[0]) and int(slot[0]) == 1
    assert int(idx[0, 1]) == 4
    # forced insertion regardless of score
    sel, _, scores, idx = streaming_topk_update(
        scores, idx, jnp.asarray([-5.0]), 5, jnp.asarray(True))
    assert bool(sel[0])


def test_mosa_decode_kv_cache_is_constant_size():
    """The paper's KV-cache claim: cache stays at k entries per head."""
    key = jax.random.PRNGKey(0)
    B, T, h, H, k = 1, 40, 16, 3, 8
    cfg = MoSAConfig(n_mosa_heads=H, sparsity=5, n_dense_heads=0, d_head=8)
    m = MoSAAttention(h, cfg)
    p = m.init(key)
    x = jax.random.normal(key, (B, T, h))
    cache = MoSAKVCache.create(B, H, k, 8, jnp.float32)
    for t in range(T):
        y, cache = m.decode_step(p, x[:, t:t + 1], cache)
    assert cache.k.shape == (B, H, k, 8)          # never grew
    assert int(cache.length[0]) == T
    assert cache.kv_entries == H * k
    # all cached indices are valid past positions
    assert int(cache.idx.max()) < T


def test_mosa_streaming_decode_approximates_training_selection():
    """Streaming top-k keeps high-score tokens: the final cached set should
    contain most of the (non-autoregressive) training-time top-k."""
    key = jax.random.PRNGKey(3)
    B, T, h, H, k = 1, 64, 16, 2, 8
    cfg = MoSAConfig(n_mosa_heads=H, sparsity=8, n_dense_heads=0, d_head=8,
                     force_first_token=False)
    m = MoSAAttention(h, cfg)
    p = m.init(key)
    x = jax.random.normal(key, (B, T, h))
    cache = MoSAKVCache.create(B, H, k, 8, jnp.float32)
    for t in range(T):
        _, cache = m.decode_step(p, x[:, t:t + 1], cache)
    scores = m.router.scores(p["router"], x)
    _, idx_train = select_topk(scores, k, False)
    # streaming top-k over per-token scores == exact top-k (scores are causal)
    got = set(np.asarray(cache.idx[0, 0]).tolist())
    want = set(np.asarray(idx_train[0, 0]).tolist())
    assert got == want


def test_mosa_prefill_matches_training_selection():
    key = jax.random.PRNGKey(4)
    B, T, h, H = 1, 32, 16, 2
    cfg = MoSAConfig(n_mosa_heads=H, sparsity=4, n_dense_heads=0, d_head=8)
    m = MoSAAttention(h, cfg)
    p = m.init(key)
    x = jax.random.normal(key, (B, T, h))
    cache = MoSAKVCache.create(B, H, m.k_for(T), 8, jnp.float32)
    y, cache = m.prefill(p, x, cache)
    y_train = m(p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_train), atol=1e-5)
    scores = m.router.scores(p["router"], x)
    _, idx = select_topk(scores, m.k_for(T), True)
    np.testing.assert_array_equal(np.asarray(cache.idx), np.asarray(idx))
