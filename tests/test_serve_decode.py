"""Fused decode path, MoSA streaming invariants, cache sharding specs,
continuous batching, and DESIGN.md reference integrity (PR 2)."""

import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoSAConfig, get_config
from repro.core.kv_cache import DenseKVCache, MoSAKVCache, WindowKVCache
from repro.core.mosa import MoSAAttention
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.nn.transformer import TransformerLM

REPO = Path(__file__).resolve().parent.parent


# ------------------------------------------------------------ fused decode
def _fused(model):
    return jax.jit(model.decode_many,
                   static_argnames=("n", "temperature", "top_k",
                                    "return_logits"))


def test_fused_decode_logits_match_full_forward():
    """Prefill + N fused decode steps == one full forward (dense caches)."""
    cfg = get_config("qwen2-1.5b", preset="smoke")
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, P, G = 2, 10, 6
    prompts = jax.random.randint(key, (B, P), 2, cfg.vocab)

    caches = model.init_cache(B, P + G, jnp.float32)
    lp, caches = model.prefill(params, prompts, caches)
    tok0 = jnp.argmax(lp[:, -1], -1).astype(jnp.int32)[:, None]
    toks, logits, _ = _fused(model)(params, tok0, caches, None, n=G,
                                    return_logits=True)
    assert toks.shape == (B, G) and logits.shape[:2] == (B, G)

    # Teacher-force the full forward with the prompt + the tokens the fused
    # decoder actually consumed; step j's logits live at position P-1+j+1.
    full_in = jnp.concatenate([prompts, tok0, toks[:, :-1]], axis=1)
    logits_full, _ = model(params, full_in)
    for j in range(G):
        np.testing.assert_allclose(
            np.asarray(logits[:, j], np.float32),
            np.asarray(logits_full[:, P + j], np.float32),
            atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch,kw", [("qwen2-1.5b", {}),
                                     ("mosa-paper", {"variant": "mosa"}),
                                     ("jamba-v0.1-52b", {})])
def test_fused_decode_matches_stepwise(arch, kw):
    """The scan-fused chunk emits exactly the per-token loop's tokens."""
    cfg = get_config(arch, preset="smoke", **kw)
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, P, G = 2, 8, 5
    prompts = jax.random.randint(key, (B, P), 2, cfg.vocab)

    caches = model.init_cache(B, 32, jnp.float32)
    lp, c0 = model.prefill(params, prompts, caches)
    tok0 = jnp.argmax(lp[:, -1], -1).astype(jnp.int32)[:, None]

    tok, cs, step = tok0, c0, []
    for _ in range(G):
        lg, cs = model.decode_step(params, tok, cs)
        tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        step.append(tok)
    caches = model.init_cache(B, 32, jnp.float32)
    _, c0 = model.prefill(params, prompts, caches)
    fused, _ = _fused(model)(params, tok0, c0, None, n=G)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(step, 1)),
                                  np.asarray(fused))


# --------------------------------------------------- MoSA streaming decode
def _mosa_layer(k_fixed=0, sparsity=4):
    cfg = MoSAConfig(n_mosa_heads=3, sparsity=sparsity, n_dense_heads=0,
                     d_head=8, k_fixed=k_fixed)
    return MoSAAttention(64, cfg), cfg


def test_mosa_streaming_cache_invariants():
    """kv_entries constant in T; idx entries valid, unique, sorted."""
    layer, c = _mosa_layer(k_fixed=6)
    key = jax.random.PRNGKey(0)
    params = layer.init(key)
    B, P, G = 2, 12, 10
    x = jax.random.normal(key, (B, P + G, 64), jnp.float32)

    cache = MoSAKVCache.create(B, c.n_mosa_heads, 6, c.d_head, jnp.float32)
    entries0 = cache.kv_entries
    _, cache = layer.prefill(params, x[:, :P], cache)
    for t in range(P, P + G):
        _, cache = layer.decode_step(params, x[:, t:t + 1], cache)
        assert cache.kv_entries == entries0          # O(k), never grows
        idx = np.asarray(cache.idx)
        assert idx.shape == (B, c.n_mosa_heads, 6)
        for b in range(B):
            for h in range(c.n_mosa_heads):
                row = idx[b, h]
                valid = row[row >= 0]
                assert (valid <= t).all()                    # positions seen
                assert len(np.unique(valid)) == len(valid)   # no duplicates
                assert (np.diff(valid) > 0).all()            # sorted ascending
                # empty slots (-1) only after the valid prefix
                assert (row[len(valid):] == -1).all()
    assert int(cache.length[0]) == P + G


def test_mosa_streaming_k_equals_T_matches_training():
    """With k = T nothing is ever evicted: streaming decode reproduces the
    training-style (non-autoregressive) selection exactly."""
    T = 10
    layer, c = _mosa_layer(k_fixed=T)
    key = jax.random.PRNGKey(1)
    params = layer.init(key)
    B, P = 2, 4
    x = jax.random.normal(key, (B, T, 64), jnp.float32)

    y_train = layer(params, x)                       # (B, T, 64)
    cache = MoSAKVCache.create(B, c.n_mosa_heads, T, c.d_head, jnp.float32)
    _, cache = layer.prefill(params, x[:, :P], cache)
    for t in range(P, T):
        y_t, cache = layer.decode_step(params, x[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(y_t[:, 0], np.float32),
                                   np.asarray(y_train[:, t], np.float32),
                                   atol=2e-4, rtol=2e-4)
    # every position ended up cached, in order
    np.testing.assert_array_equal(
        np.asarray(cache.idx),
        np.broadcast_to(np.arange(T), (B, c.n_mosa_heads, T)))


def test_mosa_decode_per_row_positions():
    """Rows at different sequence offsets decode with their own positions
    (continuous batching): a row's result is independent of its batchmates."""
    layer, c = _mosa_layer(k_fixed=5)
    key = jax.random.PRNGKey(2)
    params = layer.init(key)
    x = jax.random.normal(key, (2, 9, 64), jnp.float32)

    # batch of two rows prefilled at different lengths
    ca = MoSAKVCache.create(1, c.n_mosa_heads, 5, c.d_head, jnp.float32)
    cb = MoSAKVCache.create(1, c.n_mosa_heads, 5, c.d_head, jnp.float32)
    _, ca = layer.prefill(params, x[:1, :8], ca)
    _, cb = layer.prefill(params, x[1:, :3], cb)
    joint = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), ca, cb)
    y_joint, joint2 = layer.decode_step(params, x[:, 8:9], joint)
    y_solo, ca2 = layer.decode_step(params, x[:1, 8:9], ca)
    np.testing.assert_allclose(np.asarray(y_joint[:1], np.float32),
                               np.asarray(y_solo, np.float32),
                               atol=1e-5, rtol=1e-5)
    assert int(joint2.length[0]) == 9 and int(joint2.length[1]) == 4


def test_window_decode_parity_past_window():
    """Prompt longer than the window: prefill's slot layout must match
    append_one's ring arithmetic (slot = position % W) so decode evicts the
    oldest token and matches the full windowed forward at every step."""
    from repro.configs.base import AttentionConfig
    from repro.core.attention import MultiHeadAttention
    W = 4
    acfg = AttentionConfig(kind="gqa", n_heads=2, n_kv_heads=2, d_head=8,
                           window=W)
    mha = MultiHeadAttention(32, acfg, impl="naive")
    key = jax.random.PRNGKey(5)
    params = mha.init(key)
    B, P, T = 2, 6, 11
    x = jax.random.normal(key, (B, T, 32), jnp.float32)
    y_full = mha(params, x)

    cache = WindowKVCache.create(B, W, 2, 8, jnp.float32)
    y_pre, cache = mha.prefill(params, x[:, :P], cache)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :P]),
                               atol=1e-4, rtol=1e-4)
    for t in range(P, T):
        y_t, cache = mha.decode_step(params, x[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                                   np.asarray(y_full[:, t]),
                                   atol=1e-4, rtol=1e-4, err_msg=f"step {t}")
        pos = np.asarray(cache.positions)
        for b in range(B):   # ring holds exactly the last W positions
            assert sorted(pos[b]) == list(range(t - W + 1, t + 1)), (t, pos[b])


def test_window_cache_per_row_slots():
    """Ring-buffer slots are per-row (length % W row by row)."""
    cache = WindowKVCache.create(2, 4, 1, 8, jnp.float32)
    cache = cache._replace(length=jnp.asarray([5, 0], jnp.int32))
    k = jnp.ones((2, 1, 8), jnp.float32)
    cache = cache.append_one(k, k)
    pos = np.asarray(cache.positions)
    assert pos[0, 5 % 4] == 5 and pos[1, 0] == 0
    np.testing.assert_array_equal(np.asarray(cache.length), [6, 1])


# ------------------------------------------------------- cache sharding
def test_mosa_cache_head_dim_shards_over_model():
    """Acceptance: under the ``tp`` rule set the MoSA cache head dim maps to
    the ``model`` mesh axis (head-parallel decode, DESIGN §6)."""
    mesh = make_host_mesh(tp=1)
    cache = jax.eval_shape(
        lambda: MoSAKVCache.create(2, 4, 8, 16, jnp.float32))
    spec = shd.cache_spec(cache, mesh, "tp")
    assert spec.k[1] == "model" and spec.v[1] == "model"
    assert spec.scores[1] == "model" and spec.idx[1] == "model"
    # and through the full tree path, stacked caches shift by the layer axis
    stacked = jax.eval_shape(lambda: jax.tree.map(
        lambda t: jnp.zeros((3,) + t.shape, t.dtype), cache))
    sh = shd.cache_shardings({"scan": {"pos0": stacked}}, mesh, "tp")
    assert sh["scan"]["pos0"].k.spec[2] == "model"


def test_dense_cache_spec_seq_vs_heads():
    mesh = make_host_mesh(tp=1)
    cache = jax.eval_shape(
        lambda: DenseKVCache.create(2, 32, 4, 16, jnp.float32))
    spec = shd.cache_spec(cache, mesh, "tp")
    assert len(spec.k) >= 3 and spec.k[2] == "model"   # kv_heads -> model
    seq = shd.cache_spec(cache, mesh, "tp", seq_sharded=True)
    assert seq.k[1] == "model"                         # seq wins...
    assert len(seq.k) < 3 or seq.k[2] is None          # ...heads replicate


def test_cache_shardings_cover_every_arch():
    mesh = make_host_mesh(tp=1)
    for arch in ("gemma3-4b", "deepseek-v2-lite-16b", "jamba-v0.1-52b",
                 "xlstm-125m"):
        cfg = get_config(arch, preset="smoke")
        model = TransformerLM(cfg)
        shapes = jax.eval_shape(lambda: model.init_cache(2, 32, jnp.float32))
        sh = shd.cache_shardings(shapes, mesh, "tp")
        assert jax.tree.structure(shapes) == jax.tree.structure(
            jax.tree.map(lambda x: 0, sh)), arch


# --------------------------------------------------- continuous batching
def test_request_pool_honors_eos_and_max_steps():
    from repro.launch.serve import RequestPool, Server
    cfg = get_config("qwen2-1.5b", preset="smoke")
    server = Server(cfg, batch=2, max_len=32)
    key = jax.random.PRNGKey(3)
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (6,), 2,
                                  cfg.vocab) for i in range(3)]

    # discover a token greedy decode emits, then use it as EOS
    probe = RequestPool(server)
    for pr in prompts:
        probe.submit(pr, max_new=8)
    ref = probe.run()
    assert all(len(v) == 8 for v in ref.values())      # eos<0: no early stop
    eos = int(ref[0][2])

    pool = RequestPool(server, eos=eos)
    for pr in prompts:
        pool.submit(pr, max_new=8)
    out = pool.run()
    assert set(out) == {0, 1, 2}
    assert len(out[0]) <= 8 and int(out[0][-1]) == eos
    for rid, toks in out.items():                       # eos at most once, last
        t = np.asarray(toks)
        assert (t[:-1] != eos).all()

    # max_steps caps total decode work but still returns partial results
    pool2 = RequestPool(server, chunk=2)
    for pr in prompts[:2]:
        pool2.submit(pr, max_new=12)
    partial = pool2.run(max_steps=3)
    assert all(1 <= len(v) <= 4 for v in partial.values())


def test_request_pool_mixed_lengths_refill():
    """More requests than slots, different prompt lengths: everything is
    served to its own max_new."""
    from repro.launch.serve import RequestPool, Server
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa")
    server = Server(cfg, batch=2, max_len=64)
    pool = RequestPool(server, chunk=4)
    key = jax.random.PRNGKey(4)
    want = {}
    for i in range(4):
        n = 3 + i
        rid = pool.submit(jax.random.randint(jax.random.fold_in(key, i),
                                             (5 + 2 * i,), 2, cfg.vocab),
                          max_new=n)
        want[rid] = n
    out = pool.run()
    assert {k: len(v) for k, v in out.items()} == want


# ------------------------------------------------------------ docs
def test_design_references_resolve():
    """Every ``DESIGN §N`` / ``DESIGN.md §N`` citation in src/ names a real
    section of DESIGN.md."""
    design = (REPO / "DESIGN.md").read_text()
    sections = set(re.findall(r"^#+\s*§([\w-]+)", design, re.M))
    assert sections, "DESIGN.md has no §-numbered sections"
    refs = []
    for py in (REPO / "src").rglob("*.py"):
        for m in re.finditer(r"DESIGN(?:\.md)?\s*§([\w-]+)", py.read_text()):
            refs.append((py.name, m.group(1)))
    assert refs, "no DESIGN references found in src/ (regex broken?)"
    missing = [(f, s) for f, s in refs if s not in sections]
    assert not missing, f"unresolved DESIGN references: {missing}"


def test_bench_serve_artifact_tracks_acceptance():
    """BENCH_serve.json exists and records the PR's acceptance numbers."""
    import json
    path = REPO / "BENCH_serve.json"
    assert path.exists(), "run `make bench-smoke`"
    res = json.loads(path.read_text())
    assert res["config"]["max_len"] >= 256
    v = res["variants"]
    assert v["mosa"]["cache_bytes"] < v["dense"]["cache_bytes"]
    # The PR-2 artifact records 2.9-4.3x; the regression gate is looser
    # because the exact ratio is hardware-dependent (dispatch overhead vs
    # the shrunken model's compute varies across CI machines).
    for r in v.values():
        assert r["fused_speedup"] >= 1.5, r
