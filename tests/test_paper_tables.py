"""Exact reproduction of the paper's quantitative skeleton (Tables 2/4/5)."""

import pytest

from repro.core.flops import (PAPER_MODELS, TABLE4_GFLOPS,
                              TABLE5_HYBRID_HEADS, TABLE5_PURE_HEADS,
                              flops_dense_head, flops_fixed_head,
                              flops_mosa_head, flops_routing_head)


@pytest.mark.parametrize("size", ["tiny", "small", "large"])
def test_table4_forward_flops_exact(size):
    got = PAPER_MODELS[size].dense_flops(1024) / 1e9
    assert abs(got - TABLE4_GFLOPS[size]) < 0.005, (size, got)


def test_table4_medium_known_discrepancy():
    """Medium is architecturally exactly 2x small (18L vs 9L, same h/ff/heads)
    so its FLOPs must be 2*219.85 = 439.70G; the paper prints 430.70G —
    a likely typo we document rather than reproduce."""
    got = PAPER_MODELS["medium"].dense_flops(1024) / 1e9
    assert abs(got - 2 * TABLE4_GFLOPS["small"]) < 0.01
    assert abs(got - TABLE4_GFLOPS["medium"]) > 8.0  # the paper's printed value


@pytest.mark.parametrize("size", list(TABLE5_HYBRID_HEADS))
def test_table5_hybrid_head_counts_exact(size):
    want = TABLE5_HYBRID_HEADS[size]
    got = {s: PAPER_MODELS[size].hybrid_mosa_heads(s) for s in want}
    assert got == want


@pytest.mark.parametrize("size", list(TABLE5_PURE_HEADS))
def test_table5_pure_head_counts(size):
    want = TABLE5_PURE_HEADS[size]
    got = {s: PAPER_MODELS[size].pure_mosa_heads(s) for s in want}
    assert got == want


def test_table2_kv_cache_reduction():
    """KV = T*H_dense + k*H_mosa reproduces Table 2's KV column."""
    T = 1024
    # Tiny: dense 9 heads -> 9.2K; MoSA 4 dense + 17 sparse @ rho=32 -> 4.5K
    dense = PAPER_MODELS["tiny"].kv_total(T, 9, 0, 32)
    mosa = PAPER_MODELS["tiny"].kv_total(T, 4, 17, 32)
    assert round(dense / 1000, 1) == 9.2
    assert round(mosa / 1000, 1) == 4.6  # 4*1024 + 17*32 = 4640
    # Large: dense 16 heads -> 16.4K; MoSA 4 + 16 @ rho=16 -> 5.1K
    dense_l = PAPER_MODELS["large"].kv_total(T, 16, 0, 16)
    mosa_l = PAPER_MODELS["large"].kv_total(T, 4, 16, 16)
    assert round(dense_l / 1000, 1) == 16.4
    assert round(mosa_l / 1000, 1) == 5.1
    # headline claim: >50% reduction
    assert mosa / dense < 0.51
    assert mosa_l / dense_l < 0.32


def test_mosa_head_flops_dominated_by_projections_at_high_sparsity():
    """At k << T the MoSA head is ~T-linear (O(k^2 + T) claim)."""
    T, h, hp = 4096, 1024, 64
    k = 64
    f = flops_mosa_head(T, k, h, hp)
    proj = 8 * h * hp * k
    attn = 4 * hp * k * k
    routing = 2 * h * T + hp * k
    assert f == proj + attn + routing
    assert attn / f < 0.05           # attention negligible at rho=64
    dense = flops_dense_head(T, h, hp)
    assert f < dense / 25            # >25x cheaper per head


def test_routing_head_costs_rho_mosa_heads():
    """Paper: one Routing head ~ rho fixed/MoSA heads FLOP-wise."""
    T, h, hp, rho = 1024, 512, 64, 8
    k = T // rho
    ratio = flops_routing_head(T, k, h, hp) / flops_fixed_head(T, k, h, hp)
    assert rho * 0.6 < ratio < rho * 1.05


def test_isoflop_never_exceeds_budget():
    for size, pm in PAPER_MODELS.items():
        budget = pm.n_heads * flops_dense_head(1024, pm.h, pm.hp)
        for rho in (2, 4, 8, 16, 32):
            n = pm.hybrid_mosa_heads(rho)
            spent = 4 * flops_dense_head(1024, pm.h, pm.hp) + \
                n * flops_mosa_head(1024, 1024 // rho, pm.h, pm.hp)
            assert spent <= budget
            # and adding one more head would exceed it
            spent1 = spent + flops_mosa_head(1024, 1024 // rho, pm.h, pm.hp)
            assert spent1 > budget
