"""Block-choice MoSA (DESIGN §10), locked down.

Two contracts, two standards of proof:

* ``sel_block_size=1`` ≡ token-choice is maintained BITWISE on same-shaped
  graphs — kernel, layer ``__call__``, LM loss, fwd AND bwd, fp32 and bf16,
  einsum and pallas.  ``==``, not allclose.
* Serving paths (different graph shapes, where XLA's shape-dependent GEMM
  codegen makes float bit-equality the wrong contract) use the repo's
  established standard: integer selection state ``assert_array_equal``,
  floats to tight tolerances, scheduler-emitted token ids
  ``assert_array_equal``.

Plus the property layer (random k-schedules, random pool op sequences) via
``_property_harness`` — real hypothesis when installed, vendored fallback
otherwise; these never skip.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _property_harness import given, settings, st  # noqa: E402

from repro.configs.base import BlockSpec, MoSAConfig, get_config
from repro.core.kv_cache import MoSABlockKVCache, MoSAKVCache
from repro.core.mosa import MoSAAttention
from repro.core.router import (block_pool_scores, expand_block_index,
                               select_topk, streaming_topk_update)
from repro.kernels import ops, ref

SETTINGS = dict(max_examples=20, deadline=None)


def tok_blk_pair(impl="einsum", dtype=jnp.float32, bs=1, d_model=32,
                 n_heads=2, d_head=8, sparsity=4, window=0, dense=0):
    """A token-choice / block-choice MoSAAttention pair sharing params
    (the param tree is granularity-independent)."""
    base = dict(n_mosa_heads=n_heads, n_dense_heads=dense, d_head=d_head,
                sparsity=sparsity, local_window=window, impl=impl)
    ct = MoSAConfig(selection_granularity="token", **base)
    cb = MoSAConfig(selection_granularity="block", sel_block_size=bs, **base)
    mt = MoSAAttention(d_model, ct, compute_dtype=dtype, impl=impl)
    mb = MoSAAttention(d_model, cb, compute_dtype=dtype, impl=impl)
    p = mt.init(jax.random.PRNGKey(0))
    return mt, mb, p


def assert_trees_bitwise(a, b, msg=""):
    for pa, pb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert (np.asarray(pa) == np.asarray(pb)).all(), msg


# ---------------------------------------------------- bs=1 bitwise invariant
@pytest.mark.parametrize("impl", ["einsum", "pallas"])
def test_bs1_kernel_bitwise_equals_token_kernel(impl):
    """ops.mosa_block_attention at sel_block_size=1 IS ops.mosa_attention,
    bit for bit — identical block index/score inputs, identical output."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    B, H, S, d, T = 2, 3, 8, 16, 32
    q = jax.random.normal(ks[0], (B, H, S, d))
    k = jax.random.normal(ks[1], (B, H, S, d))
    v = jax.random.normal(ks[2], (B, H, S, d))
    idx = jnp.sort(jnp.stack([
        jnp.stack([jax.random.permutation(
            jax.random.fold_in(ks[3], b * H + h), T)[:S]
            for h in range(H)]) for b in range(B)]), -1).astype(jnp.int32)
    r = jax.nn.sigmoid(jax.random.normal(ks[4], (B, H, S)))
    if impl == "pallas":
        tok = ops.mosa_attention(q, k, v, idx, r)
        blk = ops.mosa_block_attention(q, k, v, idx, r,
                                       sel_block_size=1, T=T)
    else:
        tok = ref.mosa_attention_ref(q, k, v, idx, r)
        blk = ref.mosa_block_attention_ref(q, k, v, idx, r, 1, T)
    assert (np.asarray(tok) == np.asarray(blk)).all()


@pytest.mark.parametrize("impl,dtype", [
    ("einsum", jnp.float32), ("einsum", jnp.bfloat16),
    ("pallas", jnp.float32), ("pallas", jnp.bfloat16)])
def test_bs1_layer_bitwise_fwd_bwd(impl, dtype):
    """The maintained invariant at the layer level: block-choice with
    one-token blocks reproduces token-choice __call__ bit-for-bit — output
    AND every parameter gradient — plain, right-padded, and packed rows."""
    mt, mb, p = tok_blk_pair(impl=impl, dtype=dtype)
    B, T = 2, 16
    x = (jax.random.normal(jax.random.PRNGKey(2), (B, T, 32)) * 0.5
         ).astype(dtype)

    def loss(m):
        return lambda p_, **kw: jnp.sum(m(p_, x, **kw).astype(jnp.float32)
                                        ** 2)

    # plain
    assert (np.asarray(mt(p, x)) == np.asarray(mb(p, x))).all()
    gt = jax.grad(loss(mt))(p)
    gb = jax.grad(loss(mb))(p)
    assert_trees_bitwise(gt, gb, f"plain grad {impl}/{dtype}")

    # right-padded (bucketed serving prefill)
    valid = jnp.broadcast_to(jnp.arange(T)[None] < 11, (B, T))
    assert (np.asarray(mt(p, x, valid=valid))
            == np.asarray(mb(p, x, valid=valid))).all()

    # packed rows: two documents back to back, per-doc positions
    segs = jnp.broadcast_to((jnp.arange(T) >= 10).astype(jnp.int32), (B, T))
    pos = jnp.broadcast_to(jnp.where(jnp.arange(T) < 10, jnp.arange(T),
                                     jnp.arange(T) - 10), (B, T))
    yt = mt(p, x, positions=pos, segments=segs)
    yb = mb(p, x, positions=pos, segments=segs)
    assert (np.asarray(yt) == np.asarray(yb)).all()
    gt = jax.grad(lambda p_: jnp.sum(
        mt(p_, x, positions=pos, segments=segs).astype(jnp.float32) ** 2))(p)
    gb = jax.grad(lambda p_: jnp.sum(
        mb(p_, x, positions=pos, segments=segs).astype(jnp.float32) ** 2))(p)
    assert_trees_bitwise(gt, gb, f"packed grad {impl}/{dtype}")


def test_bs1_lm_loss_bitwise():
    """End to end: the LM loss and its full gradient tree are bitwise
    identical between token-choice and block-choice(bs=1) configs."""
    from repro.nn.transformer import TransformerLM
    cfgs = {}
    for gran in ("token", "block"):
        cfg = get_config("mosa-paper", preset="smoke", variant="mosa",
                         sparsity=4, selection_granularity=gran,
                         sel_block_size=1)
        cfgs[gran] = dataclasses.replace(cfg, n_layers=2)
    mt, mb = TransformerLM(cfgs["token"]), TransformerLM(cfgs["block"])
    params = mt.init(jax.random.PRNGKey(3))
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, T + 1), 2,
                              cfgs["token"].vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    lt = mt.loss(params, batch)[0]
    gt = jax.grad(lambda p: mt.loss(p, batch)[0])(params)
    lb = mb.loss(params, batch)[0]
    gb = jax.grad(lambda p: mb.loss(p, batch)[0])(params)
    assert float(lt) == float(lb)
    assert_trees_bitwise(gt, gb, "LM grads")


def test_block_gated_hybrid_form():
    """Block-choice + sliding-window dense side blends the branches with
    learned sigmoid gates (zero-init -> exactly the halved sum); token
    configs keep the plain head-sum with no gate parameter, and windowless
    block configs stay ungated (bitwise invariant preserved)."""
    from repro.core.hybrid import HybridAttention
    D = 32
    base = dict(n_mosa_heads=2, n_dense_heads=2, d_head=8, sparsity=4,
                min_k=4)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 12, D)) * 0.3

    cb = MoSAConfig(selection_granularity="block", sel_block_size=4,
                    local_window=8, **base)
    hb = HybridAttention(D, cb)
    p = hb.init(jax.random.PRNGKey(0))
    assert "gate" in p and p["gate"].shape == (D, 2)
    y = hb(p, x)
    ys = hb._sparse()(p["sparse"], x, None)
    yd = hb._dense()(p["dense"], x, None)
    np.testing.assert_allclose(np.asarray(y),
                               0.5 * (np.asarray(ys) + np.asarray(yd)),
                               atol=1e-6)

    ct = MoSAConfig(selection_granularity="token", local_window=8, **base)
    assert "gate" not in HybridAttention(D, ct).init(jax.random.PRNGKey(0))

    # windowless block config: ungated, bitwise == token at bs=1
    cb1 = MoSAConfig(selection_granularity="block", sel_block_size=1, **base)
    ct1 = MoSAConfig(selection_granularity="token", **base)
    hb1, ht1 = HybridAttention(D, cb1), HybridAttention(D, ct1)
    pb1 = hb1.init(jax.random.PRNGKey(0))
    assert "gate" not in pb1
    assert (np.asarray(hb1(pb1, x))
            == np.asarray(ht1(ht1.init(jax.random.PRNGKey(0)), x))).all()


# ------------------------------------------------- block kernels vs oracle
def _block_inputs(key, B, H, kb, bs, T, two_docs=False):
    ks = jax.random.split(key, 5)
    S = kb * bs
    NB = T // bs
    q = jax.random.normal(ks[0], (B, H, S, 16))
    k = jax.random.normal(ks[1], (B, H, S, 16))
    v = jax.random.normal(ks[2], (B, H, S, 16))
    bidx = jnp.sort(jnp.stack([
        jnp.stack([jax.random.permutation(
            jax.random.fold_in(ks[3], b * H + h), NB)[:kb]
            for h in range(H)]) for b in range(B)]), -1).astype(jnp.int32)
    rblk = jax.nn.sigmoid(jax.random.normal(ks[4], (B, H, kb)))
    seg = None
    if two_docs:
        pos = expand_block_index(bidx, bs, T)
        seg = jnp.where(jnp.clip(pos, 0) < T // 2, 0, 1).astype(jnp.int32)
    return q, k, v, bidx, rblk, seg


@pytest.mark.parametrize("bs,kb", [(4, 5), (16, 2)])
def test_block_kernel_matches_oracle(bs, kb):
    B, H, T = 2, 2, 16 * max(bs // 4, 1) * 4
    for two_docs in (False, True):
        q, k, v, bidx, rblk, seg = _block_inputs(
            jax.random.PRNGKey(6 + bs), B, H, kb, bs, T, two_docs)
        got = ops.mosa_block_attention(q, k, v, bidx, rblk,
                                       sel_block_size=bs, T=T, seg=seg)
        want = ref.mosa_block_attention_ref(q, k, v, bidx, rblk, bs, T,
                                            seg=seg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5,
                                   err_msg=f"bs={bs} two_docs={two_docs}")


@pytest.mark.parametrize("bs", [4, 16])
def test_block_layer_pallas_matches_einsum(bs):
    """Layer-level fwd + full-grad agreement between the fused Pallas path
    and the einsum reference at real block sizes, incl. packed segments."""
    cfg = MoSAConfig(n_mosa_heads=2, n_dense_heads=0, d_head=8, sparsity=2,
                     selection_granularity="block", sel_block_size=bs)
    me = MoSAAttention(32, cfg, impl="einsum")
    mp = MoSAAttention(32, cfg, impl="pallas")
    p = me.init(jax.random.PRNGKey(7))
    B, T = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(8), (B, T, 32)) * 0.5
    segs = jnp.broadcast_to((jnp.arange(T) >= 20).astype(jnp.int32), (B, T))
    pos = jnp.broadcast_to(jnp.where(jnp.arange(T) < 20, jnp.arange(T),
                                     jnp.arange(T) - 20), (B, T))
    for kw in ({}, {"positions": pos, "segments": segs}):
        np.testing.assert_allclose(
            np.asarray(me(p, x, **kw)), np.asarray(mp(p, x, **kw)),
            atol=3e-5, rtol=3e-5)
        ge = jax.grad(lambda p_: jnp.sum(me(p_, x, **kw) ** 2))(p)
        gp = jax.grad(lambda p_: jnp.sum(mp(p_, x, **kw) ** 2))(p)
        for a, b in zip(jax.tree_util.tree_leaves(ge),
                        jax.tree_util.tree_leaves(gp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-4, rtol=3e-4)


# ---------------------------------------------------- serving consistency
def test_bs1_decode_matches_token_decode():
    """Streaming block decode with one-token blocks follows token-choice
    decode: identical selection state (integer), tight-allclose outputs
    (the candidate layouts differ in shape — see the module docstring)."""
    key = jax.random.PRNGKey(9)
    B, T, D, H, kcap = 1, 12, 32, 2, 6
    mt, mb, p = tok_blk_pair()
    x = jax.random.normal(key, (B, T + 6, D)) * 0.5
    ct = MoSAKVCache.create(B, H, kcap, 8, jnp.float32)
    cb = MoSABlockKVCache.create(B, H, kcap, 1, 8, jnp.float32)
    yt, ct = mt.prefill(p, x[:, :T], ct)
    yb, cb = mb.prefill(p, x[:, :T], cb)
    assert (np.asarray(yt) == np.asarray(yb)).all()   # same-shape graph
    for t in range(6):
        xt = x[:, T + t:T + t + 1]
        ot, ct = mt.decode_step(p, xt, ct)
        ob, cb = mb.decode_step(p, xt, cb)
        np.testing.assert_allclose(np.asarray(ot), np.asarray(ob),
                                   atol=2e-5, rtol=2e-5, err_msg=f"t={t}")
        got = np.sort(np.asarray(cb.bidx)[..., :kcap], -1)
        want = np.sort(np.asarray(ct.idx), -1)
        np.testing.assert_array_equal(got, want, err_msg=f"t={t}")
    np.testing.assert_allclose(
        np.sort(np.asarray(cb.bscore)[..., :kcap], -1),
        np.sort(np.asarray(ct.scores), -1), atol=1e-6)


@pytest.mark.parametrize("bs", [2, 4])
def test_block_decode_candidates_match_exact_topk(bs):
    """After streaming a whole sequence through decode, the candidate set
    equals the EXACT top-CB over completed-block mean scores — the
    streaming policy loses nothing.  (force_first_token off: streaming
    forcing is insertion-only — block 0 enters when it completes but can
    be evicted later, exactly like token-choice streaming.)"""
    key = jax.random.PRNGKey(10)
    B, T, D, H, CB = 1, 22, 32, 2, 3
    cfg = MoSAConfig(n_mosa_heads=H, n_dense_heads=0, d_head=8, sparsity=4,
                     force_first_token=False,
                     selection_granularity="block", sel_block_size=bs)
    m = MoSAAttention(D, cfg)
    p = m.init(key)
    x = jax.random.normal(key, (B, T, D)) * 0.5
    cache = MoSABlockKVCache.create(B, H, CB, bs, 8, jnp.float32)
    for t in range(T):
        _, cache = m.decode_step(p, x[:, t:t + 1], cache)
    assert int(cache.length[0]) == T

    scores = np.asarray(m.router.scores(p["router"], x))      # (B,H,T)
    ncb = T // bs
    means = scores[..., :ncb * bs].reshape(B, H, ncb, bs).mean(-1)
    for b in range(B):
        for h in range(H):
            want = set(np.argsort(means[b, h])[::-1][:CB].tolist())
            got = set(int(i) for i in np.asarray(cache.bidx)[b, h, :CB]
                      if i >= 0)
            assert got == want, (b, h, got, want)
    # partial current block: T % bs tokens, running score sum
    rem = T % bs
    cur = np.asarray(cache.pos)[..., CB * bs:]
    assert ((cur >= 0).sum(-1) == rem).all()
    if rem:
        np.testing.assert_allclose(
            np.asarray(cache.bsum), scores[..., ncb * bs:].sum(-1),
            atol=1e-6)


def test_block_prefill_then_decode_matches_one_shot_prefill():
    """Decode-vs-prefill cache parity: prefill(T1) + n decode steps lands on
    the SAME cache as one-shot prefill(T1+n) — integer selection state
    bit-equal, scores/rows tight-allclose.  This is the state a preempted
    block-choice row recomputes into.  (force off: streaming forcing is
    insertion-only, training-style forcing is permanent — only the
    unforced policies coincide, as in token-choice.)"""
    key = jax.random.PRNGKey(11)
    B, D, H, CB, bs = 2, 32, 2, 3, 4
    T1, n = 12, 8                                   # T1+n = 20, block-aligned
    cfg = MoSAConfig(n_mosa_heads=H, n_dense_heads=0, d_head=8, sparsity=4,
                     force_first_token=False,
                     selection_granularity="block", sel_block_size=bs)
    m = MoSAAttention(D, cfg)
    p = m.init(key)
    x = jax.random.normal(key, (B, T1 + n, D)) * 0.5

    c1 = MoSABlockKVCache.create(B, H, CB, bs, 8, jnp.float32)
    _, c1 = m.prefill(p, x, c1)

    c2 = MoSABlockKVCache.create(B, H, CB, bs, 8, jnp.float32)
    _, c2 = m.prefill(p, x[:, :T1], c2)
    for t in range(n):
        _, c2 = m.decode_step(p, x[:, T1 + t:T1 + t + 1], c2)

    np.testing.assert_array_equal(np.asarray(c1.bidx), np.asarray(c2.bidx))
    np.testing.assert_array_equal(np.asarray(c1.length),
                                  np.asarray(c2.length))
    np.testing.assert_allclose(np.asarray(c1.bscore), np.asarray(c2.bscore),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(c1.bsum), np.asarray(c2.bsum),
                               atol=1e-6)
    ok = (np.asarray(c1.pos) >= 0)
    np.testing.assert_array_equal(np.asarray(c1.pos) * ok,
                                  np.asarray(c2.pos) * ok)
    np.testing.assert_allclose(np.asarray(c1.k) * ok[..., None],
                               np.asarray(c2.k) * ok[..., None],
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("splits", [(8, 13), (12, 9), (10, 5, 6)])
def test_block_prefill_past_chunked_matches_one_shot(splits):
    """Chunked prefill (incl. block-UNALIGNED and three-way splits) lands on
    the one-shot prefill's exact cache — the property the scheduler's
    chunked packed prefill and exact prefix hits stand on."""
    key = jax.random.PRNGKey(12)
    B, D, H, CB, bs = 2, 32, 2, 4, 4
    T = sum(splits)
    cfg = MoSAConfig(n_mosa_heads=H, n_dense_heads=0, d_head=8, sparsity=4,
                     selection_granularity="block", sel_block_size=bs)
    m = MoSAAttention(D, cfg)
    p = m.init(key)
    x = jax.random.normal(key, (B, T, D)) * 0.5

    c1 = MoSABlockKVCache.create(B, H, CB, bs, 8, jnp.float32)
    y1, c1 = m.prefill(p, x, c1)

    c2 = MoSABlockKVCache.create(B, H, CB, bs, 8, jnp.float32)
    off = splits[0]
    _, c2 = m.prefill(p, x[:, :off], c2)
    ylast = None
    for w in splits[1:]:
        ylast, c2 = m.prefill_past(p, x[:, off:off + w], c2)
        off += w

    np.testing.assert_array_equal(np.asarray(c1.bidx), np.asarray(c2.bidx))
    np.testing.assert_allclose(np.asarray(c1.bscore), np.asarray(c2.bscore),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(c1.bsum), np.asarray(c2.bsum),
                               atol=1e-6)
    ok = (np.asarray(c1.pos) >= 0)
    np.testing.assert_array_equal(np.asarray(c1.pos) * ok,
                                  np.asarray(c2.pos) * ok)
    np.testing.assert_allclose(np.asarray(c1.k) * ok[..., None],
                               np.asarray(c2.k) * ok[..., None],
                               atol=1e-5, rtol=1e-5)
    w = splits[-1]
    np.testing.assert_allclose(np.asarray(y1[:, T - w:]), np.asarray(ylast),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------- property layer
@given(T=st.integers(2, 48), bs=st.sampled_from([1, 2, 4, 8]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_block_pool_scores_is_masked_mean(T, bs, seed):
    scores = jax.random.uniform(jax.random.PRNGKey(seed), (2, 3, T))
    pooled = np.asarray(block_pool_scores(scores, bs))
    nb = -(-T // bs)
    s = np.asarray(scores)
    for j in range(nb):
        lo, hi = j * bs, min((j + 1) * bs, T)
        np.testing.assert_allclose(pooled[..., j],
                                   s[..., lo:hi].mean(-1), atol=1e-6)
    if bs == 1:                                   # bitwise identity
        assert (pooled == s).all()


@given(T=st.integers(4, 64), bs=st.sampled_from([1, 2, 4, 8]),
       k_frac=st.floats(0.1, 1.0), seed=st.integers(0, 2**16),
       force=st.booleans())
@settings(**SETTINGS)
def test_block_router_selection_invariants(T, bs, k_frac, seed, force):
    """Random k-schedules: selected block sets are sorted/unique/in-range,
    never exceed capacity, expand to in-block token positions only, and
    honor the forced first block."""
    k = max(1, int(T * k_frac))
    nb = -(-T // bs)
    kb = min(-(-k // bs), nb)
    scores = jax.random.uniform(jax.random.PRNGKey(seed), (2, 3, T))
    bsc = block_pool_scores(scores, bs)
    if kb < 2 and force:
        force = False                     # select_topk force needs k >= 2
    rblk, bidx = select_topk(bsc, kb, force_first=force)
    bi = np.asarray(bidx)
    assert bi.shape[-1] == kb and kb * bs <= (-(-T // bs)) * bs
    assert (np.diff(bi, axis=-1) > 0).all()       # sorted unique
    assert bi.min() >= 0 and bi.max() < nb
    if force:
        assert (bi[..., 0] == 0).all()
    pos = np.asarray(expand_block_index(bidx, bs, T))
    ok = pos >= 0
    assert pos[ok].max() < T
    # every valid expanded position sits inside its selected block
    rep = np.repeat(bi, bs, axis=-1)
    assert (pos[ok] // bs == rep[ok]).all()
    # -1 only for the ragged tail of the LAST block
    assert (rep[~ok] == nb - 1).all() if (~ok).any() else True
    # per-segment: pooling two concatenated docs == pooling each alone
    if T % (2 * bs) == 0:
        half = T // 2
        a = block_pool_scores(scores[..., :half], bs)
        b = block_pool_scores(scores[..., half:], bs)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([a, b], -1)), np.asarray(bsc),
            atol=1e-6)


@given(seed=st.integers(0, 2**16), CB=st.integers(2, 5),
       bs=st.sampled_from([1, 2, 4]))
@settings(**SETTINGS)
def test_streaming_block_topk_matches_exact(seed, CB, bs):
    """Blockwise streaming evict-min == exact top-CB over completed-block
    means — candidates only ever hold COMPLETED blocks (the causality the
    exact prefix cache stands on)."""
    rng = np.random.default_rng(seed)
    T = 8 * bs + rng.integers(0, bs)              # 8 completed + partial
    scores = rng.random(T).astype(np.float32)
    nbc = T // bs
    cs = jnp.full((1, CB), -jnp.inf)
    ci = jnp.full((1, CB), -1, jnp.int32)
    for j in range(nbc):                          # stream completed blocks
        mean = scores[j * bs:(j + 1) * bs].mean()
        _, _, cs, ci = streaming_topk_update(
            cs, ci, jnp.asarray([mean]), j, jnp.asarray(False))
    got = set(i for i in np.asarray(ci[0]).tolist() if i >= 0)
    means = scores[:nbc * bs].reshape(nbc, bs).mean(-1)
    want = set(np.argsort(means)[-min(CB, nbc):].tolist())
    assert got == want
    assert all(i < nbc for i in got)              # completed blocks only


@given(seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_blockpool_random_ops_refcount_invariants(seed):
    """Random alloc/incref/decref/CoW sequences against a pure-python
    mirror: free+live partition holds, refcounts match, a freed block never
    reappears while live, and ensure_owned returns a private copy exactly
    when shared."""
    from repro.serve.paged_kv import BlockPool
    rng = np.random.default_rng(seed)
    N = int(rng.integers(4, 12))
    pool = BlockPool(N, 8)
    ref_cnt = {}                                   # live id -> refcount
    for _ in range(60):
        op = rng.choice(["alloc", "incref", "decref", "cow"])
        if op == "alloc":
            n = int(rng.integers(0, 4))
            free_before = pool.free_blocks
            ids = pool.alloc(n)
            if ids is None:
                assert n > free_before             # all-or-nothing
            else:
                assert len(ids) == n
                for b in ids:
                    assert b not in ref_cnt        # was genuinely free
                    ref_cnt[b] = 1
        elif op == "incref" and ref_cnt:
            b = int(rng.choice(list(ref_cnt)))
            pool.incref([b])
            ref_cnt[b] += 1
        elif op == "decref" and ref_cnt:
            b = int(rng.choice(list(ref_cnt)))
            pool.decref([b])
            ref_cnt[b] -= 1
            if ref_cnt[b] == 0:
                del ref_cnt[b]
        elif op == "cow" and ref_cnt:
            b = int(rng.choice(list(ref_cnt)))
            shared = ref_cnt[b] > 1
            got = pool.ensure_owned(b)
            if got is None:
                assert shared and pool.free_blocks == 0
            else:
                nb_, copied = got
                assert copied == shared
                if shared:
                    assert nb_ != b and nb_ not in ref_cnt
                    ref_cnt[b] -= 1
                    ref_cnt[nb_] = 1
                else:
                    assert nb_ == b
        # invariants after every op
        assert pool.free_blocks + pool.live_blocks == N
        assert pool.live_blocks == len(ref_cnt)
        for b, c in ref_cnt.items():
            assert pool.refcount(b) == c


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_block_selection_state_snapshot_restore_roundtrip(seed):
    """launch.serve.row_snapshot / row_restore carry the FULL block-choice
    selection state (candidate ids, scores, partial-block sum) bitwise —
    the preempt/pause-resume primitive."""
    from repro.launch.serve import row_restore, row_snapshot
    key = jax.random.PRNGKey(seed)
    B, H, CB, bs, d = 3, 2, 3, 4, 8
    ks = jax.random.split(key, 4)
    cache = MoSABlockKVCache(
        jax.random.normal(ks[0], (B, H, (CB + 1) * bs, d)),
        jax.random.normal(ks[1], (B, H, (CB + 1) * bs, d)),
        jax.random.randint(ks[2], (B, H, (CB + 1) * bs), -1, 64),
        jax.random.normal(ks[3], (B, H, CB + 1)),
        jax.random.randint(ks[2], (B, H, CB + 1), -1, 16),
        jax.random.normal(ks[0], (B, H)),
        jnp.arange(B, dtype=jnp.int32) + 5)
    b = int(jax.random.randint(ks[1], (), 0, B))
    snap = jax.device_get(row_snapshot({"sparse": cache}, b))
    # clobber the row, then restore
    zeros = jax.tree.map(jnp.zeros_like, cache)
    restored = row_restore({"sparse": zeros}, snap, b)["sparse"]
    for name in cache._fields:
        a = np.asarray(getattr(cache, name))[b]
        g = np.asarray(getattr(restored, name))[b]
        assert (a == g).all(), name


# --------------------------------------------- paged scheduler exactness
def block_hybrid_cfg(bs=8, window=16):
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa",
                     sparsity=4, selection_granularity="block",
                     sel_block_size=bs)
    return dataclasses.replace(
        cfg, n_layers=3,
        attention=dataclasses.replace(cfg.attention, window=window),
        pattern=(BlockSpec("attn", "dense"), BlockSpec("attn_local", "dense"),
                 BlockSpec("mosa", "dense")))


def test_scheduler_block_choice_prefix_hit_exact():
    """THE paged-exactness acceptance: with block-choice MoSA in the stack,
    a prefix-cache hit emits exactly the no-prefix-cache tokens — the
    snapshot at a block boundary holds only completed-block state, a pure
    function of the prefix (token-choice MoSA can only ever be
    chunk-causal here; cf. test_scheduler_prefix_hit_exact_and_no_recompute
    which must use a dense+window model for exact parity)."""
    from repro.launch.serve import Scheduler, Server
    from repro.serve.paged_kv import PagedConfig
    cfg = block_hybrid_cfg()
    B = 2
    paged = PagedConfig(block_size=8, num_blocks=32, num_window_blocks=2 * B)
    server = Server(cfg, batch=B, max_len=64, paged=paged)
    shared = jax.random.randint(jax.random.PRNGKey(13), (17,), 2, cfg.vocab)
    sufs = [jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(14), i),
                               (3,), 2, cfg.vocab) for i in range(3)]

    on = Scheduler(server, chunk=4, prefix_cache=True)
    assert on.need_snapshot                   # block caches ride snapshots
    for s in sufs:
        on.submit(jnp.concatenate([shared, s]), max_new=5)
    got = on.run()
    assert on.stats["prefix_hits"] >= 2
    assert on.stats["prefix_hit_tokens"] >= 2 * 16

    server2 = Server(cfg, batch=B, max_len=64, paged=paged,
                     params=server.params)
    off = Scheduler(server2, chunk=4, prefix_cache=False)
    for s in sufs:
        off.submit(jnp.concatenate([shared, s]), max_new=5)
    want = off.run()
    for rid in want:
        np.testing.assert_array_equal(np.asarray(got[rid]),
                                      np.asarray(want[rid]),
                                      err_msg=f"request {rid}")


def test_scheduler_block_choice_preempt_restore_completes():
    """Preempt-to-recompute round-trips the block-selection state: a run
    forced through preemption still completes every request at full
    max_new and returns every block to the pools."""
    from repro.launch.serve import Scheduler, Server
    from repro.serve.paged_kv import PagedConfig
    cfg = block_hybrid_cfg()
    B = 2
    server = Server(cfg, batch=B, max_len=64,
                    paged=PagedConfig(block_size=8, num_blocks=5,
                                      num_window_blocks=2 * B))
    sched = Scheduler(server, chunk=4, prefix_cache=False)
    for i in range(2):
        sched.submit(jax.random.randint(jax.random.fold_in(
            jax.random.PRNGKey(15), i), (10,), 2, cfg.vocab), max_new=12)
    out = sched.run()
    assert {k: len(v) for k, v in out.items()} == {0: 12, 1: 12}
    assert sched.stats["preemptions"] >= 1
    assert sched.dense_pool.free_blocks == sched.dense_pool.num_blocks
