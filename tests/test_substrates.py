"""Optimizer, schedules, grad compression, checkpointing, data pipeline,
fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import (ByteTokenizer, PackedLMDataset, Prefetcher,
                                 SyntheticCorpus)
from repro.dist.fault_tolerance import (Heartbeat, StragglerMonitor,
                                        elastic_plan)
from repro.optim import schedules
from repro.optim.grad_compression import (int8_compress, topk_compress)
from repro.optim.optimizer import (adamw, apply_updates, clip_by_global_norm,
                                   global_norm, sgd)


# ------------------------------------------------------------------ optimizer
def test_adamw_converges_on_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    for i in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state, _ = opt.update(g, state, params, step + i)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_bf16_params_fp32_moments():
    opt = adamw(1e-3)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["mu"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    upd, state, _ = opt.update(g, state, params, jnp.zeros((), jnp.int32))
    assert upd["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_schedules():
    lw = schedules.linear_warmup(1.0, 10)
    assert float(lw(5.0)) == 0.5
    assert float(lw(100.0)) == 1.0
    wc = schedules.warmup_cosine(1.0, 10, 110)
    assert float(wc(10.0)) == pytest.approx(1.0)
    assert float(wc(110.0)) == pytest.approx(0.1)


# ------------------------------------------------------- gradient compression
def test_topk_compress_error_feedback_identity():
    g = jnp.asarray([1.0, -0.1, 3.0, 0.01, -2.0])
    kept, res = topk_compress(g, 0.4)
    np.testing.assert_allclose(np.asarray(kept + res), np.asarray(g))
    assert int((kept != 0).sum()) == 2


def test_int8_compress_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    deq, res = int8_compress(g)
    np.testing.assert_allclose(np.asarray(deq + res), np.asarray(g),
                               atol=1e-6)
    assert float(jnp.abs(res).max()) <= float(jnp.abs(g).max()) / 127.0 + 1e-6


def test_compressed_training_still_converges():
    """top-k compression + error feedback reaches the optimum."""
    params = {"w": jnp.asarray([5.0, -3.0, 2.0, -1.0])}
    opt = sgd(0.05)
    state = opt.init(params)
    residual = jax.tree.map(jnp.zeros_like, params)
    for i in range(600):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        g_fb = jax.tree.map(lambda a, b: a + b, g, residual)
        comp = jax.tree.map(lambda x: topk_compress(x, 0.25), g_fb)
        kept = jax.tree.map(lambda t: t[0], comp,
                            is_leaf=lambda x: isinstance(x, tuple))
        residual = jax.tree.map(lambda t: t[1], comp,
                                is_leaf=lambda x: isinstance(x, tuple))
        upd, state, _ = opt.update(kept, state, params,
                                   jnp.asarray(i, jnp.int32))
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


# ---------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "b": jnp.asarray([1, 2, 3], jnp.int32)}
    ckpt.save(str(tmp_path), 7, tree, extra_meta={"step": 7})
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          tree)
    restored, extra = ckpt.restore(str(tmp_path), target)
    assert extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]["w"]),
                                  np.asarray(tree["a"]["w"]))
    assert restored["b"].dtype == jnp.int32


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree, keep_last=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": jnp.arange(4.0)}
    path = ckpt.save(str(tmp_path), 1, tree)
    # corrupt the array file
    npz = os.path.join(path, "arrays.npz")
    data = open(npz, "rb").read()
    open(npz, "wb").write(data[:-8] + b"deadbeef")
    target = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), target)


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path), keep_last=1)
    c.save(1, {"w": jnp.ones((8,))})
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 1


# ------------------------------------------------------------------ data
def test_data_determinism_and_resume():
    ds = PackedLMDataset(SyntheticCorpus(vocab=1000, seed=3), seq_len=64,
                         global_batch=4)
    b1 = ds.batch_at(10)
    b2 = ds.batch_at(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are inputs shifted by one
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].shape == (4, 64)
    assert b1["tokens"].max() < 1000


def test_data_sharding_partitions_global_batch():
    full = PackedLMDataset(SyntheticCorpus(seed=0), 32, 8).batch_at(0)
    assert full["tokens"].shape == (8, 32)
    s0 = PackedLMDataset(SyntheticCorpus(seed=0), 32, 8, shard_index=0,
                         shard_count=2).batch_at(0)
    assert s0["tokens"].shape == (4, 32)


def test_prefetcher_orders_steps():
    ds = PackedLMDataset(SyntheticCorpus(seed=1), 16, 2)
    pf = Prefetcher(ds, start_step=5)
    try:
        s, b = pf.next()
        assert s == 5
        s2, b2 = pf.next()
        assert s2 == 6
        np.testing.assert_array_equal(b2["tokens"], ds.batch_at(6)["tokens"])
    finally:
        pf.close()


def test_tokenizer_roundtrip():
    tok = ByteTokenizer(vocab=400)
    ids = tok.encode("hello world hello")
    assert tok.decode(ids) == "hello world hello"


# ----------------------------------------------------------- fault tolerance
def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(z_threshold=3.0, warmup_steps=3)
    for i in range(20):
        assert not mon.record(i, 0.1 + 0.001 * (i % 3))
    assert mon.record(20, 1.5)      # 15x slower step
    assert mon.summary()["straggler_events"] == 1


def test_elastic_plan_shapes():
    assert elastic_plan(512, tp=16, want_pods=True)["shape"] == (2, 16, 16)
    assert elastic_plan(256, tp=16)["shape"] == (16, 16)
    # lose one host (8 chips) within a pod: shrink data axis
    p = elastic_plan(248, tp=16)
    assert p["shape"][1] == 16 and p["devices_idle"] < 16
    # tiny: CPU test hosts
    assert elastic_plan(1, tp=16)["shape"] == (1, 1)


def test_heartbeat_stale_detection(tmp_path):
    hb = Heartbeat(str(tmp_path), rank=0)
    hb.beat(5)
    assert Heartbeat.stale_ranks(str(tmp_path), timeout_s=60) == []
    assert Heartbeat.stale_ranks(str(tmp_path), timeout_s=0) == [0]
