"""Fused MoSA kernel VJP vs autodiff of the reference — the parity oracle
for the differentiable training path (DESIGN §8).

Every gradient the paper's training needs is checked: dq/dk/dv AND dr (the
router-score cotangent that makes expert-choice selection learnable), at the
kernel boundary, at the layer boundary (router weights included), and at the
full-LM loss boundary, in f32 and bf16 (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mosa_inputs(key, B, H, S, d, T, dtype):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, S, d), dtype)
    k = jax.random.normal(ks[1], (B, H, S, d), dtype)
    v = jax.random.normal(ks[2], (B, H, S, d), dtype)
    perm = jnp.stack([
        jnp.stack([jax.random.permutation(jax.random.fold_in(ks[3], b * H + h),
                                          T)[:S]
                   for h in range(H)]) for b in range(B)])
    idx = jnp.sort(perm, axis=-1).astype(jnp.int32)
    r = jax.nn.sigmoid(jax.random.normal(ks[4], (B, H, S))).astype(jnp.float32)
    return q, k, v, idx, r


GRAD_CASES = [
    # (B, H, S, d, T)
    (1, 1, 8, 16, 32),
    (2, 3, 24, 20, 100),       # non-aligned S and d
    (1, 2, 128, 64, 1024),     # paper-typical: k=128, d_head=64
    (2, 4, 33, 48, 256),
]


@pytest.mark.parametrize("B,H,S,d,T", GRAD_CASES)
def test_fused_grads_match_reference_f32(B, H, S, d, T):
    q, k, v, idx, r = _mosa_inputs(jax.random.PRNGKey(0), B, H, S, d, T,
                                   jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(9), q.shape, jnp.float32)

    def loss(fn):
        return lambda q, k, v, r: jnp.sum(
            fn(q, k, v, idx, r).astype(jnp.float32) * g)

    got = jax.grad(loss(ops.mosa_attention), argnums=(0, 1, 2, 3))(q, k, v, r)
    want = jax.grad(loss(ref.mosa_attention_ref),
                    argnums=(0, 1, 2, 3))(q, k, v, r)
    for name, a, b in zip("qkvr", got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   rtol=3e-5, err_msg=f"d{name}")


@pytest.mark.parametrize("B,H,S,d,T", [(1, 2, 32, 16, 128),
                                       (1, 2, 64, 64, 512)])
def test_fused_grads_match_reference_bf16(B, H, S, d, T):
    """bf16 kernel grads vs autodiff of the f32 reference on the SAME
    (bf16-quantized) inputs: bounds the accumulated low-precision error of
    the backward kernels, mirroring the forward bf16 sweep."""
    q, k, v, idx, r = _mosa_inputs(jax.random.PRNGKey(7), B, H, S, d, T,
                                   jnp.bfloat16)
    g = jax.random.normal(jax.random.PRNGKey(9), q.shape, jnp.float32)

    got = jax.grad(
        lambda q, k, v, r: jnp.sum(
            ops.mosa_attention(q, k, v, idx, r).astype(jnp.float32) * g),
        argnums=(0, 1, 2, 3))(q, k, v, r)
    want = jax.grad(
        lambda q, k, v, r: jnp.sum(
            ref.mosa_attention_ref(q, k, v, idx, r).astype(jnp.float32) * g),
        argnums=(0, 1, 2, 3))(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), r)
    for name, a, b in zip("qkvr", got, want):
        err = np.abs(np.asarray(a, np.float32) -
                     np.asarray(b, np.float32)).max()
        scale = max(np.abs(np.asarray(b, np.float32)).max(), 1.0)
        assert err < 7e-2 * scale, f"d{name}: max err {err} (scale {scale})"


def test_fused_grads_dense_equivalent_full_selection():
    """k = T (every token selected, r = 1): gradients must reduce to dense
    causal attention's — checked against autodiff of the DENSE flash
    reference, so a selection-mask bug in the backward kernels cannot hide
    in a shared oracle."""
    B, H, T, d = 2, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, H, T, d))
    v = jax.random.normal(ks[2], (B, H, T, d))
    g = jax.random.normal(ks[3], (B, H, T, d))
    idx = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, H, T))
    r = jnp.ones((B, H, T), jnp.float32)

    got = jax.grad(
        lambda q, k, v: jnp.sum(ops.mosa_attention(q, k, v, idx, r) * g),
        argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(
        lambda q, k, v: jnp.sum(ref.flash_attention_ref(q, k, v) * g),
        argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   rtol=3e-5, err_msg=f"d{name}")


def test_layer_grads_pallas_equals_einsum():
    """Full MoSAAttention layer under jax.grad: the fused path's parameter
    gradients — INCLUDING the router weights, whose only gradient path is
    the dr cotangent flowing through take_along_axis into the sigmoid
    scores — match the einsum reference path."""
    from repro.configs.base import MoSAConfig
    from repro.core.mosa import MoSAAttention
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 64, 32))
    cfg = MoSAConfig(n_mosa_heads=6, sparsity=8, n_dense_heads=0, d_head=16)
    m_ref = MoSAAttention(32, cfg, impl="einsum")
    m_fused = MoSAAttention(32, cfg, impl="pallas")
    p = m_ref.init(key)

    def loss(m):
        return lambda p: jnp.sum(jnp.square(m(p, x)))

    g_ref = jax.grad(loss(m_ref))(p)
    g_fused = jax.grad(loss(m_fused))(p)
    flat_r = jax.tree_util.tree_flatten_with_path(g_ref)[0]
    flat_f = jax.tree_util.tree_flatten_with_path(g_fused)[0]
    assert [k for k, _ in flat_r] == [k for k, _ in flat_f]
    for (path, a), (_, b) in zip(flat_r, flat_f):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), atol=1e-4, rtol=1e-4,
            err_msg=jax.tree_util.keystr(path))
    # the router gradient is genuinely nonzero (the learnable-selection path)
    assert np.abs(np.asarray(g_fused["router"]["w"])).max() > 0


def test_lm_loss_grads_pallas_equals_einsum():
    """End-to-end: jax.grad of TransformerLM.loss with the fused kernels
    equals the einsum path on the paper's smoke hybrid (dense heads, FFN,
    embedding — everything around the kernel included)."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.nn.transformer import TransformerLM

    cfg = get_config("mosa-paper", preset="smoke", variant="mosa")
    cfg_f = dataclasses.replace(
        cfg, mosa=dataclasses.replace(cfg.mosa, impl="pallas"))
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (2, 32), 2, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    m_ref, m_fused = TransformerLM(cfg), TransformerLM(cfg_f)
    params = m_ref.init(key)
    (l_ref, _), g_ref = jax.value_and_grad(m_ref.loss, has_aux=True)(
        params, batch)
    (l_fused, _), g_fused = jax.value_and_grad(m_fused.loss, has_aux=True)(
        params, batch)
    np.testing.assert_allclose(float(l_fused), float(l_ref), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_fused)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-4, rtol=2e-4)


def test_fused_vjp_zero_router_score_rows():
    """r == 0 rows (the masked-prefill overflow case): output is zero, dq/dk
    receive zero from those rows, and dr stays FINITE (the o_pre residual
    design avoids the out/r division that would NaN here)."""
    B, H, S, d, T = 1, 2, 16, 16, 64
    q, k, v, idx, r = _mosa_inputs(jax.random.PRNGKey(5), B, H, S, d, T,
                                   jnp.float32)
    r = r.at[:, :, -4:].set(0.0)
    g = jnp.ones((B, H, S, d), jnp.float32)
    grads = jax.grad(
        lambda q, k, v, r: jnp.sum(ops.mosa_attention(q, k, v, idx, r) * g),
        argnums=(0, 1, 2, 3))(q, k, v, r)
    for a in grads:
        assert np.isfinite(np.asarray(a)).all()
    # zero-score rows contribute no dq
    np.testing.assert_allclose(np.asarray(grads[0][:, :, -4:]), 0.0,
                               atol=1e-7)
